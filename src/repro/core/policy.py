"""Voltage-scaling policies (paper Sec. III-F baseline, Sec. IV fault-tolerant).

A policy maps a :class:`~repro.core.scenario.Scenario` (batch) to per-operator
``delay_max`` thresholds.  The protocol is one traced method::

    thresholds(scenario, operators) -> jnp.ndarray [batch_shape + (O,)]

so a whole sweep — accuracy budgets x mission profiles x operator domains —
evaluates as ONE vmapped lifetime scan via :func:`sweep_policy`.

* :class:`BaselinePolicy` — classical AVS: raise V_DD on *every* detected
  timing violation, i.e. ``delay_max = t_clk`` for every operator domain.
* :class:`FaultTolerantPolicy` — per-operator ``delay_max`` obtained by
  inverting the BER model at each operator's tolerable BER at the scenario's
  accuracy budget (``scenario.max_loss_pct``).  Voltage increases are
  deferred while the induced BER stays within the operator's resilience.
* :class:`MeasuredResiliencePolicy` (``"measured"``) — the same deferral
  machinery, but the curves are the logistic fits MEASURED on a zoo model
  by the batched fault-injection sweep (``resilience_calibrated.json``),
  closing the loop inject -> fit -> tolerable BER -> delay_max -> simulate.

New policies register by name via :func:`register_policy` and are resolved
with :func:`get_policy` (used by ``FleetRuntime`` and the launchers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from .aging import AgingParams
from .avs import LifetimeConfig, simulate
from .ber import BerModel
from .constants import DEFAULT_MAX_LOSS_PCT, T_CLK
from .delay import DelayPolynomial
from .power import PowerModel, batched_lifetime_stats
from .resilience import (OPERATORS, ResilienceCurve, default_curves,
                         measured_curves, tolerable_bers)
from .scenario import LifetimeTrajectory, Scenario


@runtime_checkable
class Policy(Protocol):
    """Anything that maps scenarios to per-operator delay thresholds."""

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        """Per-operator delay_max [s], shape ``batch_shape + (O,)``."""
        ...


POLICY_REGISTRY: Dict[str, type] = {}


def register_policy(cls):
    """Class decorator: register a policy under its ``name`` attribute."""
    POLICY_REGISTRY[cls.name] = cls
    return cls


def get_policy(name: str, **kw):
    """Instantiate a registered policy by name."""
    try:
        return POLICY_REGISTRY[name](**kw)
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{sorted(POLICY_REGISTRY)}") from None


@register_policy
@dataclasses.dataclass(frozen=True)
class BaselinePolicy:
    """Classical AVS: the threshold IS the scenario's clock period.  The
    ``t_clk`` field only serves the scenario-free legacy :meth:`delay_max`."""
    name = "baseline"
    t_clk: float = T_CLK

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        t = jnp.broadcast_to(jnp.asarray(scenario.t_clk, jnp.float32),
                             scenario.batch_shape)
        return jnp.broadcast_to(t[..., None],
                                scenario.batch_shape + (len(operators),))

    # legacy scalar API ------------------------------------------------- #
    def delay_max(self) -> Dict[str, float]:
        return {op: self.t_clk for op in OPERATORS}


@register_policy
@dataclasses.dataclass(frozen=True)
class FaultTolerantPolicy:
    """``max_loss_pct=None`` (default) defers the accuracy budget to
    ``scenario.max_loss_pct`` — budgets then batch like any scenario knob.
    An explicit float pins the budget and overrides the scenario's, keeping
    the traced path consistent with the legacy :meth:`delay_max`."""
    name = "fault_tolerant"
    ber_model: BerModel
    max_loss_pct: float | None = None
    curves: Mapping[str, ResilienceCurve] | None = None

    def _budget_scalar(self) -> float:
        return DEFAULT_MAX_LOSS_PCT if self.max_loss_pct is None \
            else self.max_loss_pct

    def _curves_for(self, operators) -> Mapping[str, ResilienceCurve]:
        """Curve source hook — subclasses swap where curves come from."""
        return self.curves or default_curves(tuple(operators))

    def _curve_params(self, operators):
        curves = self._curves_for(tuple(operators))
        ber50 = np.array([curves[op].ber50 for op in operators], np.float64)
        steep = np.array([curves[op].steepness for op in operators],
                         np.float64)
        lmax = np.array([curves[op].l_max for op in operators], np.float64)
        return (jnp.asarray(np.log10(ber50), jnp.float32),
                jnp.asarray(steep, jnp.float32),
                jnp.asarray(lmax, jnp.float32))

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        """Invert resilience curves at ``scenario.max_loss_pct``, then invert
        the BER curve — all in jnp so budgets batch/vmap like any knob."""
        log_b50, steep, lmax = self._curve_params(operators)
        budget_src = scenario.max_loss_pct if self.max_loss_pct is None \
            else self.max_loss_pct
        budget = jnp.broadcast_to(
            jnp.asarray(budget_src, jnp.float32),
            scenario.batch_shape)[..., None]
        frac = jnp.clip(budget / lmax, 1e-9, 1.0 - 1e-9)
        x = jnp.log(frac / (1.0 - frac))
        tol = 10.0 ** (log_b50 + x / steep)
        d = self.ber_model.delay_for_ber(tol)
        # the BER curve is calibrated at the nominal clock; when the scenario
        # sweeps t_clk past it, a threshold below the clock period would be
        # meaningless (violations only exist past the clock edge) — clamp.
        t_clk = jnp.broadcast_to(jnp.asarray(scenario.t_clk, jnp.float32),
                                 scenario.batch_shape)[..., None]
        return jnp.maximum(d, t_clk).astype(jnp.float32)

    # legacy scalar API ------------------------------------------------- #
    def tolerable_ber(self) -> Dict[str, float]:
        return tolerable_bers(dict(self._curves_for(OPERATORS)),
                              self._budget_scalar())

    def delay_max(self) -> Dict[str, float]:
        tols = self.tolerable_ber()
        return {op: self.ber_model.delay_max_for_ber(tol)
                for op, tol in tols.items()}


@register_policy
@dataclasses.dataclass(frozen=True)
class MeasuredResiliencePolicy(FaultTolerantPolicy):
    """Fault-tolerant AVS driven by resilience curves MEASURED in-repo.

    Identical thresholds machinery to :class:`FaultTolerantPolicy`; the
    only change is where the curves come from: the per-``model`` logistic
    fits of the batched fault-injection sweep
    (:func:`repro.calibrate.resilience_sweep.empirical_resilience`),
    loaded from the checked-in ``resilience_calibrated.json`` artifact.
    Operator domains the sweep did not characterise (or an artifact from a
    partial run) fall back to the published defaults, so the policy is
    always total over the requested operator set.  An explicit ``curves``
    mapping overrides the artifact entirely — that is also how the parity
    tests pin "measured == published" and recover Table II exactly.
    """
    name = "measured"
    model: str = "llama3_8b"
    artifact_path: str | None = None

    def _curves_for(self, operators) -> Mapping[str, ResilienceCurve]:
        if self.curves is not None:
            return FaultTolerantPolicy._curves_for(self, operators)
        measured = measured_curves(self.model, self.artifact_path)
        defaults = default_curves(tuple(operators))
        return {op: measured.get(op, defaults[op]) for op in operators}


# --------------------------------------------------------------------------- #
def sweep_policy(policy: Policy, params: AgingParams, poly: DelayPolynomial,
                 scenarios: Scenario, *, operators: tuple = OPERATORS,
                 recovery: bool = True) -> LifetimeTrajectory:
    """Run a policy over a scenario batch — ONE vmapped lifetime scan.

    Returns a trajectory with batch shape ``scenarios.batch_shape + (O,)``:
    the scenario leaves gain a trailing broadcast operator axis, the policy
    supplies the matching threshold array, and :func:`simulate` flattens the
    joint batch into a single trace/compile.
    """
    dmax = policy.thresholds(scenarios, operators)
    return simulate(params, poly, scenarios.expand_dims(-1), delay_max=dmax,
                    recovery=recovery)


def evaluate_policy(policy, params: AgingParams, poly: DelayPolynomial,
                    power: PowerModel,
                    cfg: LifetimeConfig | Scenario = LifetimeConfig()
                    ) -> Dict[str, Dict]:
    """Run the lifetime simulation for every operator domain of a policy.

    Returns ``{operator: {v_final, dvp, dvn, v_eff, p_avg, traj}}`` plus the
    ``baseline`` row (classical AVS) for the power-saving comparison.  The
    operator rows *and* the baseline run in one vmapped scan.
    """
    if isinstance(cfg, Scenario):
        scn = cfg
    else:
        budget = getattr(policy, "max_loss_pct", None)
        scn = cfg.scenario() if budget is None else cfg.scenario(budget)
    assert scn.batch_shape == (), \
        "evaluate_policy takes one scenario; use sweep_policy for batches"
    ops = list(OPERATORS)
    dmax = policy.thresholds(scn, tuple(ops))               # (O,)
    # append the baseline (delay_max = t_clk) as a 10th pseudo-operator so
    # the whole table is one vmapped call
    dmax_all = jnp.concatenate(
        [dmax, jnp.reshape(jnp.asarray(scn.t_clk, jnp.float32), (1,))])
    trajs = simulate(params, poly, scn, delay_max=dmax_all)
    stats = batched_lifetime_stats(power, trajs)

    base_traj = trajs[len(ops)]
    base_stats = {k: float(v[len(ops)]) for k, v in stats.items()}
    out: Dict[str, Dict] = {"baseline": dict(base_stats,
                                             traj=base_traj.to_dict())}
    for i, op in enumerate(ops):
        st = {k: float(v[i]) for k, v in stats.items()}
        st["power_saving_pct"] = 100.0 * (1.0 - st["p_avg"]
                                          / base_stats["p_avg"])
        st["delay_max"] = float(dmax[i])
        out[op] = dict(st, traj=trajs[i].to_dict())
    savings = [out[op]["power_saving_pct"] for op in ops]
    out["avg_power_saving_pct"] = float(np.mean(savings))
    return out
