"""Voltage-scaling policies (paper Sec. III-F baseline, Sec. IV fault-tolerant).

* :class:`BaselinePolicy` — classical AVS: raise V_DD on *every* detected
  timing violation, i.e. ``delay_max = t_clk`` for every operator domain.
* :class:`FaultTolerantPolicy` — per-operator ``delay_max`` obtained by
  inverting the BER model at each operator's tolerable BER (user-specified
  accuracy budget, default 0.5%).  Voltage increases are deferred while the
  induced BER stays within the operator's resilience.

Both produce a vector of delay thresholds over the operator domains so the
whole policy evaluates as ONE vmapped lifetime scan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping

import numpy as np

from .avs import LifetimeConfig, run_lifetime
from .ber import BerModel
from .constants import T_CLK
from .delay import DelayPolynomial
from .aging import AgingParams
from .power import PowerModel, lifetime_stats
from .resilience import OPERATORS, ResilienceCurve, default_curves, tolerable_bers


@dataclasses.dataclass(frozen=True)
class BaselinePolicy:
    t_clk: float = T_CLK

    def delay_max(self) -> Dict[str, float]:
        return {op: self.t_clk for op in OPERATORS}


@dataclasses.dataclass(frozen=True)
class FaultTolerantPolicy:
    ber_model: BerModel
    max_loss_pct: float = 0.5
    curves: Mapping[str, ResilienceCurve] | None = None

    def tolerable_ber(self) -> Dict[str, float]:
        return tolerable_bers(self.curves or default_curves(),
                              self.max_loss_pct)

    def delay_max(self) -> Dict[str, float]:
        tols = self.tolerable_ber()
        return {op: self.ber_model.delay_max_for_ber(tol)
                for op, tol in tols.items()}


def evaluate_policy(policy, params: AgingParams, poly: DelayPolynomial,
                    power: PowerModel,
                    cfg: LifetimeConfig = LifetimeConfig()) -> Dict[str, Dict]:
    """Run the lifetime simulation for every operator domain of a policy.

    Returns ``{operator: {v_final, dvp, dvn, v_eff, p_avg, traj}}`` plus the
    ``baseline`` row (classical AVS) for the power-saving comparison.
    """
    dmax = policy.delay_max()
    ops = list(dmax.keys())
    vec = np.asarray([dmax[op] for op in ops], np.float32)
    trajs = run_lifetime(params, poly, cfg, delay_max=vec)

    base = run_lifetime(params, poly, cfg, delay_max=cfg.t_clk)
    base_stats = lifetime_stats(power, base)

    out: Dict[str, Dict] = {"baseline": dict(base_stats, traj=base)}
    for i, op in enumerate(ops):
        traj_i = {k: np.asarray(v)[i] for k, v in trajs.items()}
        st = lifetime_stats(power, traj_i)
        st["power_saving_pct"] = 100.0 * (1.0 - st["p_avg"] / base_stats["p_avg"])
        st["delay_max"] = float(dmax[op])
        out[op] = dict(st, traj=traj_i)
    savings = [out[op]["power_saving_pct"] for op in ops]
    out["avg_power_saving_pct"] = float(np.mean(savings))
    return out
