"""Voltage-scaling policies (paper Sec. III-F baseline, Sec. IV fault-tolerant).

A policy maps a :class:`~repro.core.scenario.Scenario` (batch) to per-operator
``delay_max`` thresholds.  The protocol is one traced method::

    thresholds(scenario, operators) -> jnp.ndarray [batch_shape + (O,)]

so a whole sweep — accuracy budgets x mission profiles x operator domains —
evaluates as ONE vmapped lifetime scan via :func:`sweep_policy`.

* :class:`BaselinePolicy` — classical AVS: raise V_DD on *every* detected
  timing violation, i.e. ``delay_max = t_clk`` for every operator domain.
* :class:`FaultTolerantPolicy` — per-operator ``delay_max`` obtained by
  inverting the BER model at each operator's tolerable BER at the scenario's
  accuracy budget (``scenario.max_loss_pct``).  Voltage increases are
  deferred while the induced BER stays within the operator's resilience.

New policies register by name via :func:`register_policy` and are resolved
with :func:`get_policy` (used by ``FleetRuntime`` and the launchers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from .aging import AgingParams
from .avs import LifetimeConfig, simulate
from .ber import BerModel
from .constants import DEFAULT_MAX_LOSS_PCT, T_CLK
from .delay import DelayPolynomial
from .power import PowerModel, batched_lifetime_stats
from .resilience import OPERATORS, ResilienceCurve, default_curves, tolerable_bers
from .scenario import LifetimeTrajectory, Scenario


@runtime_checkable
class Policy(Protocol):
    """Anything that maps scenarios to per-operator delay thresholds."""

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        """Per-operator delay_max [s], shape ``batch_shape + (O,)``."""
        ...


POLICY_REGISTRY: Dict[str, type] = {}


def register_policy(cls):
    """Class decorator: register a policy under its ``name`` attribute."""
    POLICY_REGISTRY[cls.name] = cls
    return cls


def get_policy(name: str, **kw):
    """Instantiate a registered policy by name."""
    try:
        return POLICY_REGISTRY[name](**kw)
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{sorted(POLICY_REGISTRY)}") from None


@register_policy
@dataclasses.dataclass(frozen=True)
class BaselinePolicy:
    """Classical AVS: the threshold IS the scenario's clock period.  The
    ``t_clk`` field only serves the scenario-free legacy :meth:`delay_max`."""
    name = "baseline"
    t_clk: float = T_CLK

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        t = jnp.broadcast_to(jnp.asarray(scenario.t_clk, jnp.float32),
                             scenario.batch_shape)
        return jnp.broadcast_to(t[..., None],
                                scenario.batch_shape + (len(operators),))

    # legacy scalar API ------------------------------------------------- #
    def delay_max(self) -> Dict[str, float]:
        return {op: self.t_clk for op in OPERATORS}


@register_policy
@dataclasses.dataclass(frozen=True)
class FaultTolerantPolicy:
    """``max_loss_pct=None`` (default) defers the accuracy budget to
    ``scenario.max_loss_pct`` — budgets then batch like any scenario knob.
    An explicit float pins the budget and overrides the scenario's, keeping
    the traced path consistent with the legacy :meth:`delay_max`."""
    name = "fault_tolerant"
    ber_model: BerModel
    max_loss_pct: float | None = None
    curves: Mapping[str, ResilienceCurve] | None = None

    def _budget_scalar(self) -> float:
        return DEFAULT_MAX_LOSS_PCT if self.max_loss_pct is None \
            else self.max_loss_pct

    def _curve_params(self, operators):
        curves = self.curves or default_curves(tuple(operators))
        ber50 = np.array([curves[op].ber50 for op in operators], np.float64)
        steep = np.array([curves[op].steepness for op in operators],
                         np.float64)
        lmax = np.array([curves[op].l_max for op in operators], np.float64)
        return (jnp.asarray(np.log10(ber50), jnp.float32),
                jnp.asarray(steep, jnp.float32),
                jnp.asarray(lmax, jnp.float32))

    def thresholds(self, scenario: Scenario,
                   operators: tuple = OPERATORS) -> jnp.ndarray:
        """Invert resilience curves at ``scenario.max_loss_pct``, then invert
        the BER curve — all in jnp so budgets batch/vmap like any knob."""
        log_b50, steep, lmax = self._curve_params(operators)
        budget_src = scenario.max_loss_pct if self.max_loss_pct is None \
            else self.max_loss_pct
        budget = jnp.broadcast_to(
            jnp.asarray(budget_src, jnp.float32),
            scenario.batch_shape)[..., None]
        frac = jnp.clip(budget / lmax, 1e-9, 1.0 - 1e-9)
        x = jnp.log(frac / (1.0 - frac))
        tol = 10.0 ** (log_b50 + x / steep)
        d = self.ber_model.delay_for_ber(tol)
        # the BER curve is calibrated at the nominal clock; when the scenario
        # sweeps t_clk past it, a threshold below the clock period would be
        # meaningless (violations only exist past the clock edge) — clamp.
        t_clk = jnp.broadcast_to(jnp.asarray(scenario.t_clk, jnp.float32),
                                 scenario.batch_shape)[..., None]
        return jnp.maximum(d, t_clk).astype(jnp.float32)

    # legacy scalar API ------------------------------------------------- #
    def tolerable_ber(self) -> Dict[str, float]:
        return tolerable_bers(self.curves or default_curves(),
                              self._budget_scalar())

    def delay_max(self) -> Dict[str, float]:
        tols = self.tolerable_ber()
        return {op: self.ber_model.delay_max_for_ber(tol)
                for op, tol in tols.items()}


# --------------------------------------------------------------------------- #
def sweep_policy(policy: Policy, params: AgingParams, poly: DelayPolynomial,
                 scenarios: Scenario, *, operators: tuple = OPERATORS,
                 recovery: bool = True) -> LifetimeTrajectory:
    """Run a policy over a scenario batch — ONE vmapped lifetime scan.

    Returns a trajectory with batch shape ``scenarios.batch_shape + (O,)``:
    the scenario leaves gain a trailing broadcast operator axis, the policy
    supplies the matching threshold array, and :func:`simulate` flattens the
    joint batch into a single trace/compile.
    """
    dmax = policy.thresholds(scenarios, operators)
    return simulate(params, poly, scenarios.expand_dims(-1), delay_max=dmax,
                    recovery=recovery)


def evaluate_policy(policy, params: AgingParams, poly: DelayPolynomial,
                    power: PowerModel,
                    cfg: LifetimeConfig | Scenario = LifetimeConfig()
                    ) -> Dict[str, Dict]:
    """Run the lifetime simulation for every operator domain of a policy.

    Returns ``{operator: {v_final, dvp, dvn, v_eff, p_avg, traj}}`` plus the
    ``baseline`` row (classical AVS) for the power-saving comparison.  The
    operator rows *and* the baseline run in one vmapped scan.
    """
    if isinstance(cfg, Scenario):
        scn = cfg
    else:
        budget = getattr(policy, "max_loss_pct", None)
        scn = cfg.scenario() if budget is None else cfg.scenario(budget)
    assert scn.batch_shape == (), \
        "evaluate_policy takes one scenario; use sweep_policy for batches"
    ops = list(OPERATORS)
    dmax = policy.thresholds(scn, tuple(ops))               # (O,)
    # append the baseline (delay_max = t_clk) as a 10th pseudo-operator so
    # the whole table is one vmapped call
    dmax_all = jnp.concatenate(
        [dmax, jnp.reshape(jnp.asarray(scn.t_clk, jnp.float32), (1,))])
    trajs = simulate(params, poly, scn, delay_max=dmax_all)
    stats = batched_lifetime_stats(power, trajs)

    base_traj = trajs[len(ops)]
    base_stats = {k: float(v[len(ops)]) for k, v in stats.items()}
    out: Dict[str, Dict] = {"baseline": dict(base_stats,
                                             traj=base_traj.to_dict())}
    for i, op in enumerate(ops):
        st = {k: float(v[i]) for k, v in stats.items()}
        st["power_saving_pct"] = 100.0 * (1.0 - st["p_avg"]
                                          / base_stats["p_avg"])
        st["delay_max"] = float(dmax[i])
        out[op] = dict(st, traj=trajs[i].to_dict())
    savings = [out[op]["power_saving_pct"] for op in ops]
    out["avg_power_saving_pct"] = float(np.mean(savings))
    return out
