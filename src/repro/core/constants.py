"""Physical, workload and target-hardware constants.

Workload constants (duty factor / toggle rate / clock) follow Sec. III-E of the
paper: duty factor of critical-path cells is 0.4-0.6 and toggle rate 0.006-0.009
under a real NN inference trace; the paper uses the averages, so we adopt the
midpoints as defaults (overridable in :class:`repro.core.avs.LifetimeConfig`).
"""

# --- physical constants -----------------------------------------------------
KB_EV = 8.617333262e-5      # Boltzmann constant [eV/K]

# --- paper's accelerator operating point (Sec. V-A) -------------------------
V_NOM = 0.90                # nominal supply voltage [V]
V_MAX = 1.02                # end-of-life supply voltage reached by AVS [V]
V_STEP = 0.010              # AVS voltage increment [V]
T_CLK = 1.6e-9              # clock period [s]
D_CRIT_NOM = 1.542e-9       # nominal critical-path delay at (V_NOM, fresh) [s]
T_AMB = 298.15              # 25 degC [K]
LIFETIME_S = 10 * 365.25 * 24 * 3600.0   # 10-year product lifetime [s]

# --- workload activity (Sec. III-E, Fig. 4e) --------------------------------
DUTY_FACTOR = 0.5           # midpoint of the measured 0.4-0.6 range
TOGGLE_RATE = 0.0075        # midpoint of the measured 0.006-0.009 range
TRANSITION_TIME = 0.10e-9   # output transition (10%-90%) [s], HSPICE-typical

# --- policy defaults (Sec. IV-B) --------------------------------------------
DEFAULT_MAX_LOSS_PCT = 0.5  # default tolerable accuracy loss [% points]

# --- systolic array (Sec. V-A) ----------------------------------------------
ARRAY_DIM = 256             # 256x256 PEs
PE_IN_BITS = 8              # 8-bit multiplier inputs
PE_ACC_BITS = 32            # 32-bit accumulator

# --- target TPU (v5e-class) roofline constants ------------------------------
PEAK_FLOPS_BF16 = 197e12    # per chip [FLOP/s]
HBM_BW = 819e9              # per chip [B/s]
ICI_BW = 50e9               # per link [B/s]
