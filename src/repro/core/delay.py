"""Critical-path delay modelling (paper Sec. III-A..D).

The paper's flow is: synthesize a 256x256 int8 systolic array (14 nm PDK,
0.9 V, 1.6 ns clock), extract the 100 worst timing paths with PrimeTime,
characterise ``delay(dVth_p, dVth_n, V_DD)`` in HSPICE, and fit a ternary
sixth-degree polynomial (their RMSE: 5.85e-5 ns against a ~1.5 ns nominal).

No EDA tooling exists in this environment, so the *ground truth generator* is
replaced by an analytical alpha-power-law path model (DESIGN.md Sec. 2) —

    d_i(V, dp, dn) = w_i * [ d_wire
                             + d_p * V / (V - Vth_p0 - dp)**alpha
                             + d_n * V / (V - Vth_n0 - dn)**alpha ]

with per-path scale factors ``w_i`` drawn from a seeded population whose
worst path hits exactly ``D_CRIT_NOM`` at the fresh nominal point.  The
paper's own *polynomial-fitting step is preserved verbatim*: the AVS
framework only ever consumes the fitted polynomial, so a real HSPICE sweep
can be substituted without touching anything downstream.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .constants import D_CRIT_NOM, V_NOM

# Fitting ranges: dVth in [0, 150] mV, V_DD in [0.88, 1.06] V.
DP_RANGE = (0.0, 0.150)
DN_RANGE = (0.0, 0.150)
V_RANGE = (0.88, 1.06)
TOTAL_DEGREE = 6


@dataclasses.dataclass(frozen=True)
class PathModel:
    """Alpha-power-law ground-truth model of the worst-path population."""
    alpha: float = 1.30
    vth_p0: float = 0.38
    vth_n0: float = 0.36
    wire_frac: float = 0.30   # fraction of nominal delay that is RC / non-FET
    pn_split: float = 0.50    # PMOS share of the FET-limited delay
    n_paths: int = 100
    spread: float = 0.035     # relative spread of the worst-path population
    seed: int = 20260715

    def stage_delay(self, V, dp, dn):
        """Normalised (w_i = 1) path delay in seconds."""
        V = jnp.asarray(V)
        f_p = V / jnp.maximum(V - self.vth_p0 - dp, 1e-3) ** self.alpha
        f_n = V / jnp.maximum(V - self.vth_n0 - dn, 1e-3) ** self.alpha
        f_p0 = V_NOM / (V_NOM - self.vth_p0) ** self.alpha
        f_n0 = V_NOM / (V_NOM - self.vth_n0) ** self.alpha
        fet = self.pn_split * f_p / f_p0 + (1.0 - self.pn_split) * f_n / f_n0
        return D_CRIT_NOM * (self.wire_frac + (1.0 - self.wire_frac) * fet)

    def path_weights(self) -> np.ndarray:
        """Per-path scale factors, sorted descending; w_0 = 1 (critical)."""
        rng = np.random.default_rng(self.seed)
        eps = np.abs(rng.normal(0.0, self.spread, self.n_paths - 1))
        w = np.concatenate([[1.0], 1.0 - np.sort(eps)])
        return w

    def path_delays(self, V, dp, dn) -> jnp.ndarray:
        """All worst-path delays [s], shape (n_paths,) (+ broadcasts)."""
        base = self.stage_delay(V, dp, dn)
        return jnp.asarray(self.path_weights()) * base

    def critical_delay(self, V, dp, dn):
        """Critical-path (w_0 = 1) delay — the quantity the AVS loop watches.

        The paper characterises the 100 worst paths in HSPICE and averages to
        de-noise; our analytical generator is noise-free, so the polynomial is
        fitted to the critical path directly (nominal 1.542 ns at 0.90 V) and
        the population enters only the BER model.
        """
        return self.stage_delay(V, dp, dn)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PathModel":
        return cls(**d)


def _monomial_exponents(total_degree: int = TOTAL_DEGREE):
    """All (a, b, c) with a + b + c <= total_degree (84 terms for degree 6)."""
    return [
        (a, b, c)
        for a, b, c in itertools.product(range(total_degree + 1), repeat=3)
        if a + b + c <= total_degree
    ]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DelayPolynomial:
    """Ternary degree-6 polynomial ``delay(dp, dn, V)`` in seconds.

    Variables are affinely scaled to [-1, 1] over the fitting box before
    monomial expansion for conditioning.  Evaluation is pure JAX.
    """
    coeffs: jnp.ndarray              # (n_terms,)
    exponents: jnp.ndarray           # (n_terms, 3) int
    centers: jnp.ndarray             # (3,)
    halfspans: jnp.ndarray           # (3,)
    rmse: float = 0.0

    def tree_flatten(self):
        return ((self.coeffs, self.exponents, self.centers, self.halfspans),
                (self.rmse,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, rmse=aux[0])

    def __call__(self, dp, dn, V):
        x = (jnp.stack(jnp.broadcast_arrays(
            jnp.asarray(dp, jnp.float32), jnp.asarray(dn, jnp.float32),
            jnp.asarray(V, jnp.float32)), axis=-1) - self.centers) / self.halfspans
        # powers[..., k, d] = x_d ** k
        max_deg = TOTAL_DEGREE
        pows = jnp.stack([x ** k for k in range(max_deg + 1)], axis=-2)
        e = self.exponents
        terms = (pows[..., e[:, 0], 0] * pows[..., e[:, 1], 1]
                 * pows[..., e[:, 2], 2])
        return terms @ self.coeffs

    def to_dict(self) -> Dict[str, Any]:
        return {
            "coeffs": np.asarray(self.coeffs, np.float64).tolist(),
            "exponents": np.asarray(self.exponents).tolist(),
            "centers": np.asarray(self.centers, np.float64).tolist(),
            "halfspans": np.asarray(self.halfspans, np.float64).tolist(),
            "rmse": float(self.rmse),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DelayPolynomial":
        return cls(
            coeffs=jnp.asarray(d["coeffs"], jnp.float32),
            exponents=jnp.asarray(d["exponents"], jnp.int32),
            centers=jnp.asarray(d["centers"], jnp.float32),
            halfspans=jnp.asarray(d["halfspans"], jnp.float32),
            rmse=float(d["rmse"]),
        )


def fit_delay_polynomial(path_model: PathModel, *, grid: int = 13,
                         total_degree: int = TOTAL_DEGREE) -> DelayPolynomial:
    """Least-squares fit of the mean worst-path delay over the fitting box."""
    dps = np.linspace(*DP_RANGE, grid)
    dns = np.linspace(*DN_RANGE, grid)
    vs = np.linspace(*V_RANGE, grid + 1)
    DP, DN, VV = np.meshgrid(dps, dns, vs, indexing="ij")
    y = np.asarray(path_model.critical_delay(jnp.asarray(VV.ravel()),
                                             jnp.asarray(DP.ravel()),
                                             jnp.asarray(DN.ravel())), np.float64)

    centers = np.array([np.mean(DP_RANGE), np.mean(DN_RANGE), np.mean(V_RANGE)])
    halfspans = np.array([np.ptp(DP_RANGE) / 2, np.ptp(DN_RANGE) / 2,
                          np.ptp(V_RANGE) / 2])
    X = np.stack([DP.ravel(), DN.ravel(), VV.ravel()], axis=-1)
    Xs = (X - centers) / halfspans

    exps = _monomial_exponents(total_degree)
    basis = np.stack([
        Xs[:, 0] ** a * Xs[:, 1] ** b * Xs[:, 2] ** c for a, b, c in exps
    ], axis=-1)
    coeffs, *_ = np.linalg.lstsq(basis, y, rcond=None)
    rmse = float(np.sqrt(np.mean((basis @ coeffs - y) ** 2)))
    return DelayPolynomial(
        coeffs=jnp.asarray(coeffs, jnp.float32),
        exponents=jnp.asarray(np.array(exps), jnp.int32),
        centers=jnp.asarray(centers, jnp.float32),
        halfspans=jnp.asarray(halfspans, jnp.float32),
        rmse=rmse,
    )
