"""Accelerator power model (paper Table II: V_eff, P_avg, power saving).

    P(V, dVth) = P_dyn0 * (V / V0)**2
               + P_leak0 * (V / V0) * 10**((k_dibl * (V - V0) - dVth_mean) / S)

* dynamic CV^2f term (activity and f fixed over life — AVS here scales V only);
* subthreshold leakage with slope ``S`` [V/decade], DIBL-style supply
  sensitivity ``k_dibl``, and *aging-induced leakage reduction* (a higher
  |Vth| exponentially lowers leakage — the second-order effect that makes
  lifetime power a little kinder than V^2 alone would suggest).

``P_dyn0`` and ``P_leak0`` are calibrated from the paper's two anchor points
(Table II): lifetime-average power 0.85 W for an operator that stays at
0.90 V, and 1.03 W for the baseline AVS trajectory reaching 1.02 V.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from .constants import V_NOM


@dataclasses.dataclass
class PowerModel:
    p_dyn0: float = 0.70        # dynamic power at V0 [W]
    p_leak0: float = 0.15       # leakage power at (V0, fresh) [W]
    v0: float = V_NOM
    s_slope: float = 0.085      # subthreshold slope [V/decade]
    k_dibl: float = 1.5         # supply sensitivity of leakage

    def power_split(self, V, dvth_p_mv, dvth_n_mv):
        """(dynamic, leakage) components [W]; dVth args in mV."""
        V = jnp.asarray(V)
        dv_mean = 0.5 * (jnp.asarray(dvth_p_mv) + jnp.asarray(dvth_n_mv)) * 1e-3
        dyn = self.p_dyn0 * (V / self.v0) ** 2
        leak = self.p_leak0 * (V / self.v0) * 10.0 ** (
            (self.k_dibl * (V - self.v0) - dv_mean) / self.s_slope)
        return dyn, leak

    def power(self, V, dvth_p_mv, dvth_n_mv):
        """Instantaneous power [W] at full activity; dVth args in mV."""
        dyn, leak = self.power_split(V, dvth_p_mv, dvth_n_mv)
        return dyn + leak

    def power_at_activity(self, V, dvth_p_mv, dvth_n_mv, activity):
        """Array power when the device is busy ``activity`` of the time.

        The CV^2f dynamic term scales with the duty the scheduler routes
        onto the device; subthreshold leakage burns regardless of load.
        This is the quantity the traffic co-simulation
        (:func:`repro.sched.lifetime.cosim_stats`) integrates: serving a
        request on a low-V (young, cool) device genuinely costs less
        dynamic energy than on an aged device boosted to ``v_max``.
        """
        dyn, leak = self.power_split(V, dvth_p_mv, dvth_n_mv)
        return jnp.asarray(activity) * dyn + leak

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PowerModel":
        return cls(**d)


def calibrate_power(traj_nom, traj_avs, target_nom: float = 0.85,
                    target_avs: float = 1.03, **kw) -> PowerModel:
    """Solve the 2x2 linear system for (p_dyn0, p_leak0).

    ``traj_*`` are dicts with time-series arrays ``t, V, dvp, dvn`` from the
    lifetime simulator; averages are time-weighted.
    """
    probe = PowerModel(p_dyn0=1.0, p_leak0=0.0, **kw)

    def basis_avgs(traj):
        t = np.asarray(traj["t"], np.float64)
        wdt = np.diff(t, prepend=0.0)
        wdt = wdt / wdt.sum()
        dyn = np.asarray(probe.power(traj["V"], 0.0, 0.0), np.float64)
        probe2 = PowerModel(p_dyn0=0.0, p_leak0=1.0, **kw)
        leak = np.asarray(
            probe2.power(traj["V"], traj["dvp"], traj["dvn"]), np.float64)
        return float((dyn * wdt).sum()), float((leak * wdt).sum())

    a11, a12 = basis_avgs(traj_nom)
    a21, a22 = basis_avgs(traj_avs)
    sol = np.linalg.solve(np.array([[a11, a12], [a21, a22]]),
                          np.array([target_nom, target_avs]))
    return PowerModel(p_dyn0=float(sol[0]), p_leak0=float(sol[1]), **kw)


def batched_lifetime_stats(power_model: PowerModel, traj
                           ) -> Dict[str, np.ndarray]:
    """Vectorised :func:`lifetime_stats` over arbitrary batch dimensions.

    ``traj`` is a :class:`repro.core.scenario.LifetimeTrajectory` (or a dict
    of arrays) whose time axis is last; returns batch-shaped arrays.
    """
    if hasattr(traj, "to_dict"):
        traj = traj.to_dict()
    t = np.asarray(traj["t"], np.float64)
    wdt = np.diff(t, axis=-1, prepend=0.0)
    wdt = wdt / wdt.sum(axis=-1, keepdims=True)
    p = np.asarray(power_model.power(traj["V"], traj["dvp"], traj["dvn"]),
                   np.float64)
    v = np.asarray(traj["V"], np.float64)
    return {
        "v_eff": (v * wdt).sum(axis=-1),
        "p_avg": (p * wdt).sum(axis=-1),
        "v_final": v[..., -1],
        "dvp_final": np.asarray(traj["dvp"], np.float64)[..., -1],
        "dvn_final": np.asarray(traj["dvn"], np.float64)[..., -1],
    }


def lifetime_stats(power_model: PowerModel, traj) -> Dict[str, float]:
    """Time-weighted lifetime averages: V_eff [V] and P_avg [W]."""
    return {k: float(v)
            for k, v in batched_lifetime_stats(power_model, traj).items()}
