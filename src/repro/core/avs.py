"""AVS lifetime simulator (paper Sec. III-F + Sec. IV).

A ``lax.scan`` over a log-spaced time grid covering t0 .. 10 years.  Each
step advances the six trap populations (history-aware effective-time update
at the *current* V_DD), evaluates the fitted critical-path delay polynomial,
and raises V_DD in ``V_STEP`` increments while the delay exceeds the policy's
``delay_max`` (classical AVS: delay_max = t_clk; fault-tolerant AVS:
per-operator delay_max from the tolerable-BER inversion).

The whole simulator is jittable and ``vmap``-able over ``delay_max`` — the
entire Table II (9 operator domains + baseline) runs as a single vmapped
scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import aging
from .aging import AgingParams
from .constants import (DUTY_FACTOR, LIFETIME_S, T_AMB, T_CLK, TOGGLE_RATE,
                        TRANSITION_TIME, V_MAX, V_NOM, V_STEP)
from .delay import DelayPolynomial


@dataclasses.dataclass(frozen=True)
class LifetimeConfig:
    t_clk: float = T_CLK
    v_init: float = V_NOM
    v_step: float = V_STEP
    v_max: float = V_MAX
    duty: float = DUTY_FACTOR
    toggle: float = TOGGLE_RATE
    transition_time: float = TRANSITION_TIME
    t_amb: float = T_AMB
    lifetime_s: float = LIFETIME_S
    t_start: float = 600.0          # first grid point [s]
    n_steps: int = 480              # log-spaced grid points
    max_boosts_per_step: int = 4    # inner while-loop bound

    def time_grid(self) -> np.ndarray:
        return np.logspace(np.log10(self.t_start), np.log10(self.lifetime_s),
                           self.n_steps)


def run_lifetime(params: AgingParams, poly: DelayPolynomial,
                 cfg: LifetimeConfig = LifetimeConfig(), *,
                 delay_max: float | jnp.ndarray = T_CLK,
                 recovery: bool = True,
                 avs_enabled: bool = True) -> Dict[str, Any]:
    """Simulate one lifetime; returns the full trajectory.

    ``delay_max`` may be a scalar or a vector (vmapped policies).  With
    ``avs_enabled=False`` the supply stays at ``v_init`` (Table I rows 1-2);
    pass ``v_init == v_max`` for the constant-worst-case row 3.
    """
    rates = aging.stress_rates(params, duty=cfg.duty, toggle=cfg.toggle,
                               t_clk=cfg.t_clk,
                               transition_time=cfg.transition_time,
                               recovery=recovery)
    tgrid = jnp.asarray(cfg.time_grid(), jnp.float32)
    dts = jnp.diff(tgrid, prepend=jnp.zeros((1,), jnp.float32))
    delay_max = jnp.asarray(delay_max, jnp.float32)

    def one_lifetime(dmax):
        def step(carry, inp):
            dv, v = carry
            dt = inp
            dv = aging.update_state(params, dv, v, rates, dt, cfg.t_amb)
            dvp, dvn = aging.totals(dv)
            delay0 = poly(dvp * 1e-3, dvn * 1e-3, v)

            def boost_cond(state):
                v_, d_, it = state
                return ((d_ > dmax) & (v_ < cfg.v_max - 1e-6)
                        & (it < cfg.max_boosts_per_step) & avs_enabled)

            def boost(state):
                v_, _, it = state
                v_ = v_ + cfg.v_step
                return v_, poly(dvp * 1e-3, dvn * 1e-3, v_), it + 1

            v, delay, _ = jax.lax.while_loop(
                boost_cond, boost, (v, delay0, jnp.asarray(0)))
            out = {"V": v, "delay": delay, "dvp": dvp, "dvn": dvn, "dv": dv}
            return (dv, v), out

        init = (jnp.zeros((aging.N_POP,), jnp.float32),
                jnp.asarray(cfg.v_init, jnp.float32))
        _, traj = jax.lax.scan(step, init, dts)
        traj["t"] = tgrid
        return traj

    if delay_max.ndim == 0:
        return one_lifetime(delay_max)
    return jax.vmap(one_lifetime)(delay_max)


def final_shifts(traj) -> Dict[str, float]:
    """Convenience: end-of-life (ΔVth_p, ΔVth_n) in mV and final V."""
    return {
        "dvp": float(np.asarray(traj["dvp"])[-1]),
        "dvn": float(np.asarray(traj["dvn"])[-1]),
        "v_final": float(np.asarray(traj["V"])[-1]),
    }


def per_population_finals(traj) -> Dict[str, float]:
    dv = np.asarray(traj["dv"])[-1]
    return {name: float(dv[i]) for i, name in enumerate(aging.POPULATIONS)}
