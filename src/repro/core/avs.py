"""AVS lifetime simulator (paper Sec. III-F + Sec. IV).

A ``lax.scan`` over a log-spaced time grid covering t0 .. 10 years.  Each
step advances the six trap populations (history-aware effective-time update
at the *current* V_DD), evaluates the fitted critical-path delay polynomial,
and raises V_DD in ``v_step`` increments while the delay exceeds the policy's
``delay_max`` (classical AVS: delay_max = t_clk; fault-tolerant AVS:
per-operator delay_max from the tolerable-BER inversion).

The first-class entry point is :func:`simulate`: it takes a pytree
:class:`~repro.core.scenario.Scenario` whose leaves (duty, toggle,
temperature, clock, supply envelope, horizon, budget) may carry arbitrary
broadcastable batch dimensions, plus a broadcastable ``delay_max`` threshold
array, flattens the joint batch, and runs ONE vmapped scan over it — stress
rates are computed inside the traced function, so *every* knob batches, not
just the threshold.  A full scenario sweep (budgets x mission profiles x
operator domains) is one trace/compile.

:func:`run_lifetime` is the legacy shim over ``simulate`` (scalar config +
``delay_max`` vector, dict-of-arrays trajectory); new code should call
``simulate`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import aging
from .aging import AgingParams
from .constants import (DUTY_FACTOR, LIFETIME_S, T_AMB, T_CLK, TOGGLE_RATE,
                        TRANSITION_TIME, V_MAX, V_NOM, V_STEP)
from .delay import DelayPolynomial
from .scenario import LifetimeTrajectory, Scenario


@dataclasses.dataclass(frozen=True)
class LifetimeConfig:
    """Legacy scalar mission config; superseded by
    :class:`repro.core.scenario.Scenario` (see DESIGN.md §Migration)."""
    t_clk: float = T_CLK
    v_init: float = V_NOM
    v_step: float = V_STEP
    v_max: float = V_MAX
    duty: float = DUTY_FACTOR
    toggle: float = TOGGLE_RATE
    transition_time: float = TRANSITION_TIME
    t_amb: float = T_AMB
    lifetime_s: float = LIFETIME_S
    t_start: float = 600.0          # first grid point [s]
    n_steps: int = 480              # log-spaced grid points
    max_boosts_per_step: int = 4    # inner while-loop bound

    def time_grid(self) -> np.ndarray:
        return np.logspace(np.log10(self.t_start), np.log10(self.lifetime_s),
                           self.n_steps)

    def scenario(self, max_loss_pct: float = 0.5, **overrides) -> Scenario:
        return Scenario.from_lifetime_config(self, max_loss_pct, **overrides)


def _simulate_one(params: AgingParams, poly: DelayPolynomial, scn: Scenario,
                  dmax, *, recovery: bool, avs_enabled: bool
                  ) -> LifetimeTrajectory:
    """One lifetime with scalar (possibly traced) scenario leaves."""
    rates = aging.stress_rates(params, duty=scn.duty, toggle=scn.toggle,
                               t_clk=scn.t_clk,
                               transition_time=scn.transition_time,
                               recovery=recovery)
    tgrid = jnp.logspace(jnp.log10(jnp.asarray(scn.t_start, jnp.float32)),
                         jnp.log10(jnp.asarray(scn.lifetime_s, jnp.float32)),
                         scn.n_steps, dtype=jnp.float32)
    dts = jnp.diff(tgrid, prepend=jnp.zeros((1,), jnp.float32))
    dmax = jnp.asarray(dmax, jnp.float32)

    def step(carry, dt):
        dv, v = carry
        dv = aging.update_state(params, dv, v, rates, dt, scn.t_amb)
        dvp, dvn = aging.totals(dv)
        delay0 = poly(dvp * 1e-3, dvn * 1e-3, v)

        def boost_cond(state):
            v_, d_, it = state
            return ((d_ > dmax) & (v_ < scn.v_max - 1e-6)
                    & (it < scn.max_boosts_per_step) & avs_enabled)

        def boost(state):
            v_, _, it = state
            v_ = v_ + scn.v_step
            return v_, poly(dvp * 1e-3, dvn * 1e-3, v_), it + 1

        v, delay, _ = jax.lax.while_loop(
            boost_cond, boost, (v, delay0, jnp.asarray(0)))
        return (dv, v), {"V": v, "delay": delay, "dvp": dvp, "dvn": dvn,
                         "dv": dv}

    init = (jnp.zeros((aging.N_POP,), jnp.float32),
            jnp.asarray(scn.v_init, jnp.float32))
    _, out = jax.lax.scan(step, init, dts)
    return LifetimeTrajectory(t=tgrid, V=out["V"], delay=out["delay"],
                              dvp=out["dvp"], dvn=out["dvn"], dv=out["dv"])


def simulate(params: AgingParams, poly: DelayPolynomial,
             scenarios: Scenario, delay_max=None, *,
             recovery: bool = True,
             avs_enabled: bool = True) -> LifetimeTrajectory:
    """Simulate lifetimes for a broadcastable batch of scenarios.

    ``delay_max`` (defaults to ``scenarios.t_clk`` — classical AVS)
    broadcasts against the scenario batch shape; e.g. a scenario batch of
    shape ``(B1, B2, 1)`` against thresholds ``(B1, B2, O)`` sweeps every
    operator domain of every scenario.  The joint batch is flattened and run
    as ONE vmapped scan — a single trace/compile for any sweep shape.
    Returns a :class:`LifetimeTrajectory` with ``batch_shape`` equal to the
    joint broadcast shape.
    """
    if delay_max is None:
        delay_max = scenarios.t_clk
    delay_max = jnp.asarray(delay_max, jnp.float32)
    batch = jnp.broadcast_shapes(scenarios.batch_shape, delay_max.shape)

    if batch == ():
        return _simulate_one(params, poly, scenarios, delay_max,
                             recovery=recovery, avs_enabled=avs_enabled)

    flat_scn = scenarios.broadcast_leaves(batch).reshape((-1,))
    flat_dmax = jnp.broadcast_to(delay_max, batch).reshape(-1)

    traj = jax.vmap(
        lambda s, d: _simulate_one(params, poly, s, d, recovery=recovery,
                                   avs_enabled=avs_enabled)
    )(flat_scn, flat_dmax)
    return traj.reshape(batch)


def run_lifetime(params: AgingParams, poly: DelayPolynomial,
                 cfg: LifetimeConfig = LifetimeConfig(), *,
                 delay_max: float | jnp.ndarray = T_CLK,
                 recovery: bool = True,
                 avs_enabled: bool = True) -> Dict[str, Any]:
    """Legacy entry point: one scalar config, ``delay_max`` scalar/vector.

    Thin shim over :func:`simulate`; returns the historical dict-of-arrays
    trajectory (``t, V, delay, dvp, dvn, dv``).  See DESIGN.md §Migration.
    """
    traj = simulate(params, poly, cfg.scenario(),
                    delay_max=jnp.asarray(delay_max, jnp.float32),
                    recovery=recovery, avs_enabled=avs_enabled)
    return traj.to_dict()


def final_shifts(traj) -> Dict[str, float]:
    """Convenience: end-of-life (ΔVth_p, ΔVth_n) in mV and final V."""
    if isinstance(traj, LifetimeTrajectory):
        traj = traj.to_dict()
    return {
        "dvp": float(np.asarray(traj["dvp"])[-1]),
        "dvn": float(np.asarray(traj["dvn"])[-1]),
        "v_final": float(np.asarray(traj["V"])[-1]),
    }


def per_population_finals(traj) -> Dict[str, float]:
    if isinstance(traj, LifetimeTrajectory):
        traj = traj.to_dict()
    dv = np.asarray(traj["dv"])[-1]
    return {name: float(dv[i]) for i, name in enumerate(aging.POPULATIONS)}
