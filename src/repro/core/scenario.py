"""Scenario-batched mission profiles — the pytree core of the policy API.

A :class:`Scenario` bundles every knob of one lifetime simulation — the
mission profile (duty factor, toggle rate, ambient temperature), the supply
envelope (v_init / v_step / v_max), the clock, the horizon, and the user's
accuracy budget — as *leaves of a JAX pytree*.  Any leaf may carry batch
dimensions; all leaves broadcast against each other, so a 2-D sweep such as

    scn = scenario_grid(max_loss_pct=[0.1, 0.5, 2.0], duty=[0.3, 0.5, 0.7])

is simply a ``Scenario`` whose ``max_loss_pct`` leaf has shape ``(3, 1)``
and ``duty`` leaf shape ``(1, 3)``.  :func:`repro.core.avs.simulate` flattens
the broadcast batch, runs ONE vmapped ``lax.scan`` over it (stress rates are
computed inside the traced function, so activity knobs batch too), and
reshapes the resulting :class:`LifetimeTrajectory` back — a single
trace/compile regardless of sweep dimensionality.

Static structure (grid length, boost bound) lives in the pytree aux data so
jit/vmap treat it as compile-time constants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .constants import (DEFAULT_MAX_LOSS_PCT, DUTY_FACTOR, LIFETIME_S, T_AMB,
                        T_CLK, TOGGLE_RATE, TRANSITION_TIME, V_MAX, V_NOM,
                        V_STEP)

# Leaf fields, in pytree order.  Everything here may be batched / traced.
SCENARIO_FIELDS = (
    "t_clk", "v_init", "v_step", "v_max",
    "duty", "toggle", "transition_time", "t_amb",
    "lifetime_s", "t_start", "max_loss_pct",
)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Scenario:
    """One mission profile (or an N-D broadcastable batch of them)."""

    t_clk: Any = T_CLK                  # clock period [s]
    v_init: Any = V_NOM                 # initial supply [V]
    v_step: Any = V_STEP                # AVS increment [V]
    v_max: Any = V_MAX                  # supply ceiling [V]
    duty: Any = DUTY_FACTOR             # BTI duty factor
    toggle: Any = TOGGLE_RATE           # HCI toggle rate
    transition_time: Any = TRANSITION_TIME   # output transition [s]
    t_amb: Any = T_AMB                  # ambient temperature [K]
    lifetime_s: Any = LIFETIME_S        # simulated horizon [s]
    t_start: Any = 600.0                # first grid point [s]
    max_loss_pct: Any = DEFAULT_MAX_LOSS_PCT    # accuracy budget [% loss]
    # --- static (aux) structure -------------------------------------------
    n_steps: int = 480                  # log-spaced grid points
    max_boosts_per_step: int = 4        # inner while-loop bound

    # ------------------------------------------------------------------ #
    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in SCENARIO_FIELDS),
                (self.n_steps, self.max_boosts_per_step))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_steps=aux[0], max_boosts_per_step=aux[1])

    # ------------------------------------------------------------------ #
    @property
    def batch_shape(self) -> tuple:
        """Common broadcast shape of all leaves; ``()`` for a single one."""
        return jnp.broadcast_shapes(
            *(jnp.shape(getattr(self, f)) for f in SCENARIO_FIELDS))

    @property
    def n_scenarios(self) -> int:
        return int(np.prod(self.batch_shape, dtype=np.int64)) \
            if self.batch_shape else 1

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def map_leaves(self, fn) -> "Scenario":
        return self.replace(
            **{f: fn(jnp.asarray(getattr(self, f), jnp.float32))
               for f in SCENARIO_FIELDS})

    def expand_dims(self, axis: int = -1) -> "Scenario":
        """Insert a broadcast axis on every leaf (e.g. the operator axis)."""
        return self.map_leaves(lambda x: jnp.expand_dims(x, axis))

    def broadcast_leaves(self, shape=None) -> "Scenario":
        """Materialise every leaf at the (given or common) batch shape."""
        shape = self.batch_shape if shape is None else tuple(shape)
        return self.map_leaves(lambda x: jnp.broadcast_to(x, shape))

    def reshape(self, shape) -> "Scenario":
        return self.broadcast_leaves().map_leaves(
            lambda x: x.reshape(tuple(shape)))

    def __getitem__(self, idx) -> "Scenario":
        """Index into the batch (after materialising the broadcast)."""
        return self.broadcast_leaves().map_leaves(lambda x: x[idx])

    # ------------------------------------------------------------------ #
    @classmethod
    def nominal(cls, **overrides) -> "Scenario":
        """The paper's operating point (Sec. V-A) with optional overrides."""
        return cls(**overrides)

    @classmethod
    def from_lifetime_config(cls, cfg,
                             max_loss_pct: float = DEFAULT_MAX_LOSS_PCT,
                             **overrides) -> "Scenario":
        """Adapter from the legacy :class:`repro.core.avs.LifetimeConfig`."""
        kw = dict(
            t_clk=cfg.t_clk, v_init=cfg.v_init, v_step=cfg.v_step,
            v_max=cfg.v_max, duty=cfg.duty, toggle=cfg.toggle,
            transition_time=cfg.transition_time, t_amb=cfg.t_amb,
            lifetime_s=cfg.lifetime_s, t_start=cfg.t_start,
            max_loss_pct=max_loss_pct,
            n_steps=cfg.n_steps, max_boosts_per_step=cfg.max_boosts_per_step,
        )
        kw.update(overrides)
        return cls(**kw)

    def to_dict(self) -> Dict[str, Any]:
        d = {f: np.asarray(getattr(self, f)).tolist()
             for f in SCENARIO_FIELDS}
        d["n_steps"] = self.n_steps
        d["max_boosts_per_step"] = self.max_boosts_per_step
        return d


def stack_scenarios(scenarios: Sequence[Scenario], axis: int = 0) -> Scenario:
    """Stack single (or same-shape) scenarios into one batched Scenario.

    Static aux structure must agree across all inputs.
    """
    scenarios = list(scenarios)
    assert scenarios, "need at least one scenario"
    aux0 = (scenarios[0].n_steps, scenarios[0].max_boosts_per_step)
    for s in scenarios[1:]:
        assert (s.n_steps, s.max_boosts_per_step) == aux0, \
            "cannot stack scenarios with different static structure"
    shape = jnp.broadcast_shapes(*(s.batch_shape for s in scenarios))
    mats = [s.broadcast_leaves(shape) for s in scenarios]
    return scenarios[0].replace(**{
        f: jnp.stack([jnp.asarray(getattr(m, f), jnp.float32) for m in mats],
                     axis=axis)
        for f in SCENARIO_FIELDS})


def scenario_grid(base: Scenario | None = None, **axes) -> Scenario:
    """Cartesian product of scenario knobs as an N-D broadcastable batch.

    ``scenario_grid(max_loss_pct=[...], duty=[...])`` returns a Scenario
    whose i-th swept leaf has shape ``(1,)*i + (len_i,) + (1,)*(N-1-i)``;
    the batch shape is the full grid, but no leaf is materialised — the
    simulator broadcasts lazily.
    """
    for name in axes:
        assert name in SCENARIO_FIELDS, f"unknown scenario field {name!r}"
    base = base or Scenario.nominal()
    ndim = len(axes)
    leaves = {}
    for i, (name, values) in enumerate(axes.items()):
        v = jnp.asarray(values, jnp.float32).reshape(-1)
        shape = (1,) * i + (v.shape[0],) + (1,) * (ndim - 1 - i)
        leaves[name] = v.reshape(shape)
    return base.replace(**leaves)


# --------------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LifetimeTrajectory:
    """Structured result of :func:`repro.core.avs.simulate`.

    Time-series leaves have shape ``batch_shape + (n_steps,)`` (``dv`` has a
    trailing population axis); ``batch_shape`` mirrors the scenario batch
    (possibly extended by a threshold/operator axis).
    """

    t: jnp.ndarray          # [..., T] wall-clock grid [s]
    V: jnp.ndarray          # [..., T] supply voltage [V]
    delay: jnp.ndarray      # [..., T] critical-path delay [s]
    dvp: jnp.ndarray        # [..., T] PMOS ΔVth [mV]
    dvn: jnp.ndarray        # [..., T] NMOS ΔVth [mV]
    dv: jnp.ndarray         # [..., T, N_POP] per-population shifts [mV]

    _FIELDS = ("t", "V", "delay", "dvp", "dvn", "dv")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # ------------------------------------------------------------------ #
    @property
    def batch_shape(self) -> tuple:
        return tuple(self.V.shape[:-1])

    @property
    def n_steps(self) -> int:
        return int(self.V.shape[-1])

    def to_dict(self) -> Dict[str, jnp.ndarray]:
        """Legacy ``run_lifetime`` dict layout (keys t/V/delay/dvp/dvn/dv)."""
        return {f: getattr(self, f) for f in self._FIELDS}

    @classmethod
    def from_dict(cls, d) -> "LifetimeTrajectory":
        return cls(*(jnp.asarray(d[f]) for f in cls._FIELDS))

    def __getitem__(self, idx) -> "LifetimeTrajectory":
        """Index into the batch dimensions."""
        return LifetimeTrajectory(*(getattr(self, f)[idx]
                                    for f in self._FIELDS))

    def reshape(self, batch_shape) -> "LifetimeTrajectory":
        bs = tuple(batch_shape)
        out = {}
        for f in self._FIELDS:
            x = getattr(self, f)
            out[f] = x.reshape(bs + tuple(x.shape[len(self.batch_shape):]))
        return LifetimeTrajectory(**out)

    # ------------------------------------------------------------------ #
    def final(self) -> Dict[str, np.ndarray]:
        """End-of-life snapshot over the whole batch."""
        return {
            "v_final": np.asarray(self.V)[..., -1],
            "delay_final": np.asarray(self.delay)[..., -1],
            "dvp": np.asarray(self.dvp)[..., -1],
            "dvn": np.asarray(self.dvn)[..., -1],
            "dv": np.asarray(self.dv)[..., -1, :],
        }

    def age_index(self, age_s) -> np.ndarray:
        """Grid index of wall-clock age(s) per batch cell (vectorised)."""
        t = np.asarray(self.t)
        age = np.asarray(age_s, np.float64)
        age_b = np.broadcast_to(age, self.batch_shape) if self.batch_shape \
            else age
        idx = (t < age_b[..., None]).sum(axis=-1)
        return np.clip(idx, 0, t.shape[-1] - 1)

    def at_age(self, age_s) -> Dict[str, np.ndarray]:
        """Snapshot every series at the given wall-clock age(s)."""
        idx = self.age_index(age_s)
        out = {}
        for f in ("V", "delay", "dvp", "dvn"):
            x = np.asarray(getattr(self, f))
            out[f] = np.take_along_axis(x, idx[..., None], axis=-1)[..., 0]
        return out
