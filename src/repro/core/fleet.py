"""Fleet-scale serving-time integration of the AVS policy.

:class:`FleetRuntime` generalises the old per-op ``AgingAwareRuntime`` into a
vectorised primitive: it holds **N devices x O operator domains** as arrays.
All N·O lifetime trajectories come from ONE vmapped
:func:`repro.core.avs.simulate` call (computed lazily, cached), device ages
are a vector, and the age -> state lookup is a single vectorised
searchsorted-equivalent over the whole fleet — no Python loops on the hot
path.  The power model is built once at construction.

Devices may share one mission profile (scalar :class:`Scenario`, trajectories
broadcast across the fleet at zero extra compute) or carry per-device
profiles (a ``(N,)``-batched scenario — heterogeneous duty/temperature/budget
fleets, cf. workload-dependent stress in *Long-Term and Short-Term
Transistor Aging in DNNs*).

:meth:`device` returns a :class:`DeviceView` exposing the legacy single-
device protocol (``op_bers``, ``domain_state``, ``total_power``, ...), which
is what :class:`repro.serve.engine.ServeEngine` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

import numpy as np

from .artifacts import Calibration, load_calibration
from .avs import simulate
from .constants import DEFAULT_MAX_LOSS_PCT
from .policy import BaselinePolicy, FaultTolerantPolicy, Policy, get_policy
from .resilience import OPERATORS
from .scenario import LifetimeTrajectory, Scenario

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0


@dataclasses.dataclass
class DomainState:
    """Snapshot of one operator voltage domain at the current age."""
    v_dd: float
    delay: float
    dvth_p_mv: float
    dvth_n_mv: float
    ber: float
    power_w: float


@dataclasses.dataclass(frozen=True)
class FleetState:
    """Snapshot of the whole fleet; every field has shape ``(N, O)``."""
    v_dd: np.ndarray
    delay: np.ndarray
    dvth_p_mv: np.ndarray
    dvth_n_mv: np.ndarray
    ber: np.ndarray
    power_w: np.ndarray

    def domain(self, device: int, op_idx: int) -> DomainState:
        return DomainState(
            v_dd=float(self.v_dd[device, op_idx]),
            delay=float(self.delay[device, op_idx]),
            dvth_p_mv=float(self.dvth_p_mv[device, op_idx]),
            dvth_n_mv=float(self.dvth_n_mv[device, op_idx]),
            ber=float(self.ber[device, op_idx]),
            power_w=float(self.power_w[device, op_idx]),
        )


class FleetRuntime:
    """N aging accelerators x O operator voltage domains, fully vectorised."""

    def __init__(self, cal: Optional[Calibration] = None, *,
                 n_devices: int = 1,
                 scenario: Optional[Scenario] = None,
                 policy: Policy | str = "fault_tolerant",
                 max_loss_pct: float = DEFAULT_MAX_LOSS_PCT,
                 operators: tuple[str, ...] = OPERATORS, curves=None):
        """``max_loss_pct`` sets the budget of the *default* scenario; when
        an explicit ``scenario`` is passed, its own (possibly per-device)
        ``max_loss_pct`` leaf governs the policy thresholds instead."""
        self.cal = cal or load_calibration()
        self.operators = tuple(operators)
        if isinstance(policy, str):
            if policy == "fault_tolerant":
                # budget deliberately NOT pinned on the policy: it reads
                # scenario.max_loss_pct, so per-device budgets batch
                policy = FaultTolerantPolicy(ber_model=self.cal.ber,
                                             curves=curves)
            elif policy == "baseline":
                policy = BaselinePolicy(t_clk=self.cal.lifetime_cfg.t_clk)
            elif policy == "measured":
                # measured in-repo curves (resilience_calibrated.json);
                # pass a MeasuredResiliencePolicy instance to pick a
                # specific zoo model (the string form uses its default)
                policy = get_policy("measured", ber_model=self.cal.ber,
                                    curves=curves)
            else:
                policy = get_policy(policy)
        self.policy = policy

        if scenario is None:
            scenario = Scenario.from_lifetime_config(self.cal.lifetime_cfg,
                                                     max_loss_pct)
        sbatch = scenario.batch_shape
        assert len(sbatch) <= 1, \
            "FleetRuntime scenarios must be scalar or (n_devices,)-batched"
        if sbatch:
            assert n_devices in (1, sbatch[0]), \
                f"n_devices={n_devices} conflicts with scenario batch {sbatch}"
            n_devices = sbatch[0]
        self.scenario = scenario
        self.n_devices = int(n_devices)
        self._scenario_batched = bool(sbatch)
        # power model referenced once here — never rebuilt per lookup
        self._power = self.cal.power
        self._ages_s = np.zeros(self.n_devices, np.float64)
        self._traj: Optional[LifetimeTrajectory] = None
        self._snap: Optional[FleetState] = None     # cache, keyed on ages

    @classmethod
    def for_model(cls, cfg, **kw) -> "FleetRuntime":
        """Fleet with the architecture family's operator-domain set
        (DESIGN.md §Arch-applicability): attention-free families get their
        projection domains instead of the vacuous qkt/sv rows.  With
        ``policy="measured"`` the artifact lookup is keyed on THIS model
        (uncharacterised family domains fall back to the defaults inside
        the policy)."""
        from .resilience import default_curves, operators_for
        ops = operators_for(cfg.family)
        if kw.get("policy") == "measured":
            from .policy import MeasuredResiliencePolicy
            cal = kw.setdefault("cal", load_calibration())
            kw["policy"] = MeasuredResiliencePolicy(ber_model=cal.ber,
                                                    model=cfg.name)
            return cls(operators=ops, **kw)
        return cls(operators=ops, curves=default_curves(ops), **kw)

    # ------------------------------------------------------------------ #
    def _ensure_trajs(self) -> LifetimeTrajectory:
        """All N x O trajectories from one vmapped scan, as (N, O, T) views."""
        if self._traj is None:
            dmax = self.policy.thresholds(self.scenario, self.operators)
            traj: LifetimeTrajectory = simulate(
                self.cal.aging, self.cal.delay_poly,
                self.scenario.expand_dims(-1), delay_max=dmax)
            O = len(self.operators)
            out = {}
            for k, v in traj.to_dict().items():
                v = np.asarray(v)
                tail = v.shape[(1 if self._scenario_batched else 0) + 1:]
                # scalar scenario: (O, T...) -> broadcast view (N, O, T...)
                target = (self.n_devices, O) + tail
                out[k] = v if self._scenario_batched \
                    else np.broadcast_to(v, target)
            self._traj = LifetimeTrajectory(**out)
        return self._traj

    @property
    def trajectories(self) -> LifetimeTrajectory:
        """(N, O, T) lifetime trajectories (lazily computed, cached)."""
        return self._ensure_trajs()

    # ------------------------------------------------------------------ #
    def apply_load(self, loads=None, *, workload="diurnal",
                   router="wear_level", util_trace=None,
                   n_epochs: int = 480,
                   horizon_s: Optional[float] = None,
                   utilization: float = 0.5, key: int = 0,
                   capacity: float = 1.0,
                   heat_per_util: Optional[float] = None):
        """Age the fleet under *routed traffic* instead of static stress.

        Runs the :func:`repro.sched.lifetime.cosimulate` scan — routing
        -> stress -> ΔVth -> policy voltage, closed per epoch — and
        replaces the fleet's cached trajectories with the traffic-driven
        ones, so every downstream consumer (``snapshot``, ``op_ber_array``,
        the serving engines) sees BERs that reflect traffic-dependent age.

        ``loads`` is an ``(E,)`` offered-load trace; alternatively
        ``workload`` names a registered arrival model (or passes a
        :class:`repro.sched.workload.Workload`) sized by ``utilization``.
        ``util_trace`` — an ``(E, N)`` *measured* per-device utilization
        trace (online-serving slot occupancy; see
        ``repro.serve.online.OnlineServeResult.lane_utilization``) —
        bypasses the router entirely and replays the measured duty into
        the stress recursion: served traffic, not a synthetic envelope,
        drives the aging.
        The co-simulation *resumes from the fleet's current aged state*
        (staggered ``set_age`` ages fold into the initial trap
        populations).  Afterwards the fleet's age clock counts **service
        time under the routed traffic** over ``[0, horizon_s]`` (default
        horizon: the scenario's) and is positioned at the END of the
        routed horizon — serving immediately after ``apply_load`` uses
        the traffic-aged BERs, and a chained ``apply_load`` resumes from
        the accumulated wear; ``set_age``/``advance`` rewind or replay
        within the horizon.  Returns the
        :class:`repro.sched.lifetime.CoSimTrajectory` (also kept on
        ``self.last_cosim``).
        """
        from repro.sched import lifetime as sched_lifetime
        from repro.sched.workload import Workload, get_workload

        if util_trace is not None:
            util_trace = np.asarray(util_trace, np.float32)
            n_epochs = util_trace.shape[0]
            if loads is None:
                loads = util_trace.sum(axis=-1)
        elif loads is None:
            wl = workload if isinstance(workload, Workload) else \
                get_workload(workload, n_devices=self.n_devices,
                             utilization=utilization, n_epochs=n_epochs)
            loads = wl.loads(key)
        loads = np.asarray(loads, np.float32)
        dmax = self.policy.thresholds(self.scenario, self.operators)

        dv0 = v0 = None
        if np.any(self._ages_s > 0):        # resume from the aged state
            traj = self._ensure_trajs()
            idx = self._age_indices()[..., None]              # (N, O, 1)
            v0 = np.take_along_axis(np.asarray(traj.V), idx,
                                    axis=-1)[..., 0]
            dv0 = np.take_along_axis(np.asarray(traj.dv),
                                     idx[..., None], axis=-2)[..., 0, :]

        if horizon_s is None:
            horizon_s = float(np.mean(np.asarray(self.scenario.lifetime_s,
                                                 np.float64)))
        kw = {} if heat_per_util is None else \
            {"heat_per_util": heat_per_util}
        cos = sched_lifetime.cosimulate(
            self.cal.aging, self.cal.delay_poly, self.scenario, dmax,
            loads, router=router, util_trace=util_trace,
            n_devices=self.n_devices,
            epoch_s=horizon_s / loads.shape[0], capacity=capacity,
            dv0=dv0, v0=v0, **kw)
        self._traj = cos.as_lifetime_trajectory()
        self._snap = None
        # service-time clock, positioned at the end of the routed horizon
        self._ages_s[:] = float(np.asarray(cos.t)[-1])
        self.last_cosim = cos
        return cos

    # ------------------------------------------------------------------ #
    def set_age(self, *, years=None, seconds=None, device=None):
        """Set the simulated age of one device (or the whole fleet)."""
        assert (years is None) != (seconds is None)
        age = float(seconds if seconds is not None
                    else years * SECONDS_PER_YEAR)
        if device is None:
            self._ages_s[:] = age
        else:
            self._ages_s[device] = age
        self._snap = None

    def advance(self, seconds, device=None):
        if device is None:
            self._ages_s += np.asarray(seconds, np.float64)
        else:
            self._ages_s[device] += float(seconds)
        self._snap = None

    @property
    def ages_years(self) -> np.ndarray:
        return self._ages_s / SECONDS_PER_YEAR

    @property
    def age_years(self) -> float:
        """Fleet-uniform age convenience (device 0)."""
        return float(self._ages_s[0]) / SECONDS_PER_YEAR

    # ------------------------------------------------------------------ #
    def _age_indices(self) -> np.ndarray:
        """Per (device, op) grid index of each device's current age — the
        trajectory's vectorised searchsorted-equivalent over the fleet."""
        return self._ensure_trajs().age_index(self._ages_s[:, None])

    def snapshot(self) -> FleetState:
        """Current state of every (device, operator) domain: (N, O) arrays.

        Cached between age changes — per-domain accessors (``op_ber``,
        ``total_power``, ...) share one fleet-wide computation."""
        if self._snap is None:
            traj = self._ensure_trajs()
            idx = self._age_indices()[..., None]           # (N, O, 1)
            pick = lambda k: np.take_along_axis(
                np.asarray(getattr(traj, k)), idx, axis=-1)[..., 0]
            v, delay = pick("V"), pick("delay")
            dvp, dvn = pick("dvp"), pick("dvn")
            ber = np.asarray(self.cal.ber.ber_from_delay(delay))
            power = np.asarray(self._power.power(v, dvp, dvn))
            self._snap = FleetState(v_dd=v, delay=delay, dvth_p_mv=dvp,
                                    dvth_n_mv=dvn, ber=ber, power_w=power)
        return self._snap

    # ------------------------------------------------------------------ #
    def op_index(self, op: str) -> int:
        return self.operators.index(op)

    def domain_state(self, op: str, device: int = 0) -> DomainState:
        return self.snapshot().domain(device, self.op_index(op))

    def op_ber(self, op: str, device: int = 0) -> float:
        return float(self.snapshot().ber[device, self.op_index(op)])

    def op_bers(self, device: int = 0) -> Dict[str, float]:
        ber = self.snapshot().ber[device]
        return {op: float(ber[i]) for i, op in enumerate(self.operators)}

    def op_ber_array(self) -> np.ndarray:
        """(N, O) BER matrix, columns ordered as ``self.operators``.

        The array-native accessor the fleet serving engine consumes: one
        snapshot hands every lane its per-operator BER vector without N x O
        scalar ``DeviceView`` round-trips."""
        return self.snapshot().ber

    def total_power(self, device: int = 0) -> float:
        return float(self.snapshot().power_w[device].sum())

    def fleet_power(self) -> np.ndarray:
        """Per-device array power [W], shape (N,)."""
        return self.snapshot().power_w.sum(axis=-1)

    def summary(self, device: int = 0) -> Mapping[str, Dict]:
        s = self.snapshot()
        return {op: dataclasses.asdict(s.domain(device, i))
                for i, op in enumerate(self.operators)}

    def device(self, i: int = 0) -> "DeviceView":
        assert 0 <= i < self.n_devices
        return DeviceView(self, i)


class DeviceView:
    """Single-device facade over a :class:`FleetRuntime` — implements the
    legacy ``AgingAwareRuntime`` protocol the serving engine consumes."""

    def __init__(self, fleet: FleetRuntime, index: int):
        self.fleet = fleet
        self.index = index

    @property
    def cal(self) -> Calibration:
        return self.fleet.cal

    @property
    def operators(self) -> tuple:
        return self.fleet.operators

    @property
    def policy(self):
        return self.fleet.policy

    @property
    def age_years(self) -> float:
        return float(self.fleet.ages_years[self.index])

    def set_age(self, *, years=None, seconds=None):
        self.fleet.set_age(years=years, seconds=seconds, device=self.index)

    def advance(self, seconds):
        self.fleet.advance(seconds, device=self.index)

    def domain_state(self, op: str) -> DomainState:
        return self.fleet.domain_state(op, device=self.index)

    def op_ber(self, op: str) -> float:
        return self.fleet.op_ber(op, device=self.index)

    def op_bers(self) -> Dict[str, float]:
        return self.fleet.op_bers(device=self.index)

    def total_power(self) -> float:
        return self.fleet.total_power(device=self.index)

    def summary(self) -> Mapping[str, Dict]:
        return self.fleet.summary(device=self.index)
