"""Fleet-scale serving-time integration of the AVS policy.

:class:`FleetRuntime` generalises the old per-op ``AgingAwareRuntime`` into a
vectorised primitive: it holds **N devices x O operator domains** as arrays.
All N·O lifetime trajectories come from ONE vmapped
:func:`repro.core.avs.simulate` call (computed lazily, cached), device ages
are a vector, and the age -> state lookup is a single vectorised
searchsorted-equivalent over the whole fleet — no Python loops on the hot
path.  The power model is built once at construction.

Devices may share one mission profile (scalar :class:`Scenario`, trajectories
broadcast across the fleet at zero extra compute) or carry per-device
profiles (a ``(N,)``-batched scenario — heterogeneous duty/temperature/budget
fleets, cf. workload-dependent stress in *Long-Term and Short-Term
Transistor Aging in DNNs*).

With ``n_shards=S > 1`` every device is further split into S *mesh shards*
— the tensor-parallel partitions of :class:`repro.serve.sharded`'s
mesh-sharded serving engine, each an independently aging silicon unit.
Internally the fleet is simply ``N*S`` aging units (device-major:
device ``d``'s shards are units ``d*S .. d*S+S-1``); all the vectorised
machinery is unchanged.  ``op_ber_shard_array`` exposes the ``(N, S, O)``
view the sharded engine folds into its one dispatch;
``op_ber_array``/``op_bers`` collapse shards with a per-domain **max**
(a domain is only as reliable as its worst shard) so every existing
device-granular consumer stays meaningful.

:meth:`device` returns a :class:`DeviceView` exposing the legacy single-
device protocol (``op_bers``, ``domain_state``, ``total_power``, ...), which
is what :class:`repro.serve.engine.ServeEngine` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

import numpy as np

from .aging import N_POP
from .artifacts import Calibration, load_calibration
from .avs import simulate
from .constants import DEFAULT_MAX_LOSS_PCT
from .policy import BaselinePolicy, FaultTolerantPolicy, Policy, get_policy
from .resilience import OPERATORS
from .scenario import LifetimeTrajectory, Scenario

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0


@dataclasses.dataclass
class DomainState:
    """Snapshot of one operator voltage domain at the current age."""
    v_dd: float
    delay: float
    dvth_p_mv: float
    dvth_n_mv: float
    ber: float
    power_w: float


@dataclasses.dataclass(frozen=True)
class FleetState:
    """Snapshot of the whole fleet; every field has shape ``(N*S, O)``
    (aging units x operators; units == devices when unsharded)."""
    v_dd: np.ndarray
    delay: np.ndarray
    dvth_p_mv: np.ndarray
    dvth_n_mv: np.ndarray
    ber: np.ndarray
    power_w: np.ndarray

    def domain(self, device: int, op_idx: int) -> DomainState:
        return DomainState(
            v_dd=float(self.v_dd[device, op_idx]),
            delay=float(self.delay[device, op_idx]),
            dvth_p_mv=float(self.dvth_p_mv[device, op_idx]),
            dvth_n_mv=float(self.dvth_n_mv[device, op_idx]),
            ber=float(self.ber[device, op_idx]),
            power_w=float(self.power_w[device, op_idx]),
        )


class FleetRuntime:
    """N aging accelerators x O operator voltage domains, fully vectorised."""

    def __init__(self, cal: Optional[Calibration] = None, *,
                 n_devices: int = 1, n_shards: int = 1,
                 scenario: Optional[Scenario] = None,
                 policy: Policy | str = "fault_tolerant",
                 max_loss_pct: float = DEFAULT_MAX_LOSS_PCT,
                 operators: tuple[str, ...] = OPERATORS, curves=None):
        """``max_loss_pct`` sets the budget of the *default* scenario; when
        an explicit ``scenario`` is passed, its own (possibly per-device)
        ``max_loss_pct`` leaf governs the policy thresholds instead."""
        self.cal = cal or load_calibration()
        self.operators = tuple(operators)
        if isinstance(policy, str):
            if policy == "fault_tolerant":
                # budget deliberately NOT pinned on the policy: it reads
                # scenario.max_loss_pct, so per-device budgets batch
                policy = FaultTolerantPolicy(ber_model=self.cal.ber,
                                             curves=curves)
            elif policy == "baseline":
                policy = BaselinePolicy(t_clk=self.cal.lifetime_cfg.t_clk)
            elif policy == "measured":
                # measured in-repo curves (resilience_calibrated.json);
                # pass a MeasuredResiliencePolicy instance to pick a
                # specific zoo model (the string form uses its default)
                policy = get_policy("measured", ber_model=self.cal.ber,
                                    curves=curves)
            else:
                policy = get_policy(policy)
        self.policy = policy

        if scenario is None:
            scenario = Scenario.from_lifetime_config(self.cal.lifetime_cfg,
                                                     max_loss_pct)
        sbatch = scenario.batch_shape
        assert len(sbatch) <= 1, \
            "FleetRuntime scenarios must be scalar or (n_devices,)-batched"
        if sbatch:
            assert n_devices in (1, sbatch[0]), \
                f"n_devices={n_devices} conflicts with scenario batch {sbatch}"
            n_devices = sbatch[0]
        self.scenario = scenario
        self.n_devices = int(n_devices)
        self.n_shards = int(n_shards)
        assert self.n_shards >= 1
        self._n_units = self.n_devices * self.n_shards
        self._scenario_batched = bool(sbatch)
        if sbatch and self.n_shards > 1:
            # unit-granular scenario: every shard of a device inherits the
            # device's mission profile (device-major repeat)
            self._unit_scenario = scenario.map_leaves(
                lambda v: np.repeat(np.asarray(v), self.n_shards, axis=0)
                if np.ndim(v) else v)
        else:
            self._unit_scenario = scenario
        # power model referenced once here — never rebuilt per lookup
        self._power = self.cal.power
        self._ages_s = np.zeros(self._n_units, np.float64)
        self._traj: Optional[LifetimeTrajectory] = None
        self._snap: Optional[FleetState] = None     # cache, keyed on ages
        self._ber_jax = None                 # cached jnp views of snapshot
        self._ber_shard_jax = None
        # short-term recovery extensions: the relaxed-pool series of the
        # last traffic co-sim ((N*S, O, T, P); None = monotone run), and
        # a pending exact trap state (from load_state_dict / resize) the
        # next apply_load resumes from in preference to the age gather
        self._rec_nop: Optional[np.ndarray] = None
        self._pending: Optional[Dict[str, np.ndarray]] = None

    @classmethod
    def for_model(cls, cfg, **kw) -> "FleetRuntime":
        """Fleet with the architecture family's operator-domain set
        (DESIGN.md §Arch-applicability): attention-free families get their
        projection domains instead of the vacuous qkt/sv rows.  With
        ``policy="measured"`` the artifact lookup is keyed on THIS model
        (uncharacterised family domains fall back to the defaults inside
        the policy)."""
        from .resilience import default_curves, operators_for
        ops = operators_for(cfg.family)
        if kw.get("policy") == "measured":
            from .policy import MeasuredResiliencePolicy
            cal = kw.setdefault("cal", load_calibration())
            kw["policy"] = MeasuredResiliencePolicy(ber_model=cal.ber,
                                                    model=cfg.name)
            return cls(operators=ops, **kw)
        return cls(operators=ops, curves=default_curves(ops), **kw)

    # ------------------------------------------------------------------ #
    def _ensure_trajs(self) -> LifetimeTrajectory:
        """All units x O trajectories from one vmapped scan, (N*S, O, T)."""
        if self._traj is None:
            dmax = self.policy.thresholds(self._unit_scenario, self.operators)
            traj: LifetimeTrajectory = simulate(
                self.cal.aging, self.cal.delay_poly,
                self._unit_scenario.expand_dims(-1), delay_max=dmax)
            O = len(self.operators)
            out = {}
            for k, v in traj.to_dict().items():
                v = np.asarray(v)
                tail = v.shape[(1 if self._scenario_batched else 0) + 1:]
                # scalar scenario: (O, T...) -> broadcast view (N*S, O, T...)
                target = (self._n_units, O) + tail
                out[k] = v if self._scenario_batched \
                    else np.broadcast_to(v, target)
            self._traj = LifetimeTrajectory(**out)
        return self._traj

    @property
    def trajectories(self) -> LifetimeTrajectory:
        """(N, O, T) lifetime trajectories (lazily computed, cached)."""
        return self._ensure_trajs()

    @property
    def unit_scenario(self) -> Scenario:
        """The per-aging-unit scenario: the device scenario itself when
        unsharded, the device-major shard-repeated view when ``n_shards >
        1`` — what threshold evaluation and the obs health snapshot
        consume (one leaf row per aging unit)."""
        return self._unit_scenario

    def health(self, **kw):
        """Fleet "aging odometer" snapshot — convenience delegate to
        :func:`repro.obs.health.fleet_health` (lazy import: the obs layer
        depends on core, never the reverse)."""
        from repro.obs.health import fleet_health
        return fleet_health(self, **kw)

    # ------------------------------------------------------------------ #
    def apply_load(self, loads=None, *, workload="diurnal",
                   router="wear_level", util_trace=None,
                   n_epochs: int = 480,
                   horizon_s: Optional[float] = None,
                   utilization: float = 0.5, key: int = 0,
                   capacity: float = 1.0,
                   heat_per_util: Optional[float] = None,
                   recovery=None, thermal=None):
        """Age the fleet under *routed traffic* instead of static stress.

        Runs the :func:`repro.sched.lifetime.cosimulate` scan — routing
        -> stress -> ΔVth -> policy voltage, closed per epoch — and
        replaces the fleet's cached trajectories with the traffic-driven
        ones, so every downstream consumer (``snapshot``, ``op_ber_array``,
        the serving engines) sees BERs that reflect traffic-dependent age.

        ``loads`` is an ``(E,)`` offered-load trace; alternatively
        ``workload`` names a registered arrival model (or passes a
        :class:`repro.sched.workload.Workload`) sized by ``utilization``.
        ``util_trace`` — an ``(E, N)`` *measured* per-device utilization
        trace (online-serving slot occupancy; see
        ``repro.serve.online.OnlineServeResult.lane_utilization``) —
        bypasses the router entirely and replays the measured duty into
        the stress recursion: served traffic, not a synthetic envelope,
        drives the aging.
        The co-simulation *resumes from the fleet's current aged state*
        (staggered ``set_age`` ages fold into the initial trap
        populations).  Afterwards the fleet's age clock counts **service
        time under the routed traffic** over ``[0, horizon_s]`` (default
        horizon: the scenario's) and is positioned at the END of the
        routed horizon — serving immediately after ``apply_load`` uses
        the traffic-aged BERs, and a chained ``apply_load`` resumes from
        the accumulated wear; ``set_age``/``advance`` rewind or replay
        within the horizon.  Returns the
        :class:`repro.sched.lifetime.CoSimTrajectory` (also kept on
        ``self.last_cosim``).

        ``recovery`` enables the short-term recoverable trap pool
        (``True`` for defaults, or a
        :class:`repro.core.aging.RecoveryParams`); the relaxed-pool
        series is kept so chained ``apply_load`` calls — and
        trap-state-preserving :meth:`resize` — resume it.  ``thermal``
        closes the temperature loop on routed power (``True`` or a
        :class:`repro.sched.lifetime.ThermalParams`).
        """
        from repro.sched import lifetime as sched_lifetime
        from repro.sched.workload import Workload, get_workload

        if util_trace is not None:
            util_trace = np.asarray(util_trace, np.float32)
            if self.n_shards > 1 and util_trace.shape[-1] == self.n_devices:
                # device-granular duty replayed onto every shard of it
                util_trace = np.repeat(util_trace, self.n_shards, axis=-1)
            n_epochs = util_trace.shape[0]
            if loads is None:
                loads = util_trace.sum(axis=-1)
        elif loads is None:
            wl = workload if isinstance(workload, Workload) else \
                get_workload(workload, n_devices=self._n_units,
                             utilization=utilization, n_epochs=n_epochs)
            loads = wl.loads(key)
        loads = np.asarray(loads, np.float32)
        dmax = self.policy.thresholds(self._unit_scenario, self.operators)

        dv0 = v0 = rec0 = None
        if self._pending is not None:       # exact state from a resize /
            dv0 = self._pending["dv"]       # load_state_dict, consumed by
            v0 = self._pending["v"]         # the first co-sim
            rec0 = self._pending["rec"]
            self._pending = None
        elif np.any(self._ages_s > 0):      # resume from the aged state
            traj = self._ensure_trajs()
            idx = self._age_indices()[..., None]              # (N, O, 1)
            v0 = np.take_along_axis(np.asarray(traj.V), idx,
                                    axis=-1)[..., 0]
            dv0 = np.take_along_axis(np.asarray(traj.dv),
                                     idx[..., None], axis=-2)[..., 0, :]
            if self._rec_nop is not None:
                rec0 = np.take_along_axis(self._rec_nop, idx[..., None],
                                          axis=-2)[..., 0, :]

        if horizon_s is None:
            horizon_s = float(np.mean(np.asarray(self.scenario.lifetime_s,
                                                 np.float64)))
        kw = {} if heat_per_util is None else \
            {"heat_per_util": heat_per_util}
        cos = sched_lifetime.cosimulate(
            self.cal.aging, self.cal.delay_poly, self._unit_scenario, dmax,
            loads, router=router, util_trace=util_trace,
            n_devices=self._n_units,
            epoch_s=horizon_s / loads.shape[0], capacity=capacity,
            dv0=dv0, v0=v0, recovery_dynamics=recovery, thermal=thermal,
            rec0=rec0, **kw)
        self._traj = cos.as_lifetime_trajectory()
        self._rec_nop = (np.moveaxis(np.asarray(cos.rec), 0, 2)
                         if cos.rec is not None else None)
        self._invalidate()
        # service-time clock, positioned at the end of the routed horizon
        self._ages_s[:] = float(np.asarray(cos.t)[-1])
        self.last_cosim = cos
        return cos

    # ------------------------------------------------------------------ #
    def _invalidate(self):
        self._snap = None
        self._ber_jax = None
        self._ber_shard_jax = None

    def _unit_sel(self, device, shard):
        """ndarray index selecting the addressed aging units."""
        S = self.n_shards
        if device is None:
            return slice(None) if shard is None else slice(shard, None, S)
        if shard is None:
            return slice(device * S, (device + 1) * S)
        return device * S + shard

    def set_age(self, *, years=None, seconds=None, device=None, shard=None):
        """Set the simulated age of one device/shard (or the whole fleet).

        ``shard`` addresses one mesh shard within ``device`` (or that shard
        index across every device when ``device is None``)."""
        assert (years is None) != (seconds is None)
        age = float(seconds if seconds is not None
                    else years * SECONDS_PER_YEAR)
        self._ages_s[self._unit_sel(device, shard)] = age
        self._pending = None      # explicit rewind overrides staged state
        self._invalidate()

    def advance(self, seconds, device=None, shard=None):
        sel = self._unit_sel(device, shard)
        if device is None and shard is None:
            self._ages_s += np.asarray(seconds, np.float64)
        else:
            self._ages_s[sel] = self._ages_s[sel] + np.asarray(
                seconds, np.float64)
        self._pending = None
        self._invalidate()

    @property
    def ages_years(self) -> np.ndarray:
        """(N,) device ages — or (N, S) per-shard ages when sharded."""
        yrs = self._ages_s / SECONDS_PER_YEAR
        if self.n_shards == 1:
            return yrs
        return yrs.reshape(self.n_devices, self.n_shards)

    @property
    def age_years(self) -> float:
        """Fleet-uniform age convenience (device 0)."""
        return float(self._ages_s[0]) / SECONDS_PER_YEAR

    # ------------------------------------------------------------------ #
    def _age_indices(self) -> np.ndarray:
        """Per (device, op) grid index of each device's current age — the
        trajectory's vectorised searchsorted-equivalent over the fleet."""
        return self._ensure_trajs().age_index(self._ages_s[:, None])

    def snapshot(self) -> FleetState:
        """Current state of every (unit, operator) domain: (N*S, O) arrays.

        Cached between age changes — per-domain accessors (``op_ber``,
        ``total_power``, ...) share one fleet-wide computation."""
        if self._snap is None:
            traj = self._ensure_trajs()
            idx = self._age_indices()[..., None]           # (N, O, 1)
            pick = lambda k: np.take_along_axis(
                np.asarray(getattr(traj, k)), idx, axis=-1)[..., 0]
            v, delay = pick("V"), pick("delay")
            dvp, dvn = pick("dvp"), pick("dvn")
            ber = np.asarray(self.cal.ber.ber_from_delay(delay))
            power = np.asarray(self._power.power(v, dvp, dvn))
            self._snap = FleetState(v_dd=v, delay=delay, dvth_p_mv=dvp,
                                    dvth_n_mv=dvn, ber=ber, power_w=power)
        return self._snap

    # ------------------------------------------------------------------ #
    # trap-state round-trip: serialize / restore / resize the fleet
    # ------------------------------------------------------------------ #
    def trap_state(self) -> Dict[str, np.ndarray]:
        """Exact per-(unit, operator) aging state at the current ages.

        Returns ``{"ages_s": (N*S,), "dv": (N*S, O, P) monotone
        per-population shifts [mV], "rec": same-shaped recoverable pool
        (zeros unless a recovery-enabled ``apply_load`` ran), "v":
        (N*S, O) supplies [V]}`` — the initial-state triple a co-sim
        resume consumes, gathered by the same age lookup ``apply_load``
        itself uses (so a resize + resume is bit-exact).
        """
        if self._pending is not None:
            return {"ages_s": self._ages_s.copy(),
                    "dv": self._pending["dv"].copy(),
                    "rec": self._pending["rec"].copy(),
                    "v": self._pending["v"].copy()}
        traj = self._ensure_trajs()
        idx = self._age_indices()[..., None]                   # (N, O, 1)
        v = np.take_along_axis(np.asarray(traj.V), idx, axis=-1)[..., 0]
        dv = np.take_along_axis(np.asarray(traj.dv), idx[..., None],
                                axis=-2)[..., 0, :]
        rec = (np.take_along_axis(self._rec_nop, idx[..., None],
                                  axis=-2)[..., 0, :]
               if self._rec_nop is not None else
               np.zeros_like(dv))
        return {"ages_s": self._ages_s.copy(), "dv": dv, "rec": rec,
                "v": v}

    def state_dict(self) -> Dict[str, Any]:
        """JSON-able snapshot of the fleet's aging state (round-trips
        through :meth:`load_state_dict`, including the recoverable-state
        leaves)."""
        st = self.trap_state()
        return {"version": 1,
                "operators": list(self.operators),
                "n_shards": self.n_shards,
                "ages_s": np.asarray(st["ages_s"], np.float64).tolist(),
                "dv_mv": np.asarray(st["dv"], np.float64).tolist(),
                "rec_mv": np.asarray(st["rec"], np.float64).tolist(),
                "v": np.asarray(st["v"], np.float64).tolist()}

    def load_state_dict(self, d: Mapping[str, Any]) -> None:
        """Restore a :meth:`state_dict` snapshot.

        Old artifacts written before short-term recovery existed carry no
        ``rec_mv`` key — they load with a zero-filled recoverable pool
        (which is exact for any always-stressed or monotone history).
        The restored state is staged and consumed by the next
        ``apply_load`` resume.
        """
        ops = tuple(d.get("operators", self.operators))
        assert ops == self.operators, \
            f"operator mismatch: {ops} vs {self.operators}"
        assert int(d.get("n_shards", self.n_shards)) == self.n_shards
        dv = np.asarray(d["dv_mv"], np.float32)
        v = np.asarray(d["v"], np.float32)
        rec = (np.asarray(d["rec_mv"], np.float32) if "rec_mv" in d
               else np.zeros_like(dv))
        want = (self._n_units, len(self.operators), N_POP)
        assert dv.shape == want, f"dv shape {dv.shape} != {want}"
        assert rec.shape == want and v.shape == want[:2]
        self._ages_s[:] = np.asarray(d["ages_s"], np.float64)
        self._pending = {"dv": dv, "rec": rec, "v": v}
        self._invalidate()

    def resize(self, keep, n_fresh: int = 0) -> "FleetRuntime":
        """Trap-state-preserving fleet resize: retirement and hot-swap.

        ``keep`` lists the surviving device indices (in their new order);
        ``n_fresh`` appends that many factory-fresh devices.  Survivors
        carry their exact aging state — monotone shifts, recoverable
        pool, boosted supplies and service-time clocks — into the new
        fleet (staged; the next ``apply_load`` resumes from it
        bit-exactly).  Fresh devices start at age zero with zero trap
        state; on a heterogeneous (batched-scenario) fleet each fresh
        device inherits the mission profile of a retired slot — the
        hot-swap replacement sits in the same rack position, so it sees
        the same thermal row and budget.
        """
        assert self.n_shards == 1, \
            "resize is device-granular; reshape sharded fleets upstream"
        keep = np.asarray(keep, int)
        assert keep.size == np.unique(keep).size and \
            (keep < self.n_devices).all() and (keep >= 0).all()
        retired = np.asarray(
            [i for i in range(self.n_devices) if i not in set(keep.tolist())],
            int)
        n_new = int(keep.size + n_fresh)
        assert n_new >= 1
        if self._scenario_batched:
            slots = retired if retired.size else keep
            fresh_slots = np.resize(slots, n_fresh) if n_fresh else \
                np.empty(0, int)
            scn = self.scenario[np.concatenate([keep, fresh_slots])]
        else:
            scn = self.scenario
        new = FleetRuntime(self.cal, n_devices=n_new, scenario=scn,
                           policy=self.policy, operators=self.operators)
        st = self.trap_state()
        O = len(self.operators)
        dv = np.zeros((n_new, O, N_POP), np.float32)
        rec = np.zeros_like(dv)
        v = np.broadcast_to(
            np.asarray(scn.v_init, np.float32).reshape(-1, 1),
            (n_new, O)).copy()
        dv[:keep.size] = st["dv"][keep]
        rec[:keep.size] = st["rec"][keep]
        v[:keep.size] = st["v"][keep]
        new._ages_s[:keep.size] = self._ages_s[keep]
        new._pending = {"dv": dv, "rec": rec, "v": v}
        return new

    # ------------------------------------------------------------------ #
    def op_index(self, op: str) -> int:
        return self.operators.index(op)

    def domain_state(self, op: str, device: int = 0,
                     shard: int = 0) -> DomainState:
        return self.snapshot().domain(device * self.n_shards + shard,
                                      self.op_index(op))

    def op_ber(self, op: str, device: int = 0, shard=None) -> float:
        return self.op_bers(device, shard)[op]

    def op_bers(self, device: int = 0, shard=None) -> Dict[str, float]:
        """Per-operator BERs of one device (worst shard) or one shard."""
        if shard is None and self.n_shards > 1:
            ber = self.op_ber_array()[device]
        else:
            ber = self.snapshot().ber[device * self.n_shards + (shard or 0)]
        return {op: float(ber[i]) for i, op in enumerate(self.operators)}

    def op_ber_array(self) -> np.ndarray:
        """(N, O) BER matrix, columns ordered as ``self.operators``.

        The array-native accessor the fleet serving engine consumes: one
        snapshot hands every lane its per-operator BER vector without N x O
        scalar ``DeviceView`` round-trips.  When sharded (S > 1) each
        device's row is the per-domain **max over its shards** — the rate a
        shard-oblivious consumer must assume."""
        ber = self.snapshot().ber
        if self.n_shards == 1:
            return ber
        return ber.reshape(self.n_devices, self.n_shards, -1).max(axis=1)

    def op_ber_shard_array(self) -> np.ndarray:
        """(N, S, O) per-shard BER tensor — the mesh engine's native view."""
        return self.snapshot().ber.reshape(
            self.n_devices, self.n_shards, len(self.operators))

    def op_ber_jax(self):
        """(N, O) BERs as a cached ``jnp.float32`` array.

        jax-native twin of :meth:`op_ber_array` for consumers that feed the
        BERs straight into a jitted graph as a *traced leaf*: the
        device_put happens once per age change, not once per generate
        call, and no host numpy round-trip sits on the serve hot path."""
        if self._ber_jax is None:
            import jax.numpy as jnp
            self._ber_jax = jnp.asarray(self.op_ber_array(), jnp.float32)
        return self._ber_jax

    def op_ber_shard_jax(self):
        """(N, S, O) per-shard BERs as a cached ``jnp.float32`` array."""
        if self._ber_shard_jax is None:
            import jax.numpy as jnp
            self._ber_shard_jax = jnp.asarray(self.op_ber_shard_array(),
                                              jnp.float32)
        return self._ber_shard_jax

    def total_power(self, device: int = 0) -> float:
        return float(self.fleet_power()[device])

    def fleet_power(self) -> np.ndarray:
        """Per-device array power [W], shape (N,).

        Sharded fleets average the shard-domain voltages' array power —
        each shard is 1/S of the physical array, so the device draws the
        mean of the per-shard whole-array figures."""
        p = self.snapshot().power_w.sum(axis=-1)
        if self.n_shards == 1:
            return p
        return p.reshape(self.n_devices, self.n_shards).mean(axis=-1)

    def summary(self, device: int = 0, shard: int = 0) -> Mapping[str, Dict]:
        s = self.snapshot()
        unit = device * self.n_shards + shard
        return {op: dataclasses.asdict(s.domain(unit, i))
                for i, op in enumerate(self.operators)}

    def device(self, i: int = 0) -> "DeviceView":
        assert 0 <= i < self.n_devices
        return DeviceView(self, i)


class DeviceView:
    """Single-device facade over a :class:`FleetRuntime` — implements the
    legacy ``AgingAwareRuntime`` protocol the serving engine consumes."""

    def __init__(self, fleet: FleetRuntime, index: int):
        self.fleet = fleet
        self.index = index

    @property
    def cal(self) -> Calibration:
        return self.fleet.cal

    @property
    def operators(self) -> tuple:
        return self.fleet.operators

    @property
    def policy(self):
        return self.fleet.policy

    @property
    def age_years(self) -> float:
        return float(self.fleet._ages_s[
            self.index * self.fleet.n_shards]) / SECONDS_PER_YEAR

    def set_age(self, *, years=None, seconds=None):
        self.fleet.set_age(years=years, seconds=seconds, device=self.index)

    def advance(self, seconds):
        self.fleet.advance(seconds, device=self.index)

    def domain_state(self, op: str) -> DomainState:
        return self.fleet.domain_state(op, device=self.index)

    def op_ber(self, op: str) -> float:
        return self.fleet.op_ber(op, device=self.index)

    def op_bers(self) -> Dict[str, float]:
        return self.fleet.op_bers(device=self.index)

    def total_power(self) -> float:
        return self.fleet.total_power(device=self.index)

    def summary(self) -> Mapping[str, Dict]:
        return self.fleet.summary(device=self.index)
