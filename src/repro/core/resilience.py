"""DNN error-resilience characterisation (paper Sec. II-C / IV-B).

Two sources of the BER -> accuracy relationship:

1. **Published heterogeneity** ([14] REALM; paper Fig. 1b): tolerable BERs
   span 1e-7 .. 1e-3 across operators, with the attention *output* (O) and
   MLP *Down* projections most sensitive, K intermediate, and
   Q/V/QK^T/SV/Gate/Up tolerant.  These are the defaults used to reproduce
   Table II.

2. **Measured in-repo**: :func:`empirical_resilience` runs bit-error
   injection (``repro.kernels.bitflip``) on a model from the zoo and fits
   the same parametric curve — this is how a user recalibrates the policy
   for a new network (e.g. the attention-free RWKV6 projection set).

Parametric accuracy-loss curve (log-BER logistic, matches the knee shape of
Fig. 1b):

    loss(ber) = L_max / (1 + exp(-k * (log10(ber) - log10(ber50))))

``tolerable_ber(max_loss)`` inverts it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping

# Operator domains of the paper's Table II.
OPERATORS = ("q", "k", "v", "qkt", "sv", "o", "gate", "up", "down")

# Default per-operator BER at which accuracy loss hits 50% of L_max, from the
# REALM-style heterogeneity: sensitive O/Down, intermediate K, tolerant rest.
# The non-attention projection domains (DESIGN.md §Arch-applicability) map by
# role: output-side projections ("o") are sensitive, everything feeding a
# saturating gate/recurrence ("r", "g") is tolerant — consistent with [14]'s
# observation that sensitivity concentrates where errors propagate directly
# into the residual stream.
DEFAULT_BER50: Dict[str, float] = {
    "q": 3.2e-3, "k": 1.1e-4, "v": 3.2e-3, "qkt": 3.2e-3, "sv": 3.2e-3,
    "o": 7.0e-7, "gate": 3.2e-3, "up": 3.2e-3, "down": 6.0e-6,
    "r": 3.2e-3, "g": 3.2e-3, "router": 1.1e-4, "embed": 3.2e-3,
}
DEFAULT_STEEPNESS = 5.0     # logistic slope in decades^-1
DEFAULT_LMAX = 100.0        # accuracy collapses to chance at high BER [%]

# Operator-domain sets per architecture family (§Arch-applicability): the
# paper's 9 attention-LM rows apply directly to dense/MoE/hybrid/encdec/vlm
# archs; attention-free families degenerate to their projection set (the
# qkt/sv rows are vacuous — the *policy* is unchanged).
FAMILY_OPERATORS: Dict[str, tuple] = {
    "dense": OPERATORS,
    "moe": OPERATORS + ("router",),
    "hybrid": OPERATORS + ("r", "g"),              # rg-lru gates + local attn
    "encdec": OPERATORS,
    "vlm": OPERATORS,
    "ssm": ("q", "k", "v", "g", "o", "up", "down", "r"),   # rwkv projections
}


def operators_for(family: str) -> tuple:
    return FAMILY_OPERATORS.get(family, OPERATORS)


@dataclasses.dataclass(frozen=True)
class ResilienceCurve:
    ber50: float
    steepness: float = DEFAULT_STEEPNESS
    l_max: float = DEFAULT_LMAX

    def accuracy_loss(self, ber: float) -> float:
        """Accuracy loss [%] at a given BER."""
        if ber <= 0.0:
            return 0.0
        x = self.steepness * (math.log10(ber) - math.log10(self.ber50))
        return self.l_max / (1.0 + math.exp(-min(max(x, -60.0), 60.0)))

    def tolerable_ber(self, max_loss_pct: float = 0.5) -> float:
        """Largest BER with accuracy loss <= max_loss_pct [%]."""
        frac = max_loss_pct / self.l_max
        frac = min(max(frac, 1e-9), 1.0 - 1e-9)
        x = math.log(frac / (1.0 - frac))
        return 10.0 ** (math.log10(self.ber50) + x / self.steepness)


def default_curves(ops: tuple = OPERATORS) -> Dict[str, ResilienceCurve]:
    return {op: ResilienceCurve(ber50=DEFAULT_BER50[op]) for op in ops}


def tolerable_bers(curves: Mapping[str, ResilienceCurve] | None = None,
                   max_loss_pct: float = 0.5) -> Dict[str, float]:
    curves = curves or default_curves()
    return {op: c.tolerable_ber(max_loss_pct) for op, c in curves.items()}


def fit_curve(bers, losses, l_max: float = DEFAULT_LMAX) -> ResilienceCurve:
    """Fit the logistic curve to measured (BER, loss%) pairs.

    Simple two-parameter grid + refinement — robust for the handful of
    injection points an empirical sweep produces.
    """
    import numpy as np
    bers = np.asarray(bers, np.float64)
    losses = np.asarray(losses, np.float64)
    lb = np.log10(np.maximum(bers, 1e-12))

    def sse(log_ber50, k):
        x = k * (lb - log_ber50)
        pred = l_max / (1.0 + np.exp(-np.clip(x, -60, 60)))
        return float(((pred - losses) ** 2).sum())

    best = (math.inf, -4.0, DEFAULT_STEEPNESS)
    for log_b50 in np.linspace(-9, -1, 81):
        for k in (1.0, 2.0, 3.5, 5.0, 8.0, 12.0):
            e = sse(log_b50, k)
            if e < best[0]:
                best = (e, log_b50, k)
    return ResilienceCurve(ber50=10.0 ** best[1], steepness=best[2],
                           l_max=l_max)
