"""Serving-time integration of the AVS policy (framework feature layer).

An :class:`AgingAwareRuntime` owns one *voltage domain per operator class*
(the paper's Table II rows: q, k, v, qkt, sv, o, gate, up, down).  The
runtime advances simulated device age, and for the current age exposes each
operator's supply voltage, aging state, BER and power draw.  The serving
engine (``repro.serve``) queries :meth:`op_ber` to drive the bit-error
injection kernels, so a model served on an "old" device sees exactly the
per-operator error rates the policy admits.

All trajectories come from ONE vmapped lifetime scan, computed lazily and
cached; age lookups are O(log n) searchsorted on the log time grid.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

import numpy as np

from .artifacts import Calibration, load_calibration
from .avs import run_lifetime
from .policy import BaselinePolicy, FaultTolerantPolicy
from .power import PowerModel
from .resilience import OPERATORS

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0


@dataclasses.dataclass
class DomainState:
    """Snapshot of one operator voltage domain at the current age."""
    v_dd: float
    delay: float
    dvth_p_mv: float
    dvth_n_mv: float
    ber: float
    power_w: float


class AgingAwareRuntime:
    def __init__(self, cal: Optional[Calibration] = None, *,
                 fault_tolerant: bool = True, max_loss_pct: float = 0.5,
                 operators: tuple[str, ...] = OPERATORS, curves=None):
        self.cal = cal or load_calibration()
        self.operators = operators
        if fault_tolerant:
            self.policy = FaultTolerantPolicy(ber_model=self.cal.ber,
                                              max_loss_pct=max_loss_pct,
                                              curves=curves)
        else:
            self.policy = BaselinePolicy(t_clk=self.cal.lifetime_cfg.t_clk)
        dmax_map = self.policy.delay_max()
        self._dmax = np.asarray([dmax_map.get(op, self.cal.lifetime_cfg.t_clk)
                                 for op in operators], np.float32)
        self._age_s = 0.0
        self._trajs = None

    @classmethod
    def for_model(cls, cfg, **kw) -> "AgingAwareRuntime":
        """Runtime with the architecture family's operator-domain set
        (DESIGN.md §Arch-applicability): attention-free families get their
        projection domains instead of the vacuous qkt/sv rows."""
        from .resilience import default_curves, operators_for
        ops = operators_for(cfg.family)
        return cls(operators=ops, curves=default_curves(ops), **kw)

    # ------------------------------------------------------------------ #
    def _ensure_trajs(self):
        if self._trajs is None:
            trajs = run_lifetime(self.cal.aging, self.cal.delay_poly,
                                 self.cal.lifetime_cfg, delay_max=self._dmax)
            self._trajs = {k: np.asarray(v) for k, v in trajs.items()}
        return self._trajs

    def set_age(self, *, years: float = None, seconds: float = None):
        assert (years is None) != (seconds is None)
        self._age_s = float(seconds if seconds is not None
                            else years * SECONDS_PER_YEAR)

    @property
    def age_years(self) -> float:
        return self._age_s / SECONDS_PER_YEAR

    def advance(self, seconds: float):
        self._age_s += float(seconds)

    # ------------------------------------------------------------------ #
    def domain_state(self, op: str) -> DomainState:
        trajs = self._ensure_trajs()
        i = self.operators.index(op)
        t = trajs["t"][i] if trajs["t"].ndim == 2 else trajs["t"]
        k = int(np.clip(np.searchsorted(t, max(self._age_s, t[0])), 0,
                        len(t) - 1))
        v = float(trajs["V"][i, k])
        delay = float(trajs["delay"][i, k])
        dvp = float(trajs["dvp"][i, k])
        dvn = float(trajs["dvn"][i, k])
        power = PowerModel.from_dict(self.cal.power.to_dict()) \
            .power(v, dvp, dvn)
        return DomainState(
            v_dd=v, delay=delay, dvth_p_mv=dvp, dvth_n_mv=dvn,
            ber=float(self.cal.ber.ber_from_delay(delay)),
            power_w=float(power),
        )

    def op_ber(self, op: str) -> float:
        """Current BER the policy admits for this operator domain."""
        return self.domain_state(op).ber

    def op_bers(self) -> Dict[str, float]:
        return {op: self.op_ber(op) for op in self.operators}

    def total_power(self) -> float:
        return sum(self.domain_state(op).power_w for op in self.operators)

    def summary(self) -> Mapping[str, Dict]:
        return {op: dataclasses.asdict(self.domain_state(op))
                for op in self.operators}
