"""Serving-time integration of the AVS policy (legacy single-device shim).

:class:`AgingAwareRuntime` keeps the original one-device API — one *voltage
domain per operator class* (the paper's Table II rows: q, k, v, qkt, sv, o,
gate, up, down) with simulated age, per-operator supply voltage, aging
state, BER and power draw — but is now a thin facade over the vectorised
:class:`repro.core.fleet.FleetRuntime` with ``n_devices=1``.  All
trajectories come from ONE vmapped lifetime scan (computed lazily, cached),
age lookups are vectorised, and the power model is built once at
construction (it used to be re-deserialised per ``domain_state`` call).

New code should use :class:`~repro.core.fleet.FleetRuntime` directly; see
DESIGN.md §Scenario/Policy/FleetRuntime and §Migration.
"""
from __future__ import annotations

from typing import Optional

from .artifacts import Calibration, load_calibration
from .constants import DEFAULT_MAX_LOSS_PCT
from .fleet import SECONDS_PER_YEAR  # noqa: F401  (re-export, legacy import path)
from .fleet import DeviceView, DomainState, FleetRuntime  # noqa: F401
from .resilience import OPERATORS


class AgingAwareRuntime(DeviceView):
    def __init__(self, cal: Optional[Calibration] = None, *,
                 fault_tolerant: bool = True,
                 max_loss_pct: float = DEFAULT_MAX_LOSS_PCT,
                 operators: tuple[str, ...] = OPERATORS, curves=None):
        cal = cal or load_calibration()
        fleet = FleetRuntime(
            cal, n_devices=1,
            policy="fault_tolerant" if fault_tolerant else "baseline",
            max_loss_pct=max_loss_pct, operators=operators, curves=curves)
        super().__init__(fleet, 0)

    @classmethod
    def for_model(cls, cfg, **kw) -> "AgingAwareRuntime":
        """Runtime with the architecture family's operator-domain set
        (DESIGN.md §Arch-applicability): attention-free families get their
        projection domains instead of the vacuous qkt/sv rows."""
        from .resilience import default_curves, operators_for
        ops = operators_for(cfg.family)
        return cls(operators=ops, curves=default_curves(ops), **kw)
