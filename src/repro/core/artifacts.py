"""Load the checked-in calibration artifact into live model objects."""
from __future__ import annotations

import dataclasses
import json
import os
from functools import lru_cache
from typing import Any, Dict

from .aging import AgingParams
from .avs import LifetimeConfig
from .ber import BerModel
from .delay import DelayPolynomial, PathModel
from .power import PowerModel

CAL_PATH = os.path.join(os.path.dirname(__file__), "calibrated.json")


@dataclasses.dataclass(frozen=True)
class Calibration:
    aging: AgingParams
    path_model: PathModel
    delay_poly: DelayPolynomial
    ber: BerModel
    power: PowerModel
    lifetime_cfg: LifetimeConfig
    raw: Dict[str, Any]


@lru_cache(maxsize=1)
def load_calibration(path: str = CAL_PATH) -> Calibration:
    with open(path) as f:
        blob = json.load(f)
    return Calibration(
        aging=AgingParams.from_dict(blob["aging"]),
        path_model=PathModel.from_dict(blob["path_model"]),
        delay_poly=DelayPolynomial.from_dict(blob["delay_poly"]),
        ber=BerModel.from_dict(blob["ber"]),
        power=PowerModel.from_dict(blob["power"]),
        lifetime_cfg=LifetimeConfig(**blob["lifetime_cfg"]),
        raw=blob,
    )
