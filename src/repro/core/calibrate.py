"""One-shot calibration of the aging/delay/BER/power models.

Run as ``PYTHONPATH=src python -m repro.core.calibrate``; writes
``src/repro/core/calibrated.json`` (checked in — tests and benchmarks load it).

Calibration philosophy (DESIGN.md Sec. 2): the paper uses a commercial 14 nm
PDK whose aging coefficients are proprietary.  We therefore keep the *model
forms* of Fig. 2 and calibrate their free scale factors against the paper's
own Table I **rows 1-3** (constant-voltage scenarios).  Row 4 — the AVS
history-aware estimate — and all of Table II are then *predictions* of the
framework, compared against the paper in EXPERIMENTS.md.

Steps
-----
1. **Aging populations** — analytic: voltage-acceleration ``B`` per mechanism
   from the V_max/V_nom ratios (self-heating included, 1-D root solve);
   detrapping efficiencies ``chi`` from the recovery rows; prefactors ``A``
   from the absolute V_nom magnitudes.
2. **Delay-model knobs** — (alpha, vth0, wire_frac, pn_split) searched so the
   *baseline AVS run* reproduces the paper's trajectory: V reaches 1.02 V at
   10 years with ΔVth_p ≈ 105.3 mV / ΔVth_n ≈ 85.1 mV.  The 6th-degree
   polynomial is refitted per candidate (the paper's Sec. III-D step).
3. **Per-operator delay thresholds** — bisect ``delay_max`` to hit Table II's
   final voltages (K: 0.94, Down: 0.99, O: 1.01), then fit the BER-curve
   parameters (tau, c_ber, spread) so that inverting the *resilience*
   tolerable-BERs lands on those thresholds.  The "other" operators
   (Q/V/QK^T/SV/Gate/Up) must never trigger at 0.90 V — enforced as a
   constraint.
4. **Power** — 2x2 linear solve against Table II's anchors (0.85 W @ 0.90 V
   lifetime, 1.03 W baseline-AVS lifetime).
"""
from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import aging
from .aging import AgingParams, POPULATIONS
from .avs import LifetimeConfig, run_lifetime, final_shifts
from .ber import BerModel, solve_ber_model
from .constants import KB_EV, T_AMB, T_CLK, V_MAX, V_NOM, LIFETIME_S
from .delay import PathModel, fit_delay_polynomial
from .power import calibrate_power, lifetime_stats
from .resilience import OPERATORS, default_curves, tolerable_bers

CAL_PATH = os.path.join(os.path.dirname(__file__), "calibrated.json")

# ------------------------- Table I targets (mV) -----------------------------
TAB1 = {
    "pmos_bti": {"nom_norec": 62.2, "nom_rec": 54.9, "vmax_norec": 103.4},
    "pmos_hci": {"nom_norec": 19.8, "nom_rec": 18.2, "vmax_norec": 27.3},
    "nmos_hci": {"nom_norec": 50.5, "nom_rec": 46.1, "vmax_norec": 105.2},
}
TAB1_AVS = {"pmos": 105.3, "nmos": 85.1}      # predicted, not fitted
# ------------------------- Table II targets ---------------------------------
TAB2_VFINAL = {"k": 0.94, "o": 1.01, "down": 0.99}
TAB2_POWER = {"nom": 0.85, "avs": 1.03}

# population structure: (mechanism, share of mechanism total, n, Ea)
POP_STRUCT = {
    "pmos_bti_fast": ("pmos_bti", 0.45, 0.12, 0.06),
    "pmos_bti_slow": ("pmos_bti", 0.55, 0.22, 0.08),
    "pmos_hci_it":   ("pmos_hci", 0.60, 0.45, 0.05),
    "pmos_hci_ot":   ("pmos_hci", 0.40, 0.30, 0.05),
    "nmos_hci_it":   ("nmos_hci", 0.60, 0.45, 0.05),
    "nmos_hci_ot":   ("nmos_hci", 0.40, 0.30, 0.05),
}
# recovery multiplier of the *fast/recoverable* population per mechanism; the
# other population's multiplier is solved from the mechanism total.
FAST_REC_MULT = {"pmos_bti": 0.78}
DT_SH = 8.0


def _solve_B(ratio: float, ea: float, dt_sh: float = DT_SH) -> float:
    """Solve K(V_MAX)/K(V_NOM) = ratio for B, with self-heating in T."""
    from scipy.optimize import brentq

    def f(b):
        def k(v):
            T = T_AMB + dt_sh * (v / V_NOM) ** 2
            return np.exp(b * v) * np.exp(-ea / (KB_EV * T))
        return k(V_MAX) / k(V_NOM) - ratio

    return float(brentq(f, 0.01, 60.0))


def calibrate_aging() -> AgingParams:
    names = list(POPULATIONS)
    A = np.zeros(6)
    B = np.zeros(6)
    Ea = np.zeros(6)
    n = np.zeros(6)
    chi = np.zeros(6)

    # mechanism-level voltage acceleration and recovery split
    mech_ratio = {m: TAB1[m]["vmax_norec"] / TAB1[m]["nom_norec"] for m in TAB1}
    mech_recmult = {m: TAB1[m]["nom_rec"] / TAB1[m]["nom_norec"] for m in TAB1}

    for i, name in enumerate(names):
        mech, share, n_i, ea_i = POP_STRUCT[name]
        n[i], Ea[i] = n_i, ea_i
        B[i] = _solve_B(mech_ratio[mech], ea_i)

    # per-population recovery multipliers -> chi
    for mech in TAB1:
        idxs = [i for i, nm in enumerate(names) if POP_STRUCT[nm][0] == mech]
        shares = np.array([POP_STRUCT[names[i]][1] for i in idxs])
        total_mult = mech_recmult[mech]
        if mech == "pmos_bti":
            m_fast = FAST_REC_MULT[mech]
            m_slow = (total_mult - shares[0] * m_fast) / shares[1]
            mults = [m_fast, m_slow]
        else:
            # interface traps permanent (mult 1), oxide traps recoverable
            m_ot = (total_mult - shares[0] * 1.0) / shares[1]
            mults = [1.0, m_ot]
        for i, m in zip(idxs, mults):
            if m >= 1.0 - 1e-9:
                chi[i] = 0.0
                continue
            n_i = n[i]
            R = m ** (1.0 / n_i)
            if aging.IS_BTI[i]:
                act = 0.5
            else:
                from .constants import TOGGLE_RATE, TRANSITION_TIME
                act = TOGGLE_RATE * TRANSITION_TIME / T_CLK
            chi[i] = (1.0 / R - 1.0) * act / (1.0 - act)

    params = AgingParams(A=jnp.ones(6), B=jnp.asarray(B, jnp.float32),
                         Ea=jnp.asarray(Ea, jnp.float32),
                         n=jnp.asarray(n, jnp.float32),
                         chi=jnp.asarray(chi, jnp.float32), dT_sh=DT_SH)
    # prefactors from the absolute no-recovery magnitudes at V_NOM
    rates = np.asarray(aging.stress_rates(params, recovery=False), np.float64)
    T_nom = T_AMB + DT_SH
    for i, name in enumerate(names):
        mech, share, n_i, ea_i = POP_STRUCT[name]
        target = share * TAB1[mech]["nom_norec"]
        k_noA = np.exp(B[i] * V_NOM) * np.exp(-ea_i / (KB_EV * T_nom))
        A[i] = target / (k_noA * (rates[i] * LIFETIME_S) ** n_i)
    return AgingParams(A=jnp.asarray(A, jnp.float32),
                       B=jnp.asarray(B, jnp.float32),
                       Ea=jnp.asarray(Ea, jnp.float32),
                       n=jnp.asarray(n, jnp.float32),
                       chi=jnp.asarray(chi, jnp.float32), dT_sh=DT_SH)


def verify_table1(params: AgingParams, poly, cfg: LifetimeConfig) -> Dict:
    """Reproduce all four Table I rows with the lifetime simulator."""
    rows = {}
    # rows 1-2: constant V_NOM (AVS off)
    for rec, key in ((False, "nom_norec"), (True, "nom_rec")):
        traj = run_lifetime(params, poly, cfg, recovery=rec, avs_enabled=False)
        fs = final_shifts(traj)
        pops = np.asarray(traj["dv"])[-1]
        rows[key] = {
            "pmos_total": fs["dvp"], "nmos": fs["dvn"],
            "pmos_hci": float(pops[2] + pops[3]),
            "pmos_bti": float(pops[0] + pops[1]),
        }
    # row 3: constant V_MAX, no recovery
    cfg_max = LifetimeConfig(**{**cfg.__dict__, "v_init": V_MAX})
    traj = run_lifetime(params, poly, cfg_max, recovery=False,
                        avs_enabled=False)
    fs = final_shifts(traj)
    pops = np.asarray(traj["dv"])[-1]
    rows["vmax_norec"] = {
        "pmos_total": fs["dvp"], "nmos": fs["dvn"],
        "pmos_hci": float(pops[2] + pops[3]),
        "pmos_bti": float(pops[0] + pops[1]),
    }
    # row 4: full AVS with recovery (delay_max = t_clk) — the prediction
    traj = run_lifetime(params, poly, cfg, delay_max=cfg.t_clk, recovery=True)
    fs = final_shifts(traj)
    pops = np.asarray(traj["dv"])[-1]
    rows["avs"] = {
        "pmos_total": fs["dvp"], "nmos": fs["dvn"],
        "pmos_hci": float(pops[2] + pops[3]),
        "pmos_bti": float(pops[0] + pops[1]),
        "v_final": fs["v_final"],
    }
    return rows


def calibrate_delay_knobs(params: AgingParams, cfg: LifetimeConfig):
    """Search (alpha, vth0, wire_frac, pn_split) for the AVS-row prediction."""
    from scipy.optimize import minimize

    # the polynomial is the traced argument; everything else is closed over
    run = jax.jit(lambda po: run_lifetime(params, po, cfg,
                                          delay_max=cfg.t_clk, recovery=True))

    def objective(x):
        alpha, vth0, wire, pn = x
        if not (1.0 <= alpha <= 1.6 and 0.20 <= vth0 <= 0.52
                and 0.05 <= wire <= 0.55 and 0.25 <= pn <= 0.75):
            return 1e3
        pm = PathModel(alpha=float(alpha), vth_p0=float(vth0),
                       vth_n0=float(vth0) - 0.02, wire_frac=float(wire),
                       pn_split=float(pn))
        poly = fit_delay_polynomial(pm)
        traj = run(poly)
        v = np.asarray(traj["V"])
        dvp, dvn = float(np.asarray(traj["dvp"])[-1]), float(
            np.asarray(traj["dvn"])[-1])
        t = np.asarray(traj["t"])
        # time at which V_MAX was first reached (inf if never)
        hit = np.nonzero(v >= V_MAX - 1e-6)[0]
        t_hit = t[hit[0]] if hit.size else np.inf
        loss = ((dvp - TAB1_AVS["pmos"]) / TAB1_AVS["pmos"]) ** 2 \
            + ((dvn - TAB1_AVS["nmos"]) / TAB1_AVS["nmos"]) ** 2
        loss += (10.0 * (V_MAX - v[-1])) ** 2          # must end at 1.02
        if np.isfinite(t_hit) and t_hit < 0.2 * LIFETIME_S:
            loss += (0.2 - t_hit / LIFETIME_S) ** 2 * 10.0   # not too early
        return float(loss)

    # coarse grid then Nelder-Mead
    best, best_x = np.inf, None
    for alpha in (1.15, 1.3, 1.45):
        for vth0 in (0.30, 0.38, 0.46):
            for wire in (0.15, 0.30, 0.45):
                for pn in (0.40, 0.55):
                    x = np.array([alpha, vth0, wire, pn])
                    l = objective(x)
                    if l < best:
                        best, best_x = l, x
    res = minimize(objective, best_x, method="Nelder-Mead",
                   options={"maxiter": 250, "xatol": 1e-3, "fatol": 1e-5})
    x = res.x if res.fun < best else best_x
    alpha, vth0, wire, pn = [float(v) for v in x]
    pm = PathModel(alpha=alpha, vth_p0=vth0, vth_n0=vth0 - 0.02,
                   wire_frac=wire, pn_split=pn)
    return pm, fit_delay_polynomial(pm), float(min(res.fun, best))


def find_delay_max_for_vfinal(params, poly, cfg, v_target: float,
                              hi: float = 1.80e-9) -> float:
    """Bisect delay_max so the lifetime ends at v_target (monotone, step)."""
    run = jax.jit(lambda d: run_lifetime(params, poly, cfg, delay_max=d,
                                         recovery=True))
    lo_, hi_ = cfg.t_clk, hi
    for _ in range(48):
        mid = 0.5 * (lo_ + hi_)
        vf = float(np.asarray(run(jnp.asarray(mid, jnp.float32))["V"])[-1])
        if vf > v_target + 1e-4:
            lo_ = mid
        else:
            hi_ = mid
    return 0.5 * (lo_ + hi_)


def calibrate_ber(dmax_targets: Dict[str, float], d_never: float) -> BerModel:
    """Solve the BER curve through the (delay_max, tolerable-BER) anchors.

    The three constrained operators (O, Down, K) pin the curve exactly; the
    tolerant operators' tolerance must then exceed the curve's value at the
    end-of-life 0.90 V delay ``d_never`` (they never trigger — paper
    Sec. V-C) which we verify.
    """
    tols = tolerable_bers(max_loss_pct=0.5)
    anchors = {dmax_targets[op]: tols[op] for op in ("o", "down", "k")}
    bm = solve_ber_model(anchors)
    ber_eol = float(bm.ber_from_delay(d_never))
    if ber_eol >= tols["q"]:
        raise RuntimeError(
            f"tolerant operators would trigger: BER(EOL)={ber_eol:.3g} "
            f">= tol {tols['q']:.3g}")
    resid = max(abs(float(bm.log10_ber_from_delay(d)) - np.log10(b))
                for d, b in anchors.items())
    return bm, float(resid)


def main(out_path: str = CAL_PATH) -> Dict:
    cfg = LifetimeConfig()
    print("[1/4] calibrating aging populations against Table I rows 1-3 ...")
    params = calibrate_aging()

    print("[2/4] searching delay-model knobs for the AVS-row prediction ...")
    path_model, poly, dloss = calibrate_delay_knobs(params, cfg)
    print(f"      knobs: alpha={path_model.alpha:.3f} vth0={path_model.vth_p0:.3f} "
          f"wire={path_model.wire_frac:.3f} pn={path_model.pn_split:.3f} "
          f"(loss {dloss:.4g}, poly RMSE {poly.rmse*1e9:.3g} ns)")
    tab1 = verify_table1(params, poly, cfg)
    print(f"      Table I check: {json.dumps(tab1, indent=2)}")

    print("[3/4] calibrating per-operator thresholds / BER curve ...")
    dmax_targets = {op: find_delay_max_for_vfinal(params, poly, cfg, v)
                    for op, v in TAB2_VFINAL.items()}
    # end-of-life delay at fixed 0.90 V (with recovery)
    nom = run_lifetime(params, poly, cfg, recovery=True, avs_enabled=False)
    d_never = float(np.asarray(nom["delay"])[-1])
    ber_model, bloss = calibrate_ber(dmax_targets, d_never)
    print(f"      dmax targets: { {k: f'{v*1e9:.4f}ns' for k, v in dmax_targets.items()} }"
          f" d_never={d_never*1e9:.4f}ns (loss {bloss:.4g})")

    print("[4/4] calibrating the power model ...")
    traj_nom = {k: np.asarray(v) for k, v in nom.items()}
    base = run_lifetime(params, poly, cfg, delay_max=cfg.t_clk, recovery=True)
    traj_avs = {k: np.asarray(v) for k, v in base.items()}
    power = calibrate_power(traj_nom, traj_avs, TAB2_POWER["nom"],
                            TAB2_POWER["avs"])

    blob = {
        "aging": params.to_dict(),
        "path_model": path_model.to_dict(),
        "delay_poly": poly.to_dict(),
        "ber": ber_model.to_dict(),
        "power": power.to_dict(),
        "lifetime_cfg": {k: (v if not isinstance(v, np.generic) else float(v))
                         for k, v in cfg.__dict__.items()},
        "table1_check": tab1,
        "dmax_targets": {k: float(v) for k, v in dmax_targets.items()},
        "tolerable_ber": tolerable_bers(max_loss_pct=0.5),
    }
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"wrote {out_path}")
    return blob


if __name__ == "__main__":
    main()
