"""Timing-error / BER modelling (paper Sec. IV-A).

Under the paper's *uniform aging* first-order approximation all worst-path
delays scale with one global aging indicator, so the BER is a monotone
function of the (polynomial) critical-path delay ``d``.  Counting
sensitisation-weighted violating paths produces a curve whose log-slope is
steep just past the clock edge (the critical path and its near-critical
neighbours cross quickly) and flattens as the population and its activity
thin out — i.e. a saturating form.  We use its smooth closed form

    log10 BER(d) = log10(BER_sat) - a * exp(-(d - t_clk) / tau)

* ``BER_sat`` — sensitisation-weighted saturation rate (all worst paths
  violating; per-path activation probabilities are the 0.006-0.009
  toggle statistics of Sec. III-E, orders of magnitude below 1 — paths are
  rarely fully sensitised, cf. CLIM [12]);
* ``a``       — decades of BER dynamic range across the aging swing;
* ``tau``     — delay scale over which the violating-path mass accrues.

For ``d < t_clk`` the expression dives double-exponentially — no timing
errors with positive slack.  The three parameters are calibrated jointly
with the fault-tolerant policy (paper Sec. IV-B): inverting the curve at
the per-operator tolerable BERs must land on the delay thresholds that
reproduce Table II's final voltages.  The curve is analytically invertible,
which the policy uses directly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from .constants import T_CLK

# Cap for operators whose tolerable BER exceeds BER_sat: their threshold is
# unreachable ("path delay never reaches the maximum tolerable threshold",
# paper Sec. V-C).  Any value beyond the end-of-life delay works; we keep it
# finite for the vmapped simulator.
DELAY_MAX_CAP = 2.2e-9


@dataclasses.dataclass
class BerModel:
    log10_sat: float = -4.7     # log10 saturation BER
    a: float = 7.0              # dynamic range [decades]
    tau: float = 30.0e-12       # delay scale [s]
    t_clk: float = T_CLK

    def log10_ber_from_delay(self, d):
        d = jnp.asarray(d)
        return self.log10_sat - self.a * jnp.exp(-(d - self.t_clk) / self.tau)

    def ber_from_delay(self, d):
        """BER as a function of the aged critical-path delay [s]."""
        return 10.0 ** self.log10_ber_from_delay(d)

    def delay_max_for_ber(self, ber_tol: float) -> float:
        """Invert BER(d) -> delay threshold [s] (clamped to [t_clk, CAP])."""
        gap = self.log10_sat - math.log10(max(ber_tol, 1e-30))
        if gap <= 0.0:          # tolerance above saturation: never reached
            return DELAY_MAX_CAP
        d = self.t_clk - self.tau * math.log(gap / self.a)
        return float(min(max(d, self.t_clk), DELAY_MAX_CAP))

    def delay_for_ber(self, ber_tol):
        """Traced (jnp) form of :meth:`delay_max_for_ber` — batches over a
        tolerable-BER array so policy thresholds vmap over accuracy budgets."""
        ber_tol = jnp.asarray(ber_tol)
        gap = self.log10_sat - jnp.log10(jnp.maximum(ber_tol, 1e-30))
        d = self.t_clk - self.tau * jnp.log(jnp.maximum(gap, 1e-30) / self.a)
        return jnp.where(gap <= 0.0, DELAY_MAX_CAP,
                         jnp.clip(d, self.t_clk, DELAY_MAX_CAP))

    def to_dict(self) -> Dict[str, Any]:
        return {"log10_sat": float(self.log10_sat), "a": float(self.a),
                "tau": float(self.tau), "t_clk": float(self.t_clk)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BerModel":
        return cls(**d)


def solve_ber_model(anchors: Dict[float, float], *, t_clk: float = T_CLK,
                    sat_cap: float | None = None) -> BerModel:
    """Solve (log10_sat, a, tau) through three (delay, BER) anchors.

    ``anchors`` maps delay [s] -> BER.  With exactly three anchors the system
    is determined: the tau ratio equation is solved by bisection, then a and
    log10_sat follow linearly.  ``sat_cap`` (a BER) optionally enforces
    ``BER_sat <= sat_cap`` as a validity check (raises if violated).
    """
    (d1, b1), (d2, b2), (d3, b3) = sorted(anchors.items())
    l1, l2, l3 = (math.log10(b) for b in (b1, b2, b3))
    x1, x2, x3 = (d - t_clk for d in (d1, d2, d3))
    target = (l2 - l1) / (l3 - l2)

    def ratio(tau):
        e1, e2, e3 = (math.exp(-x / tau) for x in (x1, x2, x3))
        return (e1 - e2) / max(e2 - e3, 1e-300)

    lo, hi = 1e-12, 5e-9
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        if ratio(mid) > target:
            lo = mid
        else:
            hi = mid
    tau = math.sqrt(lo * hi)
    e1, e2 = math.exp(-x1 / tau), math.exp(-x2 / tau)
    a = (l2 - l1) / (e1 - e2)
    log10_sat = l1 + a * e1
    if sat_cap is not None and log10_sat > math.log10(sat_cap):
        raise ValueError(
            f"BER saturation 1e{log10_sat:.2f} exceeds cap {sat_cap:g}")
    return BerModel(log10_sat=log10_sat, a=a, tau=tau, t_clk=t_clk)
