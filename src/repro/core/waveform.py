"""Equivalent-waveform construction and iterative extrapolation (Fig. 4 f-h).

Cycle-by-cycle simulation of BTI trapping/detrapping over a 10-year lifetime
is computationally prohibitive (the paper, Sec. III-E).  The paper's remedy:

1. Model one activity cycle as a *stress* phase of duration
   ``t_stress = t_clk / toggle_rate * duty`` at ``Vg = V_DD`` followed by a
   *recovery* phase ``t_recovery = t_clk / toggle_rate * (1 - duty)`` at
   ``Vg = 0``.
2. Replace N such cycles by a single equivalent cycle with an N-times longer
   period, choosing an effective stress voltage ``V_geff_stress`` and an
   effective recovery strength such that the trapping and detrapping
   endpoints match:

       dVth1 = f_trapping(V_geff_stress, t * duty)
       dVth2 = f_detrapping(dVth1, V_geff_recovery, t * (1 - duty))

3. Iterate (period doubling) until the full lifetime is covered (Fig. 4h).

Micro-kinetics used here:

* trapping: effective-time power law ``dv = K(V) * t_eff**n`` (same family as
  :mod:`repro.core.aging`);
* detrapping: universal relaxation [Grasser et al.],
  ``dv(t_r) = dv_s * (p + (1 - p) / (1 + c * xi**beta))`` with
  ``xi = t_r / t_s_eq`` the recovery-to-stress time ratio and ``p`` the
  permanent fraction.

The closed-form AC factor ``R(d) = d / (d + chi*(1-d))`` consumed by the
lifetime simulator is the converged limit of this procedure; the property
tests assert the extrapolation agrees with explicit cycle-by-cycle
simulation, and that the envelope behaves like a reduced-rate power law.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .constants import KB_EV, T_AMB


@dataclasses.dataclass(frozen=True)
class MicroTrapParams:
    """Single trap-population micro-kinetics."""
    A: float = 8.0e-3      # prefactor [mV / s**n]
    B: float = 4.2         # voltage acceleration [1/V]
    Ea: float = 0.08       # activation energy [eV]
    n: float = 0.14        # time exponent
    p_perm: float = 0.35   # permanent (non-recoverable) fraction
    c_rec: float = 0.9     # relaxation strength
    beta: float = 0.45     # relaxation stretch exponent


def _K(mp: MicroTrapParams, V, T=T_AMB):
    return mp.A * jnp.exp(mp.B * V) * jnp.exp(-mp.Ea / (KB_EV * T))


def f_trapping(mp: MicroTrapParams, dv, V, t_stress):
    """Stress continuation from current shift ``dv`` (effective-time method)."""
    K = _K(mp, V)
    t_eq = jnp.where(dv > 0, (dv / K) ** (1.0 / mp.n), 0.0)
    return K * (t_eq + t_stress) ** mp.n


def f_detrapping(mp: MicroTrapParams, dv, V_recovery, t_recovery, V_stress):
    """Universal-relaxation detrapping of the recoverable fraction.

    ``V_recovery`` shifts the relaxation balance: a non-zero effective
    recovery gate voltage slows detrapping (the paper's
    ``V_geff_recovery`` is "nonzero and chosen to match the recovery
    behavior of the original waveform").  We model that as scaling the
    relaxation ratio by ``exp(-B * V_recovery)``.
    """
    K = _K(mp, V_stress)
    t_s_eq = jnp.where(dv > 0, (dv / K) ** (1.0 / mp.n), 1e-30)
    xi = (t_recovery / jnp.maximum(t_s_eq, 1e-30)) * jnp.exp(-mp.B * V_recovery)
    frac = mp.p_perm + (1.0 - mp.p_perm) / (1.0 + mp.c_rec * xi ** mp.beta)
    return dv * frac


@partial(jax.jit, static_argnums=(0, 5))
def simulate_cycles(mp: MicroTrapParams, V, duty, period, dv0, n_cycles: int):
    """Explicit cycle-by-cycle stress/recovery simulation (lax.scan).

    Returns the shift at the end of every *recovery* phase (the envelope
    sampled once per cycle), shape ``(n_cycles,)``.
    """
    t_s = duty * period
    t_r = (1.0 - duty) * period

    def body(dv, _):
        dv1 = f_trapping(mp, dv, V, t_s)
        dv2 = f_detrapping(mp, dv1, 0.0, t_r, V)
        return dv2, dv2

    _, env = jax.lax.scan(body, dv0, None, length=n_cycles)
    return env


def equivalent_stress_voltage(mp: MicroTrapParams, dv1, t_stress, T=T_AMB):
    """Invert ``dv1 = K(V_geff) * t_stress**n`` for ``V_geff`` (paper Fig. 4f)."""
    arr = mp.A * jnp.exp(-mp.Ea / (KB_EV * T))
    return jnp.log(dv1 / (arr * t_stress ** mp.n)) / mp.B


def equivalent_recovery_voltage(mp: MicroTrapParams, dv1, dv2, t_recovery, V_stress):
    """Invert the detrapping relation for ``V_geff_recovery`` (paper Fig. 4g)."""
    K = _K(mp, V_stress)
    t_s_eq = (dv1 / K) ** (1.0 / mp.n)
    frac = dv2 / dv1
    # frac = p + (1-p) / (1 + c * xi**beta)  ->  xi
    inner = (1.0 - mp.p_perm) / jnp.maximum(frac - mp.p_perm, 1e-9) - 1.0
    xi = (jnp.maximum(inner, 1e-12) / mp.c_rec) ** (1.0 / mp.beta)
    # xi = (t_r / t_s_eq) * exp(-B * V_rec)  ->  V_rec
    return -jnp.log(xi * t_s_eq / t_recovery) / mp.B


def extrapolate(mp: MicroTrapParams, V, duty, period, total_time,
                n_base: int = 16):
    """Iterative period-doubling extrapolation (paper Fig. 4h).

    Simulates ``n_base`` explicit cycles, then repeatedly replaces the history
    by a single equivalent (stress, recovery) pair with doubled horizon until
    ``total_time`` is reached.  Returns the final shift [mV].
    """
    env = simulate_cycles(mp, V, duty, period, 0.0, n_base)
    dv2 = env[-1]
    t = n_base * period
    # also need the post-stress value of the last cycle for the equivalence
    dv1 = f_trapping(mp, env[-2] if n_base > 1 else 0.0, V, duty * period)

    while t < total_time:
        step = min(t, total_time - t)  # double, or finish exactly
        t_s, t_r = duty * step, (1.0 - duty) * step
        v_eff_s = equivalent_stress_voltage(mp, dv1, duty * t)
        v_eff_r = equivalent_recovery_voltage(mp, dv1, dv2, (1.0 - duty) * t, V)
        # apply one equivalent cycle covering [t, t + step]
        dv1 = f_trapping(mp, dv2, jnp.maximum(v_eff_s, V * 0.5), t_s)
        dv2 = f_detrapping(mp, dv1, v_eff_r, t_r, V)
        t = t + step
    return dv2


def ac_factor_empirical(mp: MicroTrapParams, V, duty, period, n_cycles: int):
    """Measured AC/DC ratio after ``n_cycles`` — used to validate the closed
    form ``R(d)**n`` consumed by :mod:`repro.core.aging`."""
    env = simulate_cycles(mp, V, duty, period, 0.0, n_cycles)
    dc = _K(mp, V) * (n_cycles * period) ** mp.n
    return env[-1] / dc
