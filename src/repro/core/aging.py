"""Compact BTI / HCI aging models with history-aware accumulation.

Model structure (paper Fig. 2 + Sec. III-E):

* **BTI** (PMOS NBTI; PBTI on NMOS is ignored per the paper / [6]): two trap
  populations, *fast* and *slow*.  Each population follows a voltage- and
  temperature-accelerated power law under DC stress

      dVth_i(t) = A_i * exp(B_i * V) * exp(-Ea_i / (kB * T)) * t**n_i   [mV]

  Under a duty-cycled workload the stress time accrues at ``duty`` per wall
  second; when detrapping (recovery) is modelled, the effective stress-time
  rate is further reduced by the capture/emission balance factor

      R_i(d) = d / (d + chi_i * (1 - d))

  so that ``dVth_AC / dVth_DC = R_i(d)**n_i``.  ``chi_i`` (detrapping
  efficiency) is large for fast traps and small for slow traps.  This closed
  form is the *converged limit* of the paper's iterative equivalent-waveform
  extrapolation (Fig. 4 f-h) — :mod:`repro.core.waveform` implements the
  explicit trapping/detrapping micro-kinetics and the period-doubling
  extrapolation, and the tests assert this closed form agrees with it.

* **HCI** (both devices): occurs only during output transitions.  Per the
  unified HCD model [7] we keep two populations: *interface traps* (permanent)
  and *oxide traps* (partially detrappable between stress events).  The
  effective stress-time rate per wall second is

      rate = gamma * (transition_time / t_clk) * toggle_rate

  which is the paper's accumulation formula; ``gamma`` maps the continuously
  varying gate voltage during a transition onto an equivalent full-V_DD
  stress interval (:func:`hci_gamma`).  Because the kinetics are a power law,
  the sub-interval summation of the paper's equation is performed in the
  *effective-time* domain (damage-equivalent time), which is the
  time-additive form of the same identity.

* **History (arbitrary waveforms)**: the AVS controller changes V_DD over
  life.  We accumulate each population with the effective-time method: given
  the population's current shift ``dv`` and the new segment voltage ``V``,

      t_eq = (dv / K(V, T))**(1 / n);   dv' = K(V, T) * (t_eq + rate*dt)**n

  i.e. the damage state is carried across voltage changes instead of being
  re-evaluated at a constant worst-case voltage.  This is the paper's central
  modelling claim (Table I row 4 vs row 3).

All functions are pure JAX and are used inside ``lax.scan`` in
:mod:`repro.core.avs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .constants import KB_EV, DUTY_FACTOR, TOGGLE_RATE, TRANSITION_TIME, T_CLK, T_AMB, V_NOM

# Population index layout (fixed order used by the vectorised state).
POPULATIONS = (
    "pmos_bti_fast",   # 0: NBTI fast traps   (recoverable)
    "pmos_bti_slow",   # 1: NBTI slow traps   (weakly recoverable)
    "pmos_hci_it",     # 2: PMOS HCI interface traps (permanent)
    "pmos_hci_ot",     # 3: PMOS HCI oxide traps     (partially recoverable)
    "nmos_hci_it",     # 4: NMOS HCI interface traps (permanent)
    "nmos_hci_ot",     # 5: NMOS HCI oxide traps     (partially recoverable)
)
N_POP = len(POPULATIONS)
# Which populations are BTI-like (stress during logic stability) vs HCI-like
# (stress during transitions).
IS_BTI = np.array([1, 1, 0, 0, 0, 0], dtype=bool)
# Populations whose shift adds to the PMOS ΔVth.
IS_PMOS = np.array([1, 1, 1, 1, 0, 0], dtype=bool)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AgingParams:
    """Vectorised per-population compact-model parameters (shape ``(6,)``)."""

    A: jnp.ndarray        # prefactor [mV / s**n]
    B: jnp.ndarray        # voltage acceleration [1/V]
    Ea: jnp.ndarray       # activation energy [eV]
    n: jnp.ndarray        # time exponent
    chi: jnp.ndarray      # detrapping efficiency (recovery strength)
    dT_sh: float = 8.0    # self-heating temperature rise at (V_NOM, nominal activity) [K]

    def tree_flatten(self):
        return ((self.A, self.B, self.Ea, self.n, self.chi), (self.dT_sh,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, dT_sh=aux[0])

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AgingParams":
        return cls(
            A=jnp.asarray(d["A"], jnp.float32),
            B=jnp.asarray(d["B"], jnp.float32),
            Ea=jnp.asarray(d["Ea"], jnp.float32),
            n=jnp.asarray(d["n"], jnp.float32),
            chi=jnp.asarray(d["chi"], jnp.float32),
            dT_sh=float(d.get("dT_sh", 8.0)),
        )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "A": np.asarray(self.A).tolist(),
            "B": np.asarray(self.B).tolist(),
            "Ea": np.asarray(self.Ea).tolist(),
            "n": np.asarray(self.n).tolist(),
            "chi": np.asarray(self.chi).tolist(),
            "dT_sh": float(self.dT_sh),
        }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RecoveryParams:
    """Short-term (partially recoverable) trap-component parameters.

    The compact model's six populations accumulate *monotonically* — the
    capture/emission balance in :func:`stress_rates` only slows accrual.
    Sarmadi et al. (PAPERS.md, "Long-Term and Short-Term Transistor
    Aging in DNNs") show that on top of that permanent trajectory sits a
    large short-term component that *relaxes during idle intervals*:
    detrapped charge returns on a timescale of hours once stress is
    removed, and is re-captured when stress resumes.  We model it as a
    recoverable pool ``rec`` riding on each population's monotone shift
    ``dv``:

        cap       = rho * dv                      (recoverable fraction)
        d rec/dt  = (1-act) * k_relax * (cap - rec) - act * k_retrap * rec

    with ``act`` the fraction of the interval under stress.  The
    *effective* threshold shift a device exhibits is ``dv - rec``
    (:func:`effective_dv`).  In the always-stressed limit (``act == 1``)
    the detrapping drive vanishes, ``rec`` stays pinned at zero and the
    effective shift collapses exactly onto the historical-effect
    recursion — the property the scheduler tests assert.

    All three leaves have shape ``(6,)`` (population order of
    :data:`POPULATIONS`); interface-trap populations are permanent
    (``rho == 0``).
    """

    rho: jnp.ndarray       # recoverable fraction of the accumulated shift
    k_relax: jnp.ndarray   # idle detrapping rate [1/s]
    k_retrap: jnp.ndarray  # re-capture rate under stress [1/s]

    def tree_flatten(self):
        return ((self.rho, self.k_relax, self.k_retrap), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def default(cls) -> "RecoveryParams":
        """Population-resolved defaults: fast NBTI traps relax within
        hours, slow traps over weeks, HCI interface traps never, HCI
        oxide traps partially.  Re-capture under stress is faster than
        relaxation (captured carriers refill emptied traps quickly)."""
        return cls(
            rho=jnp.asarray([0.45, 0.10, 0.0, 0.25, 0.0, 0.25],
                            jnp.float32),
            k_relax=jnp.asarray([2e-4, 2e-6, 0.0, 5e-5, 0.0, 5e-5],
                                jnp.float32),
            k_retrap=jnp.asarray([1e-3, 1e-5, 0.0, 2e-4, 0.0, 2e-4],
                                 jnp.float32),
        )

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RecoveryParams":
        return cls(rho=jnp.asarray(d["rho"], jnp.float32),
                   k_relax=jnp.asarray(d["k_relax"], jnp.float32),
                   k_retrap=jnp.asarray(d["k_retrap"], jnp.float32))

    def to_dict(self) -> Dict[str, Any]:
        return {"rho": np.asarray(self.rho).tolist(),
                "k_relax": np.asarray(self.k_relax).tolist(),
                "k_retrap": np.asarray(self.k_retrap).tolist()}


def relax_step(rparams: RecoveryParams, dv_mv: jnp.ndarray,
               rec_mv: jnp.ndarray, act, dt) -> jnp.ndarray:
    """Advance the recoverable pool over a wall-clock segment ``dt`` [s].

    Exact exponential step of the linear relaxation ODE (see
    :class:`RecoveryParams`): with ``a = k_relax*(1-act)`` and
    ``b = k_retrap*act`` the pool decays toward the split equilibrium
    ``rec_inf = a/(a+b) * rho*dv`` with rate ``a+b``.  Clipped into
    ``[0, rho*dv]`` so the effective shift ``dv - rec`` can never drop
    below the permanent floor ``(1-rho)*dv`` nor exceed the monotone
    stress trajectory ``dv``.  At ``act == 1`` the drive ``a`` is exactly
    zero, so a pool that starts empty stays bit-exactly empty — the
    always-stressed collapse.  Fully traceable; broadcasts over any
    leading (device, operator) axes.
    """
    act = jnp.clip(jnp.asarray(act, jnp.float32), 0.0, 1.0)
    a = rparams.k_relax * (1.0 - act)
    b = rparams.k_retrap * act
    lam = a + b
    cap = rparams.rho * dv_mv
    # a == 0 -> equilibrium 0 without dividing by a zero rate sum
    rec_inf = a * cap / jnp.maximum(lam, 1e-30)
    rec = rec_inf + (rec_mv - rec_inf) * jnp.exp(-lam * jnp.asarray(
        dt, jnp.float32))
    return jnp.clip(rec, 0.0, cap)


def effective_dv(dv_mv: jnp.ndarray, rec_mv) -> jnp.ndarray:
    """Exhibited threshold shift: monotone state minus the relaxed pool."""
    if rec_mv is None:
        return dv_mv
    return dv_mv - rec_mv


def self_heating_temp(V: jnp.ndarray, t_amb: float = T_AMB, dT_sh: float = 8.0,
                      v_ref: float = V_NOM) -> jnp.ndarray:
    """Channel temperature including the transient self-heating rise [9].

    Dissipated power scales ~V^2 for the dominant dynamic component, so the
    SHE temperature rise is modelled as ``dT_sh * (V / v_ref)**2``.
    """
    return t_amb + dT_sh * (V / v_ref) ** 2


def k_factor(params: AgingParams, V: jnp.ndarray, t_amb: float = T_AMB) -> jnp.ndarray:
    """Per-population power-law prefactor ``K_i(V, T)`` [mV / s**n_i]."""
    T = self_heating_temp(V, t_amb, params.dT_sh)
    return params.A * jnp.exp(params.B * V) * jnp.exp(-params.Ea / (KB_EV * T))


def hci_gamma(B: float, V: float, n: float, num: int = 256) -> float:
    """Equivalent-stress fraction of a transition (paper Sec. III-E, HCI eq.).

    The gate voltage ramps 0 -> V during a transition.  With power-law
    kinetics ``dv = K(Vg) * t**n``, damage over sub-intervals adds in the
    effective-time domain, so the interval equivalent at full V_DD is

        gamma = (1/tt) * \\int_0^tt (K(Vg(t)) / K(V))**(1/n) dt
              = (1/tt) * \\int_0^tt exp(B * (Vg(t) - V) / n) dt

    For a linear ramp this integrates to ``(1 - exp(-B*V/n)) / (B*V/n)``;
    we evaluate numerically so that arbitrary ramp shapes can be plugged in.
    """
    tgrid = np.linspace(0.0, 1.0, num)
    vg = tgrid * V  # linear ramp
    integrand = np.exp(B * (vg - V) / n)
    return float(np.trapezoid(integrand, tgrid))


def hci_gamma_closed(B, V, n):
    """Closed form of :func:`hci_gamma` for the linear ramp — pure JAX.

    ``gamma = (1 - exp(-B*V/n)) / (B*V/n)``, with the ``x -> 0`` limit
    handled so the expression stays traceable and NaN-free.  This is the
    analytic value the numeric integral of :func:`hci_gamma` converges to,
    and is what the traced simulator uses so that activity knobs can be
    batched (vmapped) scenario axes.
    """
    x = jnp.asarray(B) * jnp.asarray(V) / jnp.asarray(n)
    safe = jnp.maximum(x, 1e-6)
    return jnp.where(x > 1e-6, -jnp.expm1(-safe) / safe, 1.0 - 0.5 * x)


def stress_rates(params: AgingParams, *, duty=DUTY_FACTOR,
                 toggle=TOGGLE_RATE, t_clk=T_CLK,
                 transition_time=TRANSITION_TIME,
                 recovery: bool = True) -> jnp.ndarray:
    """Effective stress-seconds accrued per wall-clock second, per population.

    BTI populations stress during logic-stable phases (rate = duty factor);
    HCI populations stress only during transitions (paper's accumulation
    formula with the gamma equivalence).  With ``recovery`` enabled each
    population's rate is scaled by its capture/emission balance factor
    ``R_i = act / (act + chi_i * (1 - act))`` where ``act`` is the fraction
    of time under stress for that mechanism.

    Fully traceable: every activity knob (``duty``, ``toggle``, ``t_clk``,
    ``transition_time``) may be a traced scalar, so the lifetime simulator
    can compute rates *inside* the vmapped scan and batch over mission
    profiles.  ``recovery`` stays a static Python bool.
    """
    duty = jnp.asarray(duty, jnp.float32)
    toggle = jnp.asarray(toggle, jnp.float32)
    t_clk = jnp.asarray(t_clk, jnp.float32)
    transition_time = jnp.asarray(transition_time, jnp.float32)
    is_bti = jnp.asarray(IS_BTI)
    # gamma is evaluated at V_NOM, as in the paper's accumulation formula:
    # the transition ramp always spans 0 -> V_DD ~ V_NOM for rate purposes.
    gamma = hci_gamma_closed(params.B, V_NOM, params.n)
    act = jnp.where(is_bti, duty, toggle * transition_time / t_clk)
    base = jnp.where(is_bti, duty,
                     gamma * (transition_time / t_clk) * toggle)
    if recovery:
        # safe at act == 0 (an idle device in the traffic co-simulation):
        # for chi == 0 populations (permanent traps) the balance factor is
        # act/act — guard the denominator so 0-activity yields rate 0, not
        # NaN; for act > 0 the maximum is a no-op.
        base = base * act / jnp.maximum(act + params.chi * (1.0 - act),
                                        1e-30)
    return base.astype(jnp.float32)


def update_state(params: AgingParams, dv_mv: jnp.ndarray, V: jnp.ndarray,
                 rates: jnp.ndarray, dt: jnp.ndarray,
                 t_amb: float = T_AMB) -> jnp.ndarray:
    """Advance all six trap populations by a wall-clock segment ``dt`` at ``V``.

    History-aware effective-time update: the current shift is converted into
    an equivalent stress time *at the present voltage*, extended by the
    segment's effective stress time, and re-evaluated.  ``dv_mv`` has shape
    ``(6,)`` in mV.
    """
    K = k_factor(params, V, t_amb)
    inv_n = 1.0 / params.n
    # (dv / K) ** (1/n); safe at dv == 0.
    t_eq = jnp.where(dv_mv > 0.0, (dv_mv / K) ** inv_n, 0.0)
    t_new = t_eq + rates * dt
    return K * t_new ** params.n


def totals(dv_mv: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Aggregate per-population shifts into (ΔVth_p, ΔVth_n) in mV."""
    pm = jnp.asarray(IS_PMOS, dv_mv.dtype)
    dvp = jnp.sum(dv_mv * pm)
    dvn = jnp.sum(dv_mv * (1.0 - pm))
    return dvp, dvn


def dc_shift(params: AgingParams, idx: int, V: float, t: float,
             rate: float, t_amb: float = T_AMB) -> float:
    """Closed-form shift of one population after time ``t`` at constant V."""
    K = k_factor(params, jnp.asarray(V), t_amb)[idx]
    return float(K * (rate * t) ** float(params.n[idx]))
