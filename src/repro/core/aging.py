"""Compact BTI / HCI aging models with history-aware accumulation.

Model structure (paper Fig. 2 + Sec. III-E):

* **BTI** (PMOS NBTI; PBTI on NMOS is ignored per the paper / [6]): two trap
  populations, *fast* and *slow*.  Each population follows a voltage- and
  temperature-accelerated power law under DC stress

      dVth_i(t) = A_i * exp(B_i * V) * exp(-Ea_i / (kB * T)) * t**n_i   [mV]

  Under a duty-cycled workload the stress time accrues at ``duty`` per wall
  second; when detrapping (recovery) is modelled, the effective stress-time
  rate is further reduced by the capture/emission balance factor

      R_i(d) = d / (d + chi_i * (1 - d))

  so that ``dVth_AC / dVth_DC = R_i(d)**n_i``.  ``chi_i`` (detrapping
  efficiency) is large for fast traps and small for slow traps.  This closed
  form is the *converged limit* of the paper's iterative equivalent-waveform
  extrapolation (Fig. 4 f-h) — :mod:`repro.core.waveform` implements the
  explicit trapping/detrapping micro-kinetics and the period-doubling
  extrapolation, and the tests assert this closed form agrees with it.

* **HCI** (both devices): occurs only during output transitions.  Per the
  unified HCD model [7] we keep two populations: *interface traps* (permanent)
  and *oxide traps* (partially detrappable between stress events).  The
  effective stress-time rate per wall second is

      rate = gamma * (transition_time / t_clk) * toggle_rate

  which is the paper's accumulation formula; ``gamma`` maps the continuously
  varying gate voltage during a transition onto an equivalent full-V_DD
  stress interval (:func:`hci_gamma`).  Because the kinetics are a power law,
  the sub-interval summation of the paper's equation is performed in the
  *effective-time* domain (damage-equivalent time), which is the
  time-additive form of the same identity.

* **History (arbitrary waveforms)**: the AVS controller changes V_DD over
  life.  We accumulate each population with the effective-time method: given
  the population's current shift ``dv`` and the new segment voltage ``V``,

      t_eq = (dv / K(V, T))**(1 / n);   dv' = K(V, T) * (t_eq + rate*dt)**n

  i.e. the damage state is carried across voltage changes instead of being
  re-evaluated at a constant worst-case voltage.  This is the paper's central
  modelling claim (Table I row 4 vs row 3).

All functions are pure JAX and are used inside ``lax.scan`` in
:mod:`repro.core.avs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .constants import KB_EV, DUTY_FACTOR, TOGGLE_RATE, TRANSITION_TIME, T_CLK, T_AMB, V_NOM

# Population index layout (fixed order used by the vectorised state).
POPULATIONS = (
    "pmos_bti_fast",   # 0: NBTI fast traps   (recoverable)
    "pmos_bti_slow",   # 1: NBTI slow traps   (weakly recoverable)
    "pmos_hci_it",     # 2: PMOS HCI interface traps (permanent)
    "pmos_hci_ot",     # 3: PMOS HCI oxide traps     (partially recoverable)
    "nmos_hci_it",     # 4: NMOS HCI interface traps (permanent)
    "nmos_hci_ot",     # 5: NMOS HCI oxide traps     (partially recoverable)
)
N_POP = len(POPULATIONS)
# Which populations are BTI-like (stress during logic stability) vs HCI-like
# (stress during transitions).
IS_BTI = np.array([1, 1, 0, 0, 0, 0], dtype=bool)
# Populations whose shift adds to the PMOS ΔVth.
IS_PMOS = np.array([1, 1, 1, 1, 0, 0], dtype=bool)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AgingParams:
    """Vectorised per-population compact-model parameters (shape ``(6,)``)."""

    A: jnp.ndarray        # prefactor [mV / s**n]
    B: jnp.ndarray        # voltage acceleration [1/V]
    Ea: jnp.ndarray       # activation energy [eV]
    n: jnp.ndarray        # time exponent
    chi: jnp.ndarray      # detrapping efficiency (recovery strength)
    dT_sh: float = 8.0    # self-heating temperature rise at (V_NOM, nominal activity) [K]

    def tree_flatten(self):
        return ((self.A, self.B, self.Ea, self.n, self.chi), (self.dT_sh,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, dT_sh=aux[0])

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AgingParams":
        return cls(
            A=jnp.asarray(d["A"], jnp.float32),
            B=jnp.asarray(d["B"], jnp.float32),
            Ea=jnp.asarray(d["Ea"], jnp.float32),
            n=jnp.asarray(d["n"], jnp.float32),
            chi=jnp.asarray(d["chi"], jnp.float32),
            dT_sh=float(d.get("dT_sh", 8.0)),
        )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "A": np.asarray(self.A).tolist(),
            "B": np.asarray(self.B).tolist(),
            "Ea": np.asarray(self.Ea).tolist(),
            "n": np.asarray(self.n).tolist(),
            "chi": np.asarray(self.chi).tolist(),
            "dT_sh": float(self.dT_sh),
        }


def self_heating_temp(V: jnp.ndarray, t_amb: float = T_AMB, dT_sh: float = 8.0,
                      v_ref: float = V_NOM) -> jnp.ndarray:
    """Channel temperature including the transient self-heating rise [9].

    Dissipated power scales ~V^2 for the dominant dynamic component, so the
    SHE temperature rise is modelled as ``dT_sh * (V / v_ref)**2``.
    """
    return t_amb + dT_sh * (V / v_ref) ** 2


def k_factor(params: AgingParams, V: jnp.ndarray, t_amb: float = T_AMB) -> jnp.ndarray:
    """Per-population power-law prefactor ``K_i(V, T)`` [mV / s**n_i]."""
    T = self_heating_temp(V, t_amb, params.dT_sh)
    return params.A * jnp.exp(params.B * V) * jnp.exp(-params.Ea / (KB_EV * T))


def hci_gamma(B: float, V: float, n: float, num: int = 256) -> float:
    """Equivalent-stress fraction of a transition (paper Sec. III-E, HCI eq.).

    The gate voltage ramps 0 -> V during a transition.  With power-law
    kinetics ``dv = K(Vg) * t**n``, damage over sub-intervals adds in the
    effective-time domain, so the interval equivalent at full V_DD is

        gamma = (1/tt) * \\int_0^tt (K(Vg(t)) / K(V))**(1/n) dt
              = (1/tt) * \\int_0^tt exp(B * (Vg(t) - V) / n) dt

    For a linear ramp this integrates to ``(1 - exp(-B*V/n)) / (B*V/n)``;
    we evaluate numerically so that arbitrary ramp shapes can be plugged in.
    """
    tgrid = np.linspace(0.0, 1.0, num)
    vg = tgrid * V  # linear ramp
    integrand = np.exp(B * (vg - V) / n)
    return float(np.trapezoid(integrand, tgrid))


def hci_gamma_closed(B, V, n):
    """Closed form of :func:`hci_gamma` for the linear ramp — pure JAX.

    ``gamma = (1 - exp(-B*V/n)) / (B*V/n)``, with the ``x -> 0`` limit
    handled so the expression stays traceable and NaN-free.  This is the
    analytic value the numeric integral of :func:`hci_gamma` converges to,
    and is what the traced simulator uses so that activity knobs can be
    batched (vmapped) scenario axes.
    """
    x = jnp.asarray(B) * jnp.asarray(V) / jnp.asarray(n)
    safe = jnp.maximum(x, 1e-6)
    return jnp.where(x > 1e-6, -jnp.expm1(-safe) / safe, 1.0 - 0.5 * x)


def stress_rates(params: AgingParams, *, duty=DUTY_FACTOR,
                 toggle=TOGGLE_RATE, t_clk=T_CLK,
                 transition_time=TRANSITION_TIME,
                 recovery: bool = True) -> jnp.ndarray:
    """Effective stress-seconds accrued per wall-clock second, per population.

    BTI populations stress during logic-stable phases (rate = duty factor);
    HCI populations stress only during transitions (paper's accumulation
    formula with the gamma equivalence).  With ``recovery`` enabled each
    population's rate is scaled by its capture/emission balance factor
    ``R_i = act / (act + chi_i * (1 - act))`` where ``act`` is the fraction
    of time under stress for that mechanism.

    Fully traceable: every activity knob (``duty``, ``toggle``, ``t_clk``,
    ``transition_time``) may be a traced scalar, so the lifetime simulator
    can compute rates *inside* the vmapped scan and batch over mission
    profiles.  ``recovery`` stays a static Python bool.
    """
    duty = jnp.asarray(duty, jnp.float32)
    toggle = jnp.asarray(toggle, jnp.float32)
    t_clk = jnp.asarray(t_clk, jnp.float32)
    transition_time = jnp.asarray(transition_time, jnp.float32)
    is_bti = jnp.asarray(IS_BTI)
    # gamma is evaluated at V_NOM, as in the paper's accumulation formula:
    # the transition ramp always spans 0 -> V_DD ~ V_NOM for rate purposes.
    gamma = hci_gamma_closed(params.B, V_NOM, params.n)
    act = jnp.where(is_bti, duty, toggle * transition_time / t_clk)
    base = jnp.where(is_bti, duty,
                     gamma * (transition_time / t_clk) * toggle)
    if recovery:
        # safe at act == 0 (an idle device in the traffic co-simulation):
        # for chi == 0 populations (permanent traps) the balance factor is
        # act/act — guard the denominator so 0-activity yields rate 0, not
        # NaN; for act > 0 the maximum is a no-op.
        base = base * act / jnp.maximum(act + params.chi * (1.0 - act),
                                        1e-30)
    return base.astype(jnp.float32)


def update_state(params: AgingParams, dv_mv: jnp.ndarray, V: jnp.ndarray,
                 rates: jnp.ndarray, dt: jnp.ndarray,
                 t_amb: float = T_AMB) -> jnp.ndarray:
    """Advance all six trap populations by a wall-clock segment ``dt`` at ``V``.

    History-aware effective-time update: the current shift is converted into
    an equivalent stress time *at the present voltage*, extended by the
    segment's effective stress time, and re-evaluated.  ``dv_mv`` has shape
    ``(6,)`` in mV.
    """
    K = k_factor(params, V, t_amb)
    inv_n = 1.0 / params.n
    # (dv / K) ** (1/n); safe at dv == 0.
    t_eq = jnp.where(dv_mv > 0.0, (dv_mv / K) ** inv_n, 0.0)
    t_new = t_eq + rates * dt
    return K * t_new ** params.n


def totals(dv_mv: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Aggregate per-population shifts into (ΔVth_p, ΔVth_n) in mV."""
    pm = jnp.asarray(IS_PMOS, dv_mv.dtype)
    dvp = jnp.sum(dv_mv * pm)
    dvn = jnp.sum(dv_mv * (1.0 - pm))
    return dvp, dvn


def dc_shift(params: AgingParams, idx: int, V: float, t: float,
             rate: float, t_amb: float = T_AMB) -> float:
    """Closed-form shift of one population after time ``t`` at constant V."""
    K = k_factor(params, jnp.asarray(V), t_amb)[idx]
    return float(K * (rate * t) ** float(params.n[idx]))
