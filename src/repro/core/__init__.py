"""The paper's primary contribution: aging-aware adaptive voltage scaling.

Layers:
* :mod:`repro.core.aging`      — BTI/HCI compact models, history-aware accumulation
* :mod:`repro.core.waveform`   — equivalent-waveform iterative extrapolation (Fig. 4 f-h)
* :mod:`repro.core.delay`      — critical-path model + ternary degree-6 polynomial
* :mod:`repro.core.avs`        — lifetime AVS simulator (lax.scan)
* :mod:`repro.core.ber`        — delay_max -> BER mapping and inversion
* :mod:`repro.core.resilience` — BER -> accuracy curves, per-operator tolerances
* :mod:`repro.core.policy`     — baseline & fault-tolerant voltage-scaling policies
* :mod:`repro.core.scenario`   — pytree Scenario (mission profile) batches
* :mod:`repro.core.power`      — lifetime power / V_eff model
* :mod:`repro.core.calibrate`  — one-shot calibration against the paper's Table I
* :mod:`repro.core.fleet`      — vectorised FleetRuntime (N devices x O domains)
* :mod:`repro.core.runtime`    — legacy single-device AgingAwareRuntime shim
"""
from .aging import AgingParams, POPULATIONS  # noqa: F401
from .scenario import (LifetimeTrajectory, Scenario, scenario_grid,  # noqa: F401
                       stack_scenarios)
from .avs import (LifetimeConfig, final_shifts, run_lifetime,  # noqa: F401
                  simulate)
from .delay import DelayPolynomial, PathModel, fit_delay_polynomial  # noqa: F401
from .ber import BerModel, solve_ber_model  # noqa: F401
from .power import PowerModel, batched_lifetime_stats, lifetime_stats  # noqa: F401
from .policy import (BaselinePolicy, FaultTolerantPolicy, Policy,  # noqa: F401
                     evaluate_policy, get_policy, register_policy,
                     sweep_policy)
from .resilience import OPERATORS, ResilienceCurve, tolerable_bers  # noqa: F401
from .fleet import DeviceView, DomainState, FleetRuntime  # noqa: F401
