"""The paper's primary contribution: aging-aware adaptive voltage scaling.

Layers:
* :mod:`repro.core.aging`      — BTI/HCI compact models, history-aware accumulation
* :mod:`repro.core.waveform`   — equivalent-waveform iterative extrapolation (Fig. 4 f-h)
* :mod:`repro.core.delay`      — critical-path model + ternary degree-6 polynomial
* :mod:`repro.core.avs`        — lifetime AVS simulator (lax.scan)
* :mod:`repro.core.ber`        — delay_max -> BER mapping and inversion
* :mod:`repro.core.resilience` — BER -> accuracy curves, per-operator tolerances
* :mod:`repro.core.policy`     — baseline & fault-tolerant voltage-scaling policies
* :mod:`repro.core.power`      — lifetime power / V_eff model
* :mod:`repro.core.calibrate`  — one-shot calibration against the paper's Table I
* :mod:`repro.core.runtime`    — serving-time integration (AgingDomain per operator)
"""
from .aging import AgingParams, POPULATIONS  # noqa: F401
from .avs import LifetimeConfig, run_lifetime, final_shifts  # noqa: F401
from .delay import DelayPolynomial, PathModel, fit_delay_polynomial  # noqa: F401
from .ber import BerModel, solve_ber_model  # noqa: F401
from .power import PowerModel, lifetime_stats  # noqa: F401
from .policy import BaselinePolicy, FaultTolerantPolicy, evaluate_policy  # noqa: F401
from .resilience import OPERATORS, ResilienceCurve, tolerable_bers  # noqa: F401
