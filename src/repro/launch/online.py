"""Online-serving launcher: ``python -m repro.launch.online [...]``.

Runs the continuous-batching engine end to end: a
:mod:`repro.sched.workload` arrival trace becomes a live request queue,
:class:`repro.serve.online.OnlineServeEngine` (or the router-dispatched
:class:`~repro.serve.online.OnlineFleetEngine` with ``--n-devices > 1``)
serves it on fixed slots with admission control, and the *measured*
per-device slot occupancy is replayed into
:meth:`repro.core.fleet.FleetRuntime.apply_load` — served traffic, not a
synthetic envelope, drives the aging recursion, and the wear it produced
is reported next to the serving metrics (tok/s, p50/p99 latency, drop
rate).

``--quick`` shrinks everything to a CI-sized smoke run.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.sched.router import ROUTER_REGISTRY
from repro.sched.workload import WORKLOADS, get_workload
from repro.serve.online import (OnlineFleetEngine, OnlineServeEngine,
                                requests_from_workload)
from repro.train.steps import init_train_state

YEAR_S = 365.25 * 24 * 3600.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--n-devices", type=int, default=1)
    ap.add_argument("--age-years", type=float, default=5.0,
                    help="staggered fleet ages (device i at "
                         "age*(i+1)/n) — served BERs reflect them")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--chunk-steps", type=int, default=8,
                    help="decode steps per compiled chunk (refills "
                         "happen between chunks)")
    ap.add_argument("--workload", default="diurnal",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--utilization", type=float, default=0.6,
                    help="mean offered load / fleet slot capacity")
    ap.add_argument("--n-epochs", type=int, default=12,
                    help="arrival-trace epochs")
    ap.add_argument("--steps-per-epoch", type=int, default=64,
                    help="decode steps per arrival epoch")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16,
                    help="generation budget per request")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="admission-control bound (arrivals beyond it "
                         "are dropped)")
    ap.add_argument("--router", default="wear_level",
                    choices=tuple(sorted(ROUTER_REGISTRY)),
                    help="lane-dispatch policy (fleet mode)")
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replay-horizon-years", type=float, default=1.0,
                    help="service horizon the measured occupancy trace "
                         "spans when replayed into the aging recursion")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny trace, 2 slots, short budgets")
    args = ap.parse_args(argv)

    if args.quick:
        args.n_epochs = min(args.n_epochs, 4)
        args.steps_per_epoch = min(args.steps_per_epoch, 24)
        args.n_slots = min(args.n_slots, 2)
        args.max_new = min(args.max_new, 8)
        args.prompt_len = min(args.prompt_len, 8)
        args.chunk_steps = min(args.chunk_steps, 4)

    cfg = get_config(args.arch).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    fleet = FleetRuntime(n_devices=args.n_devices)
    for i in range(args.n_devices):
        fleet.set_age(years=args.age_years * (i + 1) / args.n_devices,
                      device=i)

    wl = get_workload(args.workload, n_devices=args.n_devices,
                      utilization=args.utilization,
                      n_epochs=args.n_epochs)
    reqs = requests_from_workload(
        wl, n_slots=args.n_slots, steps_per_epoch=args.steps_per_epoch,
        max_new=args.max_new, prompt_len=args.prompt_len,
        vocab=cfg.vocab, n_devices=args.n_devices, seed=args.seed)
    max_len = args.prompt_len + args.max_new + 1
    horizon = args.n_epochs * args.steps_per_epoch

    if args.n_devices > 1:
        eng = OnlineFleetEngine(
            cfg, params, fleet, n_slots=args.n_slots, max_len=max_len,
            max_new_cap=args.max_new, chunk_steps=args.chunk_steps,
            max_queue=args.max_queue, router=args.router, seed=args.seed)
    else:
        eng = OnlineServeEngine(
            cfg, params, runtime=fleet, n_slots=args.n_slots,
            max_len=max_len, max_new_cap=args.max_new,
            chunk_steps=args.chunk_steps, max_queue=args.max_queue,
            seed=args.seed)
    res = eng.serve(reqs, greedy=args.temperature == 0.0,
                    temperature=args.temperature or None,
                    max_steps=4 * horizon)

    s = res.summary()
    mode = (f"fleet={args.n_devices} router={args.router}"
            if args.n_devices > 1 else "single-device")
    print(f"[online] arch={cfg.name} {mode} slots={args.n_slots} "
          f"chunk={args.chunk_steps} workload={args.workload}")
    print(f"[online] {s['n_arrived']} arrived, {s['n_completed']} "
          f"completed, {s['n_dropped']} dropped "
          f"(rate {s['drop_rate']:.3f}) over {s['total_steps']} steps")
    print(f"[online] {s['tok_per_s']:.1f} tok/s, latency p50 "
          f"{s['p50']:.0f} / p99 {s['p99']:.0f} steps, occupancy "
          f"{s['mean_occupancy']:.2f}")

    # close the loop: measured occupancy -> duty -> aging
    util = res.lane_utilization(max(args.n_epochs, 2))
    if util.ndim == 1:
        util = util[:, None]
    cos = fleet.apply_load(util_trace=util,
                           horizon_s=args.replay_horizon_years * YEAR_S)
    wear = cos.device_wear()[-1]
    print(f"[online] replayed measured occupancy into the aging scan: "
          f"{args.replay_horizon_years:g}y at mean duty "
          f"{util.mean():.2f} -> fleet-max ΔVth {wear.max():.1f} mV "
          f"(spread {wear.max() - wear.min():.1f} mV)")
    return res


if __name__ == "__main__":
    main()
