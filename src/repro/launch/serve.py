"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the aging-aware engine end-to-end on a reduced config: initialises
params, builds a :class:`repro.core.fleet.FleetRuntime` (``--n-devices``
simulated accelerators of possibly different age), and generates batched
tokens under the per-operator BERs the policy admits at each device's age.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--age-years", type=float, default=5.0)
    ap.add_argument("--n-devices", type=int, default=1,
                    help="fleet size; device i serves at age-years * "
                         "(i+1)/n (a staggered-deployment fleet)")
    ap.add_argument("--device", type=int, default=0,
                    help="which fleet device the engine serves from")
    ap.add_argument("--budget", type=float, default=0.5,
                    help="accuracy budget [%% loss] of the policy")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--baseline-avs", action="store_true",
                    help="resilience-agnostic policy (raise V on every "
                         "violation) instead of fault-tolerant")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run weight matmuls through the int8 systolic "
                         "Pallas kernel (interpret mode on CPU: slow)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    fleet = FleetRuntime(
        n_devices=args.n_devices,
        policy="baseline" if args.baseline_avs else "fault_tolerant",
        max_loss_pct=args.budget)
    for i in range(args.n_devices):
        fleet.set_age(years=args.age_years * (i + 1) / args.n_devices,
                      device=i)
    engine = ServeEngine(cfg, params, runtime=fleet, device=args.device,
                         max_len=args.prompt_len + args.gen_len + 1,
                         use_systolic_kernel=args.use_kernel)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       global_batch=args.batch)
    prompts = data.batch_at(0).tokens
    extra = {}
    if cfg.prefix_tokens:
        extra["prefix_embeds"] = np.zeros(
            (args.batch, cfg.prefix_tokens, cfg.d_model), np.float32)
    if cfg.n_encoder_layers:
        extra["frames"] = np.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)

    res = engine.generate(prompts, args.gen_len, **extra)
    pol = "baseline" if args.baseline_avs else "fault-tolerant"
    print(f"[serve] arch={cfg.name} fleet={args.n_devices} dev={args.device} "
          f"age={res.age_years:.1f}y policy={pol} budget={args.budget}%")
    print(f"[serve] per-op BER: " + ", ".join(
        f"{k}={v:.1e}" for k, v in sorted(res.bers.items())))
    print(f"[serve] est. array power: {res.power_w:.2f} W "
          f"(x{len(res.bers)} domains)")
    if args.n_devices > 1:
        ages = ", ".join(f"{a:.1f}y" for a in fleet.ages_years)
        pw = ", ".join(f"{p:.2f}W" for p in fleet.fleet_power())
        print(f"[serve] fleet ages: [{ages}]  power: [{pw}] "
              f"(total {fleet.fleet_power().sum():.2f} W)")
    print(f"[serve] generated {res.tokens.shape} tokens; "
          f"first row: {res.tokens[0][:12].tolist()}")
    return res


if __name__ == "__main__":
    main()
