"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the aging-aware engine end-to-end on a reduced config: initialises
params, sets the simulated device age, and generates batched tokens under
the per-operator BERs the fault-tolerant AVS policy admits at that age.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.runtime import AgingAwareRuntime
from repro.data import SyntheticLM
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--age-years", type=float, default=5.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--baseline-avs", action="store_true",
                    help="resilience-agnostic policy (raise V on every "
                         "violation) instead of fault-tolerant")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run weight matmuls through the int8 systolic "
                         "Pallas kernel (interpret mode on CPU: slow)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    runtime = AgingAwareRuntime(fault_tolerant=not args.baseline_avs)
    runtime.set_age(years=args.age_years)
    engine = ServeEngine(cfg, params, runtime=runtime,
                         max_len=args.prompt_len + args.gen_len + 1,
                         use_systolic_kernel=args.use_kernel)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       global_batch=args.batch)
    prompts = data.batch_at(0).tokens
    extra = {}
    if cfg.prefix_tokens:
        extra["prefix_embeds"] = np.zeros(
            (args.batch, cfg.prefix_tokens, cfg.d_model), np.float32)
    if cfg.n_encoder_layers:
        extra["frames"] = np.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)

    res = engine.generate(prompts, args.gen_len, **extra)
    print(f"[serve] arch={cfg.name} age={res.age_years:.1f}y "
          f"policy={'baseline' if args.baseline_avs else 'fault-tolerant'}")
    print(f"[serve] per-op BER: " + ", ".join(
        f"{k}={v:.1e}" for k, v in sorted(res.bers.items())))
    print(f"[serve] est. array power: {res.power_w:.2f} W "
          f"(x{len(res.bers)} domains)")
    print(f"[serve] generated {res.tokens.shape} tokens; "
          f"first row: {res.tokens[0][:12].tolist()}")
    return res


if __name__ == "__main__":
    main()
