"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the aging-aware engine end-to-end on a reduced config: initialises
params, builds a :class:`repro.core.fleet.FleetRuntime` (``--n-devices``
simulated accelerators of possibly different age), and generates batched
tokens under the per-operator BERs the policy admits at each device's age.

With ``--n-devices > 1`` the whole fleet serves in ONE dispatch: the
prompt batch is sharded across lanes and
:class:`~repro.serve.engine.FleetServeEngine` vmaps the compiled
prefill + scanned-decode generation over every device's BER vector.
``--device`` narrows to a single-lane :class:`ServeEngine`; ``--eager``
selects the per-token oracle loop (bit-exact, one dispatch per token).

``--router`` (default ``round_robin``) first ages the fleet under
*routed traffic*: the staggered deployment ages fold into the
:func:`repro.sched.lifetime.cosimulate` scan's initial state, the
``--workload`` arrival trace is routed each epoch, and the BERs actually
served reflect the traffic-dependent wear.  ``--router static`` keeps
the legacy fixed-profile aging; ``wear_level`` demonstrates the
scheduler actively slowing fleet aging (``python -m
repro.launch.schedule`` for the router comparison).

``--mesh`` serves ONE model sharded over a ``("data", "model")`` device
mesh instead of a fleet of replicas: tensor/expert parallelism over
``--tp`` devices (default: all visible — fake them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* launch),
with a shard-granular fleet (``n_shards == tp``) giving every mesh shard
its own staggered age and per-operator BERs inside the single sharded
dispatch (:class:`repro.serve.sharded.MeshServeEngine`).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.sched.router import ROUTER_REGISTRY
from repro.sched.workload import WORKLOADS
from repro.serve.engine import FleetServeEngine, ServeEngine
from repro.train.steps import init_train_state

YEAR_S = 365.25 * 24 * 3600.0


def _print_cache_stats():
    """``--stats``: per-cache compiled-fn hit/miss/evict table."""
    from repro.serve.engine import cache_stats
    print("[serve] compiled-fn caches (hit/miss/evict, size):")
    for name, s in sorted(cache_stats().items()):
        print(f"    {name:<20} {s['hits']:>5} {s['misses']:>5} "
              f"{s['evictions']:>5}   {s['currsize']}/{s['maxsize']}")


def main(argv=None):
    import sys
    argv_list = list(sys.argv[1:] if argv is None else argv)
    if "--online" in argv_list:
        # continuous-batching mode: delegate to the online launcher
        # (live request queue, slot refills, occupancy-driven aging)
        from . import online
        argv_list.remove("--online")
        return online.main(argv_list)
    ap = argparse.ArgumentParser()
    ap.add_argument("--online", action="store_true",
                    help="serve a LIVE request queue with continuous "
                         "batching instead of a static prompt batch "
                         "(remaining args go to repro.launch.online)")
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--age-years", type=float, default=5.0)
    ap.add_argument("--n-devices", type=int, default=1,
                    help="fleet size; device i serves at age-years * "
                         "(i+1)/n (a staggered-deployment fleet)")
    ap.add_argument("--device", type=int, default=None,
                    help="serve ONE fleet device instead of the whole "
                         "fleet in one dispatch")
    ap.add_argument("--budget", type=float, default=0.5,
                    help="accuracy budget [%% loss] of the policy")
    ap.add_argument("--batch", type=int, default=4,
                    help="prompts per device")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples softmax(logits/T)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="restrict sampling to the k highest logits")
    ap.add_argument("--router", default="round_robin",
                    choices=tuple(sorted(ROUTER_REGISTRY)) + ("static",),
                    help="age the fleet under ROUTED traffic before "
                         "serving (repro.sched): served BERs then "
                         "reflect the staggered --age-years wear PLUS "
                         "--horizon-years of routed service; 'static' "
                         "keeps the legacy fixed-profile aging")
    ap.add_argument("--workload", default="diurnal",
                    choices=sorted(WORKLOADS),
                    help="request-arrival model fed to --router")
    ap.add_argument("--utilization", type=float, default=0.55,
                    help="mean offered load / fleet capacity for "
                         "--workload")
    ap.add_argument("--horizon-years", type=float, default=2.0,
                    help="service horizon the --router traffic spans "
                         "(on top of the staggered --age-years start)")
    ap.add_argument("--policy", default=None,
                    choices=("fault_tolerant", "baseline", "measured"),
                    help="AVS policy; 'measured' uses THIS arch's curves "
                         "from resilience_calibrated.json (regenerate with "
                         "repro.launch.calibrate_resilience)")
    ap.add_argument("--baseline-avs", action="store_true",
                    help="legacy alias for --policy baseline")
    ap.add_argument("--mesh", action="store_true",
                    help="serve ONE mesh-sharded model (tensor/expert "
                         "parallel over --tp devices) with per-shard "
                         "aging instead of a fleet of replicas")
    ap.add_argument("--tp", type=int, default=None,
                    help="--mesh model-axis size (default: all visible "
                         "devices)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run weight matmuls through the int8 systolic "
                         "Pallas kernel (interpret mode on CPU: slow)")
    ap.add_argument("--fused", dest="fused", action="store_true",
                    default=None,
                    help="--mesh route: shard_map the fused aged-matmul "
                         "Pallas kernel per shard (default on TPU; "
                         "interpret mode on CPU: slow)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="--mesh route: force the kernel-free GSPMD "
                         "injection (same streams, same tokens)")
    ap.add_argument("--eager", action="store_true",
                    help="per-token oracle loop instead of the scanned "
                         "single-dispatch path (single-device only)")
    ap.add_argument("--stats", action="store_true",
                    help="print per-cache compiled-fn hit/miss/evict "
                         "stats after the run")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    pol = args.policy or ("baseline" if args.baseline_avs
                          else "fault_tolerant")
    if pol == "measured":
        # key the artifact lookup on the served arch — the closed loop:
        # measured curves -> tolerable BER -> delay_max -> admitted BERs
        from repro.core.artifacts import load_calibration
        from repro.core.policy import MeasuredResiliencePolicy
        pol = MeasuredResiliencePolicy(ber_model=load_calibration().ber,
                                       model=args.arch)
    if args.mesh:
        return _run_mesh(args, cfg, params, pol)
    fleet = FleetRuntime(
        n_devices=args.n_devices, policy=pol, max_loss_pct=args.budget)
    for i in range(args.n_devices):
        fleet.set_age(years=args.age_years * (i + 1) / args.n_devices,
                      device=i)
    if args.router != "static":
        # traffic-driven aging: fold the staggered ages into the co-sim's
        # initial state, route --horizon-years of the workload, and serve
        # at the BERs the traffic-dependent wear admits at end of horizon
        cos = fleet.apply_load(workload=args.workload, router=args.router,
                               utilization=args.utilization,
                               horizon_s=args.horizon_years * YEAR_S)
        wear = cos.device_wear()[-1]
        print(f"[serve] routed {args.horizon_years:g}y of "
              f"{args.workload} traffic ({cos.n_epochs} epochs) via "
              f"{args.router}: fleet-max ΔVth {wear.max():.1f} mV "
              f"(spread {wear.max() - wear.min():.1f} mV), mean util "
              f"{np.asarray(cos.util).mean():.2f}")

    fleet_mode = args.n_devices > 1 and args.device is None
    if args.eager and fleet_mode:
        ap.error("--eager is single-device only: pass --device <i> to "
                 "pick a lane (the fleet path has no per-token loop)")
    max_len = args.prompt_len + args.gen_len + 1
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       global_batch=args.batch)
    prompts = data.batch_at(0).tokens
    extra = {}
    if cfg.prefix_tokens:
        extra["prefix_embeds"] = np.zeros(
            (args.batch, cfg.prefix_tokens, cfg.d_model), np.float32)
    if cfg.n_encoder_layers:
        extra["frames"] = np.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)

    pol = getattr(fleet.policy, "name", "fault_tolerant")
    if fleet_mode:
        engine = FleetServeEngine(cfg, params, fleet, max_len=max_len,
                                  use_systolic_kernel=args.use_kernel)
        tile = lambda x: np.broadcast_to(
            x, (args.n_devices,) + x.shape).copy()
        res = engine.generate(tile(prompts), args.gen_len,
                              temperature=args.temperature,
                              top_k=args.top_k,
                              **{k: tile(v) for k, v in extra.items()})
        ages = ", ".join(f"{a:.1f}y" for a in res.ages_years)
        pw = ", ".join(f"{p:.2f}W" for p in res.power_w)
        print(f"[serve] arch={cfg.name} fleet={args.n_devices} "
              f"policy={pol} budget={args.budget}% — ONE dispatch for the "
              f"whole fleet")
        print(f"[serve] fleet ages: [{ages}]  power: [{pw}] "
              f"(total {res.power_w.sum():.2f} W)")
        q = res.operators.index("q")
        bq = ", ".join(f"{b:.1e}" for b in res.bers[:, q])
        print(f"[serve] per-lane BER(q): [{bq}]")
        print(f"[serve] generated {res.tokens.shape} tokens "
              "(lanes x batch x steps); lane rows: ")
        for i in range(args.n_devices):
            print(f"    dev{i} ({res.ages_years[i]:.1f}y): "
                  f"{res.tokens[i, 0][:12].tolist()}")
        if args.stats:
            _print_cache_stats()
        return res

    engine = ServeEngine(cfg, params, runtime=fleet,
                         device=args.device or 0, max_len=max_len,
                         use_systolic_kernel=args.use_kernel)
    res = engine.generate(prompts, args.gen_len,
                          temperature=args.temperature, top_k=args.top_k,
                          scan=not args.eager, **extra)
    print(f"[serve] arch={cfg.name} fleet={args.n_devices} "
          f"dev={args.device or 0} age={res.age_years:.1f}y policy={pol} "
          f"budget={args.budget}% path="
          f"{'eager-oracle' if args.eager else 'scanned'}")
    print(f"[serve] per-op BER: " + ", ".join(
        f"{k}={v:.1e}" for k, v in sorted(res.bers.items())))
    print(f"[serve] est. array power: {res.power_w:.2f} W "
          f"(x{len(res.bers)} domains)")
    print(f"[serve] generated {res.tokens.shape} tokens; "
          f"first row: {res.tokens[0][:12].tolist()}")
    if args.stats:
        _print_cache_stats()
    return res


def _run_mesh(args, cfg, params, pol):
    """One mesh-sharded model, per-shard aging, ONE sharded dispatch."""
    from repro.serve.sharded import MeshServeEngine, default_serve_mesh

    mesh = default_serve_mesh(args.tp)
    tp = mesh.shape["model"]
    fleet = FleetRuntime(n_devices=1, n_shards=tp, policy=pol,
                         max_loss_pct=args.budget)
    for s in range(tp):
        # staggered shard ages: a device rebuilt from spares of mixed age
        fleet.set_age(years=args.age_years * (s + 1) / tp, shard=s)
    if args.router != "static":
        cos = fleet.apply_load(workload=args.workload, router=args.router,
                               utilization=args.utilization,
                               horizon_s=args.horizon_years * YEAR_S)
        wear = cos.device_wear()[-1]
        print(f"[serve] routed {args.horizon_years:g}y of {args.workload} "
              f"traffic over the {tp} shards via {args.router}: max ΔVth "
              f"{wear.max():.1f} mV (spread "
              f"{wear.max() - wear.min():.1f} mV)")

    max_len = args.prompt_len + args.gen_len + 1
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.prompt_len,
                       global_batch=args.batch)
    prompts = data.batch_at(0).tokens
    extra = {}
    if cfg.prefix_tokens:
        extra["prefix_embeds"] = np.zeros(
            (args.batch, cfg.prefix_tokens, cfg.d_model), np.float32)
    if cfg.n_encoder_layers:
        extra["frames"] = np.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)

    # --fused / --no-fused; unset defaults to the engine's fused route
    engine = MeshServeEngine(cfg, params, mesh=mesh, fleet=fleet,
                             max_len=max_len,
                             use_fused_kernel=(args.fused
                                               if args.fused is not None
                                               else True))
    res = engine.generate(prompts, args.gen_len,
                          temperature=args.temperature, top_k=args.top_k,
                          **extra)
    pol_name = getattr(fleet.policy, "name", "fault_tolerant")
    ages = ", ".join(f"{a:.1f}y" for a in res.ages_years)
    print(f"[serve] arch={cfg.name} mesh tp={tp} policy={pol_name} "
          f"budget={args.budget}% — ONE sharded dispatch, per-shard aging")
    print(f"[serve] shard ages: [{ages}]  device power: {res.power_w:.2f} W")
    print("[serve] per-shard BER table (rows=shards):")
    head = "         " + " ".join(f"{op:>8s}" for op in res.operators)
    print(head)
    for s in range(res.bers.shape[0]):
        row = " ".join(f"{b:8.1e}" for b in res.bers[s])
        print(f"  shard{s} {row}")
    print(f"[serve] generated {res.tokens.shape} tokens; "
          f"first row: {res.tokens[0][:12].tolist()}")
    if args.stats:
        _print_cache_stats()
    return res


if __name__ == "__main__":
    main()
