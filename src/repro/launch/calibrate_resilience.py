"""Resilience-calibration launcher: measure the zoo, fit, close the loop.

``python -m repro.launch.calibrate_resilience [--archs all|id,id,...]
[--quick] [--seeds N] [--train-steps N] [--report]``

For every requested model-zoo config (reduced, briefly trained on the
synthetic LM task) this runs the batched fault-injection characterisation
sweep — the whole BER grid x operator-domain grid of a model as vmapped
fault lanes of ONE dispatch (:mod:`repro.calibrate.resilience_sweep`) —
fits the per-operator logistic curves, and merges them into the checked-in
``src/repro/core/resilience_calibrated.json`` artifact.  Serving then
closes the loop with ``--policy measured``
(:class:`repro.core.policy.MeasuredResiliencePolicy`):
measured curves -> tolerable BERs -> per-operator ``delay_max`` ->
``simulate()`` lifetime scan -> the BERs every matmul runs at.

``--report`` regenerates the Table II policy evaluation from the measured
curves of each characterised model and prints the per-operator
measured-vs-published BER50 and the power-saving delta (the numbers quoted
in EXPERIMENTS.md §Resilience-Calibration).

``--quick`` is the CI variant: one tiny config, coarse BER grid, one seed,
interpret-mode-friendly sizes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.calibrate.resilience_sweep import (DEFAULT_BER_GRID,
                                              QUICK_BER_GRID,
                                              empirical_resilience,
                                              write_artifact)
from repro.configs import ARCH_IDS, get_config
from repro.core.artifacts import load_calibration
from repro.core.policy import (FaultTolerantPolicy, MeasuredResiliencePolicy,
                               evaluate_policy)
from repro.core.resilience import (DEFAULT_BER50, MEASURED_PATH,
                                   load_measured)
from repro.core.scenario import Scenario
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.train.steps import init_train_state, make_train_step


def _extras_for(cfg, batch: int, seed: int = 0) -> tuple:
    """Deterministic encoder frames / prefix embeddings for the non-LM
    model families — shared between training and the sweep evaluation."""
    rng = np.random.RandomState(seed)
    if cfg.n_encoder_layers:
        return (rng.randn(batch, cfg.encoder_seq,
                          cfg.d_model).astype(np.float32),)
    if cfg.prefix_tokens:
        return (rng.randn(batch, cfg.prefix_tokens,
                          cfg.d_model).astype(np.float32),)
    return ()


def _train_params(cfg, data, extras, steps: int):
    """Briefly train the reduced config so its logits carry structure the
    injection can disrupt; ``steps=0`` keeps the random init."""
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    if steps <= 0:
        return state.params
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=5)))
    extra_keys = ("frames",) if cfg.n_encoder_layers else \
        (("prefix_embeds",) if cfg.prefix_tokens else ())
    for i in range(steps):
        tb = data.batch_at(i)
        batch = {"tokens": jnp.asarray(tb.tokens),
                 "labels": jnp.asarray(tb.labels)}
        for k, v in zip(extra_keys, extras):
            batch[k] = jnp.asarray(v)
        state, _ = step(state, batch)
    return state.params


def characterise(arch: str, *, ber_grid, n_seeds: int, train_steps: int,
                 batch: int, seq_len: int, use_kernel: bool, fused: bool):
    cfg = get_config(arch).reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq_len, global_batch=batch)
    extras = _extras_for(cfg, batch)
    params = _train_params(cfg, data, extras, train_steps)
    tokens = data.batch_at(10_000).tokens          # held-out step
    t0 = time.time()
    curves, res = empirical_resilience(
        cfg, params, tokens, ber_grid=ber_grid, n_seeds=n_seeds,
        extras=extras, use_kernel=use_kernel, fused=fused, model=cfg.name)
    dt = time.time() - t0
    lanes = len(ber_grid) * len(res.operators)
    print(f"[calibrate] {arch}: {lanes} fault lanes x {n_seeds} seed(s) "
          f"in {dt:.1f}s ({lanes * n_seeds / dt:.1f} grid points/s, "
          f"one dispatch per seed)")
    for j, op in enumerate(res.operators):
        d50 = DEFAULT_BER50.get(op, float("nan"))
        print(f"    {op:>6}: measured BER50 {curves[op].ber50:.2e} "
              f"(published {d50:.2e}), knee steepness "
              f"{curves[op].steepness:.1f}/decade")
    return res, curves


def report(path: str | None = None) -> dict:
    """Measured-vs-published Table II: re-run the policy evaluation with
    each model's measured curves and report the power-saving delta."""
    cal = load_calibration()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg)
    pub = evaluate_policy(FaultTolerantPolicy(ber_model=cal.ber),
                          cal.aging, cal.delay_poly, cal.power, scn)
    print(f"[report] published curves: avg lifetime power saving "
          f"{pub['avg_power_saving_pct']:.1f}%")
    out = {"published_avg_saving_pct": pub["avg_power_saving_pct"],
           "models": {}}
    blob = load_measured(path or MEASURED_PATH)
    for arch in sorted(blob.get("models", {})):
        pol = MeasuredResiliencePolicy(ber_model=cal.ber, model=arch,
                                       artifact_path=path)
        res = evaluate_policy(pol, cal.aging, cal.delay_poly, cal.power, scn)
        delta = res["avg_power_saving_pct"] - pub["avg_power_saving_pct"]
        print(f"[report] {arch:>18}: avg saving "
              f"{res['avg_power_saving_pct']:+.1f}% "
              f"(delta vs published {delta:+.1f} pts); per-op V_final: "
              + ", ".join(f"{op}={res[op]['v_final']:.2f}"
                          for op in ("q", "k", "o", "down")))
        out["models"][arch] = {
            "avg_saving_pct": res["avg_power_saving_pct"],
            "delta_vs_published_pts": delta}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch ids, or 'all' (default: all;"
                         " with --quick: llama3_8b)")
    ap.add_argument("--quick", action="store_true",
                    help="CI variant: tiny config, coarse BER grid, 1 seed")
    ap.add_argument("--ber-grid", default=None,
                    help="comma-separated BERs (default: log grid)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="seed repeats averaged per grid point")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="brief-training steps before measuring (0: random "
                         "init)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route weight matmuls through the Pallas systolic "
                         "path (with --fused: the serving hot-path kernel; "
                         "interpret mode off-TPU — slow, same statistics)")
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--out", default=MEASURED_PATH)
    ap.add_argument("--report", action="store_true",
                    help="skip measuring; regenerate the measured-vs-"
                         "published Table II deltas from the artifact")
    args = ap.parse_args(argv)

    if args.report:
        return report(args.out if args.out != MEASURED_PATH else None)

    if args.archs:
        archs = list(ARCH_IDS) if args.archs == "all" \
            else [a.strip().replace("-", "_")
                  for a in args.archs.split(",") if a.strip()]
    else:
        archs = ["llama3_8b"] if args.quick else list(ARCH_IDS)
    if args.ber_grid:
        grid = tuple(float(b) for b in args.ber_grid.split(","))
    else:
        grid = QUICK_BER_GRID if args.quick else DEFAULT_BER_GRID
    n_seeds = args.seeds if args.seeds is not None else (1 if args.quick
                                                        else 2)
    train_steps = args.train_steps if args.train_steps is not None \
        else (8 if args.quick else 40)
    batch = args.batch or (4 if args.quick else 8)
    seq_len = args.seq_len or (32 if args.quick else 64)

    entries = {}
    for arch in archs:
        entries[arch] = characterise(
            arch, ber_grid=grid, n_seeds=n_seeds, train_steps=train_steps,
            batch=batch, seq_len=seq_len, use_kernel=args.use_kernel,
            fused=args.fused)
    meta = {"mode": "quick" if args.quick else "full",
            "ber_grid": [float(b) for b in grid], "n_seeds": n_seeds,
            "train_steps": train_steps, "batch": [batch, seq_len],
            "backend": jax.default_backend(),
            "kernel": "fused" if (args.use_kernel and args.fused)
            else ("systolic" if args.use_kernel else "jnp-oracle")}
    write_artifact(entries, meta, path=args.out)
    print(f"[calibrate] wrote {args.out} ({len(entries)} model(s))")
    return entries


if __name__ == "__main__":
    main()
