"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Composes the full stack on whatever devices exist: reduced or full config,
sharded via the production rules, fault-tolerant loop (auto-resume, async
checkpoints, straggler watchdog), deterministic synthetic data.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.distributed.sharding import (batch_spec, input_shardings,
                                        state_specs)
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.steps import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(10, args.steps // 20))
    step_fn = make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                              remat=args.remat)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)

    def init():
        return init_train_state(cfg, jax.random.PRNGKey(0))

    state_sds = jax.eval_shape(init)
    st_specs = state_specs(state_sds, cfg, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    st_shard = jax.tree.map(ns, st_specs, is_leaf=lambda s: isinstance(s, P))
    in_shard = input_shardings(cfg, mesh, args.batch, "train")
    jitted = jax.jit(step_fn, in_shardings=(st_shard, in_shard),
                     out_shardings=(st_shard, None), donate_argnums=(0,))

    def make_batch(step):
        tb = data.batch_at(step)
        extra = {}
        if cfg.prefix_tokens:
            extra["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.prefix_tokens, cfg.d_model), jnp.float32)
        if cfg.n_encoder_layers:
            extra["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        return {"tokens": jnp.asarray(tb.tokens),
                "labels": jnp.asarray(tb.labels), **extra}

    loop = TrainLoop(jitted, data, ckpt_dir=args.ckpt_dir,
                     cfg=LoopConfig(total_steps=args.steps),
                     make_batch=make_batch)
    with mesh:
        state = loop.run(init)
    final = loop.history[-1]["loss"] if loop.history else float("nan")
    print(f"[train] done: final loss {final:.4f} "
          f"(uniform {np.log(cfg.vocab):.3f})")
    return loop


if __name__ == "__main__":
    main()
