"""Scenario-sweep launcher: map the reliability/efficiency trade space.

``python -m repro.launch.sweep [--budgets 0.1,0.5,2.0] [--duties 0.3,0.5,0.7]
[--t-ambs ...] [--policy fault_tolerant]``

Builds an N-D :func:`repro.core.scenario.scenario_grid` over the requested
axes, evaluates the policy's per-operator thresholds for every cell, and
runs the ENTIRE grid x all operator domains as one vmapped lifetime scan —
a single trace/compile regardless of sweep size (the Table II computation,
generalised).  Reports per-cell lifetime power saving vs the classical-AVS
baseline of the same mission profile, plus sweep throughput.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.policy import BaselinePolicy, get_policy, sweep_policy
from repro.core.power import batched_lifetime_stats
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario, scenario_grid

AXES = {"budgets": "max_loss_pct", "duties": "duty", "toggles": "toggle",
        "t-ambs": "t_amb", "t-clks": "t_clk"}


def _floats(s: str):
    return [float(x) for x in s.split(",") if x]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--budgets", default="0.1,0.5,2.0",
                    help="accuracy budgets [% loss]")
    ap.add_argument("--duties", default="0.3,0.5,0.7",
                    help="BTI duty factors (mission profiles)")
    ap.add_argument("--toggles", default="", help="HCI toggle rates")
    ap.add_argument("--t-ambs", default="", help="ambient temperatures [K]")
    ap.add_argument("--t-clks", default="", help="clock periods [s]")
    ap.add_argument("--policy", default="fault_tolerant",
                    choices=("fault_tolerant", "baseline"))
    args = ap.parse_args(argv)

    cal = load_calibration()
    axes = {}
    for arg_name, field in AXES.items():
        vals = _floats(getattr(args, arg_name.replace("-", "_")))
        if vals:
            axes[field] = vals
    base = Scenario.from_lifetime_config(cal.lifetime_cfg)
    scn = scenario_grid(base, **axes)
    n_cells = scn.n_scenarios
    n_ops = len(OPERATORS)
    print(f"[sweep] grid {dict((k, len(v)) for k, v in axes.items())} = "
          f"{n_cells} scenarios x {n_ops} operator domains "
          f"= {n_cells * n_ops} lifetimes, ONE vmapped scan")

    if args.policy == "fault_tolerant":
        policy = get_policy("fault_tolerant", ber_model=cal.ber)
    else:
        policy = BaselinePolicy(t_clk=cal.lifetime_cfg.t_clk)

    t0 = time.time()
    traj = sweep_policy(policy, cal.aging, cal.delay_poly, scn)
    traj.V.block_until_ready()
    dt = time.time() - t0
    print(f"[sweep] trace+compile+run: {dt:.2f}s "
          f"({n_cells * n_ops / dt:.0f} lifetimes/s incl. compile)")

    # per-profile classical-AVS baseline for the power-saving comparison —
    # the budget axis is dropped (baseline ignores it) so the second vmapped
    # call simulates only the profile grid, then broadcasts back
    base_axes = {k: v for k, v in axes.items() if k != "max_loss_pct"}
    base_scn = scenario_grid(base, **base_axes)
    base_traj = sweep_policy(BaselinePolicy(t_clk=cal.lifetime_cfg.t_clk),
                             cal.aging, cal.delay_poly, base_scn)
    stats = batched_lifetime_stats(cal.power, traj)        # grid + (O,)
    base_stats = batched_lifetime_stats(cal.power, base_traj)
    base_p = base_stats["p_avg"]
    if "max_loss_pct" in axes:
        base_p = np.expand_dims(base_p, axis=list(axes).index("max_loss_pct"))
    saving = 100.0 * (1.0 - stats["p_avg"] / base_p)
    avg_saving = saving.mean(axis=-1)                      # grid
    v_final_worst = stats["v_final"].max(axis=-1)

    names = list(axes)
    flat_save = avg_saving.reshape(-1)
    flat_vf = v_final_worst.reshape(-1)
    hdr = " | ".join(f"{n:>12}" for n in names)
    print(f"\n{hdr} | {'avg saving':>10} | {'worst V_f':>9}")
    for idx in np.ndindex(*avg_saving.shape):
        cell = " | ".join(f"{axes[n][i]:>12g}" for n, i in zip(names, idx))
        k = np.ravel_multi_index(idx, avg_saving.shape)
        print(f"{cell} | {flat_save[k]:9.1f}% | {flat_vf[k]:8.2f}V")

    print(f"\n[sweep] best cell: {flat_save.max():.1f}% avg saving; "
          f"worst: {flat_save.min():.1f}%")
    return {"saving": avg_saving, "v_final": v_final_worst}


if __name__ == "__main__":
    main()
