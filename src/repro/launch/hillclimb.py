import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> verdict.

Each iteration toggles ONE optimization flag, re-runs the probe-corrected
dry-run for the target cell, and records before/after roofline terms in
``results/hillclimb/``.  EXPERIMENTS.md §Perf narrates the log.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell hc1|hc2|hc3

Optimizations under test (all off in the baselines):
  * grouped  — GShard-style per-row MoE dispatch (repro.models.moe)
  * actshard — activation sharding constraints at layer-scan boundaries
               (repro.distributed.sharding.set_activation_sharding)
  * int8     — int8 weights + per-layer-group dequant for serving
               (repro.models.transformer.quantize_params)
  * nofsdp   — disable ZeRO-3 weight sharding (small models: the per-layer
               weight gathers cost more than the memory saved)
"""
import argparse
import functools
import json
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import (batch_spec, param_specs,
                                        set_activation_sharding)
from repro.launch import analysis
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro.models import moe as moe_lib
from repro.models import transformer as tf

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "hillclimb")


def run_variant(arch: str, shape: str, *, flags: Tuple[str, ...],
                tag: str) -> Dict:
    """Probe-corrected roofline for (arch, shape) with optimizations on."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh()
    fsdp = "nofsdp" not in flags

    if "grouped" in flags:
        moe_lib.MOE_DISPATCH = "grouped"
    if "actshard" in flags:
        b = batch_spec(cell.global_batch, mesh)
        set_activation_sharding(NamedSharding(mesh, P(b, None, None)))

    int8 = "int8" in flags and cell.kind != "train"
    serve_fsdp = "fsdp_serve" in flags

    def build(pcfg, pcell, **kw):
        if not int8:
            return dr.build_lowered(pcfg, pcell, mesh, fsdp=fsdp,
                                    serve_fsdp=serve_fsdp, **kw)
        # int8 serving: quantised abstract params replace bf16 ones
        return _build_int8(pcfg, pcell, mesh, serve_fsdp=serve_fsdp, **kw)

    try:
        # full-cell compile (the fits/shardability proof)
        mb = (max(1, cell.global_batch //
                  (16 if not cell.global_batch % 16 else 1))
              if cell.kind == "train" else 1)
        lowered, info = build(cfg, cell, microbatches=mb if cell.kind ==
                              "train" else 1, remat=True)
        compiled = lowered.compile()
        report: Dict = {"arch": arch, "shape": shape, "tag": tag,
                        "flags": list(flags), **info}
        mem = compiled.memory_analysis()
        if mem is not None:
            report["temp_size_in_bytes"] = int(
                getattr(mem, "temp_size_in_bytes", 0))

        # probes under the same flags
        import dataclasses
        seqs = dr.probe_seqs(cell)
        grid = {}
        with dr.probe_mode():
            for units in dr.PROBE_UNITS:
                pcfg = dr.probe_config(cfg, units)
                for S in seqs:
                    pcell = dataclasses.replace(cell, seq_len=S)
                    low, _ = build(pcfg, pcell, microbatches=1, remat=True)
                    grid[(units, S)] = dr._compiled_costs(low.compile())
        import numpy as np
        U, S_t = dr.layer_units(cfg), cell.seq_len
        pc = {}
        for m in sorted(grid[(1, seqs[0])].keys()):
            a = np.array([grid[(1, s)][m] for s in seqs])
            bvec = np.array([grid[(2, s)][m] - grid[(1, s)][m]
                             for s in seqs])
            val = float(np.polyval(np.polyfit(np.array(seqs, float), a, 2),
                                   S_t)
                        + (U - 1) * np.polyval(
                            np.polyfit(np.array(seqs, float), bvec, 2), S_t))
            pc[m] = max(val, 0.0)
        report["probe_costs"] = pc

        n_text = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                      else 1)
        hbm = analysis.analytic_hbm_bytes(
            cfg, cell, mesh, microbatches=mb if cell.kind == "train" else 1,
            fsdp=fsdp)
        if int8:   # int8 weights; decode also carries the int8 KV cache
            hbm["weights"] *= 0.5
            if cell.kind == "decode":
                hbm["cache"] *= 0.53     # int8 payload + f32 scale per head
            hbm["total"] = sum(v for k, v in hbm.items() if k != "total")
        report["hbm_model"] = hbm
        terms = analysis.RooflineTerms(
            flops=pc["flops"] * mesh.size,
            hbm_bytes=hbm["total"] * mesh.size,
            coll_bytes_per_dev=pc["coll_total"], n_devices=int(mesh.size),
            model_flops=analysis.model_flops_for(cfg, cell, n_text))
        report["roofline"] = terms.to_dict()
    finally:
        moe_lib.MOE_DISPATCH = "global"
        set_activation_sharding(None)
    return report


def _build_int8(cfg, cell, mesh, *, microbatches=1, remat=True,
                serve_fsdp=False):
    """Serve-cell lowering with int8-quantised abstract params."""
    from repro.serve import steps as serve_steps
    ns = lambda s: NamedSharding(mesh, s)
    inputs = dr.input_specs(cfg, cell)
    from repro.distributed.sharding import input_shardings, cache_specs
    in_shard = input_shardings(cfg, mesh, cell.global_batch, cell.kind)

    params_sds = jax.eval_shape(
        lambda k: tf.quantize_params(tf.init_params(cfg, k, jnp.bfloat16)),
        jax.random.PRNGKey(0))
    p_specs = param_specs(params_sds, cfg, mesh, fsdp=serve_fsdp)
    pshard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))
    info = {"state_bytes_per_dev": dr._tree_bytes_per_device(
        params_sds, p_specs, mesh)}

    if cell.kind == "prefill":
        step = serve_steps.make_prefill_step(cfg, max_len=cell.seq_len)
        jitted = jax.jit(step, in_shardings=(pshard, in_shard["tokens"]))
        return jitted.lower(params_sds, inputs["tokens"]), info

    cache_sds = jax.eval_shape(
        lambda: tf.init_cache(cfg, cell.global_batch, cell.seq_len,
                              quantized=True))
    c_specs = cache_specs(cfg, mesh, cell.global_batch)
    # expand each bf16 K/V spec to the {int8_q, int8_s} pair (same layout;
    # the scale's trailing dim is 1 so the identical spec applies)
    c_specs = jax.tree.map(lambda sp: {"int8_q": sp, "int8_s": sp},
                           c_specs, is_leaf=lambda x: isinstance(x, P))
    cshard = jax.tree.map(ns, c_specs, is_leaf=lambda s: isinstance(s, P))
    info["state_bytes_per_dev"] += dr._tree_bytes_per_device(
        cache_sds, c_specs, mesh)
    step = serve_steps.make_decode_step(cfg)
    jitted = jax.jit(step, in_shardings=(pshard, in_shard["tokens"], cshard,
                                         ns(P())),
                     out_shardings=(None, cshard))
    return (jitted.lower(params_sds, inputs["tokens"], cache_sds,
                         inputs["cache_len"]), info)


CLIMBS = {
    # worst useful-FLOPs cell: global-cumsum MoE dispatch
    "hc1": ("qwen3_moe_235b", "train_4k",
            [("grouped",), ("grouped", "actshard")]),
    # most collective-bound dense cell: scan-boundary resharding
    "hc2": ("deepseek_7b", "train_4k",
            [("actshard",), ("actshard", "nofsdp")]),
    # paper-representative serving cell (LLaMA-class decode): int8
    # systolic-native weights + int8 KV cache.  (The first int8 attempt on
    # prefill_32k is kept in results/ as a REFUTED hypothesis: prefill
    # memory traffic is activation-dominated, weights are <1%.)
    "hc3": ("deepseek_7b", "decode_32k",
            [("int8",), ("int8", "actshard")]),
    # bonus HC4 — the one HBM-violating cell: 480B MoE serving weights do
    # not fit under TP-only sharding (60 GiB/dev); 2-D (data x model)
    # weight sharding + int8 brings state under the 16 GiB budget at the
    # cost of per-layer weight gathers (the trade is recorded).
    "hc4": ("arctic_480b", "decode_32k",
            [("fsdp_serve",), ("fsdp_serve", "int8")]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=tuple(CLIMBS) + ("all",),
                    default="all")
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    names = list(CLIMBS) if args.cell == "all" else [args.cell]
    for name in names:
        arch, shape, iterations = CLIMBS[name]
        base_path = dr.cell_path(arch, shape, False)
        with open(base_path) as f:
            base = json.load(f)
        print(f"[{name}] baseline {arch}/{shape}: "
              f"t=({base['roofline']['t_compute']:.2e}, "
              f"{base['roofline']['t_memory']:.2e}, "
              f"{base['roofline']['t_collective']:.2e}) "
              f"dom={base['roofline']['dominant']} "
              f"roofline={100 * (base['roofline']['roofline_frac'] or 0):.2f}%",
              flush=True)
        for flags in iterations:
            tag = "+".join(flags)
            out = os.path.join(RESULTS_DIR, f"{name}__{tag}.json")
            if os.path.exists(out):
                with open(out) as f:
                    rep = json.load(f)
            else:
                rep = run_variant(arch, shape, flags=flags, tag=tag)
                with open(out, "w") as f:
                    json.dump(rep, f, indent=1)
            rt = rep["roofline"]
            print(f"[{name}] {tag:20s}: "
                  f"t=({rt['t_compute']:.2e}, {rt['t_memory']:.2e}, "
                  f"{rt['t_collective']:.2e}) dom={rt['dominant']} "
                  f"roofline={100 * (rt['roofline_frac'] or 0):.2f}%",
                  flush=True)


if __name__ == "__main__":
    main()
