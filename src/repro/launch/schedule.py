"""Scheduler launcher: route traffic to slow down fleet aging.

``python -m repro.launch.schedule [--n-devices 8] [--workload diurnal]
[--routers round_robin,least_loaded,least_aged,wear_level] [...]``

Builds a heterogeneous fleet — a rack thermal gradient
(``--t-amb-spread``) on top of a staggered deployment
(``--stagger-years``) — synthesises an offered-load trace from the
requested arrival model, and co-simulates the SAME traffic under each
routing policy: one jitted routing -> stress -> ΔVth -> policy-voltage
scan per router (``repro.sched.lifetime.cosimulate``).  Reports
fleet-max ΔVth, wear spread, lifetime fleet power and worst end-of-life
supply per router, plus the wear-leveling headline: how much of the
round-robin fleet's worst-case degradation the ``wear_level`` router
removes by treating routing as an aging actuator (the paper's 45.8 % /
30.6 % degradation-reduction story, lifted from one device's voltage
policy to the fleet's traffic policy).

``--scenario`` switches from the router comparison to a disruption
scenario (:mod:`repro.sched.disruption`): ``flash_crowd`` (sustained
overload under the closed thermal loop), ``retirement`` (mid-horizon
device retirement/hot-swap with trap-state-preserving resize + remesh
plan) or ``rest_to_recover`` (deliberate idling to harvest short-term
recovery).  ``--recovery`` / ``--thermal`` enable the short-term
recoverable trap pool and the routed-power thermal RC node on any
scenario, including the default router comparison.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.constants import T_AMB
from repro.core.policy import BaselinePolicy, get_policy
from repro.core.scenario import Scenario
from repro.sched import compare_routers, get_workload
from repro.sched.lifetime import HEAT_PER_UTIL_K
from repro.sched.router import ROUTER_REGISTRY
from repro.sched.workload import WORKLOADS

YEAR_S = 365.25 * 24 * 3600.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=8, help="fleet size")
    ap.add_argument("--workload", default="diurnal",
                    choices=sorted(WORKLOADS),
                    help="request-arrival model")
    ap.add_argument("--routers",
                    default="round_robin,least_loaded,least_aged,"
                            "wear_level",
                    help=f"comma list from {sorted(ROUTER_REGISTRY)}")
    ap.add_argument("--epochs", type=int, default=480,
                    help="scheduling epochs over the horizon")
    ap.add_argument("--horizon-years", type=float, default=5.0,
                    help="service horizon of the co-simulation")
    ap.add_argument("--utilization", type=float, default=0.55,
                    help="mean offered load / fleet capacity")
    ap.add_argument("--t-amb-spread", type=float, default=30.0,
                    help="rack thermal gradient across the fleet [K]")
    ap.add_argument("--stagger-years", type=float, default=7.0,
                    help="age of the oldest device at t=0 (staggered "
                         "deployment; 0 = fresh fleet)")
    ap.add_argument("--heat-per-util", type=float, default=HEAT_PER_UTIL_K,
                    help="load-induced heating at full utilization [K]")
    ap.add_argument("--budget", type=float, default=0.5,
                    help="accuracy budget [%% loss] of the AVS policy")
    ap.add_argument("--policy", default="fault_tolerant",
                    choices=("fault_tolerant", "baseline"))
    ap.add_argument("--seed", type=int, default=0,
                    help="arrival-noise stream")
    ap.add_argument("--scenario", default="routers",
                    choices=("routers", "flash_crowd", "retirement",
                             "rest_to_recover"),
                    help="router comparison (default) or a disruption "
                         "scenario from repro.sched.disruption")
    ap.add_argument("--recovery", action="store_true",
                    help="model the short-term recoverable trap pool")
    ap.add_argument("--thermal", action="store_true",
                    help="close the temperature loop on routed power "
                         "(thermal RC node instead of t_amb + heat*util)")
    ap.add_argument("--surge-gain", type=float, default=4.0,
                    help="flash-crowd load multiplier")
    ap.add_argument("--retire-epoch", type=int, default=None,
                    help="retirement epoch (default: mid-horizon)")
    ap.add_argument("--retire-devices", type=int, default=1,
                    help="number of (most-worn-slot) devices to retire")
    ap.add_argument("--hot-swap", type=int, default=0,
                    help="fresh replacements taking retired rack slots")
    args = ap.parse_args(argv)

    if args.scenario != "routers":
        return _run_disruption(args)

    cal = load_calibration()
    n = args.n_devices
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg,
                                        max_loss_pct=args.budget).replace(
        lifetime_s=args.horizon_years * YEAR_S)
    if args.t_amb_spread:
        scn = scn.replace(t_amb=jnp.asarray(
            T_AMB + np.linspace(0.0, args.t_amb_spread, n), jnp.float32))
    if args.policy == "fault_tolerant":
        policy = get_policy("fault_tolerant", ber_model=cal.ber)
    else:
        policy = BaselinePolicy(t_clk=cal.lifetime_cfg.t_clk)

    wl = get_workload(args.workload, n_devices=n,
                      utilization=args.utilization, n_epochs=args.epochs)
    loads = wl.loads(args.seed)
    ages = np.linspace(0.0, args.stagger_years, n) * YEAR_S
    routers = tuple(r for r in args.routers.split(",") if r)

    print(f"[schedule] fleet of {n} devices | workload={args.workload} "
          f"(mean util {args.utilization:.2f}, {args.epochs} epochs over "
          f"{args.horizon_years:g}y) | policy={args.policy} "
          f"budget={args.budget}%")
    print(f"[schedule] heterogeneity: t_amb +[0..{args.t_amb_spread:g}]K, "
          f"deployment ages [0..{args.stagger_years:g}]y; ONE jitted "
          f"co-sim scan per router")

    res = compare_routers(cal, scn, policy, loads, routers=routers,
                          n_devices=n, ages_s=ages,
                          heat_per_util=args.heat_per_util,
                          recovery_dynamics=args.recovery or None,
                          thermal=args.thermal or None)

    hdr = (f"{'router':>12} | {'max ΔVth':>9} | {'spread':>7} | "
           f"{'P_avg fleet':>11} | {'worst V_f':>9} | {'served':>6}")
    print("\n" + hdr + "\n" + "-" * len(hdr))
    for name in routers:
        s = res[name]
        print(f"{name:>12} | {s['fleet_max_dvp_mv']:7.1f}mV | "
              f"{s['wear_spread_mv']:5.1f}mV | {s['p_avg_w']:9.2f} W | "
              f"{s['v_final_max']:8.3f}V | {100 * s['served_frac']:5.1f}%")

    if "round_robin" in res and "wear_level" in res:
        rr, wlv = res["round_robin"], res["wear_level"]
        d_dvp = 100.0 * (1.0 - wlv["fleet_max_dvp_mv"]
                         / rr["fleet_max_dvp_mv"])
        d_p = 100.0 * (1.0 - wlv["p_avg_w"] / rr["p_avg_w"])
        print(f"\n[schedule] wear_level vs round_robin: fleet-max ΔVth "
              f"-{d_dvp:.1f}%, lifetime fleet power -{d_p:.2f}% "
              f"(routing as the fleet-scale aging knob, cf. the paper's "
              f"45.8%/30.6% single-device AVS headline)")
    return res


def _run_disruption(args):
    """Dispatch ``--scenario`` to the repro.sched.disruption drivers."""
    from repro.sched.disruption import (run_flash_crowd,
                                       run_rest_to_recover,
                                       run_retirement)
    common = dict(n_devices=args.n_devices, epochs=args.epochs,
                  horizon_years=args.horizon_years,
                  utilization=args.utilization, seed=args.seed)
    if args.scenario == "flash_crowd":
        out = run_flash_crowd(surge_gain=args.surge_gain,
                              recovery=True, thermal=True,
                              t_amb_spread=args.t_amb_spread, **common)
        s = out["stats"]
        print(f"[disrupt] flash crowd x{args.surge_gain:g} over epochs "
              f"[{s['surge_start']}, {s['surge_end']}): served "
              f"{100 * s['surge_served_frac']:.1f}% of surge traffic | "
              f"node T peak {s['t_peak_k']:.1f}K "
              f"(fleet-mean rise +{s['t_surge_rise_k']:.1f}K, steady "
              f"{s['t_steady_k']:.1f}K) | fleet-max ΔVth "
              f"{s['fleet_max_dvp_mv']:.1f}mV (recovered pool "
              f"{s.get('recovered_mv_final', 0.0):.1f}mV)")
        return out
    if args.scenario == "retirement":
        retire = tuple(range(args.retire_devices))
        out = run_retirement(retire=retire, hot_swap=args.hot_swap,
                             retire_epoch=args.retire_epoch,
                             workload=args.workload,
                             recovery=True,
                             thermal=args.thermal or None,
                             t_amb_spread=args.t_amb_spread, **common)
        s = out["stats"]
        pd = out["plan_degraded"]
        print(f"[disrupt] retired {s['retired']} at epoch "
              f"{s['retire_epoch']}: fleet {s['n_before']} -> "
              f"{s['n_after']} devices | remesh "
              f"{dict(zip(pd.axis_names, pd.old_shape))} -> "
              f"{dict(zip(pd.axis_names, pd.new_shape))} "
              f"(microbatches {pd.microbatches}) | survivors resumed "
              f"bit-exactly at {s['survivor_pre_max_dvp_mv']:.1f}mV, "
              f"end of horizon {s['fleet_max_dvp_mv']:.1f}mV")
        return out
    out = run_rest_to_recover(workload=args.workload,
                              t_amb_spread=args.t_amb_spread,
                              stagger_years=args.stagger_years,
                              recovery=True,
                              thermal=args.thermal or None, **common)
    h = out["headline"]
    print(f"[disrupt] rest_to_recover vs round_robin: fleet-max ΔVth "
          f"-{h['rest_vs_round_robin_pct']:.1f}% (relaxed pool "
          f"{h['recovered_mv_final']:.1f}mV harvested by resting the "
          f"most-worn devices)")
    return out


if __name__ == "__main__":
    main()
