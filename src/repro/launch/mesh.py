"""Production mesh builders (functions, not constants — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: batch shards over ("pod", "data"); tensor/expert parallelism over
    "model".  Requires 256 (512 multi-pod) visible devices — the dry-run
    sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
    jax import to fake them on CPU.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever devices exist, data-major (CPU tests / small runs)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(tp: int | None = None):
    """Tensor-parallel serving mesh: ``("data", "model")`` with model=tp.

    Default tp: every visible device (the single-replica big-model case
    ``repro.serve.sharded.MeshServeEngine`` targets).
    """
    n = len(jax.devices())
    tp = n if tp is None else int(tp)
    assert n % tp == 0, (n, tp)
    return jax.make_mesh((n // tp, tp), ("data", "model"))
