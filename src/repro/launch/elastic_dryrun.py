import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Elastic-scaling dry-run: prove the job re-lowers after losing capacity.

Scenario: a 256-chip pod loses a 16-chip slice mid-run.  The elastic plan
(`repro.distributed.elastic.plan_remesh`) shrinks the data axis 16 -> 15
... except the global batch (256) does not divide 15, so the planner backs
off to the largest feasible DP width (8) and doubles microbatches to keep
the global batch — training curves unchanged.  This script lowers+compiles
the SAME train step on the degraded mesh and re-shards the (abstract)
state, demonstrating checkpoint-boundary elasticity without real hardware.

    PYTHONPATH=src python -m repro.launch.elastic_dryrun [--arch deepseek_7b]
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.distributed.elastic import plan_remesh
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--lost-chips", type=int, default=16)
    args = ap.parse_args()

    cell = SHAPES["train_4k"]
    full_mesh = make_production_mesh()
    n_new = int(full_mesh.size) - args.lost_chips
    plan = plan_remesh(full_mesh, n_new, global_batch=cell.global_batch,
                       old_microbatches=cell.global_batch // 16)
    print(f"[elastic] {full_mesh.size} chips -> {n_new}: new mesh "
          f"{dict(zip(plan.axis_names, plan.new_shape))}, "
          f"microbatches {plan.microbatches} (global batch preserved)")

    mesh = jax.make_mesh(plan.new_shape, plan.axis_names)
    cfg = get_config(args.arch)
    lowered, info = dr.build_lowered(cfg, cell, mesh,
                                     microbatches=plan.microbatches,
                                     fsdp=True, remat=True)
    compiled = lowered.compile()
    report = {"arch": args.arch, "mesh": list(plan.new_shape),
              "microbatches": plan.microbatches, **info}
    mem = compiled.memory_analysis()
    if mem is not None:
        report["temp_size_in_bytes"] = int(
            getattr(mem, "temp_size_in_bytes", 0))
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS,
                       f"elastic__{args.arch}__train_4k__{n_new}chips.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"[elastic] degraded-mesh train step compiles: state "
          f"{report['state_bytes_per_dev'] / 2**30:.2f} GiB/dev -> {out}")


if __name__ == "__main__":
    main()
