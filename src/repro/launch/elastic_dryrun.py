import os
import sys

_QUICK = "--quick" in sys.argv
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + ("16" if _QUICK else "512"))

"""Elastic-scaling dry-run: prove the job re-lowers after losing capacity.

Scenario: a serving fleet retires a device mid-horizon (maintenance or
failure).  The retirement is driven end to end through
:func:`repro.sched.disruption.run_retirement` — the fleet co-simulation
ages every lane under routed traffic, the retired lane leaves the
rotation with the survivors resuming *bit-exactly* from their
accumulated trap state, and the matching serving-mesh change comes back
as a :class:`repro.distributed.elastic.RemeshPlan`.  This script then
lowers+compiles the SAME train step on the degraded mesh, demonstrating
checkpoint-boundary elasticity without real hardware: the model (TP)
axis is pinned, data parallelism absorbs the delta, and microbatches
rescale so the global batch (and the training curves) are unchanged.

    PYTHONPATH=src python -m repro.launch.elastic_dryrun [--arch deepseek_7b]

``--quick`` shrinks everything (16 fake chips, reduced arch, tiny shape
cell, short co-sim) for a CI subprocess smoke test.
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeCell
from repro.launch import dryrun as dr
from repro.sched.disruption import run_retirement

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--retire-lanes", type=int, default=1,
                    help="fleet lanes (TP groups) retired mid-horizon")
    ap.add_argument("--hot-swap", type=int, default=0,
                    help="fresh lanes taking the retired rack slots")
    ap.add_argument("--quick", action="store_true",
                    help="reduced arch + tiny mesh/cell for CI smoke")
    args = ap.parse_args()

    if args.quick:
        n_lanes, tp, epochs = 4, 2, 16
        cell = ShapeCell("train_quick", 128, 16, "train")
    else:
        n_lanes, tp, epochs = 16, 16, 48
        cell = SHAPES["train_4k"]

    # Fleet side: retire the worst rack slots, survivors keep trap state.
    out = run_retirement(n_devices=n_lanes,
                         retire=tuple(range(args.retire_lanes)),
                         hot_swap=args.hot_swap, epochs=epochs,
                         tp=tp, global_batch=cell.global_batch)
    plan = out["plan_degraded"]
    s = out["stats"]
    old_chips, new_chips = n_lanes * tp, len(out["keep"]) * tp
    print(f"[elastic] {old_chips} chips -> {new_chips} (retired lanes "
          f"{s['retired']} at epoch {s['retire_epoch']}): new mesh "
          f"{dict(zip(plan.axis_names, plan.new_shape))}, "
          f"microbatches {plan.microbatches} (global batch preserved); "
          f"survivors resumed at {s['survivor_pre_max_dvp_mv']:.1f}mV")

    # Serving side: the SAME train step compiles on the degraded mesh.
    mesh = jax.make_mesh(plan.new_shape, plan.axis_names)
    cfg = get_config(args.arch)
    if args.quick:
        cfg = cfg.reduced()
    lowered, info = dr.build_lowered(cfg, cell, mesh,
                                     microbatches=plan.microbatches,
                                     fsdp=True, remat=True)
    compiled = lowered.compile()
    report = {"arch": args.arch, "quick": args.quick,
              "mesh": list(plan.new_shape),
              "microbatches": plan.microbatches,
              "retired": list(s["retired"]),
              "retire_epoch": int(s["retire_epoch"]),
              "survivor_pre_max_dvp_mv": float(
                  s["survivor_pre_max_dvp_mv"]),
              "fleet_max_dvp_mv": float(s["fleet_max_dvp_mv"]),
              "plan_restored": (dataclasses.asdict(out["plan_restored"])
                                if out["plan_restored"] else None),
              **info}
    mem = compiled.memory_analysis()
    if mem is not None:
        report["temp_size_in_bytes"] = int(
            getattr(mem, "temp_size_in_bytes", 0))
    os.makedirs(RESULTS, exist_ok=True)
    out_path = os.path.join(
        RESULTS, f"elastic__{args.arch}__{cell.name}__{new_chips}chips"
                 f"{'__quick' if args.quick else ''}.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"[elastic] degraded-mesh train step compiles: state "
          f"{report['state_bytes_per_dev'] / 2**30:.2f} GiB/dev -> "
          f"{out_path}")


if __name__ == "__main__":
    main()
