import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: 512
placeholder CPU devices stand in for 2 TPU pods; ``jax.jit(...).lower(...)
.compile()`` runs the full GSPMD partitioner, so sharding mismatches,
unsupported collectives, and compile-time OOMs surface here exactly as they
would on the real mesh.  ``memory_analysis``/``cost_analysis`` plus the HLO
collective parse feed EXPERIMENTS.md §Dry-run and §Roofline.

The XLA_FLAGS line above MUST precede any jax import (device count locks on
first init) and is deliberately NOT set globally — smoke tests and benches
see 1 device.

**Scan-trip correction (probes).**  XLA's cost_analysis counts a
``lax.scan``/while body ONCE, ignoring the trip count (verified in
``tests/test_dryrun_analysis.py``), so the raw numbers for a 94-layer
scanned model undercount by ~94x.  We therefore compile *probe* variants of
each cell whose every scan has trip count 1 — depth ``units x pattern``
folded into one scan body via ``block_pattern`` replication, attention /
RWKV chunk scans forced single-chunk, whisper stacks unrolled — at depth
units {1, 2} and three sequence lengths, then fit

    cost(U, S) = alpha(S) + (U - 1) * beta(S),   alpha/beta quadratic in S

and evaluate at the real (U, S).  The quadratic captures attention's S^2
exactly; linear-cost archs get ~0 curvature.  The probes run on the SAME
512-device mesh, so GSPMD's real collective insertion is measured, not
modelled.  The full cell is still compiled as-is for the compile/sharding
proof, memory analysis, and the collective-op inventory.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_7b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import contextlib
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, get_config
from repro.configs.shapes import SHAPES, ShapeCell, applicable
from repro.distributed.sharding import (batch_spec, cache_specs,
                                        encdec_cache_spec, input_shardings,
                                        param_specs, state_specs)
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_lib
from repro.models import encdec
from repro.models import rwkv6 as rwkv_lib
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.serve import steps as serve_steps
from repro.train.steps import TrainState, init_train_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

ARCHS = ("arctic_480b", "qwen3_moe_235b", "recurrentgemma_2b",
         "whisper_large_v3", "deepseek_7b", "command_r_plus_104b",
         "starcoder2_7b", "granite_20b", "rwkv6_3b", "paligemma_3b")

# bf16 AdamW moments for the 480B MoE: f32 moments (8 B/param) exceed a
# single pod's 4 TB HBM for 480B params — EXPERIMENTS.md §Dry-run records
# the arithmetic.  All other archs use f32 moments.
BF16_MOMENT_ARCHS = ("arctic_480b",)

PROBE_UNITS = (1, 2)


@contextlib.contextmanager
def probe_mode():
    """Force every model scan to trip count 1 (see module docstring)."""
    attn_lib.FORCE_SINGLE_CHUNK = True
    rwkv_lib.FORCE_SINGLE_CHUNK = True
    encdec.PROBE_UNROLL = True
    try:
        yield
    finally:
        attn_lib.FORCE_SINGLE_CHUNK = False
        rwkv_lib.FORCE_SINGLE_CHUNK = False
        encdec.PROBE_UNROLL = False


def probe_config(cfg: ModelConfig, units: int) -> ModelConfig:
    """Depth = units x pattern, folded into ONE layer-scan group."""
    pat = cfg.block_pattern * units
    return dataclasses.replace(
        cfg, n_layers=len(pat), block_pattern=pat,
        n_encoder_layers=units if cfg.n_encoder_layers else 0)


def probe_seqs(cell: ShapeCell) -> Tuple[int, ...]:
    if cell.kind == "train":
        return (1024, 2048, 4096)
    if cell.kind == "prefill":
        return (2048, 4096, 8192)
    return (4096, 8192, 16384)       # decode: cache depth


def layer_units(cfg: ModelConfig) -> float:
    return cfg.n_layers / len(cfg.block_pattern)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.global_batch, cell.seq_len
    out: Dict[str, Any] = {}
    if cell.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["labels"] = _sds((B, S), jnp.int32)
    elif cell.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        out["tokens"] = _sds((B, 1), jnp.int32)
        out["cache_len"] = _sds((), jnp.int32)
    if cfg.prefix_tokens:
        out["prefix_embeds"] = _sds((B, cfg.prefix_tokens, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.n_encoder_layers:
        out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    init = encdec.init_params if cfg.n_encoder_layers else tf.init_params
    return jax.eval_shape(lambda k: init(cfg, k, dtype), jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.n_encoder_layers:
        return jax.eval_shape(lambda: encdec.init_cache(cfg, batch, max_len))
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len))


def _tree_bytes_per_device(tree, specs, mesh) -> int:
    """Per-device bytes of a sharded abstract pytree."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs, is_leaf=lambda s:
                                          isinstance(s, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                shards *= mesh.shape[a]
        total += n * leaf.dtype.itemsize // max(shards, 1)
    return total


# --------------------------------------------------------------------------- #
def build_lowered(cfg: ModelConfig, cell: ShapeCell, mesh, *,
                  microbatches: int = 1, fsdp: bool = True,
                  remat: bool = True, moments_dtype=jnp.float32,
                  serve_fsdp: bool = False, sharding_overrides=None):
    """Lower one (cfg, cell) on ``mesh``; returns (lowered, info).

    ``serve_fsdp``: additionally shard serving weights over the data axes
    (2-D expert/weight sharding — §Perf HC4: a 480B MoE's weights do not
    fit one device's HBM under TP-only sharding; the per-layer weight
    gather is the memory-vs-bandwidth trade, taken deliberately).
    """
    ns = lambda spec: NamedSharding(mesh, spec)
    inputs = input_specs(cfg, cell)
    in_shard = input_shardings(cfg, mesh, cell.global_batch, cell.kind)
    info: Dict[str, Any] = {}

    if cell.kind == "train":
        def init():
            st = init_train_state(cfg, jax.random.PRNGKey(0),
                                  dtype=jnp.bfloat16)
            opt = st.opt._replace(
                mu=jax.tree.map(lambda x: x.astype(moments_dtype), st.opt.mu),
                nu=jax.tree.map(lambda x: x.astype(moments_dtype), st.opt.nu))
            return TrainState(st.params, opt, None)

        state_sds = jax.eval_shape(init)
        st_specs = state_specs(state_sds, cfg, mesh, fsdp=fsdp)
        if sharding_overrides:
            st_specs = sharding_overrides(st_specs)
        step = make_train_step(cfg, AdamWConfig(), microbatches=microbatches,
                               remat=remat)
        batch_shard = {k: in_shard.get(k, ns(P())) for k in inputs}
        st_shard = jax.tree.map(ns, st_specs,
                                is_leaf=lambda s: isinstance(s, P))
        jitted = jax.jit(step, in_shardings=(st_shard, batch_shard),
                         out_shardings=(st_shard, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, inputs)
        info["state_bytes_per_dev"] = _tree_bytes_per_device(
            state_sds, st_specs, mesh)
        return lowered, info

    params_sds = abstract_params(cfg)
    p_specs = param_specs(params_sds, cfg, mesh, fsdp=serve_fsdp)
    if sharding_overrides:
        p_specs = sharding_overrides(p_specs)
    pshard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))

    if cell.kind == "prefill":
        step = serve_steps.make_prefill_step(cfg, max_len=cell.seq_len)
        args = [params_sds, inputs["tokens"]]
        arg_shards = [pshard, in_shard["tokens"]]
        if cfg.n_encoder_layers:
            args.append(inputs["frames"])
            arg_shards.append(in_shard["frames"])
        elif cfg.prefix_tokens:
            args.append(inputs["prefix_embeds"])
            arg_shards.append(in_shard["prefix_embeds"])
        jitted = jax.jit(step, in_shardings=tuple(arg_shards))
        lowered = jitted.lower(*args)
        info["state_bytes_per_dev"] = _tree_bytes_per_device(
            params_sds, p_specs, mesh)
        return lowered, info

    # decode
    b = batch_spec(cell.global_batch, mesh)
    cache_sds = abstract_cache(cfg, cell.global_batch, cell.seq_len)
    if cfg.n_encoder_layers:
        c_specs = encdec_cache_spec(cfg, mesh, cell.global_batch)
        kv_sds = jax.eval_shape(
            lambda p, e: encdec.cross_kv(p, cfg, e), params_sds,
            _sds((cell.global_batch, cfg.encoder_seq, cfg.d_model),
                 jnp.bfloat16))
        kv_specs = jax.tree.map(lambda _: P(None, b, None, None, None),
                                kv_sds)
    else:
        c_specs = cache_specs(cfg, mesh, cell.global_batch)
    step = serve_steps.make_decode_step(cfg)
    cshard = jax.tree.map(ns, c_specs, is_leaf=lambda s: isinstance(s, P))
    args = [params_sds, inputs["tokens"], cache_sds, inputs["cache_len"]]
    arg_shards = [pshard, in_shard["tokens"], cshard, ns(P())]
    if cfg.n_encoder_layers:
        args.append(kv_sds)
        arg_shards.append(jax.tree.map(
            ns, kv_specs, is_leaf=lambda s: isinstance(s, P)))
    jitted = jax.jit(step, in_shardings=tuple(arg_shards),
                     out_shardings=(None, cshard))
    lowered = jitted.lower(*args)
    info["state_bytes_per_dev"] = _tree_bytes_per_device(
        params_sds, p_specs, mesh) + _tree_bytes_per_device(
        cache_sds, c_specs, mesh)
    return lowered, info


def _compiled_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = analysis.collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        out[f"coll_{k}"] = float(v)
    return out


def probe_costs(cfg: ModelConfig, cell: ShapeCell, mesh, *,
                fsdp: bool = True, remat: bool = True,
                moments_dtype=jnp.float32,
                sharding_overrides=None) -> Dict[str, Any]:
    """Scan-trip-corrected per-device costs via the (U, S) probe grid."""
    seqs = probe_seqs(cell)
    grid: Dict[Tuple[int, int], Dict[str, float]] = {}
    with probe_mode():
        for units in PROBE_UNITS:
            pcfg = probe_config(cfg, units)
            for S in seqs:
                pcell = dataclasses.replace(cell, seq_len=S)
                lowered, _ = build_lowered(
                    pcfg, pcell, mesh, microbatches=1, fsdp=fsdp,
                    remat=remat, moments_dtype=moments_dtype,
                    sharding_overrides=sharding_overrides)
                grid[(units, S)] = _compiled_costs(lowered.compile())

    U = layer_units(cfg)
    S_t = cell.seq_len
    metrics = sorted(grid[(1, seqs[0])].keys())
    out: Dict[str, Any] = {"probe_grid": {f"u{u}_s{s}": grid[(u, s)]
                                          for (u, s) in grid}}
    for m in metrics:
        alphas = np.array([grid[(1, s)][m] for s in seqs])
        betas = np.array([grid[(2, s)][m] - grid[(1, s)][m] for s in seqs])
        a_fit = np.polyfit(np.array(seqs, float), alphas, 2)
        b_fit = np.polyfit(np.array(seqs, float), betas, 2)
        val = float(np.polyval(a_fit, S_t) + (U - 1.0)
                    * np.polyval(b_fit, S_t))
        # monotone safeguard: XLA occasionally optimises the 2-unit probe
        # harder than the 1-unit one (observed for whisper's unrolled
        # stacks), sending the depth slope negative; the extrapolation must
        # never fall below the largest measured probe.
        floor = max(grid[(u, s)][m] for u in PROBE_UNITS for s in seqs)
        out[m] = max(val, floor, 0.0)
    return out


# --------------------------------------------------------------------------- #
def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               microbatches: Optional[int] = None, fsdp: bool = True,
               remat: bool = True, probes: bool = True,
               sharding_overrides=None) -> Tuple[Any, Dict[str, Any]]:
    """Build + lower + compile one cell.  Returns (compiled, report)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = applicable(cfg, cell)
    if not ok:
        return None, {"arch": arch, "shape": shape, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    b = batch_spec(cell.global_batch, mesh)
    dp = 1
    for a in (b if isinstance(b, tuple) else ((b,) if b else ())):
        dp *= mesh.shape[a]
    report: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": dict(zip(mesh.axis_names,
                         (int(mesh.shape[a]) for a in mesh.axis_names))),
        "n_devices": int(mesh.size), "kind": cell.kind,
    }
    moments_dtype = jnp.bfloat16 if arch in BF16_MOMENT_ARCHS else jnp.float32
    if cell.kind == "train" and microbatches is None:
        microbatches = max(1, cell.global_batch // dp)
    if cell.kind == "train":
        report["microbatches"] = microbatches

    # full-cell compile: the shardability/memory proof
    lowered, info = build_lowered(
        cfg, cell, mesh, microbatches=microbatches or 1, fsdp=fsdp,
        remat=remat, moments_dtype=moments_dtype,
        sharding_overrides=sharding_overrides)
    report.update(info)
    t0 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                report[attr] = int(v)
    report["raw_costs"] = _compiled_costs(compiled)   # scan-body-once counts

    # probe-extrapolated (scan-trip-corrected) costs + analytic HBM model
    n_text = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    model_flops = analysis.model_flops_for(cfg, cell, n_text)
    hbm = analysis.analytic_hbm_bytes(
        cfg, cell, mesh, microbatches=microbatches or 1, fsdp=fsdp,
        moments_bytes=2 if arch in BF16_MOMENT_ARCHS else 4)
    report["hbm_model"] = hbm
    if probes:
        pc = probe_costs(cfg, cell, mesh, fsdp=fsdp, remat=remat,
                         moments_dtype=moments_dtype,
                         sharding_overrides=sharding_overrides)
        report["probe_costs"] = {k: v for k, v in pc.items()
                                 if k != "probe_grid"}
        report["probe_grid"] = pc["probe_grid"]
        terms = analysis.RooflineTerms(
            flops=pc["flops"] * mesh.size,
            hbm_bytes=hbm["total"] * mesh.size,
            coll_bytes_per_dev=pc["coll_total"],
            n_devices=int(mesh.size), model_flops=model_flops)
        report["roofline"] = terms.to_dict()
    return compiled, report


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}.json")


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             force: bool = False, **kw) -> Dict[str, Any]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = cell_path(arch, shape, multi_pod)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        compiled, report = lower_cell(arch, shape, multi_pod=multi_pod, **kw)
    except Exception as e:                          # a failure IS the finding
        report = {"arch": arch, "shape": shape,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    report["multi_pod"] = multi_pod
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    # llama3_8b (the paper's own case study) is runnable explicitly but is
    # not part of the assigned 40-cell --all sweep
    ap.add_argument("--arch", choices=ARCHS + ("llama3_8b",))
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in cells:
        r = run_cell(arch, shape, multi_pod=mp, force=args.force)
        mesh_tag = "2x16x16" if mp else "16x16"
        if "skipped" in r:
            print(f"[dryrun] {arch:22s} {shape:12s} {mesh_tag:8s} SKIP "
                  f"({r['skipped'][:60]}...)", flush=True)
        elif "error" in r:
            print(f"[dryrun] {arch:22s} {shape:12s} {mesh_tag:8s} FAIL "
                  f"{r['error'][:90]}", flush=True)
        else:
            rt = r["roofline"]
            print(f"[dryrun] {arch:22s} {shape:12s} {mesh_tag:8s} OK "
                  f"compile={r['compile_s']:6.1f}s "
                  f"t_comp={rt['t_compute']:.3e} t_mem={rt['t_memory']:.3e} "
                  f"t_coll={rt['t_collective']:.3e} dom={rt['dominant']}",
                  flush=True)


if __name__ == "__main__":
    main()
