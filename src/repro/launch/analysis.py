"""Roofline-term extraction from compiled dry-run artifacts.

``cost_analysis()`` supplies HLO FLOPs and HBM bytes.  Collective traffic is
NOT in cost_analysis: :func:`collective_bytes` parses the post-SPMD HLO text
(``compiled.as_text()``) and sums the *output shape* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op (per-participant payload of one execution).

Hardware constants are the v5e-class targets from ``repro.core.constants``:
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.constants import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g.:  %ag = bf16[4,512,1024]{2,1,0} all-gather(%x), ...
#        ROOT %t = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' group."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind over the HLO module.

    The result is the per-device payload of ONE step execution (post-SPMD
    HLO shapes are already per-participant).  ``all-gather-start`` /
    ``-done`` pairs are counted once (on start).
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        # find "<shape-or-tuple> <opname>(" with opname a collective
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            start_token = f" {op}-start("
            if token not in line and start_token not in line:
                continue
            if f"{op}-done(" in line:
                continue
            # shapes appear between '=' and the op name
            eq = line.find("=")
            opi = line.find(start_token)
            if opi < 0:
                opi = line.find(token)
            if eq < 0 or opi < eq:
                continue
            seg = line[eq + 1:opi]
            total = sum(_shape_bytes(s.group(0))
                        for s in _SHAPE_RE.finditer(seg))
            out[op] += total
            break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # whole-step HLO FLOPs (all devices)
    hbm_bytes: float              # whole-step HBM traffic (all devices)
    coll_bytes_per_dev: float     # per-device collective payload
    n_devices: int
    model_flops: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_devices * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_devices * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Lower-bound step time: no overlap assumption = max of terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_frac(self) -> Optional[float]:
        """MODEL_FLOPS-based MFU bound at the dominant-term step time."""
        if self.model_flops is None:
            return None
        t = self.t_step
        if t == 0:
            return None
        return self.model_flops / (t * self.n_devices * PEAK_FLOPS_BF16)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "n_devices": self.n_devices, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "t_step": self.t_step,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops_for(cfg, cell, n_text_tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D train / 2·N·D forward (N_active for MoE)."""
    n = cfg.active_param_count()
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n * n_text_tokens


# --------------------------------------------------------------------------- #
# analytic HBM-traffic model
# --------------------------------------------------------------------------- #
def analytic_hbm_bytes(cfg, cell, mesh, *, microbatches: int = 1,
                       fsdp: bool = True, moments_bytes: int = 4,
                       q_chunk: int = 512) -> Dict[str, float]:
    """Per-device HBM traffic of one step, flash-style TPU pipeline model.

    XLA's ``bytes accessed`` on the CPU backend is not a credible HBM proxy
    for a TPU target (CPU fusion boundaries differ; unfused elementwise
    chains are all counted), so the §Roofline memory term uses this explicit
    streaming model instead — every component is listed in the returned
    dict, auditable against the HHW constants:

    * **weights**: resident shard (bf16) read once per microbatch (an
      all-gathered FSDP shard is written+read locally once — its network
      cost lives in the collective term);
    * **optimizer** (train): moments read+write, f32 grads write+read,
      params write;
    * **activations**: per token per layer, the block's tensor set
      (residual/norm x4, qkv, attention out, MLP hiddens) written+read in
      fwd; backward ≈ 2x fwd (remat recompute + gradient traffic);
    * **attention KV streaming**: each query chunk re-reads the full K/V
      (the flash-attention trade: S^2 scores never hit HBM, K/V are re-read
      S/q_chunk times);
    * **KV cache** (serve): prefill writes it, decode reads it fully per
      token and writes one slot.
    """
    tp = mesh.shape.get("model", 1)
    dp = mesh.size // tp
    kind = cell.kind
    B, S = cell.global_batch, cell.seq_len

    P = cfg.param_count()
    p_shard = 2.0 * P / tp                      # bf16 resident weights/device
    if kind == "train":
        tokens_dev = B * S / dp / microbatches  # per microbatch
        weights = p_shard * microbatches        # re-read each microbatch
        opt = (P / (tp * (dp if fsdp else 1))) * (
            4 + 4                                # grads f32 write+read
            + 2 * moments_bytes * 2              # m, v read+write
            + 2)                                 # new params write
    elif kind == "prefill":
        tokens_dev = B * S / dp
        weights = p_shard
        opt = 0.0
    else:                                        # decode: one token
        tokens_dev = B / dp
        weights = p_shard
        opt = 0.0

    d, f, H, KV, hd = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads,
                       cfg.hd)
    if cfg.moe:
        f_eff = cfg.moe.top_k * f + 2 * d        # active experts + dispatch
        if cfg.moe.dense_residual:
            f_eff += f
    else:
        f_eff = f
    mlp_f = (2 if cfg.mlp == "gated" else 1) * f_eff
    per_tok_layer = (4 * d + 3 * H * hd + mlp_f) * 2.0      # bf16 fwd write
    fwd_io = 2.0 * per_tok_layer                            # write + read
    L = cfg.n_layers + cfg.n_encoder_layers
    act = tokens_dev * L * fwd_io * (3.0 if kind == "train" else 1.0)
    if kind == "train":
        act *= microbatches

    # attention KV streaming + cache traffic
    kv_bytes_tok = 2.0 * KV * hd * 2.0 if KV else 0.0       # K+V bf16
    n_attn = sum(1 for b in cfg.block_pattern
                 if b == "attn") / len(cfg.block_pattern) * cfg.n_layers
    n_attn += cfg.n_encoder_layers
    attn_S = min(S, cfg.window) if cfg.window else S
    cache = 0.0
    if kind == "decode":
        # read the full (windowed) cache once per token, write one slot;
        # the cache is model-sharded (KV heads or sequence) -> /tp
        cache = (B / dp) * n_attn * attn_S * kv_bytes_tok / tp
        attn_stream = 0.0
    else:
        n_chunks = max(1, attn_S // q_chunk)
        reads = (1.0 + (2.0 if kind == "train" else 0.0))   # fwd + bwd
        seqs_dev = tokens_dev / S                            # per microbatch
        attn_stream = seqs_dev * n_attn * n_chunks * attn_S \
            * kv_bytes_tok * reads
        if kind == "train":
            attn_stream *= microbatches                      # per-step total
        cache = (B / dp) * n_attn * attn_S * kv_bytes_tok / tp \
            if kind == "prefill" else 0.0

    total = weights + opt + act + attn_stream + cache
    return {"weights": weights, "opt": opt, "act": act,
            "attn_stream": attn_stream, "cache": cache, "total": total}
