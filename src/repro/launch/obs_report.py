"""Fleet health report: ``python -m repro.launch.obs_report [...]``.

Renders any engine/co-sim run into the per-device "aging odometer" table
(:mod:`repro.obs.health`): ΔVth, guardband headroom, ETA-to-threshold,
admitted BER, plus compile-cache hit rates and span timings from the
metrics registry.

Two run modes feed the table:

* ``--mode cosim`` (default) — age a staggered fleet under routed
  traffic (:meth:`repro.core.fleet.FleetRuntime.apply_load`) and read
  the odometer off the co-sim scan's own aux outputs
  (:func:`repro.obs.taps.cosim_taps` — per-epoch ΔVth, headroom, boost
  events, all from the ONE jitted dispatch);
* ``--mode online`` — serve a live request queue with telemetry taps
  enabled (:mod:`repro.serve.online`), replay the measured occupancy
  into the aging recursion, and fold the serving metrics (p50/p99
  latency, drop rate, tok/s) into the snapshot.

``--jsonl`` / ``--prom`` additionally export the run through
:mod:`repro.obs.export` (event log with manifest header / Prometheus
text exposition).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs.taps import cosim_taps, enable_taps, telemetry_to_host
from repro.sched.router import ROUTER_REGISTRY
from repro.sched.workload import WORKLOADS

YEAR_S = 365.25 * 24 * 3600.0


def _print_odometer_series(telem, tag="[obs]"):
    """Condense the per-epoch (N, E) tap series to a start -> end digest."""
    if not telem:
        return
    n = telem["dvth_eff_mv"].shape[0]
    for i in range(n):
        eff = telem["dvth_eff_mv"][i]
        head = telem["headroom_s"][i] * 1e12
        boosts = telem["boosts"][i].sum() if "boosts" in telem else 0.0
        rec = telem["dvth_mono_mv"][i][-1] - eff[-1]
        print(f"{tag}   dev{i}: dVth {eff[0]:6.2f} -> {eff[-1]:6.2f} mV "
              f"(recovered {rec:5.2f}), margin {head[0]:6.1f} -> "
              f"{head[-1]:6.1f} ps, {boosts:.0f} boost events")


def _run_cosim(args, fleet):
    cos = fleet.apply_load(workload=args.workload, router=args.router,
                           utilization=args.utilization,
                           horizon_s=args.horizon_years * YEAR_S)
    telem = telemetry_to_host(cosim_taps(cos, fleet.unit_scenario))
    print(f"[obs] co-sim: {cos.n_epochs} epochs of {args.workload} via "
          f"{args.router} over {args.horizon_years:g}y")
    _print_odometer_series(telem)
    return None


def _run_online(args, fleet):
    from repro.serve.online import (OnlineFleetEngine, OnlineServeEngine,
                                    requests_from_workload)
    from repro.sched.workload import get_workload
    from repro.train.steps import init_train_state

    cfg = get_config(args.arch).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    wl = get_workload(args.workload, n_devices=args.n_devices,
                      utilization=args.utilization, n_epochs=args.n_epochs)
    reqs = requests_from_workload(
        wl, n_slots=args.n_slots, steps_per_epoch=args.steps_per_epoch,
        max_new=args.max_new, prompt_len=args.prompt_len, vocab=cfg.vocab,
        n_devices=args.n_devices, seed=0)
    max_len = args.prompt_len + args.max_new + 1
    kw = dict(n_slots=args.n_slots, max_len=max_len,
              max_new_cap=args.max_new, chunk_steps=args.chunk_steps)
    if args.n_devices > 1:
        eng = OnlineFleetEngine(cfg, params, fleet, router=args.router,
                                **kw)
    else:
        eng = OnlineServeEngine(cfg, params, runtime=fleet, **kw)
    res = eng.serve(reqs, temperature=0.7,
                    max_steps=4 * args.n_epochs * args.steps_per_epoch)
    print(f"[obs] online: {res.n_completed} completed / "
          f"{res.n_dropped} dropped, p50 {res.p50:.0f} / "
          f"p99 {res.p99:.0f} steps")
    if res.telemetry is not None:
        lm = res.telemetry["logit_max"]
        print(f"[obs]   in-scan taps over {lm.shape[-1]} served steps: "
              f"mean logit_max {lm.mean():.2f}, mean margin "
              f"{res.telemetry['logit_margin'].mean():.2f}")
    # measured occupancy -> duty -> aging: the odometer advances on
    # traffic the engine actually served
    util = res.lane_utilization(max(args.n_epochs, 2))
    if util.ndim == 1:
        util = util[:, None]
    fleet.apply_load(util_trace=util,
                     horizon_s=args.horizon_years * YEAR_S)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="cosim", choices=("cosim", "online"),
                    help="what run feeds the health table")
    ap.add_argument("--arch", default="deepseek_7b",
                    help="--mode online model arch")
    ap.add_argument("--n-devices", type=int, default=3)
    ap.add_argument("--age-years", type=float, default=4.0,
                    help="staggered fleet ages (device i at age*(i+1)/n)")
    ap.add_argument("--budget", type=float, default=0.5)
    ap.add_argument("--workload", default="diurnal",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--router", default="wear_level",
                    choices=sorted(ROUTER_REGISTRY))
    ap.add_argument("--utilization", type=float, default=0.6)
    ap.add_argument("--horizon-years", type=float, default=2.0)
    # --mode online queue shape
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--chunk-steps", type=int, default=8)
    ap.add_argument("--n-epochs", type=int, default=8)
    ap.add_argument("--steps-per-epoch", type=int, default=48)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--jsonl", default=None,
                    help="write the run's event log (manifest + health "
                         "snapshot + metric samples) to this path")
    ap.add_argument("--prom", default=None,
                    help="write a Prometheus text exposition to this path")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny fleet / trace")
    args = ap.parse_args(argv)

    if args.quick:
        args.n_devices = min(args.n_devices, 2)
        args.n_epochs = min(args.n_epochs, 3)
        args.steps_per_epoch = min(args.steps_per_epoch, 16)
        args.n_slots = min(args.n_slots, 2)
        args.max_new = min(args.max_new, 6)
        args.prompt_len = min(args.prompt_len, 8)
        args.chunk_steps = min(args.chunk_steps, 4)

    fleet = FleetRuntime(n_devices=args.n_devices,
                         max_loss_pct=args.budget)
    for i in range(args.n_devices):
        fleet.set_age(years=args.age_years * (i + 1) / args.n_devices,
                      device=i)

    with enable_taps():
        online_res = (_run_online(args, fleet) if args.mode == "online"
                      else _run_cosim(args, fleet))

    hlth = fleet.health(online_result=online_res)
    print()
    print(hlth.render())

    if args.jsonl:
        n = obs_export.write_jsonl(
            args.jsonl, manifest=obs_export.run_manifest(
                run=f"obs_report:{args.mode}", arch=args.arch,
                n_devices=args.n_devices), health=hlth.to_dict())
        print(f"\n[obs] wrote {n} rows -> {args.jsonl}")
    if args.prom:
        with open(args.prom, "w") as fh:
            fh.write(obs_export.prometheus_text())
        print(f"[obs] wrote Prometheus exposition -> {args.prom}")
    return hlth


if __name__ == "__main__":
    main()
