"""Synthetic LM token pipeline — deterministic, stateless, host-shardable.

Design constraints (DESIGN.md Sec. 5, fault tolerance):

* **Stateless**: ``batch_at(step)`` is a pure function of ``(seed, step)``
  computed with counter-based hashing (a Squares-style weyl-sequence mixer),
  so a preempted job resumes mid-epoch with *no* iterator state in the
  checkpoint, and an elastic re-mesh to a different DP size reads exactly
  the same global batch for step k.
* **Host-shardable**: ``local_batch_at(step, shard, n_shards)`` slices the
  global batch without materialising it, for multi-host data loading.
* **Learnable**: tokens follow a noisy affine recurrence
  ``t[i+1] = (a * t[i] + b + eps) mod V`` with document resets, so a small
  LM's loss drops well below the uniform baseline within a few hundred
  steps (``examples/train_lm.py``) — required to demonstrate end-to-end
  training and the Fig. 1(b)-style BER/quality knee on real computation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _mix(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer — counter-based, vectorised."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
    x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK64
    x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK64
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class TokenBatch:
    tokens: np.ndarray     # (B, S) int32 — inputs
    labels: np.ndarray     # (B, S) int32 — next-token targets


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise_vocab: int = 17          # eps ∈ [0, noise_vocab)
    doc_len: int = 256             # document reset period
    a_mult: int = 31               # affine recurrence multiplier

    def _rows(self, step: int, row_ids: np.ndarray) -> TokenBatch:
        S, V = self.seq_len, self.vocab
        base = (np.uint64(self.seed) << np.uint64(40)) \
            + (np.uint64(step) << np.uint64(20))
        row_seed = _mix(base + row_ids.astype(np.uint64))        # (b,)
        # per-document starting tokens and per-position noise
        n_tok = S + 1
        pos = np.arange(n_tok, dtype=np.uint64)[None, :]
        h = _mix(row_seed[:, None] ^ _mix(pos))                  # (b, S+1)
        eps = (h % np.uint64(self.noise_vocab)).astype(np.int64)
        doc_id = (np.arange(n_tok) // self.doc_len).astype(np.uint64)[None, :]
        starts = (_mix(row_seed[:, None] ^ _mix(doc_id + np.uint64(7)))
                  % np.uint64(V)).astype(np.int64)
        toks = np.empty((len(row_ids), n_tok), np.int64)
        toks[:, 0] = starts[:, 0]
        for i in range(1, n_tok):
            fresh = (i % self.doc_len) == 0
            nxt = (self.a_mult * toks[:, i - 1] + 1 + eps[:, i]) % V
            toks[:, i] = np.where(fresh, starts[:, i], nxt)
        return TokenBatch(tokens=toks[:, :-1].astype(np.int32),
                          labels=toks[:, 1:].astype(np.int32))

    def batch_at(self, step: int) -> TokenBatch:
        return self._rows(step, np.arange(self.global_batch))

    def local_batch_at(self, step: int, shard: int,
                       n_shards: int) -> TokenBatch:
        assert self.global_batch % n_shards == 0
        per = self.global_batch // n_shards
        return self._rows(step, np.arange(shard * per, (shard + 1) * per))

    def uniform_nll(self) -> float:
        """Loss of the know-nothing predictor (upper baseline)."""
        return float(np.log(self.vocab))

    def oracle_nll(self) -> float:
        """Loss of the perfect predictor knowing the recurrence (~log eps)."""
        return float(np.log(self.noise_vocab))
