"""Deterministic, stateless, host-shardable synthetic data pipeline."""
from .pipeline import SyntheticLM, TokenBatch

__all__ = ["SyntheticLM", "TokenBatch"]
