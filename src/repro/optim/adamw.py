"""AdamW + warmup-cosine schedule + global-norm clipping, pure JAX pytrees.

Moments are stored in f32 regardless of param dtype (bf16-safe); the state
pytree mirrors the param tree so the sharding rules
(`repro.distributed.sharding.state_specs`) apply verbatim to ``mu``/``nu``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(grads, opt: OptState, params,
                 cfg: AdamWConfig) -> Tuple[Any, OptState, Dict[str, Any]]:
    """One AdamW step; returns (new_params, new_opt, metrics)."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      opt.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
                      opt.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cosine_schedule(cfg, step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(mu=mu, nu=nu, step=step), metrics
