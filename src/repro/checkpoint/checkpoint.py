"""Sharded npz checkpointing: atomic commit, async save, auto-resume.

Layout::

    <dir>/step_000123/
        manifest.json     # step, flat paths, shapes, dtypes, metadata
        arrays.npz        # flat-path -> ndarray
        COMMIT            # written LAST; presence == checkpoint is valid

Fault-tolerance properties:

* **Atomic**: everything is written into ``step_X.tmp`` and ``os.rename``d
  into place only after ``COMMIT`` exists inside, so a crash mid-save never
  produces a checkpoint that :func:`latest_step` would pick up.
* **Async**: ``CheckpointManager.save(..., blocking=False)`` snapshots to
  host memory synchronously (cheap) and writes in a background thread,
  overlapping serialization with the next training steps — the pattern used
  at scale to hide multi-second checkpoint writes.
* **Auto-resume**: :func:`latest_step` scans for the newest committed step;
  ``CheckpointManager.restore_or_init`` resumes if possible, else runs init.
* **Multi-host**: each process saves only its addressable shards under
  ``proc_<k>``; on restore every process reads its own file.  (Single-host
  CPU here exercises the proc_0 path; the layout is the multi-host one.)
* **Garbage collection**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(e) for e in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(e) -> str:
    if isinstance(e, jax.tree_util.DictKey):
        return str(e.key)
    if isinstance(e, jax.tree_util.SequenceKey):
        return str(e.idx)
    if isinstance(e, jax.tree_util.GetAttrKey):
        return e.name
    return str(e)


def _unflatten_like(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(_key_str(e) for e in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def save_checkpoint(base: str, step: int, tree, *,
                    metadata: Optional[Dict[str, Any]] = None,
                    process_index: int = 0) -> str:
    """Synchronous atomic save; returns the committed directory."""
    os.makedirs(base, exist_ok=True)
    final = _step_dir(base, step)
    tmp = final + f".tmp{process_index}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, f"proc_{process_index}.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "keys": sorted(arrays.keys()),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(base: str, step: int, template, *,
                    process_index: int = 0) -> Tuple[Any, Dict[str, Any]]:
    d = _step_dir(base, step)
    if not os.path.exists(os.path.join(d, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, f"proc_{process_index}.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    return _unflatten_like(template, arrays), manifest["metadata"]


def latest_step(base: str) -> Optional[int]:
    """Newest committed step, or None."""
    if not os.path.isdir(base):
        return None
    steps = []
    for name in os.listdir(base):
        if name.startswith("step_") and not name.endswith(".tmp"):
            d = os.path.join(base, name)
            if os.path.exists(os.path.join(d, "COMMIT")):
                try:
                    steps.append(int(name.split("_")[1].split(".")[0]))
                except ValueError:
                    continue
    return max(steps) if steps else None


class CheckpointManager:
    """Async, GC'd checkpointing for a training loop."""

    def __init__(self, base: str, *, keep: int = 3, save_every: int = 100):
        self.base = base
        self.keep = keep
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree, *, metadata=None, blocking: bool = True):
        self.wait()  # one in-flight save at a time
        # snapshot to host synchronously: cheap, and the training loop may
        # donate/overwrite device buffers right after this call
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.base, step, host_tree,
                                metadata=metadata)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.base)
            if n.startswith("step_") and "." not in n)
            if os.path.exists(os.path.join(_step_dir(self.base, s), "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore_or_init(self, init_fn: Callable[[], Any]):
        """Resume from the newest committed step, else initialise fresh.

        Returns ``(state, start_step)``.
        """
        step = latest_step(self.base)
        if step is None:
            return init_fn(), 0
        template = jax.eval_shape(init_fn)
        state, _ = load_checkpoint(self.base, step, template)
        return state, step
