"""Training layer: step builders and the fault-tolerant driver loop."""
from .steps import (TrainState, init_train_state, make_dp_train_step,
                    make_loss_fn, make_train_step)
from .loop import StragglerWatchdog, TrainLoop

__all__ = ["TrainState", "init_train_state", "make_dp_train_step",
           "make_loss_fn", "make_train_step", "StragglerWatchdog",
           "TrainLoop"]
