"""Train-step builders: loss, grad accumulation, remat, compressed DP.

Two step flavours:

* :func:`make_train_step` — the production pjit path.  Params/opt sharded by
  the rules in ``repro.distributed.sharding``; GSPMD inserts the TP/DP
  collectives.  Supports microbatch gradient accumulation (``lax.scan``) and
  layer-group remat.  ``donate_argnums=(0,)`` recycles the state buffers.
* :func:`make_dp_train_step` — an explicit ``shard_map`` data-parallel path
  with **int8 error-feedback gradient compression** over the data axes
  (``repro.distributed.collectives``), demonstrating the
  distributed-optimization trick the brief asks for; params are
  DP-replicated (compose with TP by nesting meshes at larger scale).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.distributed import collectives
from repro.distributed.sharding import data_axes
from repro.models import encdec
from repro.models import transformer as tf
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residuals: Optional[Any] = None      # error-feedback state (DP-compressed)


def init_train_state(cfg: ModelConfig, key, *, dtype=jnp.float32,
                     compressed: bool = False) -> TrainState:
    init = encdec.init_params if cfg.n_encoder_layers else tf.init_params
    params = init(cfg, key, dtype)
    return TrainState(
        params=params, opt=adamw_init(params),
        residuals=collectives.zeros_residuals(params) if compressed else None)


# --------------------------------------------------------------------------- #
# loss
# --------------------------------------------------------------------------- #
def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy.  logits (B,S,V) f32, labels (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = False,
                 aux_weight: float = 0.01) -> Callable:
    """(params, batch) -> (loss, metrics).  batch keys: tokens, labels
    [, prefix_embeds, frames]."""

    def loss_fn(params, batch):
        if cfg.n_encoder_layers:
            enc = encdec.encode(params, cfg, batch["frames"], remat=remat)
            logits, _ = encdec.decode(params, cfg, batch["tokens"],
                                      enc_out=enc, remat=remat)
            aux = jnp.zeros((), jnp.float32)
        else:
            logits, _, aux = tf.forward_logits(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"), remat=remat)
            if cfg.prefix_tokens:
                logits = logits[:, cfg.prefix_tokens:]
        xent = softmax_xent(logits, batch["labels"])
        loss = xent + aux_weight * aux
        return loss, {"loss": loss, "xent": xent, "aux": aux}

    return loss_fn


# --------------------------------------------------------------------------- #
# pjit production step
# --------------------------------------------------------------------------- #
def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, remat: bool = False,
                    aux_weight: float = 0.01) -> Callable:
    """(state, batch) -> (state, metrics); pure — jit/pjit it at the caller
    with the sharding rules (see ``repro.launch``)."""
    loss_fn = make_loss_fn(cfg, remat=remat, aux_weight=aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        params = state.params

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                return jax.tree.map(jnp.add, carry, grads), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(acc_step, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(jnp.mean, metrics)

        new_params, new_opt, opt_m = adamw_update(grads, state.opt, params,
                                                  opt_cfg)
        metrics = dict(metrics, **opt_m)
        return TrainState(new_params, new_opt, state.residuals), metrics

    return train_step


# --------------------------------------------------------------------------- #
# shard_map DP step with gradient compression
# --------------------------------------------------------------------------- #
def make_dp_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh, *,
                       compress: bool = True, remat: bool = False,
                       aux_weight: float = 0.01) -> Callable:
    """Explicit-DP step: per-shard grads -> (compressed) all-reduce -> update.

    Params replicated over the mesh; batch sharded over the data axes.  The
    returned function is already jitted with donated state.
    """
    loss_fn = make_loss_fn(cfg, remat=remat, aux_weight=aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    axes = data_axes(mesh)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def body(state: TrainState, batch):
        (loss, metrics), grads = grad_fn(state.params, batch)
        if compress:
            # residuals carry a leading per-shard axis; body sees (1, ...)
            local_res = jax.tree.map(lambda r: r[0], state.residuals)
            grads, new_res = collectives.tree_psum_compressed(
                grads, local_res, axes, n_shards)
            new_res = jax.tree.map(lambda r: r[None], new_res)
        else:
            grads = collectives.tree_psum(grads, axes, n_shards)
            new_res = state.residuals
        metrics = {k: jax.lax.pmean(v, axes) for k, v in metrics.items()}
        new_params, new_opt, opt_m = adamw_update(grads, state.opt,
                                                  state.params, opt_cfg)
        return (TrainState(new_params, new_opt, new_res),
                dict(metrics, **opt_m))

    replicated = P()
    res_spec = P(axes) if compress else replicated
    state_sp = TrainState(params=replicated,
                          opt=OptState(replicated, replicated, replicated),
                          residuals=res_spec)

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(state_sp, P(axes)),
        out_specs=(state_sp, replicated),
        check_rep=False)
    return jax.jit(mapped, donate_argnums=(0,))


def dp_residuals_init(params, mesh: Mesh):
    """Error-feedback residuals: one copy per data shard (leading dp axis)."""
    axes = data_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + tuple(p.shape), jnp.float32), params)
