"""Fault-tolerant training driver: auto-resume, async ckpt, straggler watch.

The loop composes the substrate: deterministic stateless data pipeline
(resume needs only the step counter), async atomic checkpoints, and a
straggler watchdog.  On real multi-pod deployments the watchdog's decision
function drives microbatch redistribution / slice replacement; here its
detection + decision path is exercised with injectable step-time spikes
(``tests/test_train_loop.py``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float
    action: str


class StragglerWatchdog:
    """Rolling-median step-time monitor with a mitigation decision rule.

    A step slower than ``threshold`` x rolling median is flagged.  One
    flag -> "warn" (transient hiccup); ``consecutive`` flags -> "rebalance"
    (persistent straggler: the driver should shrink that replica's
    microbatch share or arrange replacement).  The decision logic is pure
    so it is unit-testable without real stragglers.
    """

    def __init__(self, *, window: int = 32, threshold: float = 2.0,
                 consecutive: int = 3):
        self.window = window
        self.threshold = threshold
        self.consecutive = consecutive
        self._times: deque = deque(maxlen=window)
        self._flags = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[str]:
        med = float(np.median(self._times)) if len(self._times) >= 4 else None
        self._times.append(step_time)
        if med is None:
            return None
        if step_time > self.threshold * med:
            self._flags += 1
            action = ("rebalance" if self._flags >= self.consecutive
                      else "warn")
            self.events.append(StragglerEvent(step, step_time, med, action))
            return action
        self._flags = 0
        return None


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 300
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_keep: int = 2
    async_ckpt: bool = True


class TrainLoop:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` to completion."""

    def __init__(self, step_fn: Callable, data: SyntheticLM, *,
                 ckpt_dir: Optional[str] = None,
                 cfg: LoopConfig = LoopConfig(),
                 make_batch: Optional[Callable[[int], Dict[str, Any]]] = None,
                 log_fn: Callable[[str], None] = print,
                 time_fn: Callable[[], float] = time.monotonic):
        self.step_fn = step_fn
        self.data = data
        self.cfg = cfg
        self.log = log_fn
        self.time = time_fn
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(ckpt_dir, keep=cfg.ckpt_keep,
                                       save_every=cfg.ckpt_every)
                     if ckpt_dir else None)
        self._make_batch = make_batch or self._default_batch
        self.history: List[Dict[str, float]] = []

    def _default_batch(self, step: int) -> Dict[str, Any]:
        tb = self.data.batch_at(step)
        return {"tokens": tb.tokens, "labels": tb.labels}

    # ------------------------------------------------------------------ #
    def run(self, init_fn: Callable[[], Any]) -> Any:
        """Run (or resume) to ``total_steps``; returns the final state."""
        if self.ckpt is not None:
            state, start = self.ckpt.restore_or_init(init_fn)
            if start:
                self.log(f"[loop] resumed from step {start}")
        else:
            state, start = init_fn(), 0

        for step in range(start, self.cfg.total_steps):
            t0 = self.time()
            batch = self._make_batch(step)
            state, metrics = self.step_fn(state, batch)
            # block on the loss so step timing is real, not dispatch time
            loss = float(jax.device_get(metrics["loss"]))
            dt = self.time() - t0

            action = self.watchdog.observe(step, dt)
            if action:
                self.log(f"[watchdog] step {step}: {dt * 1e3:.0f} ms "
                         f"({action})")

            if step % self.cfg.log_every == 0 or step == \
                    self.cfg.total_steps - 1:
                self.log(f"[train] step {step:5d} loss {loss:.4f} "
                         f"({dt * 1e3:.0f} ms)")
            self.history.append(dict(step=step, loss=loss, time=dt))

            if self.ckpt is not None and self.ckpt.should_save(step + 1):
                self.ckpt.save(step + 1, state,
                               metadata={"loss": loss},
                               blocking=not self.cfg.async_ckpt)

        if self.ckpt is not None:
            self.ckpt.save(self.cfg.total_steps, state, blocking=True)
        return state
