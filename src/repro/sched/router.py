"""Routing policies: who serves this epoch's traffic — a wear lever.

A router maps one epoch's offered load (a scalar, in device-equivalents)
plus the fleet's current per-device state onto a per-device utilization
vector — the fraction of the epoch each device spends serving.  That
vector is exactly the stress input of the aging model (duty cycle, toggle
rate and load-induced heating all scale with it), so the router is the
one aging knob a production operator actually holds: DNN-Life frames
wear-leveling as a first-class aging mitigation, and the co-simulation in
:mod:`repro.sched.lifetime` closes the loop routing -> stress -> ΔVth ->
policy voltage -> power inside one scan.

The protocol is a single traced method, mirroring
:class:`repro.core.policy.Policy`::

    assign(load, wear, util_prev, capacity) -> jnp.ndarray (N,)

with ``load`` a traced scalar, ``wear`` the per-device aging signal
(ΔVth_p in mV, worst operator domain), ``util_prev`` the previous epoch's
assignment and ``capacity`` the per-device utilization ceiling.  Every
implementation is a vectorised assignment over the device axis (sorts,
clips and a fixed-iteration waterfill bisection — no Python loop over
requests or devices), so the co-simulation can vmap/scan it freely.
Routers are frozen dataclasses: hashable, so a compiled co-simulation is
cached per router configuration.

Registered routers (``register_router`` / ``get_router``):

* ``round_robin``  — uniform spread, aging-blind (the baseline);
* ``least_loaded`` — waterfill on the previous epoch's utilization
  (queue-balancing; equals round_robin under stationary traffic);
* ``least_aged``   — greedy: fill the least-worn devices to capacity
  first (maximal steering, at the cost of slamming young devices);
* ``wear_level``   — waterfill on the wear signal itself: devices below
  the fleet's wear level absorb proportionally more traffic until the
  fleet converges to a common ΔVth (minimises fleet-max ΔVth);
* ``rest_to_recover`` — wear-level steering plus *deliberate idling*:
  when the fleet has capacity headroom, the most-worn devices are rested
  entirely so their short-term recoverable trap component relaxes
  (:class:`repro.core.aging.RecoveryParams`); under overload nobody
  rests, so the conservation contract is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class Router(Protocol):
    """Anything that maps (load, fleet state) to per-device utilization."""

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        """Per-device utilization for this epoch, shape ``(N,)``."""
        ...


ROUTER_REGISTRY: Dict[str, type] = {}


def register_router(cls):
    """Class decorator: register a router under its ``name`` attribute."""
    ROUTER_REGISTRY[cls.name] = cls
    return cls


def get_router(name_or_router, **kw) -> "Router":
    """Resolve a registered router by name (instances pass through)."""
    if not isinstance(name_or_router, str):
        return name_or_router
    try:
        return ROUTER_REGISTRY[name_or_router](**kw)
    except KeyError:
        raise KeyError(f"unknown router {name_or_router!r}; registered: "
                       f"{sorted(ROUTER_REGISTRY)}") from None


# --------------------------------------------------------------------------- #
# shared vectorised primitives
# --------------------------------------------------------------------------- #
def _servable(load, n, capacity):
    """Load the fleet can actually serve this epoch (the rest is dropped)."""
    cap = jnp.broadcast_to(jnp.asarray(capacity, jnp.float32), (n,))
    return jnp.minimum(jnp.asarray(load, jnp.float32), cap.sum()), cap


def waterfill(levels, load, capacity, *, gain=1.0, n_iter: int = 40
              ) -> jnp.ndarray:
    """Allocate ``load`` by flooding the lowest ``levels`` first.

    Solves for the water level ``lam`` such that

        u_i = clip((lam - levels_i) * gain, 0, capacity_i),   sum_i u_i = load

    by fixed-iteration bisection (traceable; ``n_iter=40`` resolves the
    level to ~1e-12 of the search interval).  Devices below the water
    line receive allocation proportional to their headroom — the
    continuous form of "send the next request to the lowest-level
    device".  With identical levels it degenerates to a uniform split.
    """
    levels = jnp.asarray(levels, jnp.float32)
    load, cap = _servable(load, levels.shape[0], capacity)
    gain = jnp.asarray(gain, jnp.float32)
    lo = jnp.min(levels)
    hi = jnp.max(levels) + jnp.max(cap) / jnp.maximum(gain, 1e-9)

    def body(_, bounds):
        lo_, hi_ = bounds
        mid = 0.5 * (lo_ + hi_)
        tot = jnp.sum(jnp.clip((mid - levels) * gain, 0.0, cap))
        under = tot < load
        return jnp.where(under, mid, lo_), jnp.where(under, hi_, mid)

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    u = jnp.clip((0.5 * (lo + hi) - levels) * gain, 0.0, cap)
    # bisection leaves an O(interval / 2^n_iter) residual; the power-law
    # aging kinetics amplify any nonzero stress time, so zero load must
    # yield EXACTLY zero utilization
    return jnp.where(load > 0.0, u, 0.0)


# --------------------------------------------------------------------------- #
# registered routers
# --------------------------------------------------------------------------- #
@register_router
@dataclasses.dataclass(frozen=True)
class RoundRobinRouter:
    """Uniform spread: every device gets ``load / N`` — aging-blind.

    The continuum limit of dealing request quanta cyclically; the
    baseline every aging-aware router is compared against.
    """
    name = "round_robin"

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        # waterfill on flat levels == uniform split, with overflow from
        # capacity-saturated devices redistributed to the rest (keeps the
        # conservation contract under heterogeneous per-device capacity)
        return waterfill(jnp.zeros_like(wear), load, capacity)


@register_router
@dataclasses.dataclass(frozen=True)
class LeastLoadedRouter:
    """Waterfill on the previous epoch's utilization (queue balancing).

    Smooths bursty arrival noise across epochs; blind to aging, so under
    stationary traffic it converges to the round-robin split.
    """
    name = "least_loaded"

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        return waterfill(util_prev, load, capacity)


@register_router
@dataclasses.dataclass(frozen=True)
class LeastAgedRouter:
    """Greedy on ΔVth: fill the least-worn devices to capacity first.

    Maximal steering away from aged silicon — the freshest device is
    slammed to ``capacity`` before the next one sees a request.  Strong
    on fleet-max wear but concentrates stress on the young tail (the
    pathology :class:`WearLevelRouter` avoids).
    """
    name = "least_aged"

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        load, cap = _servable(load, wear.shape[0], capacity)
        order = jnp.argsort(wear)                      # least aged first
        cap_sorted = cap[order]
        # capacity consumed by all strictly-less-aged devices
        before_sorted = jnp.cumsum(cap_sorted) - cap_sorted
        before = before_sorted[jnp.argsort(order)]
        return jnp.clip(load - before, 0.0, cap)


@register_router
@dataclasses.dataclass(frozen=True)
class WearLevelRouter:
    """Minimise fleet-max ΔVth: waterfill on the wear signal itself.

    Devices below the fleet's wear level receive proportionally more
    traffic (``gain`` utilization per normalised-wear unit of headroom),
    so the closed loop routing -> stress -> ΔVth keeps pulling the fleet
    toward a common wear level each epoch — duty-cycle feedback into the
    aging scan.  On a fresh homogeneous fleet (zero wear spread) it
    degenerates to the uniform split.
    """
    name = "wear_level"
    gain: float = 4.0           # steering aggressiveness

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        spread = jnp.maximum(jnp.max(wear) - jnp.min(wear), 1e-6)
        levels = (wear - jnp.min(wear)) / spread       # [0, 1]
        return waterfill(levels, load, capacity, gain=self.gain)


@register_router
@dataclasses.dataclass(frozen=True)
class RestToRecoverRouter:
    """Idle the most-worn devices to harvest short-term recovery.

    With the recoverable trap pool modelled
    (:func:`repro.core.aging.relax_step`), an epoch at zero utilization
    lets a device's fast traps relax — wear that plain steering can only
    *redistribute*, resting actually *removes*.  Each epoch the
    ``rest_frac`` most-worn devices are taken out of rotation entirely,
    but only while the surviving capacity still covers the servable
    load: the rest set is the longest most-worn-first prefix that keeps
    ``sum(capacity[active]) >= load`` (remaining capacity is monotone in
    the prefix length, so the feasibility cut is exact).  Under overload
    the prefix is empty and the router degenerates to wear-level
    waterfilling — the conservation contract (serve ``min(load, total
    capacity)``) holds unconditionally.
    """
    name = "rest_to_recover"
    rest_frac: float = 0.25     # fraction of the fleet eligible to rest
    gain: float = 4.0           # wear-level steering for the active set

    def assign(self, load, wear, util_prev, capacity=1.0) -> jnp.ndarray:
        n = wear.shape[0]
        load, cap = _servable(load, n, capacity)
        k_max = int(min(n - 1, round(self.rest_frac * n)))
        order = jnp.argsort(-wear)                 # most worn first
        rank = jnp.argsort(order)                  # rank 0 == most worn
        # capacity left if every device of rank <= r rests
        remaining = cap.sum() - jnp.cumsum(cap[order])
        can_rest = (rank < k_max) & (remaining[rank] >= load)
        cap_active = jnp.where(can_rest, 0.0, cap)
        spread = jnp.maximum(jnp.max(wear) - jnp.min(wear), 1e-6)
        levels = (wear - jnp.min(wear)) / spread
        return waterfill(levels, load, cap_active, gain=self.gain)
