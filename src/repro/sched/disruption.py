"""Disruption scenarios: the events a serving fleet actually survives.

The router comparison in :mod:`repro.sched.lifetime` ages a fleet under
*well-behaved* traffic.  This module drives the co-simulation through the
disruptions a production fleet faces, exercising the short-term recovery
dynamics (:class:`repro.core.aging.RecoveryParams`) and the closed
thermal loop (:class:`repro.sched.lifetime.ThermalParams`) end to end:

* :func:`run_flash_crowd` — a sustained overload window
  (``flash_crowd`` workload) with temperature derived from *routed
  power* via the thermal RC node instead of a fixed ``t_amb`` leaf: the
  surge saturates the fleet, boosted supplies burn more per request, the
  node heats, aging accelerates — and relaxes back after the crowd
  passes.
* :func:`run_retirement` — mid-horizon device retirement (and optional
  hot-swap): the worn devices leave, the surviving fleet's trap state is
  carried bit-exactly across the resize
  (:meth:`repro.core.fleet.FleetRuntime.resize`), and the accompanying
  serving-mesh change is planned through
  :func:`repro.distributed.elastic.plan_remesh_shape` — the same
  data-axis-resizing elasticity the training stack uses.
* :func:`run_rest_to_recover` — the ``rest_to_recover`` router idles the
  most-worn devices whenever capacity headroom allows, harvesting the
  recoverable trap component that plain wear-leveling can only
  redistribute.

Every scenario runs as ONE jitted scan per fleet segment with all
scenario parameters traced (``TRACE_COUNTS``-guarded by
``tests/test_disruption.py``), and is reachable from the CLI:
``python -m repro.launch.schedule --scenario flash_crowd | retirement |
rest_to_recover``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.aging import IS_PMOS, RecoveryParams
from repro.core.artifacts import Calibration, load_calibration
from repro.core.constants import T_AMB
from repro.core.fleet import FleetRuntime
from repro.core.policy import get_policy
from repro.core.scenario import Scenario
from repro.distributed.elastic import RemeshPlan, plan_remesh_shape

from .lifetime import (DEFAULT_EPOCHS, ThermalParams, compare_routers,
                       cosim_stats, cosimulate)
from .workload import get_workload

YEAR_S = 365.25 * 24 * 3600.0


def _fleet_scenario(cal: Calibration, n_devices: int, *,
                    horizon_years: float, t_amb_spread: float,
                    budget: float = 0.5) -> Scenario:
    """Heterogeneous rack scenario shared by the disruption drivers."""
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg,
                                        max_loss_pct=budget).replace(
        lifetime_s=horizon_years * YEAR_S)
    if t_amb_spread and n_devices > 1:
        scn = scn.replace(t_amb=jnp.asarray(
            T_AMB + np.linspace(0.0, t_amb_spread, n_devices), jnp.float32))
    return scn


def _resolve(cal, policy):
    cal = cal or load_calibration()
    if policy is None:
        policy = get_policy("fault_tolerant", ber_model=cal.ber)
    return cal, policy


# --------------------------------------------------------------------------- #
# (a) flash crowd with closed thermal feedback
# --------------------------------------------------------------------------- #
def run_flash_crowd(cal: Optional[Calibration] = None, *,
                    n_devices: int = 8, epochs: int = DEFAULT_EPOCHS,
                    horizon_years: float = 1.0, utilization: float = 0.6,
                    surge_gain: float = 4.0, router: str = "wear_level",
                    recovery=True, thermal=True,
                    t_amb_spread: float = 20.0, policy=None,
                    seed: int = 0) -> Dict[str, Any]:
    """Sustained overload under the closed thermal loop.

    The ``flash_crowd`` workload multiplies the offered load by
    ``surge_gain`` over a contiguous window; with ``thermal`` enabled
    the epoch stress temperature is the RC-node response to *routed
    power* — overload drives every device to capacity, dissipation
    peaks, the node temperature rises toward its (bounded) fixed point
    and relaxes after the window.  Returns the trajectory plus thermal
    diagnostics (peak/steady node temperature, surge-window wear rate).
    """
    cal, policy = _resolve(cal, policy)
    scn = _fleet_scenario(cal, n_devices, horizon_years=horizon_years,
                          t_amb_spread=t_amb_spread)
    if thermal is True:
        thermal = ThermalParams.from_power_model(cal.power)
    wl = get_workload("flash_crowd", n_devices=n_devices,
                      utilization=utilization, n_epochs=epochs,
                      surge_gain=surge_gain)
    loads = wl.loads(seed)
    from repro.core.resilience import OPERATORS
    dmax = policy.thresholds(scn, OPERATORS)
    cos = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads,
                     router=router, n_devices=n_devices,
                     recovery_dynamics=recovery, thermal=thermal)
    stats = cosim_stats(cal.power, cos)
    surge = np.zeros(epochs, bool)
    s0 = int(float(np.asarray(wl.surge_start)))
    s1 = s0 + int(float(np.asarray(wl.surge_len)))
    surge[s0:min(s1, epochs)] = True
    tn = np.asarray(cos.t_node, np.float64) if cos.t_node is not None \
        else None
    report = dict(stats)
    report.update({
        "surge_start": s0, "surge_end": min(s1, epochs),
        "surge_served_frac": float(
            np.asarray(cos.util, np.float64)[surge].sum()
            / max(np.asarray(cos.load, np.float64)[surge].sum(), 1e-12)),
    })
    if cos.boosts is not None:
        # odometer tap (:attr:`CoSimTrajectory.boosts`): the surge shows
        # up as a burst of AVS boost events — overload heats the node,
        # delays blow through ``dmax``, supplies climb
        bo = np.asarray(cos.boosts, np.float64)
        report.update({
            "boost_events": float(bo.sum()),
            "boost_events_surge": float(bo[surge].sum()),
        })
    if tn is not None:
        # fleet-MEAN temperature carries the surge signature: individual
        # devices already hit their full-load steady state in normal
        # operation (the wear-level router concentrates load), but only
        # the overload pins the whole fleet there at once
        fm = tn.mean(axis=1)
        report.update({
            "t_peak_k": float(tn.max()),
            "t_steady_k": float(tn[~surge][-8:].mean()),
            "t_surge_rise_k": float(fm[surge].max()
                                    - fm[:max(s0, 1)].mean()),
        })
    return {"cos": cos, "workload": wl, "stats": report,
            "scenario": scn, "thermal": thermal}


# --------------------------------------------------------------------------- #
# (b) mid-horizon retirement / hot-swap
# --------------------------------------------------------------------------- #
def run_retirement(cal: Optional[Calibration] = None, *,
                   n_devices: int = 8, retire=(0,), hot_swap: int = 0,
                   retire_epoch: Optional[int] = None,
                   epochs: int = DEFAULT_EPOCHS,
                   horizon_years: float = 5.0, utilization: float = 0.5,
                   workload: str = "diurnal", router: str = "wear_level",
                   recovery=True, thermal=None,
                   t_amb_spread: float = 20.0, tp: int = 1,
                   global_batch: int = 64, policy=None,
                   seed: int = 0) -> Dict[str, Any]:
    """Retire devices mid-horizon; survivors keep their trap state.

    Two co-sim segments around the retirement epoch: the full fleet ages
    under routed traffic, then ``retire`` (device indices) leave the
    rotation, ``hot_swap`` factory-fresh replacements take their rack
    slots, and the resized fleet — survivors resuming *bit-exactly* from
    their accumulated monotone + recoverable state via
    :meth:`repro.core.fleet.FleetRuntime.resize` — serves the remaining
    horizon.  The matching serving-mesh change is planned with
    :func:`repro.distributed.elastic.plan_remesh_shape` (each fleet lane
    is one ``tp``-chip model-parallel group on a ("data", "model")
    mesh).  Returns both segment trajectories, the degraded and restored
    :class:`repro.distributed.elastic.RemeshPlan`, and before/after
    fleet wear stats.
    """
    cal, policy = _resolve(cal, policy)
    if retire_epoch is None:
        retire_epoch = epochs // 2
    assert 0 < retire_epoch < epochs
    retire = tuple(int(i) for i in retire)
    keep = [i for i in range(n_devices) if i not in set(retire)]
    assert keep, "cannot retire the whole fleet"
    scn = _fleet_scenario(cal, n_devices, horizon_years=horizon_years,
                          t_amb_spread=t_amb_spread)
    fleet = FleetRuntime(cal, n_devices=n_devices, scenario=scn,
                         policy=policy)
    wl = get_workload(workload, n_devices=n_devices,
                      utilization=utilization, n_epochs=epochs)
    loads = np.asarray(wl.loads(seed), np.float32)
    epoch_s = horizon_years * YEAR_S / epochs

    cos1 = fleet.apply_load(loads=loads[:retire_epoch], router=router,
                            horizon_s=retire_epoch * epoch_s,
                            recovery=recovery, thermal=thermal)
    pre_wear = cos1.device_wear()[-1]                      # (N,)

    fleet2 = fleet.resize(keep, n_fresh=hot_swap)
    n_after = len(keep) + hot_swap
    plan_degraded = plan_remesh_shape(
        ("data", "model"), {"data": n_devices, "model": tp},
        len(keep) * tp, global_batch=global_batch)
    plan_restored = plan_remesh_shape(
        ("data", "model"), {"data": n_devices, "model": tp},
        n_after * tp, global_batch=global_batch) if hot_swap else None

    cos2 = fleet2.apply_load(loads=loads[retire_epoch:], router=router,
                             horizon_s=(epochs - retire_epoch) * epoch_s,
                             recovery=recovery, thermal=thermal)
    stats = cosim_stats(cal.power, cos2)
    stats.update({
        "n_before": n_devices, "n_after": n_after,
        "retired": list(retire), "retire_epoch": int(retire_epoch),
        "pre_retire_max_dvp_mv": float(pre_wear.max()),
        "survivor_pre_max_dvp_mv": float(pre_wear[keep].max()),
    })
    return {"fleet": fleet2, "cos_before": cos1, "cos_after": cos2,
            "plan_degraded": plan_degraded, "plan_restored": plan_restored,
            "keep": keep, "stats": stats}


# --------------------------------------------------------------------------- #
# (c) rest-to-recover vs round-robin
# --------------------------------------------------------------------------- #
def run_rest_to_recover(cal: Optional[Calibration] = None, *,
                        n_devices: int = 8, epochs: int = DEFAULT_EPOCHS,
                        horizon_years: float = 5.0,
                        utilization: float = 0.55,
                        workload: str = "diurnal",
                        t_amb_spread: float = 30.0,
                        stagger_years: float = 7.0,
                        recovery=True, thermal=None, policy=None,
                        seed: int = 0) -> Dict[str, Any]:
    """Quantify the recovery harvest of deliberate idling.

    Same fleet + traffic under ``round_robin``, ``wear_level`` and
    ``rest_to_recover`` with the short-term recoverable pool enabled:
    resting the most-worn devices lets their fast traps relax, so the
    rest router's fleet-max *effective* ΔVth undercuts both the blind
    baseline and pure steering.  Returns per-router stats plus the
    headline delta vs round-robin.
    """
    cal, policy = _resolve(cal, policy)
    if recovery is True:
        recovery = RecoveryParams.default()
    scn = _fleet_scenario(cal, n_devices, horizon_years=horizon_years,
                          t_amb_spread=t_amb_spread)
    wl = get_workload(workload, n_devices=n_devices,
                      utilization=utilization, n_epochs=epochs)
    loads = wl.loads(seed)
    ages = np.linspace(0.0, stagger_years, n_devices) * YEAR_S
    res = compare_routers(
        cal, scn, policy, loads,
        routers=("round_robin", "wear_level", "rest_to_recover"),
        n_devices=n_devices, ages_s=ages, recovery_dynamics=recovery,
        thermal=thermal)
    rr = res["round_robin"]["fleet_max_dvp_mv"]
    rest = res["rest_to_recover"]["fleet_max_dvp_mv"]
    res["headline"] = {
        "rest_vs_round_robin_pct": 100.0 * (1.0 - rest / rr),
        "recovered_mv_final":
            res["rest_to_recover"].get("recovered_mv_final", 0.0),
    }
    return res


def recovered_totals(cos) -> np.ndarray:
    """(E, N) fleet view of the relaxed PMOS pool of a recovery run."""
    assert cos.rec is not None, "run had no recovery dynamics"
    pm = np.asarray(IS_PMOS, np.float64)
    return (np.asarray(cos.rec, np.float64) * pm).sum(axis=-1).max(axis=-1)
