"""Synthetic request-arrival models — traffic as an array program.

A :class:`Workload` describes how much inference traffic the fleet is
offered per scheduling epoch, as a pytree whose leaves (mean load, diurnal
modulation depth/period, burst probability/gain, Poisson granularity) may
carry broadcastable batch dimensions exactly like
:class:`repro.core.scenario.Scenario` leaves.  :meth:`Workload.loads`
compiles the whole arrival trace — diurnal envelope, Poisson counting
noise, flash-crowd bursts — as one vectorised program over the epoch grid
(``jnp.arange``-driven; no Python loop over epochs or requests), so a
batch of workloads emits a batch of traces from one trace/compile.

Units: offered load is measured in *device-equivalents* — ``load == 1.0``
keeps exactly one device busy for the whole epoch, ``load == N`` saturates
an N-device fleet.  The router (not the workload) decides what happens
above fleet capacity.

Four registered shapes cover the serving-traffic regimes the scheduler
cares about:

* ``poisson``  — stationary mean with Poisson counting noise (steady API
  traffic);
* ``diurnal``  — sinusoidal day/night envelope on top of the Poisson
  noise (consumer traffic; the shape the wear-leveling acceptance test
  and ``repro.launch.schedule`` default to);
* ``bursty``   — Poisson base plus Bernoulli flash crowds that multiply
  the epoch's load (launch-day spikes);
* ``flash_crowd`` — a *sustained* overload window (``surge_gain`` x the
  mean for a contiguous stretch of epochs) — the disruption scenario
  driving the thermal-feedback co-simulation
  (:mod:`repro.sched.disruption`).

``get_workload(name, n_devices=N)`` resolves a registered shape with its
mean pre-scaled to the fleet size.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

# Leaf fields, in pytree order.  Everything here may be batched / traced.
WORKLOAD_FIELDS = ("mean_load", "amplitude", "period", "phase",
                   "burst_prob", "burst_gain", "quanta",
                   "surge_start", "surge_len", "surge_gain")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Workload:
    """One request-arrival process (or a broadcastable batch of them)."""

    mean_load: Any = 4.0       # mean offered load [device-equivalents]
    amplitude: Any = 0.0       # diurnal modulation depth (0 = flat)
    period: Any = 24.0         # diurnal period [epochs]
    phase: Any = 0.0           # phase offset [epochs]
    burst_prob: Any = 0.0      # per-epoch flash-crowd probability
    burst_gain: Any = 3.0      # load multiplier inside a burst epoch
    quanta: Any = 64.0         # requests per device-epoch (Poisson grain)
    surge_start: Any = 0.0     # flash-crowd window start [epochs]
    surge_len: Any = 0.0       # flash-crowd window length (0 = no surge)
    surge_gain: Any = 1.0      # load multiplier inside the window
    # --- static (aux) structure -------------------------------------------
    n_epochs: int = 480        # length of the emitted trace
    kind: str = "poisson"      # registry label (provenance only)

    # ------------------------------------------------------------------ #
    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in WORKLOAD_FIELDS),
                (self.n_epochs, self.kind))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_epochs=aux[0], kind=aux[1])

    @property
    def batch_shape(self) -> tuple:
        return jnp.broadcast_shapes(
            *(jnp.shape(getattr(self, f)) for f in WORKLOAD_FIELDS))

    def replace(self, **kw) -> "Workload":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ #
    def envelope(self) -> jnp.ndarray:
        """Deterministic mean-load trace, shape ``batch_shape + (E,)``."""
        e = jnp.arange(self.n_epochs, dtype=jnp.float32)
        mean = jnp.asarray(self.mean_load, jnp.float32)[..., None]
        amp = jnp.asarray(self.amplitude, jnp.float32)[..., None]
        period = jnp.asarray(self.period, jnp.float32)[..., None]
        phase = jnp.asarray(self.phase, jnp.float32)[..., None]
        day = 1.0 + amp * jnp.sin(2.0 * jnp.pi * (e + phase) / period)
        # sustained flash-crowd window (distinct from per-epoch Bernoulli
        # bursts): a contiguous overload interval multiplying the mean.
        start = jnp.asarray(self.surge_start, jnp.float32)[..., None]
        length = jnp.asarray(self.surge_len, jnp.float32)[..., None]
        sgain = jnp.asarray(self.surge_gain, jnp.float32)[..., None]
        surge = jnp.where((e >= start) & (e < start + length), sgain, 1.0)
        return mean * jnp.maximum(day, 0.0) * surge

    def loads(self, key=None) -> jnp.ndarray:
        """Sample the offered-load trace, shape ``batch_shape + (E,)``.

        The envelope is quantised into Poisson request counts at ``quanta``
        requests per device-epoch (so relative noise shrinks as traffic
        grows, like real arrival counts), then flash-crowd epochs multiply
        their load by ``burst_gain``.  ``key=None`` (or an int seed)
        selects a deterministic stream — an int seed ``s`` and
        ``jax.random.PRNGKey(s)`` are the SAME stream, and two calls with
        the same key are bit-identical, which the co-simulation caching
        relies on.

        Every field broadcasts against the full ``batch_shape`` before
        sampling, so batch dims carried only by ``quanta`` or
        ``burst_prob`` (e.g. a granularity sweep over one envelope) emit
        proper batched traces; a zero envelope stays exactly zero through
        quantisation and bursts (``0 * burst_gain == 0``).
        """
        if key is None or isinstance(key, int):
            key = jax.random.PRNGKey(0 if key is None else key)
        k_noise, k_burst = jax.random.split(key)
        shape = self.batch_shape + (self.n_epochs,)
        env = jnp.broadcast_to(self.envelope(), shape)
        q = jnp.broadcast_to(
            jnp.asarray(self.quanta, jnp.float32)[..., None], shape)
        counts = jax.random.poisson(k_noise, env * q, shape=shape)
        load = counts.astype(jnp.float32) / q
        p = jnp.asarray(self.burst_prob, jnp.float32)[..., None]
        gain = jnp.asarray(self.burst_gain, jnp.float32)[..., None]
        burst = jax.random.bernoulli(k_burst, jnp.broadcast_to(p, shape))
        return jnp.where(burst, load * gain, load)

    def to_dict(self) -> Dict[str, Any]:
        d = {f: np.asarray(getattr(self, f)).tolist()
             for f in WORKLOAD_FIELDS}
        d.update(n_epochs=self.n_epochs, kind=self.kind)
        return d


# --------------------------------------------------------------------------- #
# registry of named traffic shapes
# --------------------------------------------------------------------------- #
def poisson(mean_load: float = 4.0, **kw) -> Workload:
    """Stationary Poisson traffic at ``mean_load`` device-equivalents."""
    return Workload(mean_load=mean_load, amplitude=0.0, burst_prob=0.0,
                    kind="poisson", **kw)


def diurnal(mean_load: float = 4.0, amplitude: float = 0.6,
            period: float = 24.0, **kw) -> Workload:
    """Day/night sinusoid (depth ``amplitude``) on Poisson noise."""
    return Workload(mean_load=mean_load, amplitude=amplitude, period=period,
                    burst_prob=0.0, kind="diurnal", **kw)


def bursty(mean_load: float = 3.0, burst_prob: float = 0.05,
           burst_gain: float = 3.0, **kw) -> Workload:
    """Poisson base plus Bernoulli flash crowds multiplying the epoch."""
    return Workload(mean_load=mean_load, amplitude=0.0,
                    burst_prob=burst_prob, burst_gain=burst_gain,
                    kind="bursty", **kw)


def flash_crowd(mean_load: float = 4.0, surge_gain: float = 4.0,
                surge_start=None, surge_len=None, *,
                n_epochs: int = 480, **kw) -> Workload:
    """Sustained overload window: ``surge_gain`` x the mean for a
    contiguous stretch of epochs (default: 8%% of the horizon starting
    at 40%%) — the disruption the thermal-feedback co-sim is stressed
    with.  Distinct from ``bursty``'s independent single-epoch spikes.
    """
    if surge_start is None:
        surge_start = 0.4 * n_epochs
    if surge_len is None:
        surge_len = max(1.0, 0.08 * n_epochs)
    return Workload(mean_load=mean_load, amplitude=0.0, burst_prob=0.0,
                    surge_start=surge_start, surge_len=surge_len,
                    surge_gain=surge_gain, n_epochs=n_epochs,
                    kind="flash_crowd", **kw)


WORKLOADS = {"poisson": poisson, "diurnal": diurnal, "bursty": bursty,
             "flash_crowd": flash_crowd}


def get_workload(name: str, *, n_devices: int = 1, utilization: float = 0.5,
                 **kw) -> Workload:
    """Named workload with its mean sized for an ``n_devices`` fleet.

    ``utilization`` is the fleet-average duty the traffic should impose
    (``mean_load = utilization * n_devices``); an explicit ``mean_load``
    kwarg overrides it.
    """
    try:
        factory = WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; registered: "
                       f"{sorted(WORKLOADS)}") from None
    kw.setdefault("mean_load", utilization * n_devices)
    return factory(**kw)
