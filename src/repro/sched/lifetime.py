"""Piecewise lifetime co-simulation: traffic drives the aging recursion.

:func:`repro.core.avs.simulate` ages a device under *static* stress — one
(duty, toggle, T_amb) triple for the whole lifetime.  This module extends
that scan across scheduling epochs whose stress leaves are *recomputed
from routed load each epoch*: the router assigns the epoch's offered
traffic, the assignment scales every device's duty cycle, toggle rate and
load-induced heating, the six trap populations advance with the same
history-aware effective-time update (the paper's historical-effect
recursion, now driven by traffic instead of a fixed profile), and the AVS
policy boosts each (device, operator-domain) supply against its
``delay_max`` — all inside ONE jitted ``lax.scan`` per fleet:

    routing -> stress -> ΔVth -> policy voltage -> power,  closed per epoch.

Compiled co-simulations are cached per (router, static shape) —
``_cosim_fn`` — with the arrival trace, scenario leaves, thresholds and
initial state entering as traced arguments, so re-routing new traffic
(or resuming from a different fleet age) re-jits NOTHING.
``TRACE_COUNTS`` ticks once per trace exactly like
``repro.serve.steps.TRACE_COUNTS`` and is regression-guarded by
``tests/test_sched.py`` and ``benchmarks/sched_bench.py``; it now lives
in the metrics registry (:func:`repro.obs.metrics.trace_counts` folds it
into the unified retrace guard) while keeping the plain-``Counter``
protocol.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aging
from repro.core.aging import AgingParams, RecoveryParams
from repro.core.constants import V_NOM
from repro.core.delay import DelayPolynomial
from repro.core.scenario import SCENARIO_FIELDS, LifetimeTrajectory, Scenario

from repro.obs.metrics import REGISTRY

from .router import Router, get_router
from .workload import Workload

# Registry-homed trace counter; still a collections.Counter, so the
# historical ``dict(TRACE_COUNTS)`` before/after idiom keeps working.
TRACE_COUNTS = REGISTRY.trace_counter("sched_lifetime")

# Default scheduling resolution: enough epochs that a 24-epoch diurnal
# period repeats ~20x over the horizon, cheap enough for CPU CI.
DEFAULT_EPOCHS = 480
# Load-induced heating [K] at full utilization (rack-level, on top of the
# V^2 self-heating the aging model already applies).
HEAT_PER_UTIL_K = 12.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ThermalParams:
    """Per-device thermal RC node closing temperature on *routed power*.

    The open-loop heating model (``t_amb + heat_per_util * util``) scales
    with utilization only; under a flash crowd the feedback that matters
    is power: an aged device boosted to ``v_max`` burns more per served
    request, heats further, ages faster.  This node closes that loop
    inside the co-sim scan:

        P_dev  = sum_ops( util * dyn(V) + leak(V, dVth) )   [W]
        T_ss   = t_amb + r_th * P_dev                       [K]
        T'     = T_ss + (T - T_ss) * exp(-epoch_s / tau_s)

    and the epoch's stress temperature is ``T'`` instead of the fixed
    leaf.  The power coefficients mirror
    :class:`repro.core.power.PowerModel` but live here as *pytree leaves*
    so every thermal knob is a traced argument of the cached scan — a
    thermal sweep re-jits nothing.  The fixed point is bounded: ``util <=
    1``, ``V <= v_max`` and leakage falls with ΔVth, so ``T_ss`` is
    bounded by the fresh-device full-load dissipation.
    """

    r_th: Any = 2.5          # node thermal resistance [K/W]
    tau_s: Any = 21600.0     # node RC time constant [s]
    p_dyn0: Any = 0.70       # dynamic power / operator at v0 [W]
    p_leak0: Any = 0.15      # leakage / operator at (v0, fresh) [W]
    v0: Any = V_NOM
    s_slope: Any = 0.085     # subthreshold slope [V/decade]
    k_dibl: Any = 1.5        # supply sensitivity of leakage

    _FIELDS = ("r_th", "tau_s", "p_dyn0", "p_leak0", "v0", "s_slope",
               "k_dibl")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def from_power_model(cls, pm, *, r_th: float = 2.5,
                         tau_s: float = 21600.0) -> "ThermalParams":
        """Lift a calibrated :class:`repro.core.power.PowerModel` into
        the thermal node (same per-operator dissipation model)."""
        return cls(r_th=r_th, tau_s=tau_s, p_dyn0=pm.p_dyn0,
                   p_leak0=pm.p_leak0, v0=pm.v0, s_slope=pm.s_slope,
                   k_dibl=pm.k_dibl)

    def replace(self, **kw) -> "ThermalParams":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CoSimTrajectory:
    """Structured result of :func:`cosimulate`.

    ``E`` epochs x ``N`` devices x ``O`` operator domains; the epoch axis
    leads (scan layout).  ``as_lifetime_trajectory`` re-lays the series
    into the fleet's ``(N, O, T)`` convention so a
    :class:`repro.core.fleet.FleetRuntime` can serve from it.

    With short-term recovery enabled, ``dv`` remains the *monotone*
    per-population state while ``dvp``/``dvn`` (and everything downstream
    of them: delay, supply, wear signal) are the **effective** totals
    ``sum(dv - rec)`` — the shift the silicon actually exhibits after
    idle-interval relaxation.  ``rec`` is the relaxed pool itself;
    ``t_node`` is the closed-loop node temperature when thermal feedback
    is on.  Both are ``None`` for legacy (monotone, open-loop) runs.
    """

    t: jnp.ndarray          # (E,) epoch-end wall-clock [s]
    load: jnp.ndarray       # (E,) offered load [device-equivalents]
    util: jnp.ndarray       # (E, N) routed utilization
    V: jnp.ndarray          # (E, N, O) supply voltage [V]
    delay: jnp.ndarray      # (E, N, O) critical-path delay [s]
    dvp: jnp.ndarray        # (E, N, O) PMOS ΔVth [mV] (effective)
    dvn: jnp.ndarray        # (E, N, O) NMOS ΔVth [mV] (effective)
    dv: jnp.ndarray         # (E, N, O, P) monotone per-population shifts
    # short-term recovery / thermal feedback extensions (None when the
    # corresponding dynamics are disabled — the legacy trajectory shape)
    rec: Any = None         # (E, N, O, P) relaxed (recovered) pool [mV]
    t_node: Any = None      # (E, N) thermal-node temperature [K]
    # telemetry tap: per-epoch AVS boost-event counts (summed over
    # operator domains); zeros when AVS is disabled, None on trajectories
    # predating the obs layer
    boosts: Any = None      # (E, N) boost events this epoch

    _FIELDS = ("t", "load", "util", "V", "delay", "dvp", "dvn", "dv",
               "rec", "t_node", "boosts")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # ------------------------------------------------------------------ #
    @property
    def n_epochs(self) -> int:
        return int(self.V.shape[0])

    @property
    def n_devices(self) -> int:
        return int(self.V.shape[1])

    def device_wear(self) -> np.ndarray:
        """(E, N) per-device wear signal: ΔVth_p of the worst domain."""
        return np.asarray(self.dvp).max(axis=-1)

    def as_lifetime_trajectory(self) -> LifetimeTrajectory:
        """Re-lay to the fleet's ``(N, O, T)`` series convention."""
        E, N, O = self.V.shape
        move = lambda x: np.moveaxis(np.asarray(x), 0, 2)
        return LifetimeTrajectory(
            t=np.broadcast_to(np.asarray(self.t), (N, O, E)),
            V=move(self.V), delay=move(self.delay),
            dvp=move(self.dvp), dvn=move(self.dvn),
            dv=np.moveaxis(np.asarray(self.dv), 0, 2))


# --------------------------------------------------------------------------- #
# the compiled co-simulation
# --------------------------------------------------------------------------- #
def _pop_totals(dv):
    """Batched :func:`repro.core.aging.totals`: sum the population axis."""
    pm = jnp.asarray(aging.IS_PMOS, dv.dtype)
    return jnp.sum(dv * pm, axis=-1), jnp.sum(dv * (1.0 - pm), axis=-1)


@functools.lru_cache(maxsize=None)
def _cosim_fn(router: Optional[Router], n_epochs: int, n_devices: int,
              n_ops: int, max_boosts: int, recovery: bool,
              avs_enabled: bool, replay: bool = False,
              short_term: bool = False, thermal: bool = False):
    """Jitted co-sim scan for one (router, static shape) bucket.

    Routers are frozen dataclasses (hashable), so each router
    configuration owns one compiled executable; everything else —
    arrival trace, scenario leaves, thresholds, heating coefficient,
    capacity, initial state, recovery rates, thermal-node coefficients —
    is a traced argument.

    ``replay=True`` builds the *measured-utilization* variant: the scan
    consumes a per-epoch ``(E, N)`` utilization trace instead of calling
    ``router.assign`` (``router`` is ``None`` — one executable serves
    every replay source).  Feeding a routed run's own ``util`` output
    back through the replay path reproduces its trajectory bit-for-bit:
    the stress recursion downstream of ``util`` is the same code.

    ``short_term=True`` threads the recoverable trap pool through the
    carry (:func:`repro.core.aging.relax_step`); ``thermal=True``
    replaces the open-loop ``t_amb + heat*util`` heating with the
    :class:`ThermalParams` RC node driven by routed power.  Both are
    *structure* flags: the rate constants and thermal coefficients
    themselves stay traced, so sweeping them re-jits nothing.
    """

    def run(params: AgingParams, poly: DelayPolynomial, scn: Scenario,
            dmax, loads, epoch_s, capacity, heat, dv0, v0, util0,
            rparams, rec0, tparams, tn0, *util_xs):
        TRACE_COUNTS["cosim"] += 1
        duty0 = jnp.broadcast_to(
            jnp.asarray(scn.duty, jnp.float32), (n_devices,))
        toggle0 = jnp.broadcast_to(
            jnp.asarray(scn.toggle, jnp.float32), (n_devices,))
        t_amb0 = jnp.broadcast_to(
            jnp.asarray(scn.t_amb, jnp.float32), (n_devices,))
        t_clk = jnp.broadcast_to(
            jnp.asarray(scn.t_clk, jnp.float32), (n_devices,))
        tt = jnp.broadcast_to(
            jnp.asarray(scn.transition_time, jnp.float32), (n_devices,))
        v_max = jnp.broadcast_to(
            jnp.asarray(scn.v_max, jnp.float32), (n_devices,))[:, None]
        v_step = jnp.broadcast_to(
            jnp.asarray(scn.v_step, jnp.float32), (n_devices,))[:, None]
        dmax = jnp.broadcast_to(jnp.asarray(dmax, jnp.float32),
                                (n_devices, n_ops))
        epoch_s = jnp.asarray(epoch_s, jnp.float32)

        def epoch_step(carry, x):
            dv, rec, v, util_prev, tn = carry
            if replay:                      # measured duty, no routing
                load, util = x
            else:
                load = x
                # duty-cycle feedback: route on the wear traffic created
                # (the *effective* wear when recovery is modelled — a
                # rested device genuinely looks younger to the router)
                eff = dv - rec if short_term else dv
                wear = jnp.max(_pop_totals(eff)[0], axis=-1)     # (N,)
                util = router.assign(load, wear, util_prev, capacity)
            # the paper's stress inputs, recomputed from routed load
            duty = duty0 * util
            toggle = toggle0 * util
            if thermal:
                # routed power -> RC node: previous epoch's supply and
                # wear set this epoch's dissipation
                eff_c = dv - rec if short_term else dv
                dvp_c, dvn_c = _pop_totals(eff_c)                # (N, O)
                dvm = 0.5 * (dvp_c + dvn_c) * 1e-3
                dyn = tparams.p_dyn0 * (v / tparams.v0) ** 2
                leak = tparams.p_leak0 * (v / tparams.v0) * 10.0 ** (
                    (tparams.k_dibl * (v - tparams.v0) - dvm)
                    / tparams.s_slope)
                p_dev = jnp.sum(util[:, None] * dyn + leak, axis=-1)
                t_ss = t_amb0 + tparams.r_th * p_dev
                tn = t_ss + (tn - t_ss) * jnp.exp(-epoch_s / tparams.tau_s)
                t_amb = tn
            else:
                t_amb = t_amb0 + heat * util
            rates = aging.stress_rates(
                params, duty=duty[:, None], toggle=toggle[:, None],
                t_clk=t_clk[:, None], transition_time=tt[:, None],
                recovery=recovery)                               # (N, P)
            dv = aging.update_state(params, dv, v[..., None],
                                    rates[:, None, :], epoch_s,
                                    t_amb[:, None, None])        # (N, O, P)
            if short_term:
                rec = aging.relax_step(rparams, dv, rec,
                                       util[:, None, None], epoch_s)
                dvp, dvn = _pop_totals(dv - rec)                 # effective
            else:
                dvp, dvn = _pop_totals(dv)                       # (N, O)
            delay = poly(dvp * 1e-3, dvn * 1e-3, v)

            if avs_enabled:
                v_pre = v

                def boost(_, vd):
                    v_, d_ = vd
                    need = (d_ > dmax) & (v_ < v_max - 1e-6)
                    v_ = v_ + jnp.where(need, v_step, 0.0)
                    return v_, poly(dvp * 1e-3, dvn * 1e-3, v_)

                v, delay = jax.lax.fori_loop(0, max_boosts, boost,
                                             (v, delay))
                # telemetry: boost events = steps the supply climbed
                boosts = jnp.sum((v - v_pre) / v_step, axis=-1)
            else:
                boosts = jnp.zeros((n_devices,), jnp.float32)
            out = {"util": util, "V": v, "delay": delay,
                   "dvp": dvp, "dvn": dvn, "dv": dv, "boosts": boosts}
            if short_term:
                out["rec"] = rec
            if thermal:
                out["t_node"] = tn
            return (dv, rec, v, util, tn), out

        xs = jnp.asarray(loads, jnp.float32)
        if replay:
            xs = (xs, jnp.asarray(util_xs[0], jnp.float32))
        _, out = jax.lax.scan(epoch_step, (dv0, rec0, v0, util0, tn0), xs)
        return out

    return jax.jit(run)


def cosimulate(params: AgingParams, poly: DelayPolynomial,
               scenario: Scenario, delay_max, loads,
               router: Router | str = "wear_level", *,
               util_trace=None,
               n_devices: Optional[int] = None,
               epoch_s: Optional[float] = None,
               capacity: float = 1.0,
               heat_per_util: float = HEAT_PER_UTIL_K,
               dv0=None, v0=None, util0=None,
               recovery: bool = True,
               avs_enabled: bool = True,
               recovery_dynamics: RecoveryParams | bool | None = None,
               thermal: "ThermalParams | bool | None" = None,
               rec0=None, t_node0=None) -> CoSimTrajectory:
    """Run the traffic-driven lifetime co-simulation for one fleet.

    ``scenario`` holds per-device *full-utilization* stress knobs (scalar
    leaves broadcast across the fleet; ``(N,)``-batched leaves give a
    heterogeneous fleet — e.g. a rack thermal gradient in ``t_amb``).
    ``delay_max`` is the policy threshold array, ``(O,)`` or ``(N, O)``.
    ``loads`` is the offered-load trace ``(E,)`` (see
    :mod:`repro.sched.workload`).  ``epoch_s`` defaults to
    ``scenario.lifetime_s / E`` so the trace spans the scenario horizon.
    ``dv0 / v0 / util0`` resume the recursion from an existing fleet
    state (see :meth:`repro.core.fleet.FleetRuntime.apply_load`).

    ``util_trace`` — an ``(E, N)`` *measured* per-device utilization
    trace (e.g. online-serving slot occupancy resampled to the epoch
    grid; see ``repro.serve.online``) — switches the scan to replay
    mode: the trace drives the stress recursion directly and ``router``
    is ignored.  ``loads`` may then be ``None`` (it defaults to the
    per-epoch sum of the trace, recorded for bookkeeping only).
    Replaying a routed run's own ``cos.util`` output is bit-identical
    to the routed run.

    ``recovery_dynamics`` enables the short-term recoverable trap pool
    (``True`` for :meth:`repro.core.aging.RecoveryParams.default`, or an
    explicit instance); ``rec0`` resumes it.  ``thermal`` closes the
    temperature loop on routed power (``True`` for default
    :class:`ThermalParams`); ``t_node0`` resumes the node state.  Note
    ``recovery`` (the capture/emission *rate* scaling, a long-term AC/DC
    effect) and ``recovery_dynamics`` (the short-term relaxing pool) are
    independent knobs.

    Returns a :class:`CoSimTrajectory`; ONE jitted scan per
    (router, shape, dynamics-structure) — re-routing new traffic or
    sweeping recovery/thermal *values* re-jits nothing.
    """
    if recovery_dynamics is True:
        recovery_dynamics = RecoveryParams.default()
    elif recovery_dynamics is False:
        recovery_dynamics = None
    if thermal is True:
        thermal = ThermalParams()
    elif thermal is False:
        thermal = None
    short_term = recovery_dynamics is not None
    replay = util_trace is not None
    if replay:
        util_trace = jnp.asarray(util_trace, jnp.float32)
        assert util_trace.ndim == 2, \
            f"util_trace must be (E, N), got {util_trace.shape}"
        if n_devices is None:
            n_devices = util_trace.shape[1]
        assert util_trace.shape[1] == n_devices, \
            f"util_trace device dim {util_trace.shape[1]} != {n_devices}"
        if loads is None:
            loads = util_trace.sum(axis=-1)
        router = None
    else:
        router = get_router(router)
    loads = jnp.asarray(loads, jnp.float32)
    assert loads.ndim == 1, f"loads must be (E,), got {loads.shape}"
    if replay:
        assert loads.shape[0] == util_trace.shape[0], \
            f"loads epochs {loads.shape[0]} != util_trace " \
            f"{util_trace.shape[0]}"
    dmax = jnp.asarray(delay_max, jnp.float32)
    sbatch = scenario.batch_shape
    assert len(sbatch) <= 1, \
        "cosimulate scenarios must be scalar or (n_devices,)-batched"
    if n_devices is None:
        n_devices = (sbatch[0] if sbatch else
                     (dmax.shape[0] if dmax.ndim == 2 else 1))
    n_ops = dmax.shape[-1]
    E = loads.shape[0]
    if epoch_s is None:
        epoch_s = float(np.asarray(
            jnp.mean(jnp.asarray(scenario.lifetime_s, jnp.float32)))) / E

    if dv0 is None:
        dv0 = jnp.zeros((n_devices, n_ops, aging.N_POP), jnp.float32)
    if v0 is None:
        v0 = jnp.broadcast_to(jnp.asarray(scenario.v_init, jnp.float32)
                              .reshape(-1, 1), (n_devices, n_ops))
    if util0 is None:
        util0 = jnp.zeros((n_devices,), jnp.float32)

    if rec0 is None:
        rec0 = jnp.zeros((n_devices, n_ops, aging.N_POP), jnp.float32)
    if t_node0 is None:
        t_node0 = jnp.broadcast_to(
            jnp.asarray(scenario.t_amb, jnp.float32).reshape(-1),
            (n_devices,))

    fn = _cosim_fn(router, E, n_devices, n_ops,
                   scenario.max_boosts_per_step, recovery, avs_enabled,
                   replay, short_term, thermal is not None)
    xtra = (util_trace,) if replay else ()
    out = fn(params, poly, scenario, dmax, loads,
             jnp.float32(epoch_s), jnp.float32(capacity),
             jnp.float32(heat_per_util),
             jnp.asarray(dv0, jnp.float32), jnp.asarray(v0, jnp.float32),
             jnp.asarray(util0, jnp.float32),
             recovery_dynamics, jnp.asarray(rec0, jnp.float32),
             thermal, jnp.asarray(t_node0, jnp.float32), *xtra)
    t = (np.arange(E, dtype=np.float64) + 1.0) * float(epoch_s)
    return CoSimTrajectory(t=jnp.asarray(t, jnp.float32), load=loads,
                           util=out["util"], V=out["V"],
                           delay=out["delay"], dvp=out["dvp"],
                           dvn=out["dvn"], dv=out["dv"],
                           rec=out.get("rec"), t_node=out.get("t_node"),
                           boosts=out.get("boosts"))


# --------------------------------------------------------------------------- #
# pre-aged fleet state (staggered deployments)
# --------------------------------------------------------------------------- #
def initial_state_at_ages(params: AgingParams, poly: DelayPolynomial,
                          scenario: Scenario, delay_max, ages_s):
    """Per-device ``(dv0, v0)`` after ``ages_s`` of static-stress service.

    Runs the classic :func:`repro.core.avs.simulate` scan for the
    scenario (one vmapped call; scalar scenarios broadcast across the
    fleet) and gathers each device's trap-population state and supply at
    its age — the state a *staggered deployment* hands the traffic
    co-simulation to resume from.  Vectorised gathers, no loop over
    devices.
    """
    from repro.core.avs import simulate
    traj = simulate(params, poly, scenario.expand_dims(-1),
                    delay_max=jnp.asarray(delay_max, jnp.float32))
    t, dv, V = (np.asarray(traj.t), np.asarray(traj.dv), np.asarray(traj.V))
    ages = np.atleast_1d(np.asarray(ages_s, np.float64))
    n = ages.shape[0]
    if t.ndim == 2:                       # scalar scenario: (O, T) series
        t = np.broadcast_to(t, (n,) + t.shape)
        V = np.broadcast_to(V, (n,) + V.shape)
        dv = np.broadcast_to(dv, (n,) + dv.shape)
    idx = np.clip((t < ages[:, None, None]).sum(-1), 0, t.shape[-1] - 1)
    v0 = np.take_along_axis(V, idx[..., None], axis=-1)[..., 0]
    dv0 = np.take_along_axis(dv, idx[..., None, None], axis=-2)[..., 0, :]
    return (jnp.asarray(dv0, jnp.float32), jnp.asarray(v0, jnp.float32))


# --------------------------------------------------------------------------- #
# summary statistics + router comparison
# --------------------------------------------------------------------------- #
def cosim_stats(power_model, cos: CoSimTrajectory) -> Dict[str, Any]:
    """Fleet-level lifetime summary of one co-simulation.

    Epochs are uniform, so lifetime averages are plain means over the
    epoch axis.  ``p_avg_w`` is the lifetime-average TOTAL fleet array
    power, activity-scaled (:meth:`repro.core.power.PowerModel.
    power_at_activity` — dynamic power follows the routed duty, leakage
    burns regardless); ``fleet_max_dvp_mv`` is the headline wear number
    (worst device, worst domain, end of life) the wear-leveling router
    is built to minimise.
    """
    wear = cos.device_wear()                      # (E, N)
    p = np.asarray(power_model.power_at_activity(
        cos.V, cos.dvp, cos.dvn, np.asarray(cos.util)[..., None]),
        np.float64)
    load = np.asarray(cos.load, np.float64)
    served = np.asarray(cos.util, np.float64).sum(axis=-1)
    out = {
        "fleet_max_dvp_mv": float(wear[-1].max()),
        "fleet_mean_dvp_mv": float(wear[-1].mean()),
        "wear_spread_mv": float(wear[-1].max() - wear[-1].min()),
        "p_avg_w": float(p.mean(axis=0).sum()),
        "v_final_max": float(np.asarray(cos.V)[-1].max()),
        "served_frac": float(served.sum() / max(load.sum(), 1e-12)),
        "util_mean": float(np.asarray(cos.util).mean()),
    }
    if cos.rec is not None:
        pm = np.asarray(aging.IS_PMOS, np.float64)
        rec_p = (np.asarray(cos.rec, np.float64) * pm).sum(axis=-1)
        out["recovered_mv_final"] = float(rec_p[-1].max())
    if cos.t_node is not None:
        tn = np.asarray(cos.t_node, np.float64)
        out["t_node_peak_k"] = float(tn.max())
        out["t_node_final_k"] = float(tn[-1].max())
    return out


def compare_routers(cal, scenario: Scenario, policy, loads, *,
                    routers=("round_robin", "least_loaded", "least_aged",
                             "wear_level"),
                    operators=None, n_devices: Optional[int] = None,
                    epoch_s: Optional[float] = None,
                    heat_per_util: float = HEAT_PER_UTIL_K,
                    ages_s=None, dv0=None, v0=None,
                    capacity: float = 1.0,
                    recovery_dynamics=None,
                    thermal=None) -> Dict[str, Dict[str, Any]]:
    """Co-simulate the same fleet + traffic under each router.

    ``cal`` is a :class:`repro.core.artifacts.Calibration`; the policy's
    per-operator ``delay_max`` thresholds are evaluated once on the
    (possibly per-device) scenario and shared across routers, so the
    comparison isolates the routing decision.  ``ages_s`` pre-ages the
    fleet (staggered deployment) via :func:`initial_state_at_ages`;
    explicit ``dv0 / v0`` override it (a pre-aged fleet starts with an
    empty recoverable pool: sustained static stress pins it at zero).
    ``recovery_dynamics`` / ``thermal`` pass through to
    :func:`cosimulate` so router comparisons can include the short-term
    recovery harvest and the closed thermal loop.  Returns
    ``{router_name: cosim_stats + trajectory}``.
    """
    from repro.core.resilience import OPERATORS
    ops = tuple(operators or OPERATORS)
    dmax = policy.thresholds(scenario, ops)
    if ages_s is not None and dv0 is None:
        ages_s = np.atleast_1d(np.asarray(ages_s, np.float64))
        if n_devices is None and not scenario.batch_shape:
            n_devices = ages_s.shape[0]
        dv0, v0 = initial_state_at_ages(cal.aging, cal.delay_poly,
                                        scenario, dmax, ages_s)
    out: Dict[str, Dict[str, Any]] = {}
    for name in routers:
        cos = cosimulate(cal.aging, cal.delay_poly, scenario, dmax, loads,
                         router=name, n_devices=n_devices, epoch_s=epoch_s,
                         heat_per_util=heat_per_util, dv0=dv0, v0=v0,
                         capacity=capacity,
                         recovery_dynamics=recovery_dynamics,
                         thermal=thermal)
        out[name] = dict(cosim_stats(cal.power, cos), traj=cos)
    return out
