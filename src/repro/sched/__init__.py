"""Traffic-driven fleet scheduling: wear-leveling request routing.

The scheduler layer turns the paper's stress inputs (duty cycle, toggle
rate, temperature) into *decisions*: a :class:`~repro.sched.workload.Workload`
emits per-epoch offered load, a :class:`~repro.sched.router.Router` assigns
it across the fleet, and :func:`~repro.sched.lifetime.cosimulate` closes
routing -> stress -> ΔVth -> policy voltage -> power in one jitted scan.
``FleetRuntime.apply_load`` replays the result into the serving stack so
served BERs reflect traffic-dependent age; ``python -m
repro.launch.schedule`` compares routers end to end.
"""
from .disruption import (run_flash_crowd, run_rest_to_recover,
                         run_retirement)
from .lifetime import (DEFAULT_EPOCHS, HEAT_PER_UTIL_K, CoSimTrajectory,
                       ThermalParams, compare_routers, cosim_stats,
                       cosimulate, initial_state_at_ages)
from .router import (LeastAgedRouter, LeastLoadedRouter, ROUTER_REGISTRY,
                     RestToRecoverRouter, RoundRobinRouter, Router,
                     WearLevelRouter, get_router, register_router,
                     waterfill)
from .workload import (WORKLOADS, Workload, bursty, diurnal, flash_crowd,
                       get_workload, poisson)

__all__ = [
    "DEFAULT_EPOCHS", "HEAT_PER_UTIL_K",
    "CoSimTrajectory", "ThermalParams", "compare_routers", "cosim_stats",
    "cosimulate", "initial_state_at_ages",
    "run_flash_crowd", "run_rest_to_recover", "run_retirement",
    "LeastAgedRouter", "LeastLoadedRouter", "ROUTER_REGISTRY",
    "RestToRecoverRouter", "RoundRobinRouter", "Router", "WearLevelRouter",
    "get_router", "register_router", "waterfill",
    "WORKLOADS", "Workload", "bursty", "diurnal", "flash_crowd",
    "get_workload", "poisson",
]
