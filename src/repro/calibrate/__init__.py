"""Measured-characterisation layer: fault-injection sweeps over the zoo.

:mod:`repro.calibrate.resilience_sweep` measures the per-operator
BER -> accuracy-loss curves the fault-tolerant policy consumes, as batched
single-dispatch fault-injection grids (DESIGN.md §6).  The physics-side
one-shot calibration lives in :mod:`repro.core.calibrate`; this package is
the *model*-side counterpart.
"""
from .resilience_sweep import (SweepResult, empirical_resilience, fit_sweep,
                               grid_fault_config, run_sweep, write_artifact)

__all__ = ["SweepResult", "empirical_resilience", "fit_sweep",
           "grid_fault_config", "run_sweep", "write_artifact"]
