"""Batched fault-injection resilience characterisation (the measured path).

The fault-tolerant policy's headline trick — deferring voltage boosts by
exploiting per-operator DNN resilience — is only as good as its
BER -> accuracy-loss curves.  ``core/resilience.py`` ships the published
REALM-style defaults; this module MEASURES the curves on a model from the
zoo, with the same fault machinery the serving engine uses in production
(:class:`repro.models.layers.FaultConfig` through every ``op_linear`` /
``op_batched_matmul`` domain, optionally on the fused aged-matmul kernel).

Vectorisation mirrors :class:`repro.serve.engine.FleetServeEngine`: where
the fleet engine vmaps generation over N device lanes, the sweep vmaps a
teacher-forced evaluation over L = |BER grid| x |operator domains| *fault
lanes* — one :class:`FaultConfig` whose leaves carry the lane axis, lane
``b * O + j`` injecting ``ber_grid[b]`` into operator ``j`` only.  The
whole characterisation grid for a model is therefore ONE compiled dispatch
(the lane axis runs as a ``lax.map`` over vmapped chunks — full vmap on
TPU, lane-serial on CPU where a wide vmap is cache-bound; see
:func:`default_chunk`), and because BER values / keys are traced pytree
leaves, re-running with a different grid of the same length (more seeds,
refined BERs) re-jits NOTHING.  ``TRACE_COUNTS`` ticks per trace exactly like
``repro.serve.steps.TRACE_COUNTS`` and is regression-guarded by
``tests/test_resilience_sweep.py`` and ``benchmarks/resilience_bench.py``.

Metric: **top-1 disagreement** against the quantised-but-error-free
reference execution (all-zero BER through the same int8 path), in percent —
0 at vanishing BER, collapsing to ~100 (chance) at saturating BER, matching
the ``l_max = 100`` logistic of :func:`repro.core.resilience.fit_curve`.
Comparing against the quantised reference isolates *bit errors* from
quantisation error.

Entry points: :func:`run_sweep` (measure), :func:`fit_sweep` (fit),
:func:`empirical_resilience` (both — the function the
``core/resilience.py`` docstring promises), :func:`write_artifact`
(checked-in ``resilience_calibrated.json``).  CLI:
``python -m repro.launch.calibrate_resilience``.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.resilience import (DEFAULT_LMAX, MEASURED_PATH,
                                   ResilienceCurve, curve_to_dict, fit_curve,
                                   load_measured, operators_for)
from repro.models import encdec
from repro.models import transformer as tf
from repro.models.layers import FaultConfig
from repro.obs.metrics import REGISTRY

# name -> number of times jax traced that evaluation body (cf.
# serve.steps.TRACE_COUNTS).  The whole BER x operator grid is one vmapped
# call, so a model's characterisation must tick "grid_eval" exactly once —
# and repeat sweeps (new seeds / BER values, same grid length) not at all.
# Registry-homed (repro.obs.metrics.trace_counts folds it into the unified
# retrace guard) but still a plain collections.Counter.
TRACE_COUNTS = REGISTRY.trace_counter("resilience_sweep")

# log10-uniform BER grids.  The full grid spans the published curves'
# dynamic range (Fig. 1b: 1e-7 .. 1e-3) plus headroom on both sides so the
# logistic knee of *less* resilient models (tiny zoo-reduced configs) is
# still bracketed; quick is the CI variant.
DEFAULT_BER_GRID: Tuple[float, ...] = tuple(
    float(b) for b in np.logspace(-7.0, -1.5, 12))
QUICK_BER_GRID: Tuple[float, ...] = tuple(
    float(b) for b in np.logspace(-6.0, -2.0, 5))


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Measured loss surface of one model: ``loss_pct[b, j]`` is the top-1
    disagreement [%] at ``ber_grid[b]`` injected into ``operators[j]``."""
    model: str
    family: str
    operators: Tuple[str, ...]
    ber_grid: np.ndarray           # (n_bers,)
    loss_pct: np.ndarray           # (n_bers, n_ops), seed-averaged
    n_seeds: int
    metric: str = "top1_disagreement_pct"


# --------------------------------------------------------------------------- #
# evaluation bodies — shared forward with the serving engine's score() path
# --------------------------------------------------------------------------- #
def _forward_logits(params, cfg: ModelConfig, tokens, fi, extras):
    if cfg.n_encoder_layers:
        (frames,) = extras
        enc = encdec.encode(params, cfg, frames, fi=fi)
        logits, _ = encdec.decode(params, cfg, tokens, enc_out=enc, fi=fi)
        return logits
    pe = extras[0] if cfg.prefix_tokens else None
    logits, _, _ = tf.forward_logits(params, cfg, tokens,
                                     prefix_embeds=pe, fi=fi)
    if cfg.prefix_tokens:
        logits = logits[:, cfg.prefix_tokens:]
    return logits


@functools.lru_cache(maxsize=None)
def _predict_fn(cfg: ModelConfig):
    """Jitted (params, tokens, fi, *extras) -> top-1 predictions (B, S)."""
    def predict(params, tokens, fi, *extras):
        TRACE_COUNTS["predict"] += 1
        logits = _forward_logits(params, cfg, tokens, fi, extras)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.jit(predict)


def default_chunk() -> Optional[int]:
    """Lanes vmapped together per in-graph step of the grid evaluation.

    On TPU the whole lane axis batches into the MXU — full vmap
    (``None``).  On CPU, XLA's executable for a wide lane-vmap is
    memory-bound (per-matmul injection randoms scale with the lane axis
    and blow the cache: measured 6x slower at 45 lanes than lane-serial),
    so the default is ``1``: a ``lax.map`` over lanes — still ONE
    dispatch, one trace, zero per-lane Python — with a lane-local working
    set.
    """
    return None if jax.default_backend() == "tpu" else 1


@functools.lru_cache(maxsize=None)
def _grid_eval_fn(cfg: ModelConfig, chunk: Optional[int]):
    """The single-dispatch grid evaluation: loss per fault lane.

    The lane axis (axis 0 of the :class:`FaultConfig` leaves — params,
    tokens, the reference predictions and extras broadcast, exactly how
    ``serve.engine._fleet_generate_fn`` maps fleet lanes) is evaluated as
    a ``lax.map`` over chunks of ``chunk`` vmapped lanes; ``chunk=None``
    degenerates to the pure vmap.  Either way the whole grid is one
    compiled dispatch and the evaluation body traces ONCE
    (``TRACE_COUNTS["grid_eval"]`` — ``lax.map``/``vmap`` both trace the
    body a single time).
    """
    def lane_loss(params, tokens, ref_pred, fi, *extras):
        TRACE_COUNTS["grid_eval"] += 1
        logits = _forward_logits(params, cfg, tokens, fi, extras)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        agree = jnp.mean((pred == ref_pred).astype(jnp.float32))
        return 100.0 * (1.0 - agree)

    n_extras = 1 if (cfg.n_encoder_layers or cfg.prefix_tokens) else 0
    in_axes = (None, None, None, 0) + (None,) * n_extras
    vloss = jax.vmap(lane_loss, in_axes=in_axes)
    if chunk is None:
        return jax.jit(vloss)

    def grid(params, tokens, ref_pred, fi, *extras):
        n_lanes = jax.tree_util.tree_leaves(fi)[0].shape[0]
        pad = (-n_lanes) % chunk       # any chunk works: repeat tail lanes
        if pad:
            fi = jax.tree.map(
                lambda x: jnp.concatenate([x, x[-pad:]], axis=0), fi)
        fi_c = jax.tree.map(
            lambda x: x.reshape((-1, chunk) + x.shape[1:]), fi)
        out = jax.lax.map(
            lambda fc: vloss(params, tokens, ref_pred, fc, *extras), fi_c)
        return out.reshape(-1)[:n_lanes]
    return jax.jit(grid)


# --------------------------------------------------------------------------- #
# lane construction
# --------------------------------------------------------------------------- #
def grid_fault_config(operators: Tuple[str, ...], ber_grid, key, *,
                      use_kernel: bool = False,
                      fused: bool = False) -> FaultConfig:
    """One batched :class:`FaultConfig` covering the whole (BER, operator)
    grid: every leaf carries a leading lane axis of length
    ``len(ber_grid) * len(operators)``; lane ``b * O + j`` injects
    ``ber_grid[b]`` into ``operators[j]`` and zero everywhere else.

    BER values and per-lane keys are traced leaves — refining the grid
    *values* or redrawing seeds reuses the compiled evaluation.
    """
    n_ops = len(operators)
    ber = jnp.asarray(np.asarray(ber_grid, np.float32))       # (n_bers,)
    lane_ber = jnp.repeat(ber, n_ops)                         # (L,)
    lane_op = jnp.tile(jnp.arange(n_ops, dtype=jnp.int32), ber.shape[0])
    bers = {op: jnp.where(lane_op == j, lane_ber, jnp.float32(0.0))
            for j, op in enumerate(operators)}
    keys = jax.random.split(key, ber.shape[0] * n_ops)        # (L, key)
    return FaultConfig(bers=bers, key=keys,
                       step=jnp.zeros((ber.shape[0] * n_ops,), jnp.int32),
                       use_systolic_kernel=use_kernel, fused=fused)


def _reference_fault_config(operators: Tuple[str, ...], key, *,
                            use_kernel: bool, fused: bool) -> FaultConfig:
    """Quantised-but-error-free execution: the sweep's accuracy reference
    runs the SAME int8 path with every BER pinned to zero (deterministic —
    the key is never consumed at BER 0)."""
    bers = {op: jnp.float32(0.0) for op in operators}
    return FaultConfig(bers=bers, key=key, step=jnp.int32(0),
                       use_systolic_kernel=use_kernel, fused=fused)


# --------------------------------------------------------------------------- #
# sweep + fit
# --------------------------------------------------------------------------- #
def run_sweep(cfg: ModelConfig, params, tokens, *,
              ber_grid=DEFAULT_BER_GRID,
              operators: Optional[Tuple[str, ...]] = None,
              n_seeds: int = 2, seed: int = 0, extras: tuple = (),
              use_kernel: bool = False, fused: bool = False,
              chunk: Optional[int] = 0,
              model: Optional[str] = None) -> SweepResult:
    """Measure the (BER x operator) loss surface of one model.

    Each seed repeat is ONE dispatch over all ``len(ber_grid) * O`` fault
    lanes, evaluated teacher-forced on ``tokens`` against the quantised
    error-free reference.  ``use_kernel=True`` routes the weight matmuls
    through the Pallas systolic path (``fused=True`` selects the fused
    in-kernel-PRNG injection — the serving hot path; interpret mode
    off-TPU, so expect wall-clock overhead, not different statistics).
    ``chunk`` sets the vmap width per in-graph step (default: backend
    heuristic, see :func:`default_chunk`; ``None``: pure vmap).
    """
    operators = tuple(operators or operators_for(cfg.family))
    tokens = jnp.asarray(tokens, jnp.int32)
    extras = tuple(jnp.asarray(e) for e in extras)
    key = jax.random.PRNGKey(seed)

    ref_fi = _reference_fault_config(operators, key, use_kernel=use_kernel,
                                     fused=fused)
    ref_pred = _predict_fn(cfg)(params, tokens, ref_fi, *extras)

    n_lanes = len(ber_grid) * len(operators)
    chunk = default_chunk() if chunk == 0 else chunk
    if chunk is not None:
        chunk = max(1, min(int(chunk), n_lanes))
    gfn = _grid_eval_fn(cfg, chunk)
    per_seed = []
    for s in range(n_seeds):
        fi = grid_fault_config(operators, ber_grid,
                               jax.random.fold_in(key, s),
                               use_kernel=use_kernel, fused=fused)
        per_seed.append(np.asarray(gfn(params, tokens, ref_pred, fi,
                                       *extras)))
    loss = np.mean(per_seed, axis=0).reshape(len(ber_grid), len(operators))
    return SweepResult(model=model or cfg.name, family=cfg.family,
                       operators=operators,
                       ber_grid=np.asarray(ber_grid, np.float64),
                       loss_pct=loss.astype(np.float64), n_seeds=n_seeds)


def fit_sweep(result: SweepResult,
              l_max: float = DEFAULT_LMAX) -> Dict[str, ResilienceCurve]:
    """Logistic fit per operator column of a measured loss surface."""
    return {op: fit_curve(result.ber_grid, result.loss_pct[:, j],
                          l_max=l_max)
            for j, op in enumerate(result.operators)}


def empirical_resilience(cfg: ModelConfig, params, tokens, *,
                         ber_grid=DEFAULT_BER_GRID, n_seeds: int = 2,
                         seed: int = 0, extras: tuple = (),
                         use_kernel: bool = False, fused: bool = False,
                         model: Optional[str] = None,
                         ) -> Tuple[Dict[str, ResilienceCurve], SweepResult]:
    """Measure AND fit: the in-repo recalibration entry point.

    Returns ``(curves, sweep_result)`` — feed ``curves`` to
    :class:`repro.core.policy.MeasuredResiliencePolicy` (or persist them
    with :func:`write_artifact` and use ``policy="measured"``).
    """
    res = run_sweep(cfg, params, tokens, ber_grid=ber_grid, n_seeds=n_seeds,
                    seed=seed, extras=extras, use_kernel=use_kernel,
                    fused=fused, model=model)
    return fit_sweep(res), res


# --------------------------------------------------------------------------- #
# artifact
# --------------------------------------------------------------------------- #
def write_artifact(entries: Dict[str, Tuple[SweepResult,
                                            Dict[str, ResilienceCurve]]],
                   meta: Dict, path: str = MEASURED_PATH) -> Dict:
    """Merge measured models into ``resilience_calibrated.json``.

    ``entries`` maps arch id -> (sweep result, fitted curves).  Existing
    models not re-characterised in this run are preserved, so per-arch
    recalibration is incremental.  Raw measured points are stored next to
    the fits for the EXPERIMENTS.md tables and round-trip tests.
    """
    try:
        with open(path) as f:
            blob = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        blob = {}
    blob["_meta"] = dict(
        meta, generator="PYTHONPATH=src python -m "
                        "repro.launch.calibrate_resilience",
        metric="top1_disagreement_pct")
    models = blob.setdefault("models", {})
    for arch, (res, curves) in entries.items():
        models[arch] = {
            "config_name": res.model,
            "family": res.family,
            "ber_grid": [float(b) for b in res.ber_grid],
            "n_seeds": res.n_seeds,
            "curves": {op: curve_to_dict(curves[op])
                       for op in res.operators},
            "loss_pct": {op: [float(v) for v in res.loss_pct[:, j]]
                         for j, op in enumerate(res.operators)},
        }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    load_measured.cache_clear()      # the loader must see the new artifact
    return blob
