"""Serving layer: step/generation builders and the aging-aware engines."""
from .steps import (make_decode_fn, make_decode_step, make_generate_fn,
                    make_prefill_fn, make_prefill_step, sample_token)
from .engine import FleetServeEngine, ServeEngine

__all__ = ["make_decode_fn", "make_decode_step", "make_generate_fn",
           "make_prefill_fn", "make_prefill_step", "sample_token",
           "FleetServeEngine", "ServeEngine"]
