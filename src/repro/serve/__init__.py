"""Serving layer: prefill/decode steps and the aging-aware engine."""
from .steps import make_decode_step, make_prefill_step
from .engine import ServeEngine

__all__ = ["make_decode_step", "make_prefill_step", "ServeEngine"]
