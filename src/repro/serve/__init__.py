"""Serving layer: step/generation builders, the aging-aware engines, and
the continuous-batching online engine (live request queues)."""
from .steps import (make_decode_fn, make_decode_step, make_generate_fn,
                    make_prefill_fn, make_prefill_step, sample_token)
from .engine import (FleetServeEngine, ServeEngine, cache_stats,
                     clear_caches)
from .slots import SlotState, init_slots
from .online import (OnlineFleetEngine, OnlineServeEngine,
                     OnlineServeResult, Request, RequestQueue,
                     requests_from_workload)
from .sharded import MeshGenerateResult, MeshServeEngine, default_serve_mesh

__all__ = ["make_decode_fn", "make_decode_step", "make_generate_fn",
           "make_prefill_fn", "make_prefill_step", "sample_token",
           "FleetServeEngine", "ServeEngine", "cache_stats",
           "clear_caches", "SlotState", "init_slots",
           "OnlineFleetEngine", "OnlineServeEngine", "OnlineServeResult",
           "Request", "RequestQueue", "requests_from_workload",
           "MeshGenerateResult", "MeshServeEngine", "default_serve_mesh"]
