"""Serve-step builders: prefill (full-sequence) and decode (one token).

These are the functions the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` shape cells, and the engine jits for real serving.
``decode_step`` consumes/produces the KV-cache pytree whose shardings come
from ``repro.distributed.sharding.cache_specs`` (sequence-sharded over
"model" when KV heads cannot split — partial-softmax decode attention).

Fault injection: ``fi`` (a ``repro.models.layers.FaultConfig``) threads the
per-operator BERs from the AVS runtime into every matmul domain.  The
config carries only scalars — BERs plus a base key hashed to per-operator
int32 *seeds* that the fused kernel expands in-register, so the weight
matmuls (``op_linear`` domains) lower with no output-sized random arrays.
The activation x activation qkt/sv domains (``op_batched_matmul``) still
route through the three-pass injection.  ``fi=None`` lowers the clean
graph (what the roofline measures).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import encdec
from repro.models import transformer as tf
from repro.models.layers import FaultConfig


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      fi: Optional[FaultConfig] = None) -> Callable:
    """(params, tokens[, prefix_embeds/frames]) -> (logits_last, cache).

    The cache is allocated at ``max_len`` so subsequent decode steps reuse
    it in place.
    """
    if cfg.n_encoder_layers:
        def prefill(params, tokens, frames):
            B = tokens.shape[0]
            enc = encdec.encode(params, cfg, frames, fi=fi)
            kv = encdec.cross_kv(params, cfg, enc, fi=fi)
            cache = encdec.init_cache(cfg, B, max_len)
            logits, _ = encdec.decode(params, cfg, tokens, kv=kv, fi=fi)
            return logits[:, -1], cache, kv
        return prefill

    def prefill(params, tokens, prefix_embeds=None):
        B, S = tokens.shape
        cache = tf.init_cache(cfg, B, max_len)
        kwargs = {}
        if cfg.prefix_tokens:
            kwargs["prefix_embeds"] = prefix_embeds
        logits, cache, _ = tf.forward_logits(
            params, cfg, tokens, states=cache,
            cache_len=jnp.asarray(S + cfg.prefix_tokens, jnp.int32),
            fi=fi, **kwargs)
        return logits[:, -1], cache
    return prefill


def make_decode_step(cfg: ModelConfig,
                     fi: Optional[FaultConfig] = None) -> Callable:
    """(params, token (B,1), cache, cache_len) -> (logits (B,V), cache)."""
    if cfg.n_encoder_layers:
        def decode(params, token, cache, cache_len, kv):
            logits, new_cache = encdec.decode(
                params, cfg, token, kv=kv, fi=fi, cache=cache,
                cache_len=cache_len, pos_offset=cache_len - 1)
            return logits[:, -1], new_cache
        return decode

    def decode(params, token, cache, cache_len):
        logits, new_cache = tf.decode_step(params, cfg, token, cache,
                                           cache_len, fi=fi)
        return logits[:, -1], new_cache
    return decode
