"""Serve-step builders: prefill, decode, and whole-generation functions.

Three layers of API, all sharing the same model code paths:

* :func:`make_prefill_fn` / :func:`make_decode_fn` — steps that take the
  :class:`~repro.models.layers.FaultConfig` as a *runtime argument* (it is
  a registered pytree: BERs/keys/seeds are traced leaves).  One jitted
  instance serves every device age — advancing the runtime between calls
  re-jits nothing.  These are what :class:`repro.serve.engine.ServeEngine`
  caches and what the eager-loop oracle path dispatches per token.
* :func:`make_generate_fn` — the serving hot path: prefill + a
  ``lax.scan`` decode loop + in-graph sampling fused into ONE function,
  jitted once per (config, n_steps, top_k) bucket.  A whole generation is
  a single device dispatch: no per-token host sync, no per-token argmax
  round-trip, per-step fault streams derived in-trace by folding the scan
  index into the ``FaultConfig`` streams (``fi.for_step(t)``).
* :func:`make_prefill_step` / :func:`make_decode_step` — the legacy
  builders (``fi`` captured at build time), kept for the dry-run /
  hillclimb lowering cells that jit them with explicit shardings.

``decode_step`` consumes/produces the KV-cache pytree whose shardings come
from ``repro.distributed.sharding.cache_specs`` (sequence-sharded over
"model" when KV heads cannot split — partial-softmax decode attention).

Fault injection: ``fi`` threads the per-operator BERs from the AVS runtime
into every matmul domain.  The config carries only scalars — BERs plus
int32 *seed* streams the fused kernel expands in-register, so the weight
matmuls (``op_linear`` domains) lower with no output-sized random arrays.
The activation x activation qkt/sv domains (``op_batched_matmul``) still
route through the three-pass injection.  ``fi=None`` lowers the clean
graph (what the roofline measures).  Under a serve-mesh scope with
``(S,)`` per-shard BER vectors and the fused flags on, the weight-matmul
domains shard_map the fused kernel per column block
(``repro.kernels.ops.aged_linear`` — same streams as the kernel-free
GSPMD route, so routing never changes sampled tokens).

``TRACE_COUNTS`` ticks once per *trace* of each built function (the Python
body only runs while jax traces) — the regression tests assert repeated
``generate()`` calls on an aged runtime add zero counts.

:func:`make_generate_fn` additionally returns a
:class:`repro.obs.taps.Telemetry` bundle of per-step serving-health
scalars next to the tokens.  The taps are computed unconditionally inside
the one trace (O(batch) per step — see :func:`repro.obs.taps.logit_taps`),
so enabling/disabling telemetry at the engine layer neither retraces nor
perturbs the sampled tokens.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import encdec
from repro.models import transformer as tf
from repro.models.layers import FaultConfig
from repro.obs.metrics import REGISTRY
from repro.obs.taps import Telemetry, logit_taps

# name -> number of times jax traced that step body.  jit caches traces, so
# a steady-state serve loop must not tick these; see
# tests/test_serve_scanned.py::test_repeated_generate_zero_retrace.
# Registry-homed (``repro.obs.metrics.trace_counts`` folds it into the
# unified retrace guard) but still a plain ``collections.Counter``.
TRACE_COUNTS = REGISTRY.trace_counter("serve_steps")


def _fi_step(fi: Optional[FaultConfig], step):
    return None if fi is None else fi.for_step(step)


# --------------------------------------------------------------------------- #
# runtime-fi steps (the engine path)
# --------------------------------------------------------------------------- #
def make_prefill_fn(cfg: ModelConfig, max_len: int) -> Callable:
    """(params, tokens, fi[, prefix_embeds/frames]) -> (logits_last, cache
    [, kv]).

    The cache is allocated at ``max_len`` so subsequent decode steps reuse
    it in place.  ``fi`` is a runtime argument (pytree) — one jitted
    instance covers every device age of a fault flavour.
    """
    if cfg.n_encoder_layers:
        def prefill(params, tokens, fi, frames):
            TRACE_COUNTS["prefill"] += 1
            B, S = tokens.shape
            enc = encdec.encode(params, cfg, frames, fi=fi)
            kv = encdec.cross_kv(params, cfg, enc, fi=fi)
            # cache slots must match the decoder's compute dtype (the
            # params dtype): decoder-only prefill overwrites the whole
            # cache so a mismatch is silently fixed there, but the enc-dec
            # cache is written slot by slot
            cache = encdec.init_cache(cfg, B, max_len,
                                      dtype=getattr(params["embed"], "dtype",
                                                    jnp.bfloat16))
            logits, cache = encdec.decode(
                params, cfg, tokens, kv=kv, fi=fi, cache=cache,
                cache_len=jnp.asarray(S, jnp.int32))
            return logits[:, -1], cache, kv
        return prefill

    if cfg.prefix_tokens:
        def prefill(params, tokens, fi, prefix_embeds):
            TRACE_COUNTS["prefill"] += 1
            B, S = tokens.shape
            cache = tf.init_cache(cfg, B, max_len)
            logits, cache, _ = tf.forward_logits(
                params, cfg, tokens, states=cache,
                cache_len=jnp.asarray(S + cfg.prefix_tokens, jnp.int32),
                fi=fi, prefix_embeds=prefix_embeds)
            return logits[:, -1], cache
        return prefill

    def prefill(params, tokens, fi):
        TRACE_COUNTS["prefill"] += 1
        B, S = tokens.shape
        cache = tf.init_cache(cfg, B, max_len)
        logits, cache, _ = tf.forward_logits(
            params, cfg, tokens, states=cache,
            cache_len=jnp.asarray(S, jnp.int32), fi=fi)
        return logits[:, -1], cache
    return prefill


def make_decode_fn(cfg: ModelConfig) -> Callable:
    """(params, token (B,1), cache, cache_len, fi[, kv]) -> (logits, cache).

    ``fi`` is a runtime argument; engines donate the cache operand so the
    eager loop updates it in place on backends that support aliasing.
    """
    if cfg.n_encoder_layers:
        def decode(params, token, cache, cache_len, fi, kv):
            TRACE_COUNTS["decode"] += 1
            logits, new_cache = encdec.decode(
                params, cfg, token, kv=kv, fi=fi, cache=cache,
                cache_len=cache_len, pos_offset=cache_len - 1)
            return logits[:, -1], new_cache
        return decode

    def decode(params, token, cache, cache_len, fi):
        TRACE_COUNTS["decode"] += 1
        logits, new_cache = tf.decode_step(params, cfg, token, cache,
                                           cache_len, fi=fi)
        return logits[:, -1], new_cache
    return decode


# --------------------------------------------------------------------------- #
# in-graph sampling
# --------------------------------------------------------------------------- #
def sample_token(logits: jax.Array, key: jax.Array, temperature,
                 top_k: Optional[int] = None) -> jax.Array:
    """Greedy/temperature/top-k sampling as a pure graph op.

    ``temperature`` is a traced scalar: ``temperature == 0`` selects the
    argmax (exact greedy, not a limit), anything positive samples from
    ``softmax(logits / temperature)``; ``top_k`` (static) masks all but the
    k highest logits first.  Because the selection is a ``jnp.where`` and
    not Python control flow, the same compiled generation covers greedy
    and sampled serving without retracing.
    """
    greedy = jnp.argmax(logits, axis=-1)
    if top_k is not None:
        vals = jax.lax.top_k(logits, top_k)[0]
        logits = jnp.where(logits < vals[..., -1:], -jnp.inf, logits)
    t = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
    sampled = jax.random.categorical(key, logits / t, axis=-1)
    pick = jnp.where(jnp.asarray(temperature, jnp.float32) > 0,
                     sampled, greedy)
    return pick.astype(jnp.int32)


# --------------------------------------------------------------------------- #
# whole-generation (scanned) serving
# --------------------------------------------------------------------------- #
def make_generate_fn(cfg: ModelConfig, max_len: int, n_steps: int,
                     top_k: Optional[int] = None) -> Callable:
    """Build the single-dispatch generation function.

    Returns ``generate(params, prompts, fi, key, temperature[, extras])
    -> (tokens (B, n_steps), telemetry)`` where ``extras`` is
    ``prefix_embeds`` for prefix (VLM) families and ``frames`` for
    encoder-decoder families.  ``telemetry`` is a
    :class:`repro.obs.taps.Telemetry` of per-step ``(n_steps,)`` health
    series (:func:`repro.obs.taps.logit_taps`), always computed in-graph;
    callers that ignore it pay one dead-code-eliminated tuple slot, and
    the tokens are bit-identical whether or not anyone reads it.
    Prefill, a ``lax.scan`` over ``n_steps - 1`` decode steps, and
    sampling all live in one trace:

    * the KV cache never leaves the device or the trace — the scan carry
      aliases it in place (XLA donates scan carries by construction);
    * sampling keys thread through the carry with one ``split`` per step
      — the same derivation the eager oracle performs, so token sequences
      are bit-exact between the two paths;
    * fault streams per step come from ``fi.for_step(t)`` — in-trace
      integer folds, no materialised randoms, no per-step retrace.

    Tokens generated past a ring-buffered (windowed) cache's capacity
    follow the same ring semantics as the eager loop (both call the same
    ``decode_step``).
    """
    prefill = make_prefill_fn(cfg, max_len)
    decode = make_decode_fn(cfg)
    has_kv = bool(cfg.n_encoder_layers)

    def generate(params, prompts, fi, key, temperature, *extras):
        TRACE_COUNTS["generate"] += 1
        S = prompts.shape[1]
        if fi is not None:
            # hoist the per-op threefry stream bases out of the scan body:
            # in-loop derivation is then pure fmix32 integer folds
            fi = fi.with_seeds()
        out = prefill(params, prompts, fi, *extras)
        logits, cache = out[0], out[1]
        kv = out[2] if has_kv else None
        key, sub = jax.random.split(key)
        tok = sample_token(logits, sub, temperature, top_k)
        tap0 = logit_taps(logits)
        cache_len0 = S + cfg.prefix_tokens

        def body(carry, t):
            tok, cache, key = carry
            cache_len = jnp.asarray(cache_len0 + t, jnp.int32)
            fi_t = _fi_step(fi, t)
            if has_kv:
                logits, cache = decode(params, tok[:, None], cache,
                                       cache_len, fi_t, kv)
            else:
                logits, cache = decode(params, tok[:, None], cache,
                                       cache_len, fi_t)
            key, sub = jax.random.split(key)
            tok = sample_token(logits, sub, temperature, top_k)
            return (tok, cache, key), (tok, logit_taps(logits))

        (_, _, _), (toks, taps) = jax.lax.scan(
            body, (tok, cache, key), jnp.arange(1, n_steps, dtype=jnp.int32))
        if n_steps > 1:
            tokens = jnp.concatenate([tok[:, None], toks.T], axis=1)
            series = {k: jnp.concatenate([tap0[k][None], taps[k]])
                      for k in tap0}
        else:
            tokens = tok[:, None]
            series = {k: tap0[k][None] for k in tap0}
        return tokens, Telemetry(series)
    return generate


# --------------------------------------------------------------------------- #
# legacy builders (fi captured at build time) — dry-run / hillclimb surface
# --------------------------------------------------------------------------- #
def make_prefill_step(cfg: ModelConfig, max_len: int,
                      fi: Optional[FaultConfig] = None) -> Callable:
    """(params, tokens[, prefix_embeds/frames]) -> (logits_last, cache).

    ``fi`` is closed over — what the dry-run lowers for the ``prefill_*``
    shape cells.  Engines use :func:`make_prefill_fn` instead.
    """
    fn = make_prefill_fn(cfg, max_len)
    if cfg.n_encoder_layers:
        return lambda params, tokens, frames: fn(params, tokens, fi, frames)
    if cfg.prefix_tokens:
        return lambda params, tokens, prefix_embeds=None: \
            fn(params, tokens, fi, prefix_embeds)
    return lambda params, tokens: fn(params, tokens, fi)


def make_decode_step(cfg: ModelConfig,
                     fi: Optional[FaultConfig] = None) -> Callable:
    """(params, token (B,1), cache, cache_len[, kv]) -> (logits, cache)."""
    fn = make_decode_fn(cfg)
    if cfg.n_encoder_layers:
        return lambda params, token, cache, cache_len, kv: \
            fn(params, token, cache, cache_len, fi, kv)
    return lambda params, token, cache, cache_len: \
        fn(params, token, cache, cache_len, fi)
