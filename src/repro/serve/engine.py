"""Aging-aware serving engine — the paper's technique as a runtime feature.

The engine serves one device of an AVS runtime — a legacy
:class:`repro.core.runtime.AgingAwareRuntime` or (the fleet-scale path) one
:class:`repro.core.fleet.FleetRuntime` device — with one AVS voltage domain
per operator class (the paper's Table II rows).  Before each
generation call it snapshots the runtime's current per-operator BERs into a
:class:`FaultConfig`, so every matmul executes at exactly the error rate the
fault-tolerant AVS policy admits at the device's current age.  Advancing the
simulated age between calls re-jits nothing: ``FaultConfig`` is a pytree,
the BERs enter as traced leaves of a cached compiled function (see
``tests/test_serve_scanned.py`` for the zero-retrace regression guards).

Serving model: static-batch generate.  The default path compiles prefill +
the whole decode loop + sampling into ONE dispatch
(:func:`repro.serve.steps.make_generate_fn` — a ``lax.scan`` decode with
in-graph sampling and in-trace per-step fault streams; no per-token host
sync).  The legacy per-token Python loop survives as the oracle path
(``scan=False``) and is bit-exact against the scanned path.  Compiled
functions are cached per (config, n_steps/top_k bucket, fault flavour,
shapes) at module level, shared across engine instances.

:class:`FleetServeEngine` vmaps the same generation function over the N
devices of a :class:`~repro.core.fleet.FleetRuntime`: each lane receives
its own per-operator BER vector straight from the fleet snapshot (the
array-native ``op_ber_array`` accessor — no per-device ``DeviceView``
round-trips), so a heterogeneous-age fleet serves a sharded prompt batch
in a single dispatch.

Continuous batching lives one layer up: :mod:`repro.serve.online` runs a
LIVE request queue on fixed slots over the same scanned decode — slot
refills between compiled chunks are traced-leaf updates (no re-jit), and
the measured slot occupancy replays into the fleet's aging recursion.
This module stays the static-batch engine underneath it.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.fleet import FleetRuntime
from repro.models.layers import FaultConfig
from repro.obs import metrics as obs_metrics
from repro.obs.taps import taps_enabled, telemetry_to_host
from . import steps


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # (B, steps) generated ids
    bers: Dict[str, float]       # per-operator BER used
    age_years: float
    power_w: float
    # per-step tap series ({name: (n_steps,)}) when taps are enabled
    # (repro.obs.taps.enable_taps); None otherwise — the compiled graph
    # and the tokens are identical either way
    telemetry: Optional[Dict[str, np.ndarray]] = None


@dataclasses.dataclass
class FleetGenerateResult:
    tokens: np.ndarray           # (N, B, steps) generated ids per lane
    bers: np.ndarray             # (N, O) per-operator BER served per lane
    operators: tuple             # column order of ``bers``
    ages_years: np.ndarray       # (N,)
    power_w: np.ndarray          # (N,)
    telemetry: Optional[Dict[str, np.ndarray]] = None   # {name: (N, steps)}


# --------------------------------------------------------------------------- #
# module-level compile caches: engines with the same config share traces
# --------------------------------------------------------------------------- #
# Online serving is a long-lived process: an unbounded cache of compiled
# functions (each jit wrapper owns its XLA executables) is a slow memory
# leak across config/shape churn.  Every serve-side compile cache is a
# bounded LRU registered here — ``cache_stats()`` / ``clear_caches()``
# expose and reset them fleet-wide (``repro.serve.online`` registers its
# slot-prefill/decode-chunk caches through the same mechanism).
COMPILE_CACHE_MAXSIZE = 32

# The registry itself now lives in the (dependency-free) obs layer so
# health snapshots and exporters can read cache stats without importing
# serve; this module keeps the historical name as an alias to the SAME
# list object — ``CompiledFnCache.__init__`` still appends here.
_COMPILE_CACHES: list = obs_metrics._CACHES


class CompiledFnCache:
    """Bounded LRU over a compiled-function *builder*.

    Keys are the builder's (hashable) positional args; values are jitted
    wrappers.  Evicting an entry drops the only reference to its jit
    wrapper — and with it the wrapper's compiled executables — so a
    long-lived serving process cannot grow compiled-fn memory without
    bound.  ``maxsize`` is mutable (tests shrink it to exercise eviction).
    """

    def __init__(self, name: str, builder,
                 maxsize: int = COMPILE_CACHE_MAXSIZE):
        self.name = name
        self._builder = builder
        self.__doc__ = builder.__doc__
        self.maxsize = maxsize
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.hits = self.misses = self.evictions = 0
        _COMPILE_CACHES.append(self)

    def __call__(self, *key):
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        fn = self._builder(*key)
        self._entries[key] = fn
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1
        return fn

    def clear(self):
        self._entries.clear()

    def stats(self) -> Dict[str, int]:
        return {"currsize": len(self._entries), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


def compile_cache(name: str):
    """Decorator: route a builder through a registered bounded LRU."""
    return lambda builder: CompiledFnCache(name, builder)


def cache_stats() -> Dict[str, Dict[str, int]]:
    """Per-cache ``{currsize, maxsize, hits, misses, evictions}``.

    Back-compat alias for :func:`repro.obs.metrics.cache_stats`.
    """
    return obs_metrics.cache_stats()


def clear_caches() -> None:
    """Drop every cached compiled function (and its XLA executables).

    Back-compat alias for :func:`repro.obs.metrics.clear_caches`.
    """
    obs_metrics.clear_caches()


@compile_cache("step_fns")
def _step_fns(cfg: ModelConfig, max_len: int):
    """Jitted (prefill, decode) taking ``fi`` as a runtime pytree argument.

    One cache entry per (config, max_len); jax's own jit cache then keys
    on shapes and on the fault flavour (the ``fi`` treedef: clean ``None``
    vs faulted, fused vs oracle meta flags).  The decode cache operand is
    donated so the eager loop updates it in place where the backend
    supports aliasing (TPU; CPU falls back to a copy).
    """
    prefill = jax.jit(steps.make_prefill_fn(cfg, max_len))
    decode = jax.jit(steps.make_decode_fn(cfg), donate_argnums=(2,))
    return prefill, decode


@compile_cache("generate")
def _generate_fn(cfg: ModelConfig, max_len: int, n_steps: int,
                 top_k: Optional[int]):
    """The single-dispatch generation function, jitted."""
    return jax.jit(steps.make_generate_fn(cfg, max_len, n_steps, top_k))


@compile_cache("fleet_generate")
def _fleet_generate_fn(cfg: ModelConfig, max_len: int, n_steps: int,
                       top_k: Optional[int]):
    """vmap of the generation function over fleet lanes.

    params and temperature broadcast; prompts, the FaultConfig leaves
    (per-lane BER vectors, keys, steps) and any extras map over axis 0.
    """
    gen = steps.make_generate_fn(cfg, max_len, n_steps, top_k)
    n_extras = 1 if (cfg.n_encoder_layers or cfg.prefix_tokens) else 0
    in_axes = (None, 0, 0, 0, None) + (0,) * n_extras
    return jax.jit(jax.vmap(gen, in_axes=in_axes))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 runtime=None, device: int = 0,
                 max_len: int = 512, use_systolic_kernel: bool = False,
                 use_fused_kernel: bool = True, seed: int = 0):
        """``runtime`` accepts a legacy ``AgingAwareRuntime``, a vectorised
        :class:`FleetRuntime` (served from fleet device ``device``), or any
        object exposing ``op_bers / age_years / total_power``.

        With ``use_systolic_kernel=True`` every weight matmul runs on the
        Pallas int8 path; ``use_fused_kernel`` (default) selects the
        single-pass kernel that draws upsets with its in-core PRNG from a
        per-(call, operator, step) seed — the engine hands the graph seeds,
        never materialised random tensors.  Set it False to route through
        the legacy three-pass injection (the oracle path)."""
        self.cfg = cfg
        self.params = params
        if isinstance(runtime, FleetRuntime):
            runtime = runtime.device(device)
        self.runtime = runtime
        self.max_len = max_len
        self.use_kernel = use_systolic_kernel
        self.use_fused = use_fused_kernel
        self._key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------------ #
    def _fault_config(self) -> Optional[FaultConfig]:
        if self.runtime is None:
            return None
        self._key, sub = jax.random.split(self._key)
        bers = {op: jnp.float32(ber)
                for op, ber in self.runtime.op_bers().items()}
        return FaultConfig(bers=bers, key=sub, step=jnp.int32(0),
                           use_systolic_kernel=self.use_kernel,
                           fused=self.use_fused)

    def _extras(self, prefix_embeds, frames):
        cfg = self.cfg
        if cfg.n_encoder_layers:
            assert frames is not None, "enc-dec family needs frames="
            return (jnp.asarray(frames),)
        if cfg.prefix_tokens:
            assert prefix_embeds is not None, "prefix family needs " \
                                              "prefix_embeds="
            return (jnp.asarray(prefix_embeds),)
        return ()

    @staticmethod
    def _temperature(greedy, temperature):
        """Resolve the legacy ``greedy`` flag against ``temperature``."""
        if temperature is None:
            temperature = 0.0 if greedy else 1.0
        return jnp.float32(temperature)

    # ------------------------------------------------------------------ #
    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 prefix_embeds=None, frames=None, greedy: bool = True,
                 temperature: Optional[float] = None,
                 top_k: Optional[int] = None,
                 scan: bool = True) -> GenerateResult:
        """prompts: (B, S) int32.  Returns ``n_steps`` generated tokens.

        ``temperature=0`` (or the legacy ``greedy=True``) is exact argmax;
        positive temperature samples ``softmax(logits / T)`` restricted to
        the ``top_k`` highest logits when given.  Both resolve *in-graph*:
        changing them between calls re-jits nothing (``top_k`` is a static
        bucket).  ``scan=False`` runs the per-token eager loop — the
        oracle path, bit-exact with the default scanned path.
        """
        cfg = self.cfg
        fi = self._fault_config()
        self._key, call_key = jax.random.split(self._key)
        temp = self._temperature(greedy, temperature)
        prompts = jnp.asarray(prompts, jnp.int32)
        extras = self._extras(prefix_embeds, frames)

        telemetry = None
        if scan:
            m0 = _generate_fn.misses
            gen = _generate_fn(cfg, self.max_len, int(n_steps), top_k)
            t0 = time.perf_counter()
            tokens_dev, telem = gen(self.params, prompts, fi, call_key,
                                    temp, *extras)
            tokens = np.asarray(tokens_dev)
            span = time.perf_counter() - t0
            # host-side only: whether to transfer + record the aux leaves;
            # the compiled dispatch above is identical either way
            if taps_enabled():
                telemetry = telemetry_to_host(telem)
                self._record(tokens, telemetry, span,
                             cold=_generate_fn.misses > m0)
        else:
            tokens = self._generate_eager(prompts, int(n_steps), fi,
                                          call_key, temp, top_k, extras)

        bers = (self.runtime.op_bers() if self.runtime else {})
        return GenerateResult(
            tokens=tokens,
            bers={k: float(v) for k, v in bers.items()},
            age_years=self.runtime.age_years if self.runtime else 0.0,
            power_w=self.runtime.total_power() if self.runtime else 0.0,
            telemetry=telemetry,
        )

    def _record(self, tokens, telemetry, span_s: float, cold: bool) -> None:
        """Fold one generate call into the metrics registry (host-side)."""
        reg = obs_metrics.REGISTRY
        reg.counter("serve_generate_calls", "generate() dispatches").inc()
        reg.counter("serve_tokens", "tokens generated").inc(tokens.size)
        name = ("serve_generate_compile_s" if cold
                else "serve_generate_warm_s")
        obs_metrics.observe_span(name, span_s)
        for sig in ("logit_max", "logit_margin"):
            if telemetry and sig in telemetry:
                reg.histogram("serve_" + sig, "per-step serving health") \
                   .observe_many(np.asarray(telemetry[sig]).ravel())
        if self.runtime is not None:
            bers = self.runtime.op_bers()
            if bers:
                reg.gauge("serve_admitted_ber_max",
                          "worst per-operator BER served") \
                   .set(max(float(v) for v in bers.values()))

    def _generate_eager(self, prompts, n_steps, fi, key, temp, top_k,
                        extras) -> np.ndarray:
        """Per-token oracle loop: one dispatch + host sync per token.

        Kept for parity testing and as the reference semantics; the key /
        fault-stream derivation mirrors the scanned path exactly, so token
        sequences are bit-exact between the two.
        """
        cfg = self.cfg
        prefill, decode = _step_fns(cfg, self.max_len)
        out = prefill(self.params, prompts, fi, *extras)
        logits, cache = out[0], out[1]
        kv = out[2] if cfg.n_encoder_layers else None
        key, sub = jax.random.split(key)
        tok = steps.sample_token(logits, sub, temp, top_k)
        toks = [np.asarray(tok)]
        cache_len0 = prompts.shape[1] + cfg.prefix_tokens
        for t in range(1, n_steps):
            fi_t = None if fi is None else fi.for_step(jnp.int32(t))
            cache_len = jnp.asarray(cache_len0 + t, jnp.int32)
            if cfg.n_encoder_layers:
                logits, cache = decode(self.params, tok[:, None], cache,
                                       cache_len, fi_t, kv)
            else:
                logits, cache = decode(self.params, tok[:, None], cache,
                                       cache_len, fi_t)
            key, sub = jax.random.split(key)
            tok = steps.sample_token(logits, sub, temp, top_k)
            toks.append(np.asarray(tok))
        return np.stack(toks, axis=1)

    # ------------------------------------------------------------------ #
    def score(self, tokens: np.ndarray, *, prefix_embeds=None,
              frames=None) -> float:
        """Mean next-token NLL of a token batch under the aged device."""
        from repro.models import encdec
        from repro.models import transformer as tf
        from repro.train.steps import softmax_xent
        cfg = self.cfg
        fi = self._fault_config()
        tokens = jnp.asarray(tokens, jnp.int32)
        inp, lab = tokens[:, :-1], tokens[:, 1:]
        if cfg.n_encoder_layers:
            enc = encdec.encode(self.params, cfg, frames, fi=fi)
            logits, _ = encdec.decode(self.params, cfg, inp, enc_out=enc,
                                      fi=fi)
        else:
            logits, _, _ = tf.forward_logits(self.params, cfg, inp,
                                             prefix_embeds=prefix_embeds,
                                             fi=fi)
            if cfg.prefix_tokens:
                logits = logits[:, cfg.prefix_tokens:]
        return float(softmax_xent(logits, lab))


# --------------------------------------------------------------------------- #
class FleetServeEngine:
    """Serve the WHOLE fleet in one dispatch.

    Where :class:`ServeEngine` serves one device of a
    :class:`~repro.core.fleet.FleetRuntime`, this engine vmaps the
    single-dispatch generation function over all N lanes: device ``i``
    executes its slice of the prompt batch at its own policy-admitted
    per-operator BERs (one row of ``fleet.op_ber_array()``).  Params are
    broadcast, fault streams are decorrelated per lane, and the entire
    heterogeneous-age fleet generation — prefill, decode scan, sampling,
    upsets — is one compiled call.
    """

    def __init__(self, cfg: ModelConfig, params, fleet: FleetRuntime, *,
                 max_len: int = 512, use_systolic_kernel: bool = False,
                 use_fused_kernel: bool = True, seed: int = 0,
                 router=None, workload="diurnal", loads=None,
                 apply_load_kw=None):
        """``router`` (a name from ``repro.sched.router.ROUTER_REGISTRY``
        or a Router instance) ages the fleet under routed traffic before
        serving: the served per-lane BERs then reflect *traffic-dependent*
        age rather than the static mission profile.  ``workload`` /
        ``loads`` select the arrival trace and ``apply_load_kw`` passes
        any further knobs (``utilization``, ``n_epochs``, ``horizon_s``,
        ``capacity``, ``key``, ...) through to
        :meth:`repro.core.fleet.FleetRuntime.apply_load`, which this
        forwards to."""
        assert getattr(fleet, "n_shards", 1) == 1, \
            "FleetServeEngine vmaps whole devices; a shard-granular fleet " \
            "(n_shards > 1) is served by repro.serve.sharded.MeshServeEngine"
        self.cfg = cfg
        self.params = params
        self.fleet = fleet
        self.max_len = max_len
        self.use_kernel = use_systolic_kernel
        self.use_fused = use_fused_kernel
        self._key = jax.random.PRNGKey(seed)
        if router is not None:
            fleet.apply_load(loads=loads, workload=workload, router=router,
                             **(apply_load_kw or {}))

    @property
    def n_devices(self) -> int:
        return self.fleet.n_devices

    # ------------------------------------------------------------------ #
    def _fleet_fault_config(self, call_key) -> FaultConfig:
        """Batched FaultConfig: every leaf carries the fleet axis (N, ...).

        BER columns come straight from the fleet snapshot's (N, O) array —
        no per-device ``DeviceView`` round-trips — and each lane gets an
        independent fold of the call key.  The source is the fleet's
        *cached jax-native* view (``op_ber_jax``): between age changes the
        host->device transfer has already happened, so building the config
        is pure jnp slicing.
        """
        N = self.fleet.n_devices
        ber = self.fleet.op_ber_jax()                        # (N, O) jnp
        bers = {op: ber[:, i]
                for i, op in enumerate(self.fleet.operators)}
        keys = jax.random.split(call_key, N)                 # (N, key)
        return FaultConfig(bers=bers, key=keys,
                           step=jnp.zeros((N,), jnp.int32),
                           use_systolic_kernel=self.use_kernel,
                           fused=self.use_fused)

    def _shard(self, x, name: str, lane_ndim: int) -> jax.Array:
        """Per-lane input (rank ``lane_ndim``, leading N) passes through;
        a flat batch (one rank lower) is sharded over lanes.  Dispatch is
        by rank, not leading dim — a flat (N, S) batch with one prompt per
        lane is sharding, not an N-lane rank-1 prompt."""
        N = self.fleet.n_devices
        x = jnp.asarray(x)
        if x.ndim == lane_ndim:
            assert x.shape[0] == N, \
                f"{name} lane dim {x.shape[0]} != fleet size {N}"
            return x
        assert x.ndim == lane_ndim - 1, \
            f"{name} must be rank {lane_ndim} (per-lane) or " \
            f"{lane_ndim - 1} (flat batch), got rank {x.ndim}"
        assert x.shape[0] % N == 0, \
            f"{name} leading dim {x.shape[0]} not divisible by fleet " \
            f"size {N}"
        return x.reshape(N, x.shape[0] // N, *x.shape[1:])

    # ------------------------------------------------------------------ #
    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 prefix_embeds=None, frames=None,
                 temperature: float = 0.0,
                 top_k: Optional[int] = None) -> FleetGenerateResult:
        """prompts: (N, B, S) per-lane, or (N*B, S) sharded across lanes.

        Returns per-lane token blocks plus the (N, O) BER matrix actually
        served.  Repeated calls after ``fleet.advance(...)`` reuse the
        compiled function — ages enter as traced leaves.
        """
        cfg = self.cfg
        self._key, call_key = jax.random.split(self._key)
        prompts = self._shard(jnp.asarray(prompts, jnp.int32), "prompts",
                              lane_ndim=3)
        fi = self._fleet_fault_config(call_key)
        keys = jax.random.split(jax.random.fold_in(call_key, 1),
                                self.fleet.n_devices)
        extras = ()
        if cfg.n_encoder_layers:
            assert frames is not None, "enc-dec family needs frames="
            extras = (self._shard(frames, "frames", lane_ndim=4),)
        elif cfg.prefix_tokens:
            assert prefix_embeds is not None, "prefix family needs " \
                                              "prefix_embeds="
            extras = (self._shard(prefix_embeds, "prefix_embeds",
                                  lane_ndim=4),)

        m0 = _fleet_generate_fn.misses
        gen = _fleet_generate_fn(cfg, self.max_len, int(n_steps), top_k)
        t0 = time.perf_counter()
        tokens, telem = gen(self.params, prompts, fi, keys,
                            jnp.float32(temperature), *extras)
        tokens = np.asarray(tokens)
        span = time.perf_counter() - t0
        telemetry = None
        if taps_enabled():
            # vmapped dispatch: every tap leaf carries the lane axis (N, T)
            telemetry = telemetry_to_host(telem)
            reg = obs_metrics.REGISTRY
            reg.counter("fleet_generate_calls",
                        "fleet generate() dispatches").inc()
            reg.counter("serve_tokens", "tokens generated").inc(tokens.size)
            obs_metrics.observe_span(
                "fleet_generate_compile_s"
                if _fleet_generate_fn.misses > m0
                else "fleet_generate_warm_s", span)

        snap = self.fleet.snapshot()
        return FleetGenerateResult(
            tokens=tokens,
            bers=np.asarray(snap.ber),
            operators=self.fleet.operators,
            ages_years=np.asarray(self.fleet.ages_years),
            power_w=np.asarray(self.fleet.fleet_power()),
            telemetry=telemetry,
        )
