"""Aging-aware serving engine — the paper's technique as a runtime feature.

The engine serves one device of an AVS runtime — a legacy
:class:`repro.core.runtime.AgingAwareRuntime` or (the fleet-scale path) one
:class:`repro.core.fleet.FleetRuntime` device — with one AVS voltage domain
per operator class (the paper's Table II rows).  Before each
generation call it snapshots the runtime's current per-operator BERs into a
:class:`FaultConfig`, so every matmul executes at exactly the error rate the
fault-tolerant AVS policy admits at the device's current age.  Advancing the
simulated age between calls re-jits nothing: the BERs enter as traced
scalars.

Serving model: static-batch generate (prefill the prompt batch, then decode
step-by-step with an in-place KV cache).  Continuous batching slots are
deliberately out of scope — the paper's contribution is below the batching
policy layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.fleet import FleetRuntime
from repro.models.layers import FaultConfig
from . import steps


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # (B, steps) generated ids
    bers: Dict[str, float]       # per-operator BER used
    age_years: float
    power_w: float


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 runtime=None, device: int = 0,
                 max_len: int = 512, use_systolic_kernel: bool = False,
                 use_fused_kernel: bool = True, seed: int = 0):
        """``runtime`` accepts a legacy ``AgingAwareRuntime``, a vectorised
        :class:`FleetRuntime` (served from fleet device ``device``), or any
        object exposing ``op_bers / age_years / total_power``.

        With ``use_systolic_kernel=True`` every weight matmul runs on the
        Pallas int8 path; ``use_fused_kernel`` (default) selects the
        single-pass kernel that draws upsets with its in-core PRNG from a
        per-(call, operator) seed — the engine hands the graph seeds, never
        materialised random tensors.  Set it False to route through the
        legacy three-pass injection (the oracle path)."""
        self.cfg = cfg
        self.params = params
        if isinstance(runtime, FleetRuntime):
            runtime = runtime.device(device)
        self.runtime = runtime
        self.max_len = max_len
        self.use_kernel = use_systolic_kernel
        self.use_fused = use_fused_kernel
        self._key = jax.random.PRNGKey(seed)
        self._prefill = None
        self._decode = None

    # ------------------------------------------------------------------ #
    def _fault_config(self) -> Optional[FaultConfig]:
        if self.runtime is None:
            return None
        self._key, sub = jax.random.split(self._key)
        bers = {op: jnp.float32(ber)
                for op, ber in self.runtime.op_bers().items()}
        return FaultConfig(bers=bers, key=sub,
                           use_systolic_kernel=self.use_kernel,
                           fused=self.use_fused)

    def _build(self, fi: Optional[FaultConfig]):
        cfg = self.cfg
        # faulted graphs close over `fi` arrays -> pass them as args via
        # closure-conversion: jit once per (faulted?) flavour
        pre = steps.make_prefill_step(cfg, self.max_len, fi)
        dec = steps.make_decode_step(cfg, fi)
        return jax.jit(pre), jax.jit(dec)

    # ------------------------------------------------------------------ #
    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 prefix_embeds=None, frames=None,
                 greedy: bool = True) -> GenerateResult:
        """prompts: (B, S) int32.  Returns ``n_steps`` generated tokens."""
        cfg = self.cfg
        fi = self._fault_config()
        prefill, decode = self._build(fi)

        B, S = prompts.shape
        prompts = jnp.asarray(prompts, jnp.int32)
        extra_kv = None
        if cfg.n_encoder_layers:
            assert frames is not None
            logits, cache, extra_kv = prefill(self.params, prompts, frames)
        elif cfg.prefix_tokens:
            assert prefix_embeds is not None
            logits, cache = prefill(self.params, prompts, prefix_embeds)
        else:
            logits, cache = prefill(self.params, prompts)

        out = []
        cache_len = S + cfg.prefix_tokens
        tok = self._pick(logits, greedy)
        out.append(np.asarray(tok))
        for i in range(1, n_steps):
            cache_len += 1
            if cfg.n_encoder_layers:
                logits, cache = decode(self.params, tok[:, None], cache,
                                       jnp.asarray(cache_len, jnp.int32),
                                       extra_kv)
            else:
                logits, cache = decode(self.params, tok[:, None], cache,
                                       jnp.asarray(cache_len, jnp.int32))
            tok = self._pick(logits, greedy)
            out.append(np.asarray(tok))

        bers = (self.runtime.op_bers() if self.runtime else {})
        return GenerateResult(
            tokens=np.stack(out, axis=1),
            bers={k: float(v) for k, v in bers.items()},
            age_years=self.runtime.age_years if self.runtime else 0.0,
            power_w=self.runtime.total_power() if self.runtime else 0.0,
        )

    def _pick(self, logits, greedy: bool):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    # ------------------------------------------------------------------ #
    def score(self, tokens: np.ndarray, *, prefix_embeds=None,
              frames=None) -> float:
        """Mean next-token NLL of a token batch under the aged device."""
        from repro.models import encdec
        from repro.models import transformer as tf
        from repro.train.steps import softmax_xent
        cfg = self.cfg
        fi = self._fault_config()
        tokens = jnp.asarray(tokens, jnp.int32)
        inp, lab = tokens[:, :-1], tokens[:, 1:]
        if cfg.n_encoder_layers:
            enc = encdec.encode(self.params, cfg, frames, fi=fi)
            logits, _ = encdec.decode(self.params, cfg, inp, enc_out=enc,
                                      fi=fi)
        else:
            logits, _, _ = tf.forward_logits(self.params, cfg, inp,
                                             prefix_embeds=prefix_embeds,
                                             fi=fi)
            if cfg.prefix_tokens:
                logits = logits[:, cfg.prefix_tokens:]
        return float(softmax_xent(logits, lab))
