"""Mesh-sharded serving: one big model, ONE sharded dispatch, per-shard aging.

Where :class:`repro.serve.engine.FleetServeEngine` vmaps N whole devices
over replicated params (fleet of small models), this engine serves ONE
model that is too big for a single device by sharding prefill + the scanned
decode + in-graph sampling over a ``jax.sharding`` mesh — tensor/expert
parallelism over the ``"model"`` axis using the *serve layout* rules in
:mod:`repro.distributed.sharding` (output-dim-only sharding, replicated
fallbacks, activations pinned replicated at op boundaries).  That layout is
**bit-exact** against the single-device scanned path: no float contraction
ever spans shards, so GSPMD's only collectives are all-gathers
(``tests/test_serve_sharded.py`` locks this down).

Aging is *heterogeneous inside the dispatch*: with a shard-granular
:class:`repro.core.fleet.FleetRuntime` (``n_shards == tp``), each mesh
shard carries its own (age, dVth, BER) aging unit, and the
:class:`~repro.models.layers.FaultConfig` handed to the graph holds
``(S,)`` per-operator BER *vectors* — every weight matmul's output-column
block (the columns shard ``s`` physically owns under the serve layout)
flips at shard ``s``'s policy-admitted rate, from a shard-distinct fmix32
stream (:func:`repro.kernels.ops.inject_bitflips_sharded`).  The BER
vectors, keys and step enter as traced pytree leaves ``device_put``
replicated over the mesh with one consistent sharding, so advancing shard
ages between calls re-jits nothing (``steps.TRACE_COUNTS`` guards).

The engine casts floating-point params to ``serve_dtype`` (default
bfloat16) at construction: bf16 GEMM column slices are bit-exact on the
reference backend, float32 ones are not — the measured fact the exactness
contract rests on (see the module docstring of
``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.core.fleet import FleetRuntime
from repro.distributed import sharding as shrules
from repro.models.layers import FaultConfig
from repro.obs import metrics as obs_metrics
from repro.obs.taps import taps_enabled, telemetry_to_host
from . import steps
from .engine import ServeEngine, compile_cache


@dataclasses.dataclass
class MeshGenerateResult:
    tokens: np.ndarray           # (B, steps) generated ids
    bers: np.ndarray             # (S, O) per-shard BERs served ((1, O) uniform)
    operators: tuple             # column order of ``bers``
    ages_years: np.ndarray       # (S,) per-shard ages
    power_w: float
    telemetry: Optional[Dict[str, np.ndarray]] = None   # {name: (steps,)}


def default_serve_mesh(tp: Optional[int] = None) -> Mesh:
    """("data", "model") mesh over the visible devices, model=tp (all)."""
    n = len(jax.devices())
    tp = n if tp is None else int(tp)
    assert n % tp == 0, (n, tp)
    return jax.make_mesh((n // tp, tp), ("data", "model"))


@compile_cache("mesh_generate")
def _mesh_generate_fn(cfg: ModelConfig, max_len: int, n_steps: int,
                      top_k: Optional[int], mesh: Mesh):
    """The single-dispatch sharded generation function, jitted.

    The serve-mesh scope is entered *inside* the function body, i.e. at
    trace time: every ``constrain_replicated`` hook in the model lowers to
    a with_sharding_constraint against this mesh, and the hook stays a
    no-op for every other trace in the process.
    """
    gen = steps.make_generate_fn(cfg, max_len, n_steps, top_k)

    def sharded_gen(params, prompts, fi, key, temp, *extras):
        with shrules.serve_mesh_scope(mesh):
            return gen(params, prompts, fi, key, temp, *extras)

    # prompts and the call key are freshly device_put per call and never
    # reused — donate their buffers so XLA can alias them into the decode
    # carry (a no-op on backends without donation, e.g. CPU CI).  params
    # and fi are NOT donated: params persist across calls and fi's BER
    # leaves are cached between age updates.
    return jax.jit(sharded_gen, donate_argnums=(1, 3))


class MeshServeEngine:
    """Serve one mesh-sharded model with per-shard aging in one dispatch."""

    def __init__(self, cfg: ModelConfig, params, *,
                 mesh: Optional[Mesh] = None, tp: Optional[int] = None,
                 fleet: Optional[FleetRuntime] = None, device: int = 0,
                 runtime=None, max_len: int = 512, seed: int = 0,
                 serve_dtype=jnp.bfloat16, use_fused_kernel: bool = True):
        """``fleet`` (shard-granular, ``n_shards == tp``) drives per-shard
        BERs for fleet device ``device``; alternatively a legacy
        single-device ``runtime`` serves shard-uniform BERs (the legacy
        scalar fault streams — bit-exact with ``ServeEngine``'s oracle).
        Neither: clean sharded serving.  ``params`` may live anywhere;
        they are cast (floats -> ``serve_dtype``) and laid out over
        ``mesh`` with the serve-layout rules here, once.

        ``use_fused_kernel`` (fleet path only) routes every divisible
        weight matmul through the shard_map-wrapped fused Pallas kernel —
        per-shard int8 matmul + in-flush upsets + dequant in ONE kernel —
        instead of the kernel-free three-pass GSPMD route.  Both routes
        draw identical counter streams, so generated tokens are
        bit-identical; only bytes/compile-time change.  The legacy
        ``runtime=`` path always stays kernel-free (scalar streams are the
        pre-shard_map threefry contract, pinned by parity tests)."""
        self.cfg = cfg
        if mesh is None:
            mesh = default_serve_mesh(tp)
        self.mesh = mesh
        self.tp = shrules._tp(mesh)
        assert fleet is None or runtime is None, \
            "pass a shard-granular fleet= OR a uniform runtime=, not both"
        if fleet is not None:
            assert fleet.n_shards == self.tp, \
                f"fleet n_shards={fleet.n_shards} != mesh tp={self.tp}"
            assert 0 <= device < fleet.n_devices
        self.fleet = fleet
        self.device = device
        if isinstance(runtime, FleetRuntime):
            runtime = runtime.device(device)
        self.runtime = runtime
        self.use_fused_kernel = bool(use_fused_kernel)
        self.max_len = max_len
        self._key = jax.random.PRNGKey(seed)
        self._repl = NamedSharding(mesh, P())
        # dispatch-overhead caches: the replicated step-0 constant and the
        # per-op BER leaves (invalidated when the fleet publishes a new
        # shard-BER table, i.e. on age advance — not per generate call)
        self._step0 = jax.device_put(jnp.int32(0), self._repl)
        self._ber_cache: Optional[tuple] = None

        cast = jax.tree.map(
            lambda x: jnp.asarray(x).astype(serve_dtype)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
            else jnp.asarray(x), params)
        self.specs = shrules.param_specs(cast, cfg, mesh, layout="serve")
        self.params = shrules.shard_tree(cast, self.specs, mesh)

    # ------------------------------------------------------------------ #
    def _fault_config(self) -> Optional[FaultConfig]:
        """(S,)-vector BERs from the fleet's shard row, or uniform scalars.

        The fleet path honours ``use_fused_kernel``: vector-BER matmuls
        then take the shard_map fused-kernel route inside the serve-mesh
        scope (kernel-free GSPMD otherwise — identical streams either
        way).  The legacy ``runtime`` path forces the kernel-free scalar
        paths (``use_systolic_kernel=False``): a scalar-BER ``pallas_call``
        is a single-device program that does not partition under GSPMD,
        and its threefry streams are the pinned pre-shard_map contract.

        BER leaves are device_put replicated once per fleet BER table (the
        table object is cached inside ``FleetRuntime`` between age scans),
        not once per generate call — only the per-call subkey is put fresh.
        """
        if self.fleet is None and self.runtime is None:
            return None
        self._key, sub = jax.random.split(self._key)
        fused = False
        if self.fleet is not None:
            fused = self.use_fused_kernel
            tab = self.fleet.op_ber_shard_jax()
            if self._ber_cache is None or self._ber_cache[0] is not tab:
                ber = tab[self.device]                           # (S, O)
                bers = {op: jax.device_put(ber[:, i], self._repl)
                        for i, op in enumerate(self.fleet.operators)}
                self._ber_cache = (tab, bers)
            bers = self._ber_cache[1]
        else:
            vals = tuple(sorted(self.runtime.op_bers().items()))
            if self._ber_cache is None or self._ber_cache[0] != vals:
                bers = {op: jax.device_put(jnp.float32(b), self._repl)
                        for op, b in vals}
                self._ber_cache = (vals, bers)
            bers = self._ber_cache[1]
        return FaultConfig(bers=bers, key=jax.device_put(sub, self._repl),
                           step=self._step0,
                           use_systolic_kernel=fused, fused=fused)

    def _extras(self, prefix_embeds, frames) -> tuple:
        cfg = self.cfg
        if cfg.n_encoder_layers:
            assert frames is not None, "enc-dec family needs frames="
            return (jnp.asarray(frames),)
        if cfg.prefix_tokens:
            assert prefix_embeds is not None, "prefix family needs " \
                                              "prefix_embeds="
            return (jnp.asarray(prefix_embeds),)
        return ()

    # ------------------------------------------------------------------ #
    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 prefix_embeds=None, frames=None, greedy: bool = True,
                 temperature: Optional[float] = None,
                 top_k: Optional[int] = None) -> MeshGenerateResult:
        """prompts: (B, S) int32 -> ``n_steps`` tokens from ONE dispatch.

        Every runtime input (prompts, FaultConfig leaves, key,
        temperature) enters replicated over the mesh with the same
        NamedSharding on every call, so age advances and shard-BER updates
        between calls hit the compiled executable — zero retrace.  BER
        leaves are re-put only when the fleet publishes a new table;
        prompts and the call key are donated to the executable.
        """
        cfg = self.cfg
        fi = self._fault_config()
        self._key, call_key = jax.random.split(self._key)
        put = lambda t: jax.device_put(t, self._repl)
        prompts = put(jnp.asarray(prompts, jnp.int32))
        extras = tuple(put(e) for e in self._extras(prefix_embeds, frames))
        # fi leaves are already replicated by _fault_config (BERs cached
        # across calls, key/step put there) — no per-call tree device_put
        temp = put(ServeEngine._temperature(greedy, temperature))
        call_key = put(call_key)

        m0 = _mesh_generate_fn.misses
        gen = _mesh_generate_fn(cfg, self.max_len, int(n_steps), top_k,
                                self.mesh)
        t0 = time.perf_counter()
        tokens, telem = gen(self.params, prompts, fi, call_key, temp,
                            *extras)
        tokens = np.asarray(tokens)
        span = time.perf_counter() - t0
        telemetry = None
        if taps_enabled():
            # taps are replicated scalars per step under the serve layout —
            # one host transfer, no extra collectives
            telemetry = telemetry_to_host(telem)
            obs_metrics.REGISTRY.counter(
                "mesh_generate_calls", "sharded generate() dispatches").inc()
            obs_metrics.observe_span(
                "mesh_generate_compile_s"
                if _mesh_generate_fn.misses > m0
                else "mesh_generate_warm_s", span)

        if self.fleet is not None:
            ops = self.fleet.operators
            bers = np.asarray(self.fleet.op_ber_shard_array()[self.device])
            ages = np.asarray(self.fleet.ages_years).reshape(
                self.fleet.n_devices, self.fleet.n_shards)[self.device]
            power = float(self.fleet.fleet_power()[self.device])
        elif self.runtime is not None:
            d = self.runtime.op_bers()
            ops = tuple(d)
            bers = np.asarray([[d[o] for o in ops]])
            ages = np.asarray([self.runtime.age_years])
            power = float(self.runtime.total_power())
        else:
            ops, bers = (), np.zeros((1, 0))
            ages, power = np.zeros(1), 0.0
        return MeshGenerateResult(tokens=tokens, bers=bers, operators=ops,
                                  ages_years=ages, power_w=power,
                                  telemetry=telemetry)
