"""Slot-state machinery for continuous-batching online serving.

A :class:`SlotState` is the whole in-flight batch of an online engine as
ONE pytree: ``K`` fixed slots, each holding one live request's ragged KV
cache row, its decode depth, its sampled-token buffer and its completion
flags — every per-slot field a traced *leaf*, so the two compiled
functions built here cover every queue state without retracing:

* :func:`make_prefill_slots_fn` — refill freed slots from the host queue:
  prefill the whole ``(K, S)`` prompt matrix in one fixed-shape dispatch
  and ``jnp.where``-merge only the refilled rows into the live state
  (prompt ids, the refill mask and per-request generation budgets are all
  traced, extending the FaultConfig-as-pytree caching pattern);
* :func:`make_decode_chunk_fn` — a ``lax.scan`` over ``chunk_steps``
  decode steps in which every slot advances at ITS OWN cache depth
  (vector ``cache_len`` — see :func:`repro.models.transformer.decode_step`),
  samples in-graph, and retires itself on EOS or budget exhaustion via
  per-slot completion masks.  Inactive slots still flow through the
  batched matmuls (fixed shapes) but their state is frozen by masks; the
  garbage they compute never crosses slot rows and is overwritten by the
  next refill prefill.

Bit-exactness contract (regression-tested): on a trace with no mid-decode
arrivals — all ``K`` slots filled once at step 0, no EOS — the initial
prefill plus chunked decode reproduces
:func:`repro.serve.steps.make_generate_fn`'s one-shot scanned generation
token-for-token, including fused-kernel fault streams: the key chain
splits once per step, the fault stream folds the same global step index,
and the all-equal vector ``cache_len`` masks identically to the scalar.

``TRACE_COUNTS`` ticks live in :data:`repro.serve.steps.TRACE_COUNTS`
(``online_prefill`` / ``online_chunk``) — the online tests assert slot
refills and queue churn re-trace NOTHING.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import transformer as tf
from repro.obs.taps import Telemetry, logit_taps

from . import steps

# request_id of an empty (never filled / harvested) slot
EMPTY = -1


@dataclasses.dataclass(frozen=True)
class SlotState:
    """K in-flight request slots as one pytree (all fields are leaves).

    ``cache`` is the model's decode-state pytree with the slot axis as its
    batch axis (attention K/V rings, rglru/rwkv recurrent states).
    ``cache_len`` counts the tokens currently materialised in each slot's
    cache row (prompt + generated-so-far); ``tokens`` buffers each slot's
    generated ids at ``[slot, 0:n_generated]``; ``key`` is the single
    sampling chain shared by the whole batch (split once per decode step,
    exactly like the one-shot scanned path); ``step`` is the global decode
    step counter every per-step fault stream folds in.
    """

    cache: Any                  # model decode-state pytree, slot-batched
    cache_len: jax.Array        # (K,) int32 tokens in each slot's cache
    last_tok: jax.Array         # (K,) int32 next decode input per slot
    active: jax.Array           # (K,) bool — slot is mid-generation
    request_id: jax.Array       # (K,) int32 live request id (EMPTY = free)
    n_generated: jax.Array      # (K,) int32 tokens emitted per slot
    max_new: jax.Array          # (K,) int32 per-request generation budget
    tokens: jax.Array           # (K, C) int32 generated-token buffer
    key: jax.Array              # sampling PRNG chain (shared, split/step)
    step: jax.Array             # () int32 global decode-step counter

    @property
    def n_slots(self) -> int:
        return int(self.cache_len.shape[-1])

    def replace(self, **kw) -> "SlotState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    SlotState,
    data_fields=("cache", "cache_len", "last_tok", "active", "request_id",
                 "n_generated", "max_new", "tokens", "key", "step"),
    meta_fields=())


def init_slots(cfg: ModelConfig, n_slots: int, max_len: int,
               max_new_cap: int, key: jax.Array) -> SlotState:
    """All-free slot state (every slot empty, caches zeroed)."""
    K = int(n_slots)
    return SlotState(
        cache=tf.init_cache(cfg, K, max_len),
        cache_len=jnp.zeros((K,), jnp.int32),
        last_tok=jnp.zeros((K,), jnp.int32),
        active=jnp.zeros((K,), bool),
        request_id=jnp.full((K,), EMPTY, jnp.int32),
        n_generated=jnp.zeros((K,), jnp.int32),
        max_new=jnp.zeros((K,), jnp.int32),
        tokens=jnp.zeros((K, int(max_new_cap)), jnp.int32),
        key=key,
        step=jnp.int32(0))


def _check_family(cfg: ModelConfig):
    assert not cfg.n_encoder_layers and not cfg.prefix_tokens, \
        "online slot serving covers decoder-only families (the enc-dec / " \
        "prefix extras are per-request payloads the fixed-slot refill " \
        "does not thread yet); use the static-batch engines instead"


def _merge_cache(refill, new_cache, old_cache):
    """``jnp.where`` the refilled rows of ``new_cache`` into ``old_cache``.

    The slot (batch) axis sits at axis 1 of grouped leaves
    (``(n_groups, K, ...)`` — see :func:`repro.models.transformer.init_cache`)
    and axis 0 of tail leaves, so the mask is reshaped per section rather
    than guessed per leaf.
    """
    def section(axis):
        def merge(new, old):
            shape = [1] * new.ndim
            shape[axis] = refill.shape[0]
            return jnp.where(refill.reshape(shape), new, old)
        return merge

    out = {}
    if "groups" in old_cache:
        out["groups"] = jax.tree.map(section(1), new_cache["groups"],
                                     old_cache["groups"])
    if "tail" in old_cache:
        out["tail"] = jax.tree.map(section(0), new_cache["tail"],
                                   old_cache["tail"])
    return out


# --------------------------------------------------------------------------- #
# refill: batched prompt prefill merged into freed slots
# --------------------------------------------------------------------------- #
def make_prefill_slots_fn(cfg: ModelConfig, max_len: int,
                          top_k: Optional[int] = None) -> Callable:
    """Build ``refill(params, slots, prompts, refill, request_id, max_new,
    fi, temperature, eos) -> SlotState``.

    ``prompts`` is the full ``(K, S)`` matrix (rows of non-refilled slots
    are don't-care padding — the fixed shape is what keeps one compiled
    instance covering every refill pattern); ``refill`` is the ``(K,)``
    boolean mask of slots to (re)fill.  The whole prompt batch prefills in
    one dispatch, the first token of each refilled request is sampled from
    the prefill logits (one key split, exactly like the one-shot path),
    and only the refilled rows replace live state.  A request whose first
    sampled token is ``eos`` — or whose budget is a single token —
    completes immediately.
    """
    _check_family(cfg)
    prefill = steps.make_prefill_fn(cfg, max_len)

    def refill_fn(params, slots: SlotState, prompts, refill, request_id,
                  max_new, fi, temperature, eos) -> SlotState:
        steps.TRACE_COUNTS["online_prefill"] += 1
        K, S = prompts.shape
        if fi is not None:
            fi = fi.with_seeds()
        logits, new_cache = prefill(params, prompts,
                                    None if fi is None
                                    else fi.for_step(slots.step))
        key, sub = jax.random.split(slots.key)
        tok0 = steps.sample_token(logits, sub, temperature, top_k)

        refill = refill.astype(bool)
        C = slots.tokens.shape[1]
        max_new = jnp.clip(jnp.asarray(max_new, jnp.int32), 1, C)
        done0 = (tok0 == eos) | (max_new <= 1)       # one-token requests
        row0 = jnp.zeros_like(slots.tokens).at[:, 0].set(tok0)
        return slots.replace(
            cache=_merge_cache(refill, new_cache, slots.cache),
            cache_len=jnp.where(refill, jnp.int32(S), slots.cache_len),
            last_tok=jnp.where(refill, tok0, slots.last_tok),
            active=jnp.where(refill, ~done0, slots.active),
            request_id=jnp.where(refill, jnp.asarray(request_id, jnp.int32),
                                 slots.request_id),
            n_generated=jnp.where(refill, jnp.int32(1), slots.n_generated),
            max_new=jnp.where(refill, max_new, slots.max_new),
            tokens=jnp.where(refill[:, None], row0, slots.tokens),
            key=key)

    return refill_fn


# --------------------------------------------------------------------------- #
# chunked decode: every slot advances at its own depth
# --------------------------------------------------------------------------- #
def make_decode_chunk_fn(cfg: ModelConfig, chunk_steps: int,
                         top_k: Optional[int] = None) -> Callable:
    """Build ``chunk(params, slots, fi, temperature, eos) ->
    (SlotState, active_trace, telemetry)``.

    One ``lax.scan`` advances every slot ``chunk_steps`` decode steps:
    per-slot ragged depths enter :func:`repro.models.transformer.decode_step`
    as a vector ``cache_len``, sampling splits the shared key once per
    step, fault streams fold the global step counter, and per-slot
    completion masks (EOS hit or budget exhausted) retire slots in-scan.
    ``active_trace`` is the ``(chunk_steps, K)`` occupancy matrix — which
    slots actually served each step, the duty-cycle measurement the fleet
    aging replay consumes.  ``telemetry`` is a
    :class:`repro.obs.taps.Telemetry` of per-step ``(chunk_steps,)`` health
    series (:func:`repro.obs.taps.logit_taps` masked to live slots —
    inactive slots' garbage logits never pollute the signal), always
    computed in-graph so reading it can never retrace.
    """
    _check_family(cfg)
    decode = steps.make_decode_fn(cfg)

    def chunk(params, slots: SlotState, fi, temperature, eos):
        steps.TRACE_COUNTS["online_chunk"] += 1
        if fi is not None:
            fi = fi.with_seeds()
        K = slots.cache_len.shape[0]
        C = slots.tokens.shape[1]
        rows = jnp.arange(K)

        def body(s: SlotState, _):
            active0 = s.active
            cl = s.cache_len + 1         # per-slot depth incl. this token
            t = s.step + 1               # global decode-step index
            fi_t = None if fi is None else fi.for_step(t)
            logits, cache = decode(params, s.last_tok[:, None], s.cache,
                                   cl, fi_t)
            key, sub = jax.random.split(s.key)
            tok = steps.sample_token(logits, sub, temperature, top_k)
            ngen = s.n_generated + 1
            done = (tok == eos) | (ngen >= s.max_new)
            col = jnp.clip(s.n_generated, 0, C - 1)
            tokens = s.tokens.at[rows, col].set(
                jnp.where(active0, tok, s.tokens[rows, col]))
            new = s.replace(
                cache=cache,
                cache_len=jnp.where(active0, cl, s.cache_len),
                last_tok=jnp.where(active0, tok, s.last_tok),
                active=active0 & ~done,
                n_generated=jnp.where(active0, ngen, s.n_generated),
                tokens=tokens, key=key, step=t)
            return new, (active0, logit_taps(logits, active=active0))

        slots, (active_trace, taps) = jax.lax.scan(body, slots, None,
                                                   length=chunk_steps)
        return slots, active_trace, Telemetry(taps)

    return chunk
