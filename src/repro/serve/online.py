"""Continuous-batching online serve engine: live request queues on the
aging fleet.

The static-batch engines (:mod:`repro.serve.engine`) answer "generate
n tokens for this fixed prompt batch".  Production traffic is a *queue*:
requests arrive mid-decode, finish at different lengths, and the slots
they vacate must be refilled without stalling the requests still in
flight.  This module is that layer:

* :class:`Request` / :class:`RequestQueue` — host-side arrivals with
  bounded-queue admission control (the queue drops what it cannot hold;
  drop rates are part of the benchmark output);
* :func:`requests_from_workload` — turn a :class:`repro.sched.workload`
  arrival trace into a concrete request schedule (Little's-law sizing:
  ``load`` device-equivalents ≈ ``load * n_slots * steps_per_epoch /
  max_new`` requests per epoch);
* :class:`OnlineServeEngine` — one device: a fixed-slot
  :class:`~repro.serve.slots.SlotState` advances in compiled decode
  chunks; between chunks the host harvests completed slots and refills
  them from the queue.  Every piece of queue state enters the two
  compiled functions as traced leaves, so slot churn re-jits NOTHING
  (guarded by ``serve.steps.TRACE_COUNTS``), and a trace with no
  mid-decode arrivals is bit-exact with the one-shot scanned
  ``generate`` path;
* :class:`OnlineFleetEngine` — N fleet lanes stepped in lockstep by
  vmapped slot functions (one dispatch per chunk for the whole fleet,
  the :class:`~repro.serve.engine.FleetServeEngine` idiom), with a
  :mod:`repro.sched.router` policy assigning queued requests to lanes
  each chunk — utilization feedback uses the *measured* slot occupancy
  of the previous chunk, and per-lane fault streams come from each
  lane's own policy-admitted BERs;
* :class:`OnlineServeResult` — tok/s, p50/p99 request latency,
  admission drops, and the measured per-step slot-occupancy trace.
  :meth:`OnlineServeResult.lane_utilization` resamples that occupancy
  onto a scheduling-epoch grid — the ``util_trace`` that
  :meth:`repro.core.fleet.FleetRuntime.apply_load` replays into the
  aging recursion, closing the loop slots -> duty -> aging with
  *measured* duty instead of a synthetic envelope.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.fleet import FleetRuntime
from repro.models.layers import FaultConfig
from repro.obs import metrics as obs_metrics
from repro.obs.taps import taps_enabled, telemetry_to_host

from . import engine as serve_engine
from . import slots as slots_mod
from . import steps
from .slots import EMPTY, SlotState, init_slots


# --------------------------------------------------------------------------- #
# host-side requests
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class Request:
    """One inference request moving through the online engine.

    ``arrival`` is in decode-step units on the engine's service clock;
    the engine stamps ``t_start`` (prefill) and ``t_done`` (completion)
    on the same clock, so ``t_done - arrival`` is the request latency in
    decode steps.  ``tokens`` holds the generated ids once finished.
    """

    id: int
    prompt: np.ndarray                    # (S,) int32
    max_new: int
    arrival: int = 0
    t_start: int = -1
    t_done: int = -1
    lane: int = -1
    n_generated: int = 0
    tokens: Optional[np.ndarray] = None

    @property
    def latency(self) -> int:
        return self.t_done - self.arrival


class RequestQueue:
    """Bounded FIFO admission queue.

    ``push`` admits until ``max_queue`` is reached and *drops* the rest
    (counted — the flash-crowd benchmark reports the drop rate); ``take``
    hands the scheduler up to ``k`` requests in arrival order.
    """

    def __init__(self, max_queue: int = 64):
        self.max_queue = int(max_queue)
        self._q: collections.deque = collections.deque()
        self.n_arrived = 0
        self.n_admitted = 0
        self.n_dropped = 0

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> bool:
        """Admit one request; returns False (and counts a drop) if full."""
        self.n_arrived += 1
        if len(self._q) >= self.max_queue:
            self.n_dropped += 1
            return False
        self.n_admitted += 1
        self._q.append(req)
        return True

    def take(self, k: int) -> List[Request]:
        out = []
        while len(out) < k and self._q:
            out.append(self._q.popleft())
        return out


def requests_from_workload(workload, *, n_slots: int,
                           steps_per_epoch: int, max_new: int,
                           prompt_len: int, vocab: int = 256,
                           n_devices: int = 1, seed: int = 0,
                           n_epochs: Optional[int] = None,
                           loads=None) -> List[Request]:
    """Concretise a :class:`~repro.sched.workload.Workload` trace into
    requests.

    ``load`` device-equivalents in an epoch means the traffic would keep
    ``load`` devices' slots busy for the whole epoch; with ``n_slots``
    slots serving one token per step, that is ``load * n_slots *
    steps_per_epoch`` slot-steps, i.e. ``~ / max_new`` requests
    (Little's law).  Arrival offsets are uniform within each epoch and
    prompts are uniform token ids — the *count* process carries the
    workload's structure (diurnal envelope, Poisson noise, flash
    crowds), which is what the serving metrics respond to.
    ``loads`` overrides the sampled trace (e.g. a hand-built schedule).
    """
    from repro.sched.workload import Workload, get_workload
    if loads is None:
        wl = workload if isinstance(workload, Workload) else \
            get_workload(workload, n_devices=n_devices,
                         **({} if n_epochs is None
                            else {"n_epochs": n_epochs}))
        loads = np.asarray(wl.loads(seed), np.float64)
    loads = np.atleast_1d(np.asarray(loads, np.float64))
    assert loads.ndim == 1, f"loads must be (E,), got {loads.shape}"
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = 0
    per_req = max(int(max_new), 1)
    for e, load in enumerate(loads):
        lam = float(load) * n_slots * steps_per_epoch / per_req
        n = int(rng.poisson(max(lam, 0.0)))
        offs = np.sort(rng.integers(0, steps_per_epoch, size=n))
        for off in offs:
            reqs.append(Request(
                id=rid,
                prompt=rng.integers(0, vocab, size=prompt_len)
                          .astype(np.int32),
                max_new=per_req,
                arrival=int(e * steps_per_epoch + off)))
            rid += 1
    return reqs


# --------------------------------------------------------------------------- #
# result + occupancy -> aging replay
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class OnlineServeResult:
    """What an online serve run measured.

    ``occupancy`` is the per-step slot-activity trace — ``(T, K)`` for a
    single device, ``(T, N, K)`` for a fleet — where idle host steps
    (empty system waiting on arrivals) appear as all-False rows: the
    duty cycle the hardware actually sustained, which
    :meth:`lane_utilization` resamples onto the aging epoch grid.

    ``telemetry`` holds the in-scan tap series harvested from the decode
    chunks when taps were enabled (:func:`repro.obs.taps.enable_taps`):
    ``{name: (T_served,)}`` for a single device, ``{name: (N, T_served)}``
    for a fleet, covering the steps the device actually decoded (idle
    clock skips carry no taps).  ``None`` when taps were off — the served
    tokens are identical either way.
    """

    completed: List[Request]
    occupancy: np.ndarray
    n_arrived: int
    n_dropped: int
    total_steps: int
    wall_s: float
    n_tokens: int
    telemetry: Optional[Dict[str, np.ndarray]] = None

    @property
    def n_completed(self) -> int:
        return len(self.completed)

    @property
    def drop_rate(self) -> float:
        return self.n_dropped / max(self.n_arrived, 1)

    @property
    def tok_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    # the one shared latency-quantile implementation: engines, benchmarks
    # and the obs health snapshot all read these properties
    @property
    def p50(self) -> float:
        """Median request latency [decode steps] (NaN with no completions)."""
        return self.latency_percentiles((50.0,))["p50"]

    @property
    def p99(self) -> float:
        """p99 request latency [decode steps] (NaN with no completions)."""
        return self.latency_percentiles((99.0,))["p99"]

    def latencies(self) -> np.ndarray:
        """Request latencies [decode steps], one per completed request."""
        return np.asarray([r.latency for r in self.completed], np.float64)

    def latency_percentiles(self, qs=(50.0, 99.0)) -> Dict[str, float]:
        lat = self.latencies()
        if lat.size == 0:
            return {f"p{q:g}": float("nan") for q in qs}
        return {f"p{q:g}": float(np.percentile(lat, q)) for q in qs}

    def lane_utilization(self, n_epochs: int) -> np.ndarray:
        """Measured per-device duty cycle on an ``n_epochs`` grid.

        Splits the step axis into ``n_epochs`` contiguous windows and
        averages slot activity per window — the mean fraction of slots
        busy, exactly the ``util`` a router would have assigned.  Shape
        ``(E,)`` for a single device, ``(E, N)`` for a fleet: feed the
        fleet form to ``FleetRuntime.apply_load(util_trace=...)``.
        """
        occ = np.asarray(self.occupancy, np.float64)
        T = occ.shape[0]
        assert T > 0, "no served steps to resample"
        # per-step duty: mean over the slot axis (last)
        duty = occ.mean(axis=-1)                      # (T,) or (T, N)
        edges = np.linspace(0, T, n_epochs + 1).astype(np.int64)
        out = np.zeros((n_epochs,) + duty.shape[1:], np.float64)
        for e in range(n_epochs):
            lo, hi = edges[e], max(edges[e + 1], edges[e] + 1)
            out[e] = duty[lo:min(hi, T)].mean(axis=0) if lo < T else 0.0
        return out

    def summary(self) -> Dict[str, float]:
        d = {"n_arrived": self.n_arrived, "n_dropped": self.n_dropped,
             "n_completed": self.n_completed,
             "drop_rate": self.drop_rate, "total_steps": self.total_steps,
             "n_tokens": self.n_tokens, "wall_s": self.wall_s,
             "tok_per_s": self.tok_per_s,
             "mean_occupancy": float(np.asarray(self.occupancy,
                                                np.float64).mean())}
        d.update(self.latency_percentiles())
        return d


def _record_online(res: "OnlineServeResult") -> None:
    """Fold one finished online run into the metrics registry."""
    reg = obs_metrics.REGISTRY
    reg.counter("online_requests_arrived", "requests offered").inc(
        res.n_arrived)
    reg.counter("online_requests_dropped",
                "requests dropped at admission").inc(res.n_dropped)
    reg.counter("online_requests_completed", "requests completed").inc(
        res.n_completed)
    reg.counter("serve_tokens", "tokens generated").inc(res.n_tokens)
    reg.histogram("online_latency_steps",
                  "request latency [decode steps]") \
       .observe_many(res.latencies())
    reg.gauge("online_drop_rate", "drop rate of the last run").set(
        res.drop_rate)


# --------------------------------------------------------------------------- #
# compiled slot functions (bounded LRU, shared with the engine caches)
# --------------------------------------------------------------------------- #
@serve_engine.compile_cache("online_prefill")
def _prefill_slots_fn(cfg: ModelConfig, max_len: int, top_k: Optional[int]):
    """Jitted slot-refill prefill (one entry per config/max_len/top_k)."""
    return jax.jit(slots_mod.make_prefill_slots_fn(cfg, max_len, top_k))


@serve_engine.compile_cache("online_chunk")
def _decode_chunk_fn(cfg: ModelConfig, chunk_steps: int,
                     top_k: Optional[int]):
    """Jitted decode chunk (one entry per config/chunk_steps/top_k)."""
    return jax.jit(slots_mod.make_decode_chunk_fn(cfg, chunk_steps, top_k))


@serve_engine.compile_cache("online_fleet_prefill")
def _fleet_prefill_slots_fn(cfg: ModelConfig, max_len: int,
                            top_k: Optional[int]):
    """vmap of the slot refill over fleet lanes (params broadcast)."""
    fn = slots_mod.make_prefill_slots_fn(cfg, max_len, top_k)
    return jax.jit(jax.vmap(fn, in_axes=(None, 0, 0, 0, 0, 0, 0, None,
                                         None)))


@serve_engine.compile_cache("online_fleet_chunk")
def _fleet_decode_chunk_fn(cfg: ModelConfig, chunk_steps: int,
                           top_k: Optional[int]):
    """vmap of the decode chunk over fleet lanes (params broadcast)."""
    fn = slots_mod.make_decode_chunk_fn(cfg, chunk_steps, top_k)
    return jax.jit(jax.vmap(fn, in_axes=(None, 0, 0, None, None)))


# --------------------------------------------------------------------------- #
# single-device online engine
# --------------------------------------------------------------------------- #
class OnlineServeEngine:
    """Serve a live request queue on one (aging) device.

    The service loop alternates two compiled calls — refill freed slots
    (batched prompt prefill, ``jnp.where``-merged into live state) and a
    ``chunk_steps``-long scanned decode — with host work between chunks
    limited to queue bookkeeping on small ``(K,)`` vectors.  All slot
    state is traced leaves: steady-state serving re-jits nothing.

    With no mid-decode arrivals (all slots filled once, no EOS) the
    token output is bit-exact with ``ServeEngine.generate(scan=True)``
    at the same seed — the chunked path consumes the identical key and
    fault-stream chains (regression-tested in
    ``tests/test_serve_online.py``).
    """

    def __init__(self, cfg: ModelConfig, params, *, runtime=None,
                 device: int = 0, n_slots: int = 4, max_len: int = 512,
                 max_new_cap: int = 64, chunk_steps: int = 8,
                 max_queue: int = 64, use_systolic_kernel: bool = False,
                 use_fused_kernel: bool = True, seed: int = 0):
        assert not cfg.n_encoder_layers and not cfg.prefix_tokens, \
            "online serving covers decoder-only families"
        self.cfg = cfg
        self.params = params
        if isinstance(runtime, FleetRuntime):
            runtime = runtime.device(device)
        self.runtime = runtime
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.max_new_cap = int(max_new_cap)
        self.chunk_steps = int(chunk_steps)
        self.max_queue = int(max_queue)
        self.use_kernel = use_systolic_kernel
        self.use_fused = use_fused_kernel
        self._key = jax.random.PRNGKey(seed)

    # same derivation as ServeEngine._fault_config — the parity tests
    # rely on the two engines consuming identical key chains
    def _fault_config(self) -> Optional[FaultConfig]:
        if self.runtime is None:
            return None
        self._key, sub = jax.random.split(self._key)
        bers = {op: jnp.float32(ber)
                for op, ber in self.runtime.op_bers().items()}
        return FaultConfig(bers=bers, key=sub, step=jnp.int32(0),
                           use_systolic_kernel=self.use_kernel,
                           fused=self.use_fused)

    # ------------------------------------------------------------------ #
    def serve(self, requests: Sequence[Request], *, greedy: bool = True,
              temperature: Optional[float] = None,
              top_k: Optional[int] = None, eos_id: int = -1,
              max_steps: Optional[int] = None) -> OnlineServeResult:
        """Run the queue to completion (or ``max_steps``).

        ``requests`` arrive on the service clock at their ``arrival``
        steps; the bounded queue applies admission control; ``eos_id=-1``
        disables EOS (every request runs its ``max_new`` budget).
        Returns the measured :class:`OnlineServeResult`.
        """
        cfg = self.cfg
        K, C = self.n_slots, self.max_new_cap
        fi = self._fault_config()
        self._key, call_key = jax.random.split(self._key)
        temp = serve_engine.ServeEngine._temperature(greedy, temperature)
        eos = jnp.int32(eos_id)

        pending = sorted(requests, key=lambda r: r.arrival)
        assert all(len(r.prompt) + min(r.max_new, C) <= self.max_len
                   for r in pending), \
            "prompt_len + max_new must fit the cache (max_len)"
        prompt_len = len(pending[0].prompt) if pending else 1
        assert all(len(r.prompt) == prompt_len for r in pending), \
            "online slots serve one fixed prompt length per run"
        queue = RequestQueue(self.max_queue)
        refill_fn = _prefill_slots_fn(cfg, self.max_len, top_k)
        chunk_fn = _decode_chunk_fn(cfg, self.chunk_steps, top_k)

        slots = init_slots(cfg, K, self.max_len, C, call_key)
        live: Dict[int, Request] = {}
        completed: List[Request] = []
        occ_rows: List[np.ndarray] = []
        telem_rows: List[Dict[str, np.ndarray]] = []
        now = 0                       # host service clock [decode steps]
        wall0 = time.perf_counter()

        def admit():
            while pending and pending[0].arrival <= now:
                queue.push(pending.pop(0))

        while pending or len(queue) or live:
            if max_steps is not None and now >= max_steps:
                break
            admit()
            # ---- refill freed slots from the queue ------------------- #
            free = [k for k in range(K) if k not in live]
            take = queue.take(len(free))
            if take:
                prompts = np.zeros((K, prompt_len), np.int32)
                mask = np.zeros((K,), bool)
                rids = np.full((K,), EMPTY, np.int32)
                mnew = np.ones((K,), np.int32)
                for k, r in zip(free, take):
                    prompts[k] = r.prompt
                    mask[k] = True
                    rids[k] = r.id
                    mnew[k] = r.max_new
                    r.t_start = now
                    live[k] = r
                slots = refill_fn(self.params, slots,
                                  jnp.asarray(prompts), jnp.asarray(mask),
                                  jnp.asarray(rids), jnp.asarray(mnew),
                                  fi, temp, eos)
                # prefill emits token 0 of each refilled request; requests
                # already done (1-token budget / instant EOS) harvest below
                self._harvest(slots, live, completed, now, trace=None)
            if not live:
                if len(queue):
                    # every refilled request finished AT prefill (instant
                    # EOS / 1-token budget): slots freed, refill again
                    continue
                # idle: no device work — jump the clock to the next
                # arrival, recording zero occupancy for the skipped steps
                if not pending:
                    break
                nxt = pending[0].arrival
                if max_steps is not None:
                    nxt = min(nxt, max_steps)
                skip = max(nxt - now, 1)
                occ_rows.append(np.zeros((skip, K), bool))
                now += skip
                continue
            # ---- one compiled decode chunk --------------------------- #
            slots, active_trace, telem = chunk_fn(self.params, slots, fi,
                                                  temp, eos)
            trace = np.asarray(active_trace)          # (chunk, K)
            occ_rows.append(trace)
            if taps_enabled():       # host-side read of the always-on taps
                telem_rows.append(telemetry_to_host(telem))
            now += self.chunk_steps
            self._harvest(slots, live, completed, now, trace=trace)

        if live:                  # max_steps cutoff: stamp partial progress
            ngen = np.asarray(slots.n_generated)
            toks = np.asarray(slots.tokens)
            for k, r in live.items():
                r.n_generated = int(ngen[k])
                r.tokens = toks[k, :r.n_generated].copy()
        occupancy = (np.concatenate(occ_rows, axis=0) if occ_rows
                     else np.zeros((0, K), bool))
        n_tokens = int(sum(r.n_generated for r in completed))
        n_tokens += int(sum(r.n_generated for r in live.values()))
        telemetry = None
        if telem_rows:
            telemetry = {k: np.concatenate([row[k] for row in telem_rows])
                         for k in telem_rows[0]}
        result = OnlineServeResult(
            completed=completed, occupancy=occupancy,
            n_arrived=queue.n_arrived, n_dropped=queue.n_dropped,
            total_steps=now, wall_s=time.perf_counter() - wall0,
            n_tokens=n_tokens, telemetry=telemetry)
        if taps_enabled():
            _record_online(result)
        return result

    # ------------------------------------------------------------------ #
    def _harvest(self, slots: SlotState, live: Dict[int, Request],
                 completed: List[Request], now: int,
                 trace: Optional[np.ndarray]):
        """Move finished slots' requests out of ``live`` (one host sync)."""
        active = np.asarray(slots.active)
        if active.all():
            return
        ngen = np.asarray(slots.n_generated)
        toks = None
        for k in [k for k, r in live.items() if not active[k]]:
            r = live.pop(k)
            if toks is None:
                toks = np.asarray(slots.tokens)
            r.n_generated = int(ngen[k])
            r.tokens = toks[k, :r.n_generated].copy()
            if trace is None:
                r.t_done = now            # finished at prefill
            else:
                # last chunk step this slot actually served
                served = np.flatnonzero(trace[:, k])
                last = int(served[-1]) + 1 if served.size else 0
                r.t_done = now - trace.shape[0] + last
            completed.append(r)


# --------------------------------------------------------------------------- #
# fleet online engine: router-dispatched lanes, one vmapped dispatch/chunk
# --------------------------------------------------------------------------- #
class OnlineFleetEngine:
    """Serve a live queue across every lane of a :class:`FleetRuntime`.

    All N lanes advance in lockstep: one vmapped refill + one vmapped
    decode chunk per scheduling round for the WHOLE fleet.  Between
    rounds a :mod:`repro.sched.router` policy converts the queue's
    offered load into per-lane utilization targets — fed by each lane's
    *measured* occupancy from the previous chunk and the fleet's current
    wear signal — and the dispatcher hands queued requests to the lanes
    with the most headroom.  Per-lane fault streams carry each device's
    own policy-admitted BERs (the ``op_ber_array`` fleet snapshot), so
    an aged lane serves its requests at its own error rate.
    """

    def __init__(self, cfg: ModelConfig, params, fleet: FleetRuntime, *,
                 n_slots: int = 4, max_len: int = 512,
                 max_new_cap: int = 64, chunk_steps: int = 8,
                 max_queue: int = 256, router="wear_level",
                 capacity: float = 1.0,
                 use_systolic_kernel: bool = False,
                 use_fused_kernel: bool = True, seed: int = 0):
        from repro.sched.router import get_router
        assert not cfg.n_encoder_layers and not cfg.prefix_tokens, \
            "online serving covers decoder-only families"
        assert getattr(fleet, "n_shards", 1) == 1, \
            "online lanes are whole devices; a shard-granular fleet " \
            "(n_shards > 1) is served by repro.serve.sharded.MeshServeEngine"
        self.cfg = cfg
        self.params = params
        self.fleet = fleet
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.max_new_cap = int(max_new_cap)
        self.chunk_steps = int(chunk_steps)
        self.max_queue = int(max_queue)
        self.router = get_router(router)
        self.capacity = float(capacity)
        self.use_kernel = use_systolic_kernel
        self.use_fused = use_fused_kernel
        self._key = jax.random.PRNGKey(seed)

    @property
    def n_devices(self) -> int:
        return self.fleet.n_devices

    # ------------------------------------------------------------------ #
    def _fleet_fault_config(self, call_key) -> FaultConfig:
        """Per-lane FaultConfig: every leaf carries the fleet axis."""
        N = self.fleet.n_devices
        ber = self.fleet.op_ber_array()                     # (N, O)
        bers = {op: jnp.asarray(ber[:, i], jnp.float32)
                for i, op in enumerate(self.fleet.operators)}
        keys = jax.random.split(call_key, N)
        return FaultConfig(bers=bers, key=keys,
                           step=jnp.zeros((N,), jnp.int32),
                           use_systolic_kernel=self.use_kernel,
                           fused=self.use_fused)

    def _init_slots(self, key) -> SlotState:
        """Lane-stacked slot state: every leaf gains a leading N axis."""
        states = [init_slots(self.cfg, self.n_slots, self.max_len,
                             self.max_new_cap, k)
                  for k in jax.random.split(key, self.n_devices)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def _wear(self) -> np.ndarray:
        """Per-device wear signal for the router (worst-domain ΔVth_p)."""
        return np.asarray(self.fleet.snapshot().dvth_p_mv).max(axis=-1)

    # ------------------------------------------------------------------ #
    def serve(self, requests: Sequence[Request], *, greedy: bool = True,
              temperature: Optional[float] = None,
              top_k: Optional[int] = None, eos_id: int = -1,
              max_steps: Optional[int] = None) -> OnlineServeResult:
        """Run the queue across the fleet; see
        :meth:`OnlineServeEngine.serve` for the protocol.  ``occupancy``
        comes back ``(T, N, K)`` — ``lane_utilization`` then yields the
        ``(E, N)`` trace ``FleetRuntime.apply_load(util_trace=...)``
        replays into the aging recursion.
        """
        cfg = self.cfg
        N, K, C = self.n_devices, self.n_slots, self.max_new_cap
        self._key, fi_key = jax.random.split(self._key)
        self._key, call_key = jax.random.split(self._key)
        fi = self._fleet_fault_config(fi_key)
        temp = serve_engine.ServeEngine._temperature(greedy, temperature)
        eos = jnp.int32(eos_id)

        pending = sorted(requests, key=lambda r: r.arrival)
        assert all(len(r.prompt) + min(r.max_new, C) <= self.max_len
                   for r in pending), \
            "prompt_len + max_new must fit the cache (max_len)"
        prompt_len = len(pending[0].prompt) if pending else 1
        assert all(len(r.prompt) == prompt_len for r in pending), \
            "online slots serve one fixed prompt length per run"
        queue = RequestQueue(self.max_queue)
        refill_fn = _fleet_prefill_slots_fn(cfg, self.max_len, top_k)
        chunk_fn = _fleet_decode_chunk_fn(cfg, self.chunk_steps, top_k)

        slots = self._init_slots(call_key)
        live: Dict[tuple, Request] = {}          # (lane, slot) -> Request
        completed: List[Request] = []
        occ_rows: List[np.ndarray] = []
        telem_rows: List[Dict[str, np.ndarray]] = []
        util_prev = np.zeros((N,), np.float64)   # measured, fed back
        wear = self._wear()
        now = 0
        wall0 = time.perf_counter()

        def admit():
            while pending and pending[0].arrival <= now:
                queue.push(pending.pop(0))

        while pending or len(queue) or live:
            if max_steps is not None and now >= max_steps:
                break
            admit()
            # ---- route queued requests to lanes ---------------------- #
            if len(queue):
                free = {n: [k for k in range(K) if (n, k) not in live]
                        for n in range(N)}
                # offered load in device-equivalents over the next chunk
                demand = sum(min(r.max_new, C) for r in queue._q)
                load = demand / max(self.chunk_steps * K, 1)
                util = np.asarray(self.router.assign(
                    jnp.float32(load), jnp.asarray(wear, jnp.float32),
                    jnp.asarray(util_prev, jnp.float32), self.capacity),
                    np.float64)
                # lane headroom: target slots minus already-busy slots
                busy = np.asarray([K - len(free[n]) for n in range(N)],
                                  np.float64)
                head = np.maximum(util * K - busy, 0.0)
                order = np.argsort(-head, kind="stable")
                assign: Dict[int, List[Request]] = {}
                for n in order:
                    n = int(n)
                    want = int(np.ceil(head[n]))
                    grab = queue.take(min(want, len(free[n])))
                    if grab:
                        assign[n] = grab
                # leftovers when every targeted lane is full: spill to
                # any free slot (defer only when the fleet is saturated)
                for n in range(N):
                    room = len(free[n]) - len(assign.get(n, []))
                    if room > 0 and len(queue):
                        assign.setdefault(n, []).extend(queue.take(room))
                if assign:
                    prompts = np.zeros((N, K, prompt_len), np.int32)
                    mask = np.zeros((N, K), bool)
                    rids = np.full((N, K), EMPTY, np.int32)
                    mnew = np.ones((N, K), np.int32)
                    for n, rs in assign.items():
                        for k, r in zip(free[n], rs):
                            prompts[n, k] = r.prompt
                            mask[n, k] = True
                            rids[n, k] = r.id
                            mnew[n, k] = r.max_new
                            r.t_start = now
                            r.lane = n
                            live[(n, k)] = r
                    slots = refill_fn(self.params, slots,
                                      jnp.asarray(prompts),
                                      jnp.asarray(mask),
                                      jnp.asarray(rids),
                                      jnp.asarray(mnew), fi, temp, eos)
                    self._harvest(slots, live, completed, now, trace=None)
            if not live:
                if len(queue):
                    continue      # freed at prefill: dispatch again
                if not pending:
                    break
                nxt = pending[0].arrival
                if max_steps is not None:
                    nxt = min(nxt, max_steps)
                skip = max(nxt - now, 1)
                occ_rows.append(np.zeros((skip, N, K), bool))
                util_prev = np.zeros((N,), np.float64)
                now += skip
                continue
            # ---- one vmapped decode chunk over all lanes ------------- #
            slots, active_trace, telem = chunk_fn(self.params, slots, fi,
                                                  temp, eos)
            trace = np.asarray(active_trace)         # (N, chunk, K)
            trace = np.moveaxis(trace, 0, 1)         # (chunk, N, K)
            occ_rows.append(trace)
            if taps_enabled():   # vmapped taps: leaves are (N, chunk)
                telem_rows.append(telemetry_to_host(telem))
            util_prev = trace.mean(axis=(0, 2))      # measured duty (N,)
            now += self.chunk_steps
            self._harvest(slots, live, completed, now, trace=trace)

        if live:                  # max_steps cutoff: stamp partial progress
            ngen = np.asarray(slots.n_generated)
            toks = np.asarray(slots.tokens)
            for (n, k), r in live.items():
                r.n_generated = int(ngen[n, k])
                r.tokens = toks[n, k, :r.n_generated].copy()
        occupancy = (np.concatenate(occ_rows, axis=0) if occ_rows
                     else np.zeros((0, N, K), bool))
        n_tokens = int(sum(r.n_generated for r in completed))
        n_tokens += int(sum(r.n_generated for r in live.values()))
        telemetry = None
        if telem_rows:               # (N, chunk) rows -> (N, T_served)
            telemetry = {k: np.concatenate([row[k] for row in telem_rows],
                                           axis=-1)
                         for k in telem_rows[0]}
        result = OnlineServeResult(
            completed=completed, occupancy=occupancy,
            n_arrived=queue.n_arrived, n_dropped=queue.n_dropped,
            total_steps=now, wall_s=time.perf_counter() - wall0,
            n_tokens=n_tokens, telemetry=telemetry)
        if taps_enabled():
            _record_online(result)
        return result

    # ------------------------------------------------------------------ #
    def _harvest(self, slots: SlotState, live: Dict[tuple, Request],
                 completed: List[Request], now: int,
                 trace: Optional[np.ndarray]):
        active = np.asarray(slots.active)            # (N, K)
        if active.all():
            return
        ngen = np.asarray(slots.n_generated)
        toks = None
        for (n, k) in [lk for lk, r in live.items()
                       if not active[lk[0], lk[1]]]:
            r = live.pop((n, k))
            if toks is None:
                toks = np.asarray(slots.tokens)
            r.n_generated = int(ngen[n, k])
            r.tokens = toks[n, k, :r.n_generated].copy()
            if trace is None:
                r.t_done = now
            else:
                served = np.flatnonzero(trace[:, n, k])
                last = int(served[-1]) + 1 if served.size else 0
                r.t_done = now - trace.shape[0] + last
            completed.append(r)
