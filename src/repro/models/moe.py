"""Mixture-of-Experts FFN with capacity-bucketed sort-free dispatch (EP).

Routing: top-k softmax over expert logits.  Dispatch is gather/scatter based
(one-hot cumsum positions -> scatter into an (E, C, d) buffer) rather than
the Switch-style dense dispatch einsum, whose FLOP cost T*E*C*d would dwarf
the expert FFNs themselves at these shapes; data movement instead of
redundant compute is the TPU-appropriate trade.  Expert weights carry the
leading E axis which the sharding rules map onto the "model" mesh axis
(expert parallelism); the scatter/gather across the token(data) <-> expert
(model) axes is where SPMD inserts the dispatch collectives (baseline; see
EXPERIMENTS.md §Perf for the shard_map all-to-all hillclimb).

Dropped tokens (capacity overflow) fall back to the residual path, as usual
for capacity-based MoE.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import MoEConfig
from repro.distributed.sharding import constrain_replicated
from .layers import FaultConfig, mlp_apply, mlp_init, op_linear


def moe_init(key, d: int, f: int, moe: MoEConfig, variant: str, dtype) -> Dict:
    kr, ke, kd = jax.random.split(key, 3)
    E = moe.n_experts
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "w_router": jax.random.normal(kr, (d, E), jnp.float32) * s_in,
        "w_up": jax.random.normal(ke, (E, d, f), dtype) * s_in,
        "w_down": jax.random.normal(kd, (E, f, d), dtype) * s_out,
    }
    if variant == "gated":
        p["w_gate"] = jax.random.normal(
            jax.random.fold_in(ke, 1), (E, d, f), dtype) * s_in
    if moe.dense_residual:
        p["dense"] = mlp_init(jax.random.fold_in(kd, 1), d, f, variant, dtype)
    return p


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(8, -(-c // 8) * 8)


# Dispatch algorithm selector (see EXPERIMENTS.md §Perf HC1):
#   "global"  — one cumsum over ALL B*S*K (token, slot) pairs.  Faithful to
#               a single-array view but the global cumsum is serial in T*K,
#               is counted super-linearly by the cost model, and forces
#               GSPMD to replicate the (T*K, E) routing tensors (huge
#               all-gathers).  The measured baseline.
#   "grouped" — GShard-style per-batch-row dispatch: capacity and positions
#               are computed independently per row (cumsum length S*K, not
#               B*S*K), keeping every routing tensor batch-sharded; the
#               expert einsum carries the B axis so tokens meet expert
#               shards in ONE all-to-all-shaped resharding.
MOE_DISPATCH = "global"


def moe_apply(x: jax.Array, p: Dict, moe: MoEConfig, variant: str,
              fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    if MOE_DISPATCH == "grouped" and fi is None:
        return moe_apply_grouped(x, p, moe, variant)
    return moe_apply_global(x, p, moe, variant, fi, salt)


def moe_apply_grouped(x: jax.Array, p: Dict, moe: MoEConfig, variant: str):
    """Per-row dispatch: x (B, S, d) -> (B, S, d); routing stays sharded."""
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    C = _capacity(S, moe)                               # per-row capacity

    logits = x @ p["w_router"].astype(x.dtype)          # (B, S, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)              # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    aux = aux_load_balance_loss(probs.reshape(-1, E),
                                top_e.reshape(-1, K), E)

    flat_e = top_e.reshape(B, S * K)                    # row-major slots
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (B, S*K, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot       # per-row positions
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)                  # C = overflow slot

    xrep = jnp.repeat(x, K, axis=1)                     # (B, S*K, d)
    bidx = jnp.arange(B)[:, None] * jnp.ones((1, S * K), jnp.int32)
    buf = jnp.zeros((B, E, C + 1, d), x.dtype)
    buf = buf.at[bidx, flat_e, safe_pos].set(xrep)[:, :, :C]

    # expert FFN with the batch axis carried: (B, E, C, d) @ (E, d, f)
    if variant == "gated":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
            * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["w_up"]))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])

    out_tok = out_buf[bidx, flat_e, safe_pos]           # (B, S*K, d)
    out_tok = jnp.where(keep[..., None], out_tok, 0.0)
    w = top_p.reshape(B, S * K, 1).astype(x.dtype)
    out = (out_tok * w).reshape(B, S, K, d).sum(axis=2)

    if moe.dense_residual:
        out = out + mlp_apply(x, p["dense"], variant)
    return out, aux


def moe_apply_global(x: jax.Array, p: Dict, moe: MoEConfig, variant: str,
                     fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    C = _capacity(T, moe)
    xf = x.reshape(T, d)

    logits = op_linear(xf, p["w_router"].astype(x.dtype), "router", fi, salt)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)              # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    aux = aux_load_balance_loss(probs, top_e, E)

    # position of each (token, slot) within its expert queue
    flat_e = top_e.reshape(-1)                          # (T*K,) slot-major? no:
    # reshape is row-major: entries of token t occupy t*K..t*K+K-1 — fine for
    # cumsum ordering (token order preserved, slots interleaved).
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (T*K, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)                  # C = overflow slot

    # scatter tokens into the (E, C+1, d) expert buffer (overflow row dropped)
    xrep = jnp.repeat(xf, K, axis=0)                    # (T*K, d)
    buf = jnp.zeros((E, C + 1, d), x.dtype).at[flat_e, safe_pos].set(xrep)
    buf = buf[:, :C]

    # expert FFN: (E, C, d) @ (E, d, f)
    if variant == "gated":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    # expert-parallel under a serve mesh: E-sharded weights keep h/out_buf
    # E-sharded (batch-like, exact); pin the combined buffer replicated
    # before the token gather crosses shards
    out_buf = constrain_replicated(
        jnp.einsum("ecf,efd->ecd", h, p["w_down"]))

    # gather back and combine with router weights
    out_tok = out_buf[flat_e, safe_pos]                 # (T*K, d)
    out_tok = jnp.where(keep[:, None], out_tok, 0.0)
    w = top_p.reshape(-1)[:, None].astype(x.dtype)
    out = (out_tok * w).reshape(T, K, d).sum(axis=1)

    if moe.dense_residual:
        out = out + mlp_apply(xf, p["dense"], variant, fi, salt)
    return out.reshape(B, S, d), aux


def aux_load_balance_loss(logits_or_probs, top_e, n_experts: int):
    """Switch-style load-balancing auxiliary loss."""
    probs = logits_or_probs
    me = probs.mean(axis=0)                              # (E,)
    ce = jnp.zeros((n_experts,)).at[top_e.reshape(-1)].add(1.0)
    ce = ce / top_e.size
    return n_experts * jnp.sum(me * ce)
