"""RWKV6 (Finch) time-mix with data-dependent decay — chunked-parallel form.

Per head (dim N), per step the matrix-valued state S (N x N) evolves as

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = (r_t)^T (S_{t-1} + diag(u) k_t v_t^T)

with data-dependent per-channel decay ``w_t = exp(-exp(ww_t))`` (LoRA-
parameterised from x_t) and a bonus ``u`` for the current token.  Training /
prefill uses the standard chunked linear-attention algorithm: within a chunk
the quadratic form with decay masks, across chunks a scanned state carry —
O(S * N^2 / chunk + S * chunk * N) work, parallel over (B, H).

Decode is the O(N^2) single-step update.  The token-shift mixers use the
static interpolation form (the LoRA-dynamic token-shift of the reference
implementation is an accuracy refinement orthogonal to system structure —
recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import FaultConfig, op_linear

DECAY_LORA = 64

# Dry-run cost probes: run the whole sequence as ONE chunk so the chunk scan
# has a single trip (XLA cost_analysis counts scan bodies once — see
# repro.launch.dryrun.probe_mode).
FORCE_SINGLE_CHUNK = False


def rwkv_time_mix_init(key, d: int, hd: int, dtype) -> Dict:
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    H = d // hd
    return {
        "w_r": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d, d), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d, d), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d, d), dtype) * s,
        "decay_base": jnp.asarray(
            jax.random.uniform(ks[5], (d,), jnp.float32, -7.0, -5.0)),
        "decay_lora_a": jax.random.normal(ks[6], (d, DECAY_LORA), dtype) * s,
        "decay_lora_b": jax.random.normal(
            ks[7], (DECAY_LORA, d), dtype) * DECAY_LORA ** -0.5,
        "bonus_u": jnp.asarray(
            jax.random.normal(ks[8], (H, hd), jnp.float32) * 0.1),
        "mix": jnp.full((5, d), 0.5, dtype),   # r,k,v,g,w token-shift mixes
    }


def rwkv_channel_mix_init(key, d: int, f: int, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": jax.random.normal(k1, (d, f), dtype) * d ** -0.5,
        "w_out": jax.random.normal(k2, (f, d), dtype) * f ** -0.5,
        "mix": jnp.full((d,), 0.5, dtype),
    }


def _token_shift(x, x_prev1):
    """shifted(x)[t] = x[t-1]; first step uses carried x_prev1 (B, d)."""
    return jnp.concatenate([x_prev1[:, None], x[:, :-1]], axis=1)


def _chunked_wkv(r, k, v, w_log, u, chunk: int, s0):
    """Chunked linear attention with per-channel decay.

    r,k,v: (B, S, H, N); w_log: (B, S, H, N) log-decay (<0); u: (H, N);
    s0: (B, H, N, N) initial state.  Returns (out (B,S,H,N), sT).
    """
    B, S, H, N = r.shape
    nc = S // chunk
    rc = r.reshape(B, nc, chunk, H, N)
    kc = k.reshape(B, nc, chunk, H, N)
    vc = v.reshape(B, nc, chunk, H, N)
    wc = w_log.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    def step(s, inp):
        rb, kb, vb, wb = inp                       # (B, chunk, H, N) each
        cum = jnp.cumsum(wb, axis=1)               # inclusive decay sums
        total = cum[:, -1:]                        # (B,1,H,N)
        # inter-chunk: o_inter[t] = (r_t * exp(cum[t-1])) @ s
        decay_in = jnp.exp(cum - wb)               # exp(cum[t-1]) = cum - w_t
        o_inter = jnp.einsum("bthn,bhnm->bthm", rb * decay_in, s)
        # intra-chunk quadratic with decay mask:
        # A[t,s] = r_t . (exp(cum[t-1]-cum[s]) * k_s)   for s < t
        #          r_t . (u * k_t)                      for s == t
        q_ = rb * decay_in                          # (B,t,H,N)
        k_ = kb * jnp.exp(-cum)                     # (B,s,H,N)
        att = jnp.einsum("bthn,bshn->bhts", q_, k_)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        att = jnp.where(tri[None, None], att, 0.0)
        diag = jnp.einsum("bthn,hn,bthn->bth", rb, u, kb)
        o_intra = jnp.einsum("bhts,bshn->bthn", att, vb) \
            + diag[..., None] * vb
        # state update: s' = diag(exp(total)) s + sum_s exp(total-cum[s]) k v^T
        k_carry = kb * jnp.exp(total - cum)
        s_new = jnp.exp(total)[:, 0, :, :, None] * s \
            + jnp.einsum("bshn,bshm->bhnm", k_carry, vb)
        return s_new, o_inter + o_intra

    s_fin, outs = jax.lax.scan(
        step, s0,
        (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4), wc.transpose(1, 0, 2, 3, 4)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    return out, s_fin


def rwkv_time_mix(x, p, hd: int, *, state: Optional[Dict] = None,
                  chunk: int = 128,
                  fi: Optional[FaultConfig] = None, salt=0
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d).  state: {"shift": (B,d), "wkv": (B,H,N,N)}."""
    B, S, d = x.shape
    H = d // hd
    if FORCE_SINGLE_CHUNK:
        chunk = S
    xp = _token_shift(x, state["shift"] if state
                      else jnp.zeros((B, d), x.dtype))
    mixed = [x * p["mix"][i] + xp * (1 - p["mix"][i]) for i in range(5)]
    r = op_linear(mixed[0], p["w_r"], "q", fi, salt).reshape(B, S, H, hd)
    k = op_linear(mixed[1], p["w_k"], "k", fi, salt).reshape(B, S, H, hd)
    v = op_linear(mixed[2], p["w_v"], "v", fi, salt).reshape(B, S, H, hd)
    g = jax.nn.silu(op_linear(mixed[3], p["w_g"], "g", fi, salt))
    ww = p["decay_base"] + jnp.tanh(
        mixed[4] @ p["decay_lora_a"]) @ p["decay_lora_b"]
    # clamp per-step decay rate: faster than 0.25/step is numerically
    # indistinguishable from full decay within a chunk, and the clamp keeps
    # exp(-cum) inside float32 range in the separated chunked form.
    w_log = -jnp.clip(jnp.exp(ww.astype(jnp.float32)), 1e-6, 0.25) \
        .reshape(B, S, H, hd)

    s0 = state["wkv"] if state else jnp.zeros((B, H, hd, hd), jnp.float32)
    if S == 1 and state is not None:                    # decode fast path
        rt, kt, vt = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        wt = jnp.exp(w_log[:, 0])
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         s0 + p["bonus_u"][None, :, :, None] * kv)
        s_fin = wt[..., None] * s0 + kv
        out = out[:, None].reshape(B, 1, d)
    else:
        rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
        pad = (-S) % chunk
        if pad:
            z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
            rf, kf, vf = z(rf), z(kf), z(vf)
            w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out, s_fin = _chunked_wkv(rf, kf, vf, w_log, p["bonus_u"],
                                  min(chunk, rf.shape[1]), s0)
        out = out[:, :S].reshape(B, S, d)
    out = op_linear(out.astype(x.dtype) * g, p["w_o"], "o", fi, salt)
    new_state = ({"shift": x[:, -1], "wkv": s_fin}
                 if state is not None else None)
    return out, new_state


def rwkv_channel_mix(x, p, *, state: Optional[jax.Array] = None,
                     fi: Optional[FaultConfig] = None, salt=0):
    B, S, d = x.shape
    xp = _token_shift(x, state if state is not None
                      else jnp.zeros((B, d), x.dtype))
    xm = x * p["mix"] + xp * (1 - p["mix"])
    h = jnp.square(jax.nn.relu(op_linear(xm, p["w_in"], "up", fi, salt)))
    out = op_linear(h, p["w_out"], "down", fi, salt)
    return out, (x[:, -1] if state is not None else None)


def rwkv_init_state(batch: int, d: int, hd: int) -> Dict:
    H = d // hd
    return {
        "tm": {"shift": jnp.zeros((batch, d), jnp.bfloat16),
               "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32)},
        "cm_shift": jnp.zeros((batch, d), jnp.bfloat16),
    }
