"""Decoder-only LM assembly: block patterns, grouped layer scan, KV caches.

Depth is handled by ``jax.lax.scan`` over *stacked* layer groups so compile
time and HLO size are O(1) in depth (DESIGN.md Sec. 5).  A group is one
period of ``cfg.block_pattern`` (e.g. ("rec","rec","attn") for
RecurrentGemma); layers beyond the last full period form an unstacked tail.

The same assembly serves dense, MoE, hybrid, SSM (RWKV) and VLM (prefix
embeddings + prefix-bidirectional mask) families; whisper's encoder/decoder
live in :mod:`repro.models.encdec` on top of the same block functions.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.distributed.sharding import constrain_replicated
from . import attention as attn_lib
from .layers import (FaultConfig, apply_rope, init_norm, mlp_apply, mlp_init,
                     norm, op_einsum, op_linear, rms_norm)
from .moe import moe_apply, moe_init
from .rglru import rglru_block, rglru_init, rglru_init_state
from .rwkv6 import (rwkv_channel_mix, rwkv_channel_mix_init, rwkv_init_state,
                    rwkv_time_mix, rwkv_time_mix_init)


# --------------------------------------------------------------------------- #
# block parameter init
# --------------------------------------------------------------------------- #
def _attn_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, H, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, KV, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, KV, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H, hd, d), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _block_init(key, kind: str, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {"norm1": init_norm(cfg.norm, d, dtype),
         "norm2": init_norm(cfg.norm, d, dtype)}
    if kind == "attn":
        p["attn"] = _attn_init(k1, cfg, dtype)
        p["ffn"] = (moe_init(k2, d, f, cfg.moe, cfg.mlp, dtype) if cfg.moe
                    else mlp_init(k2, d, f, cfg.mlp, dtype))
    elif kind == "rec":
        p["rglru"] = rglru_init(k1, d, dtype)
        p["ffn"] = mlp_init(k2, d, f, cfg.mlp, dtype)
    elif kind == "rwkv":
        p["tm"] = rwkv_time_mix_init(k1, d, cfg.rwkv_head_dim, dtype)
        p["cm"] = rwkv_channel_mix_init(k2, d, f, dtype)
    else:
        raise ValueError(kind)
    return p


def _layer_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    kinds = _layer_kinds(cfg)
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail_kinds = kinds[n_groups * len(pat):]

    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), dtype) * 0.02,
        "final_norm": init_norm(cfg.norm, d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (d, cfg.vocab),
                                              dtype) * d ** -0.5
    if cfg.prefix_tokens:
        params["prefix_proj"] = jax.random.normal(keys[2], (d, d),
                                                  dtype) * d ** -0.5

    def one_group(gkey):
        gks = jax.random.split(gkey, len(pat))
        return {f"b{i}_{kind}": _block_init(gks[i], kind, cfg, dtype)
                for i, kind in enumerate(pat)}

    if n_groups:
        gkeys = jax.random.split(keys[3], n_groups)
        params["groups"] = jax.vmap(one_group)(gkeys)
    if tail_kinds:
        tks = jax.random.split(keys[4], len(tail_kinds))
        params["tail"] = [
            {f"b0_{kind}": _block_init(tks[i], kind, cfg, dtype)}
            for i, kind in enumerate(tail_kinds)]
    return params


# --------------------------------------------------------------------------- #
# int8 weight quantisation (EXPERIMENTS.md §Perf HC3 — paper-native: the
# accelerator's systolic array is int8; serving weights live in HBM as int8
# + per-output-channel scales and are dequantised PER LAYER GROUP inside the
# scan body, so the bf16 copy only ever exists for the layer being computed.
# Halves weight HBM residency/traffic and any weight collectives.
# --------------------------------------------------------------------------- #
def quantize_params(params: Dict) -> Dict:
    """bf16/f32 param tree -> int8 {"int8_q","int8_s"} leaves (>=2-D only)."""
    def q(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        amax = jnp.max(jnp.abs(leaf.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        s = jnp.maximum(amax, 1e-8) / 127.0
        qv = jnp.clip(jnp.round(leaf.astype(jnp.float32) / s),
                      -127, 127).astype(jnp.int8)
        return {"int8_q": qv, "int8_s": s.astype(jnp.float32)}
    return jax.tree.map(q, params)


def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and "int8_q" in x


def dequant_tree(tree, dtype=jnp.bfloat16):
    """Dequantise every int8 leaf (called inside the layer-scan body)."""
    return jax.tree.map(
        lambda x: (x["int8_q"].astype(dtype) * x["int8_s"].astype(dtype)
                   if _is_qleaf(x) else x),
        tree, is_leaf=lambda x: _is_qleaf(x) or not isinstance(x, dict))


# --------------------------------------------------------------------------- #
# block application
# --------------------------------------------------------------------------- #
def _attn_block(x, bp, cfg: ModelConfig, *, positions, prefix_len,
                cache=None, cache_len=None, fi=None, salt=0):
    """Self-attention + FFN block.  With ``cache`` (decode): single token."""
    h = norm(x, bp["norm1"], cfg.norm)
    ap = bp["attn"]
    q = op_einsum("bsd,dhk->bshk", h, ap["wq"], "q", fi, salt)
    k = op_einsum("bsd,dhk->bshk", h, ap["wk"], "k", fi, salt)
    v = op_einsum("bsd,dhk->bshk", h, ap["wv"], "v", fi, salt)
    if cfg.qk_norm:
        q, k = rms_norm(q, ap["q_norm"]), rms_norm(k, ap["k_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        qcache = _is_qleaf(cache["k"])   # int8 KV cache (§Perf HC3)
        kbuf = cache["k"]["int8_q"] if qcache else cache["k"]
        kv_len = kbuf.shape[1]
        if q.shape[1] == 1:      # decode: ring-write to cache, attend
            # ring addressing: token t lives at slot t % kv_len (identity for
            # full-length caches; wraps for windowed local attention)
            idx = jnp.remainder(cache_len - 1, kv_len)
            if jnp.ndim(idx) == 0:
                write = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
                    buf, new, idx, 1)
            else:                # per-row depths (continuous-batching slots):
                                 # each row scatters at its own ring position
                rows = jnp.arange(k.shape[0])
                write = lambda buf, new: buf.at[rows, idx].set(new[:, 0])
            if qcache:
                knew, vnew = quantize_cache_entry(k), quantize_cache_entry(v)
                kc = {f: write(cache["k"][f], knew[f]) for f in knew}
                vc = {f: write(cache["v"][f], vnew[f]) for f in vnew}
                k_at = kc["int8_q"].astype(q.dtype) \
                    * kc["int8_s"].astype(q.dtype)
                v_at = vc["int8_q"].astype(q.dtype) \
                    * vc["int8_s"].astype(q.dtype)
            else:
                kc = write(cache["k"], k)
                vc = write(cache["v"], v)
                k_at, v_at = kc, vc
            out = attn_lib.decode_attention(q, k_at, v_at, cache_len, fi=fi,
                                            salt=salt)
            new_cache = {"k": kc, "v": vc}
        else:                    # prefill: run full attn, stash K/V
            out = attn_lib.attention(q, k, v, causal=True, window=cfg.window,
                                     prefix_len=prefix_len, fi=fi, salt=salt)
            S = k.shape[1]
            if S >= kv_len:      # windowed: keep the last kv_len tokens,
                                 # rolled so token t sits at slot t % kv_len
                kc = jnp.roll(k[:, -kv_len:], S % kv_len, axis=1)
                vc = jnp.roll(v[:, -kv_len:], S % kv_len, axis=1)
            else:
                pad = kv_len - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if qcache:
                kc, vc = quantize_cache_entry(kc), quantize_cache_entry(vc)
            new_cache = {"k": kc, "v": vc}
    else:
        out = attn_lib.attention(q, k, v, causal=True, window=cfg.window,
                                 prefix_len=prefix_len, fi=fi, salt=salt)
    x = x + op_einsum("bshk,hkd->bsd", out, ap["wo"], "o", fi, salt)

    h2 = norm(x, bp["norm2"], cfg.norm)
    if cfg.moe:
        y, aux = moe_apply(h2, bp["ffn"], cfg.moe, cfg.mlp, fi, salt)
    else:
        y, aux = mlp_apply(h2, bp["ffn"], cfg.mlp, fi, salt), 0.0
    return x + y, new_cache, aux


def _rec_block(x, bp, cfg: ModelConfig, *, state=None, fi=None, salt=0):
    h = norm(x, bp["norm1"], cfg.norm)
    out, new_state = rglru_block(h, bp["rglru"], state=state, fi=fi,
                                 salt=salt)
    x = x + out
    h2 = norm(x, bp["norm2"], cfg.norm)
    return x + mlp_apply(h2, bp["ffn"], cfg.mlp, fi, salt), new_state, 0.0


def _rwkv_block(x, bp, cfg: ModelConfig, *, state=None, fi=None, salt=0):
    h = norm(x, bp["norm1"], cfg.norm)
    out, tm_state = rwkv_time_mix(h, bp["tm"], cfg.rwkv_head_dim,
                                  state=state["tm"] if state else None,
                                  fi=fi, salt=salt)
    x = x + out
    h2 = norm(x, bp["norm2"], cfg.norm)
    out2, cm_shift = rwkv_channel_mix(h2, bp["cm"],
                                      state=state["cm_shift"] if state
                                      else None, fi=fi, salt=salt)
    new_state = ({"tm": tm_state, "cm_shift": cm_shift}
                 if state is not None else None)
    return x + out2, new_state, 0.0


def _apply_block(x, bp, kind, cfg, *, positions, prefix_len, state, cache_len,
                 fi, salt):
    if kind == "attn":
        return _attn_block(x, bp, cfg, positions=positions,
                           prefix_len=prefix_len, cache=state,
                           cache_len=cache_len, fi=fi, salt=salt)
    if kind == "rec":
        return _rec_block(x, bp, cfg, state=state, fi=fi, salt=salt)
    if kind == "rwkv":
        return _rwkv_block(x, bp, cfg, state=state, fi=fi, salt=salt)
    raise ValueError(kind)


def _run_blocks(x, params, cfg: ModelConfig, *, positions, prefix_len=0,
                states=None, cache_len=None, fi=None, remat=False):
    """Scan the grouped blocks (+ tail); threads per-block state pytrees.

    ``remat=True`` rematerialises each layer group in the backward pass
    (activation checkpointing at group granularity: stored activations are
    O(n_groups * B * S * d) instead of every intermediate — the standard
    memory/compute trade for the train_4k cells; matmul outputs with no
    batch dims are kept per ``dots_with_no_batch_dims_saveable``).
    """
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    have_state = states is not None

    def group_step(carry, inp):
        from repro.distributed.sharding import constrain_activation
        x, aux = carry
        x = constrain_activation(x)   # pin batch sharding across the scan
        gparams, gstate, gidx = inp
        gparams = dequant_tree(gparams, x.dtype)   # no-op unless int8 leaves
        new_gstate = {}
        for i, kind in enumerate(pat):
            key = f"b{i}_{kind}"
            st = gstate[key] if have_state else None
            salt = gidx * len(pat) + i
            x, ns, a = _apply_block(x, gparams[key], kind, cfg,
                                    positions=positions,
                                    prefix_len=prefix_len, state=st,
                                    cache_len=cache_len, fi=fi, salt=salt)
            new_gstate[key] = ns if have_state else jnp.zeros((0,))
            aux = aux + a
        return (x, aux), new_gstate

    new_states = {}
    aux_total = jnp.zeros((), jnp.float32)
    if n_groups:
        if have_state:
            gstates = states["groups"]
        else:
            gstates = {f"b{i}_{kind}": jnp.zeros((n_groups, 0))
                       for i, kind in enumerate(pat)}
        step_fn = group_step
        if remat:
            step_fn = jax.checkpoint(
                group_step,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        (x, aux_total), scanned_states = jax.lax.scan(
            step_fn, (x, aux_total),
            (params["groups"], gstates, jnp.arange(n_groups)))
        if have_state:
            new_states["groups"] = scanned_states
    for t, tp in enumerate(params.get("tail", [])):
        tp = dequant_tree(tp, x.dtype)
        (key,) = tp.keys()
        kind = key.split("_", 1)[1]
        st = states["tail"][t][key] if have_state else None
        x, ns, a = _apply_block(x, tp[key], kind, cfg, positions=positions,
                                prefix_len=prefix_len, state=st,
                                cache_len=cache_len, fi=fi,
                                salt=n_groups * len(pat) + t)
        aux_total = aux_total + a
        if have_state:
            new_states.setdefault("tail", []).append({key: ns})
    return x, (new_states if have_state else None), aux_total


# --------------------------------------------------------------------------- #
# public API
# --------------------------------------------------------------------------- #
def embed_tokens(params, cfg: ModelConfig, tokens, prefix_embeds=None,
                 dtype=jnp.bfloat16, with_prefix=True):
    emb = params["embed"]
    if _is_qleaf(emb):        # gather int8 rows, dequantise the slice only
        x = emb["int8_q"][tokens].astype(dtype) \
            * emb["int8_s"][tokens].astype(dtype)
    else:
        x = emb[tokens]
    if cfg.scale_embeds:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.prefix_tokens and with_prefix:
        assert prefix_embeds is not None
        proj = dequant_tree({"p": params["prefix_proj"]}, x.dtype)["p"]
        pe = op_linear(prefix_embeds.astype(x.dtype), proj, "embed")
        x = jnp.concatenate([pe, x], axis=1)
    # serve mesh: the gather from a vocab-sharded table psums exact zeros —
    # pin the result replicated so downstream ops see full activations
    return constrain_replicated(x)


def unembed(params, cfg: ModelConfig, x):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    w = dequant_tree({"w": w}, x.dtype)["w"]
    if cfg.tie_embeddings:
        w = w.T
    return constrain_replicated((x @ w).astype(jnp.float32))


def forward_logits(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
                   fi: Optional[FaultConfig] = None,
                   states=None, cache_len=None, remat=False):
    """Full-sequence forward (train / prefill).  tokens: (B, S_text)."""
    x = embed_tokens(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, new_states, aux = _run_blocks(
        x, params, cfg, positions=positions, prefix_len=cfg.prefix_tokens,
        states=states, cache_len=cache_len, fi=fi, remat=remat)
    x = norm(x, params["final_norm"], cfg.norm)
    return unembed(params, cfg, x), new_states, aux


def quantize_cache_entry(x):
    """bf16 (B, 1, KV, hd) -> int8 + per-(token, head) scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127) \
        .astype(jnp.int8)
    return {"int8_q": q, "int8_s": s.astype(jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, quantized: bool = False) -> Dict:
    """Decode-state pytree mirroring the grouped param structure.

    ``quantized=True`` stores attention K/V as int8 + per-(token, head)
    scales (§Perf HC3): the cache — the dominant HBM traffic of decode — is
    halved; dequantisation fuses into the attention matmul's operand read.
    """
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail_kinds = _layer_kinds(cfg)[n_groups * len(pat):]

    def one(kind):
        if kind == "attn":
            kv_len = min(max_len, cfg.window) if cfg.window else max_len
            shp = (batch, kv_len, cfg.n_kv_heads, cfg.hd)
            if quantized:
                z = {"int8_q": jnp.zeros(shp, jnp.int8),
                     "int8_s": jnp.zeros(shp[:-1] + (1,), jnp.float32)}
                return {"k": dict(z),
                        "v": {k: jnp.copy(v) for k, v in z.items()}}
            return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        if kind == "rec":
            return rglru_init_state(batch, cfg.d_model, dtype)
        if kind == "rwkv":
            return rwkv_init_state(batch, cfg.d_model, cfg.rwkv_head_dim)
        raise ValueError(kind)

    out: Dict = {}
    if n_groups:
        out["groups"] = {
            f"b{i}_{kind}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one(kind))
            for i, kind in enumerate(pat)}
    if tail_kinds:
        out["tail"] = [{f"b0_{kind}": one(kind)} for kind in tail_kinds]
    return out


def decode_step(params, cfg: ModelConfig, token, cache, cache_len, *,
                fi: Optional[FaultConfig] = None):
    """One decode step.  token: (B, 1) int32; cache_len includes this token.

    For windowed attention the cache is ring-indexed by the caller keeping
    ``cache_len <= window`` (the serve engine rolls it); here we index
    directly — correct for cache_len within capacity.

    ``cache_len`` is a scalar (static-batch decode: every row at the same
    depth) or a ``(B,)`` vector of per-row depths — the continuous-batching
    slot path, where each slot decodes at its own position and ring-writes
    its own cache row.  An all-equal vector is bit-identical to the scalar.
    """
    x = embed_tokens(params, cfg, token, with_prefix=False)
    if jnp.ndim(cache_len) == 0:
        positions = jnp.full((1, 1), cache_len - 1, jnp.int32)
    else:
        positions = (cache_len - 1).astype(jnp.int32)[:, None]    # (B, 1)
    x, new_cache, _ = _run_blocks(x, params, cfg, positions=positions,
                                  states=cache, cache_len=cache_len, fi=fi)
    x = norm(x, params["final_norm"], cfg.norm)
    return unembed(params, cfg, x), new_cache
