"""Shared model layers with per-operator fault-injection hooks.

Every matmul in the zoo flows through :func:`op_linear` /
:func:`op_batched_matmul`, tagged with its operator-domain name (the paper's
Table II rows).  With a :class:`FaultConfig` attached, the op is executed the
way the paper's accelerator executes it — int8 systolic matmul + BER
bit-error injection at that operator's current admitted BER (from
``repro.core.runtime``).  Without one (training / dry-run) it is a clean
dense op, keeping the lowered HLO free of simulation artefacts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (constrain_replicated,
                                        serve_shard_map_info)
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-operator error-injection config for serving-time evaluation.

    Randomness enters the weight matmuls as *seeds*, not materialised
    random arrays: :meth:`seed_for` hashes (base key, operator, salt, step)
    down to an int32 scalar that the fused kernel's in-core PRNG expands
    in-register.  ``fused=False`` routes through the legacy three-pass
    injection (kept as the oracle path); the batched qkt/sv activation
    matmuls always use it (:func:`op_batched_matmul` has no 2-D tiling to
    fuse into).

    The config is a registered pytree: the BERs, key, per-op seed bases and
    ``step`` are *leaves*, so it enters jitted serve steps as a traced
    argument — advancing device age (new BER values) or the decode position
    (new ``step``) re-jits nothing.  :meth:`for_step` folds a scan index
    into every stream, giving each generated token its own deterministic
    upsets per (call, operator, step); inside ``lax.scan`` the fold is pure
    in-trace integer mixing (:func:`repro.kernels.ops.fold_seed` on the
    fused path), with no materialised randoms and no per-step retrace.
    """
    bers: Dict[str, jax.Array]          # op -> BER (scalar or (S,) per-shard)
    key: jax.Array                      # base PRNG key
    seeds: Optional[Dict[str, jax.Array]] = None  # op -> int32 stream base
    step: jax.Array | int = 0           # decode-step index (folded in-trace)
    use_systolic_kernel: bool = True    # int8 Pallas path for weight matmuls
    fused: bool = True                  # single-pass in-kernel injection

    def ber_for(self, op: str):
        return self.bers.get(op, jnp.float32(0.0))

    def for_step(self, step) -> "FaultConfig":
        """This config at decode step ``step`` (traced-safe, zero retrace)."""
        return dataclasses.replace(self, step=step)

    def with_seeds(self) -> "FaultConfig":
        """Precompute the per-operator int32 stream bases.

        Call *outside* the decode scan (the serve engine does, once per
        generate call): ``seed_for`` then derives the per-(salt, step)
        stream with two integer mixes instead of a threefry chain, keeping
        the scanned decode body free of per-token key hashing.
        """
        seeds = {op: kops.seed_from_key(jax.random.fold_in(
            self.key, _op_salt(op))) for op in self.bers}
        return dataclasses.replace(self, seeds=seeds)

    def key_for(self, op: str, salt) -> jax.Array:
        k = jax.random.fold_in(self.key, _op_salt(op))
        k = jax.random.fold_in(k, salt)
        return jax.random.fold_in(k, self.step)

    def seed_for(self, op: str, salt) -> jax.Array:
        """int32 seed for the fused kernel's per-tile PRNG streams."""
        base = (self.seeds or {}).get(op)
        if base is None:      # no precomputed base: hash the key path down
            base = kops.seed_from_key(jax.random.fold_in(
                self.key, _op_salt(op)))
        return kops.fold_seed(base, salt, self.step)


jax.tree_util.register_dataclass(
    FaultConfig, data_fields=("bers", "key", "seeds", "step"),
    meta_fields=("use_systolic_kernel", "fused"))


_OP_IDS = {op: i for i, op in enumerate(
    ("q", "k", "v", "qkt", "sv", "o", "gate", "up", "down", "router",
     "embed", "head", "r", "g", "w", "conv"))}


def _op_salt(op: str) -> int:
    return _OP_IDS.get(op, 31)


def op_linear(x: jax.Array, w: jax.Array, op: str,
              fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    """``x (..., K) @ w (K, N)`` through the operator domain ``op``.

    Outputs pass :func:`~repro.distributed.sharding.constrain_replicated`
    — a no-op except under a serve-mesh scope, where pinning every op
    boundary replicated over "model" keeps the sharded graph bit-exact.
    A per-shard ``(S,)`` BER vector in ``fi`` flips each output-column
    block at its own shard's admitted rate with counter streams keyed on
    ``fold_seed(seed_for(op, salt), shard)``.  When a serve mesh is in
    scope (``serve_shard_map_info``) and the fused-kernel flags are on, the
    matmul is shard_mapped so each shard runs the ONE fused Pallas kernel
    on its local column block; otherwise the bit-identical kernel-free
    GSPMD route runs (see ``aged_linear`` — routing is performance-only).
    """
    if fi is None:
        return constrain_replicated(x @ w)
    ber = fi.ber_for(op)
    if jnp.ndim(ber) == 1:
        mesh = axis = None
        if fi.fused and fi.use_systolic_kernel:
            info = serve_shard_map_info(w.shape[-1])
            if info is not None and info[2] == int(ber.shape[0]):
                mesh, axis = info[0], info[1]
        return constrain_replicated(kops.aged_linear(
            x, w, ber=ber, seed=fi.seed_for(op, salt),
            use_kernel=fi.use_systolic_kernel, fused=fi.fused,
            shard_axis=axis, mesh=mesh))
    if fi.fused and fi.use_systolic_kernel:
        return constrain_replicated(kops.aged_linear(
            x, w, ber=ber, seed=fi.seed_for(op, salt),
            use_kernel=True, fused=True))
    # legacy routes keep the full 64-bit key stream (pre-fused behaviour)
    return constrain_replicated(kops.aged_linear(
        x, w, ber=ber, key=fi.key_for(op, salt),
        use_kernel=fi.use_systolic_kernel, fused=False))


def op_einsum(spec: str, x: jax.Array, w: jax.Array, op: str,
              fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    """Einsum variant for fused head layouts; falls back to 2-D for faults.

    Supports specs whose contraction letters form a *suffix* of the x spec
    and a *prefix* of the w spec (all uses here: "bsd,dhk->bshk",
    "bshk,hkd->bsd") — the faulted path flattens both to one 2-D systolic
    matmul, matching how the accelerator executes the fused layout.
    """
    if fi is None:
        return constrain_replicated(jnp.einsum(spec, x, w))
    ins, out_spec = spec.split("->")
    x_spec, w_spec = ins.split(",")
    contract = [c for c in x_spec if c in w_spec]
    nc = len(contract)
    assert x_spec[-nc:] == w_spec[:nc] == "".join(contract), spec
    k = 1
    for d in w.shape[:nc]:
        k *= d
    x2 = x.reshape(*x.shape[:x.ndim - nc], k)
    w2 = w.reshape(k, -1)
    out = op_linear(x2, w2, op, fi, salt)
    return out.reshape(*x.shape[:x.ndim - nc], *w.shape[nc:])


def op_batched_matmul(a: jax.Array, b: jax.Array, op: str,
                      fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    """Activation x activation matmul (QK^T / SV domains): ``a @ b`` over
    leading batch dims, int8-quantised with accumulator upsets when faulted.

    Scalar BER keeps the historical stream (Pallas injection on the kernel
    path, its bit-exact jnp oracle otherwise — identical outputs either
    way).  A per-shard ``(S,)`` BER vector maps shards onto the flattened
    head axis (shard ``s`` owns heads ``[s*H//S, (s+1)*H//S)`` — the heads
    whose projections it owns in the serve layout) with shard-distinct
    fmix32 streams.
    """
    if fi is None:
        return constrain_replicated(a @ b)
    aq, ascale = kops.quantize_int8(a, axis=-1)
    bq, bscale = kops.quantize_int8(b, axis=-2)
    acc = jnp.einsum("...ik,...kj->...ij", aq.astype(jnp.int32),
                     bq.astype(jnp.int32))
    ber = fi.ber_for(op)
    if jnp.ndim(ber) == 1:
        # (B, *heads, M, N) -> (B, H, M, N): blocks of flattened heads,
        # counter streams (matches op_linear's sharded seed plumbing — no
        # threefry chain inside the decode scan)
        flat = acc.reshape(acc.shape[0], -1, *acc.shape[-2:])
        flat = kops.inject_bitflips_sharded(flat, ber,
                                            seed=fi.seed_for(op, salt),
                                            axis=1)
        acc = flat.reshape(acc.shape)
    elif fi.use_systolic_kernel:
        acc = kops.inject_bitflips(acc, ber, fi.key_for(op, salt))
    else:
        acc = kops.inject_bitflips_ref(acc, ber, fi.key_for(op, salt))
    return constrain_replicated(
        (acc.astype(jnp.float32) * ascale * bscale).astype(a.dtype))


# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * scale


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


def norm(x: jax.Array, p: Dict, kind: str) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p.get("bias"))


def init_norm(kind: str, d: int, dtype) -> Dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# --------------------------------------------------------------------------- #
def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / (d // 2)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
def mlp_apply(x: jax.Array, p: Dict, variant: str,
              fi: Optional[FaultConfig] = None, salt=0) -> jax.Array:
    if variant == "gated":
        g = op_linear(x, p["w_gate"], "gate", fi, salt)
        u = op_linear(x, p["w_up"], "up", fi, salt)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(op_linear(x, p["w_up"], "up", fi, salt))
    return op_linear(h, p["w_down"], "down", fi, salt)


def mlp_init(key, d: int, f: int, variant: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"w_up": jax.random.normal(k2, (d, f), dtype) * s_in,
         "w_down": jax.random.normal(k3, (f, d), dtype) * s_out}
    if variant == "gated":
        p["w_gate"] = jax.random.normal(k1, (d, f), dtype) * s_in
    return p
