"""Griffin/RecurrentGemma recurrent block: causal conv1d + RG-LRU.

RG-LRU (arXiv:2402.19427):

    r_t = sigmoid(x_t W_a);  i_t = sigmoid(x_t W_x)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is diagonal-linear, so training/prefill uses
``jax.lax.associative_scan`` (O(log S) depth — TPU-friendly) and decode is a
single fused update.  The block is Griffin's: two branches (gate: GeLU;
recurrent: conv1d(4) -> RG-LRU), multiplied, projected back.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import FaultConfig, op_linear

C_RGLRU = 8.0
CONV_W = 4


def rglru_init(key, d: int, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, d), dtype) * s,     # input proj
        "w_gate": jax.random.normal(ks[1], (d, d), dtype) * s,  # gate branch
        "w_out": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_a": jax.random.normal(ks[3], (d, d), dtype) * s,     # recurrence gate
        "w_i": jax.random.normal(ks[4], (d, d), dtype) * s,     # input gate
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (d,), jnp.float32, 0.7, 1.3)),
        "conv_w": jnp.zeros((CONV_W, d), dtype).at[-1].set(1.0),
        "conv_b": jnp.zeros((d,), dtype),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
            state: Optional[jax.Array] = None):
    """Causal depthwise conv, width CONV_W.  x: (B, S, d).

    ``state``: (B, CONV_W-1, d) trailing inputs from the previous segment
    (decode); returns (y, new_state).
    """
    B, S, d = x.shape
    if state is None:
        state = jnp.zeros((B, CONV_W - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+3, d)
    y = sum(xp[:, i:i + S] * w[i] for i in range(CONV_W)) + b
    return y, xp[:, -(CONV_W - 1):]


def _rglru_scan(xin: jax.Array, a: jax.Array,
                h0: Optional[jax.Array] = None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t via assoc. scan."""
    b = xin
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(x: jax.Array, p: Dict, *, state: Optional[Dict] = None,
                fi: Optional[FaultConfig] = None, salt=0
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d) -> (B, S, d); state carries (conv, h) across segments."""
    gate = jax.nn.gelu(op_linear(x, p["w_gate"], "g", fi, salt))
    u = op_linear(x, p["w_x"], "v", fi, salt)
    conv_state = state["conv"] if state else None
    u, new_conv = _conv1d(u, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(op_linear(u, p["w_a"], "r", fi, salt)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(op_linear(u, p["w_i"], "k", fi, salt)
                       .astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    xin = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * u.astype(jnp.float32))

    h0 = state["h"] if state else None
    if x.shape[1] == 1 and state is not None:           # decode fast path
        h = a[:, 0] * h0 + xin[:, 0]
        hs = h[:, None]
    else:
        hs = _rglru_scan(xin, a, h0)
        h = hs[:, -1]
    out = op_linear(hs.astype(x.dtype) * gate, p["w_out"], "o", fi, salt)
    new_state = {"conv": new_conv, "h": h} if state is not None else None
    return out, new_state


def rglru_init_state(batch: int, d: int, dtype) -> Dict:
    return {"conv": jnp.zeros((batch, CONV_W - 1, d), dtype),
            "h": jnp.zeros((batch, d), jnp.float32)}
