"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

``input_specs`` provides precomputed frame embeddings (B, encoder_seq, d) —
the paper's AVS technique concerns the matmul operator domains, which the
conv frontend does not add to (it is a fixed preprocessing stage on the
paper's accelerator too).  Encoder: bidirectional attention + plain-GELU
MLP, sinusoidal positions.  Decoder: causal self-attention + cross-attention
into the encoder output + MLP, learned positions.  Both stacks scan over
stacked layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.distributed.sharding import constrain_replicated
from . import attention as attn_lib
from .layers import (FaultConfig, init_norm, layer_norm, mlp_apply, mlp_init,
                     norm, op_einsum, sinusoid_positions)
from .transformer import _attn_init, unembed

MAX_DEC_POS = 8192  # learned decoder position table (paper backbone stub)

# Dry-run cost probes: fully unroll the layer scans so XLA cost_analysis
# (which counts a scan body once) sees every layer (repro.launch.dryrun).
PROBE_UNROLL = False


def _scan(f, init, xs, n: int):
    return jax.lax.scan(f, init, xs, unroll=n if PROBE_UNROLL else 1)


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": init_norm(cfg.norm, d, dtype),
                "attn": _attn_init(k1, cfg, dtype),
                "norm2": init_norm(cfg.norm, d, dtype),
                "ffn": mlp_init(k2, d, cfg.d_ff, cfg.mlp, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": init_norm(cfg.norm, d, dtype),
                "self_attn": _attn_init(k1, cfg, dtype),
                "norm_x": init_norm(cfg.norm, d, dtype),
                "cross_attn": _attn_init(k2, cfg, dtype),
                "norm2": init_norm(cfg.norm, d, dtype),
                "ffn": mlp_init(k3, d, cfg.d_ff, cfg.mlp, dtype)}

    return {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), dtype) * 0.02,
        "dec_pos": jax.random.normal(keys[1], (MAX_DEC_POS, d), dtype) * 0.01,
        "enc_layers": jax.vmap(enc_layer)(
            jax.random.split(keys[2], cfg.n_encoder_layers)),
        "dec_layers": jax.vmap(dec_layer)(
            jax.random.split(keys[3], cfg.n_layers)),
        "enc_final": init_norm(cfg.norm, d, dtype),
        "final_norm": init_norm(cfg.norm, d, dtype),
        "lm_head": jax.random.normal(keys[4], (d, cfg.vocab),
                                     dtype) * d ** -0.5,
    }


def _self_attn(h, ap, cfg, *, causal, fi=None, salt=0, cache=None,
               cache_len=None):
    q = op_einsum("bsd,dhk->bshk", h, ap["wq"], "q", fi, salt)
    k = op_einsum("bsd,dhk->bshk", h, ap["wk"], "k", fi, salt)
    v = op_einsum("bsd,dhk->bshk", h, ap["wv"], "v", fi, salt)
    new_cache = None
    if cache is not None and q.shape[1] == 1:
        idx = cache_len - 1
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 idx, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 idx, 1)
        out = attn_lib.decode_attention(q, kc, vc, cache_len, fi=fi,
                                        salt=salt)
        new_cache = {"k": kc, "v": vc}
    elif cache is not None:
        # prefill-with-cache: run full attention AND stash the prompt's
        # K/V in slots [0, S) so subsequent decode steps attend over the
        # prompt (learned positions are applied pre-projection, so raw
        # K/V slots are position-correct)
        S = k.shape[1]
        pad = cache["k"].shape[1] - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0),
                         (0, 0))).astype(cache["k"].dtype)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0),
                         (0, 0))).astype(cache["v"].dtype)
        out = attn_lib.attention(q, k, v, causal=causal, fi=fi, salt=salt)
        new_cache = {"k": kc, "v": vc}
    else:
        out = attn_lib.attention(q, k, v, causal=causal, fi=fi, salt=salt)
    return out, new_cache


def _cross_attn(h, enc_kv, ap, cfg, *, fi=None, salt=0):
    q = op_einsum("bsd,dhk->bshk", h, ap["wq"], "q", fi, salt)
    out = attn_lib.attention(q, enc_kv["k"], enc_kv["v"], causal=False,
                             fi=fi, salt=salt)
    return out


def encode(params, cfg: ModelConfig, frames, *,
           fi: Optional[FaultConfig] = None, remat: bool = False):
    """frames: (B, S_enc, d) precomputed frame embeddings (stub frontend)."""
    x = frames.astype(params["embed"].dtype)
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)

    def step(carry, lp):
        x = carry
        h = norm(x, lp["norm1"], cfg.norm)
        out, _ = _self_attn(h, lp["attn"], cfg, causal=False, fi=fi)
        x = x + op_einsum("bshk,hkd->bsd", out, lp["attn"]["wo"], "o", fi)
        h2 = norm(x, lp["norm2"], cfg.norm)
        return x + mlp_apply(h2, lp["ffn"], cfg.mlp, fi), None

    if remat:
        step = jax.checkpoint(
            step, policy=jax.checkpoint_policies
            .dots_with_no_batch_dims_saveable)
    x, _ = _scan(step, x, params["enc_layers"], cfg.n_encoder_layers)
    return norm(x, params["enc_final"], cfg.norm)


def cross_kv(params, cfg: ModelConfig, enc_out, *,
             fi: Optional[FaultConfig] = None):
    """Precompute per-decoder-layer cross-attention K/V (stacked (L, ...))."""
    def one(lp):
        ap = lp["cross_attn"]
        k = op_einsum("bsd,dhk->bshk", enc_out, ap["wk"], "k", fi)
        v = op_einsum("bsd,dhk->bshk", enc_out, ap["wv"], "v", fi)
        return {"k": k, "v": v}
    return jax.vmap(one)(params["dec_layers"])


def decode(params, cfg: ModelConfig, tokens, enc_out=None, kv=None, *,
           fi: Optional[FaultConfig] = None, cache=None, cache_len=None,
           pos_offset=0, remat: bool = False):
    """Teacher-forced decoder (full seq) or single-step (with cache)."""
    if kv is None:
        kv = cross_kv(params, cfg, enc_out, fi=fi)
    x = params["embed"][tokens]
    S = tokens.shape[1]
    pos = jnp.arange(S) + pos_offset
    x = x + params["dec_pos"][pos][None]

    def step(carry, inp):
        x = carry
        lp, lkv, lcache, lidx = inp
        h = norm(x, lp["norm1"], cfg.norm)
        out, new_c = _self_attn(h, lp["self_attn"], cfg, causal=True, fi=fi,
                                salt=lidx, cache=lcache if cache else None,
                                cache_len=cache_len)
        x = x + op_einsum("bshk,hkd->bsd", out, lp["self_attn"]["wo"], "o",
                          fi, lidx)
        hx = norm(x, lp["norm_x"], cfg.norm)
        xo = _cross_attn(hx, lkv, lp["cross_attn"], cfg, fi=fi, salt=lidx)
        x = x + op_einsum("bshk,hkd->bsd", xo, lp["cross_attn"]["wo"], "o",
                          fi, lidx)
        h2 = norm(x, lp["norm2"], cfg.norm)
        x = x + mlp_apply(h2, lp["ffn"], cfg.mlp, fi, lidx)
        return x, (new_c if cache else jnp.zeros((0,)))

    dummy_cache = cache if cache is not None else \
        {"k": jnp.zeros((cfg.n_layers, 0)), "v": jnp.zeros((cfg.n_layers, 0))}
    if remat:
        step = jax.checkpoint(
            step, policy=jax.checkpoint_policies
            .dots_with_no_batch_dims_saveable)
    x, new_cache = _scan(
        step, x, (params["dec_layers"], kv, dummy_cache,
                  jnp.arange(cfg.n_layers)), cfg.n_layers)
    x = norm(x, params["final_norm"], cfg.norm)
    logits = constrain_replicated(
        (x @ params["lm_head"]).astype(jnp.float32))
    return logits, (new_cache if cache is not None else None)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
