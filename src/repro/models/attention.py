"""Attention: GQA/MQA, flash-style chunked online-softmax, sliding window,
decode-with-KV-cache.  Pure JAX (lax.scan) — TPU-idiomatic chunking bounds
activation memory for 32k prefill without a custom kernel, and the grouped
einsum form never materialises repeated KV heads.

Shapes: q (B, S, H, hd) grouped as (B, S, KV, G, hd) with G = H // KV;
k/v (B, S, KV, hd).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import FaultConfig, op_batched_matmul

NEG_INF = -1e30

# Dry-run cost probes set this: forces the single-block (non-scanned)
# attention path so XLA cost_analysis — which counts a lax.scan body ONCE,
# ignoring trip count — sees every FLOP (see repro.launch.dryrun.probe_mode).
FORCE_SINGLE_CHUNK = False

# EXPERIMENTS.md §Perf HC3: skip fully-masked (future) KV chunks in causal
# chunked attention.  The naive loop computes all nq x nk chunk pairs — at
# 32k prefill that is 2x the causal work (plus window waste).  With the
# flag on, the KV scan only visits chunks that intersect the mask, bounding
# the inner trip count per query chunk.  Off by default: baselines measure
# the naive cost.
CAUSAL_CHUNK_SKIP = False


def _mask(q_pos, k_pos, causal: bool, window: Optional[int],
          prefix_len: int = 0, kv_valid: Optional[int] = None):
    """(Sq, Sk) boolean mask; True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        cm = q_pos[:, None] >= k_pos[None, :]
        if prefix_len:
            cm = cm | ((q_pos[:, None] < prefix_len)
                       & (k_pos[None, :] < prefix_len))
        m &= cm
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if kv_valid is not None:
        m &= (k_pos < kv_valid)[None, :]
    return m


def full_attention(q, k, v, *, causal: bool = True,
                   window: Optional[int] = None, prefix_len: int = 0,
                   q_offset: int = 0,
                   fi: Optional[FaultConfig] = None, salt=0):
    """Reference path for modest S (and the faulted QK^T / SV domains)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd) * (hd ** -0.5)
    # scores: (B, KV, G, Sq, Sk)
    qt = qg.transpose(0, 2, 3, 1, 4)                   # B KV G Sq hd
    kt = k.transpose(0, 2, 3, 1)                       # B KV hd Sk
    scores = op_batched_matmul(qt, kt[:, :, None], "qkt", fi, salt)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, causal, window, prefix_len)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    vt = v.transpose(0, 2, 1, 3)                       # B KV Sk hd
    out = op_batched_matmul(probs, vt[:, :, None], "sv", fi, salt)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, prefix_len: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 512):
    """Flash-style two-level chunked attention (online softmax).

    Outer scan over query chunks, inner scan over KV chunks carrying
    (running max, denominator, accumulator).  Peak activation is
    O(q_chunk * kv_chunk) per head — 32k x 32k never materialises.
    Causality is enforced by masking (the masked upper blocks still lower
    as FLOPs; see EXPERIMENTS.md §Roofline for the accounting and §Perf for
    the mitigation).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    q_chunk, kv_chunk = min(q_chunk, S), min(kv_chunk, Sk)
    # pad to chunk multiples: padded query rows are sliced off at the end;
    # padded key columns are masked via kv_valid
    pad_q, pad_k = (-S) % q_chunk, (-Sk) % kv_chunk
    kv_valid = Sk if pad_k else None
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (S + pad_q) // q_chunk, (Sk + pad_k) // kv_chunk
    qg = (q * (hd ** -0.5)).reshape(B, nq, q_chunk, KV, G, hd)
    kg = k.reshape(B, nk, kv_chunk, KV, hd)
    vg = v.reshape(B, nk, kv_chunk, KV, hd)

    def q_step(_, qi):
        qc, qidx = qi                                   # (B,qc,KV,G,hd), ()
        q_pos = qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kc, vc, kidx = ki
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc,
                           preferred_element_type=jnp.float32)
            mask = _mask(q_pos, k_pos, causal, window, prefix_len, kv_valid)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(qc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32))
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_step, init,
            (kg.transpose(1, 0, 2, 3, 4), vg.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        q_step, None, (qg.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)))
    # outs: (nq, B, KV, G, qc, hd) -> (B, S(+pad), H, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S + pad_q, H, hd)
    return out[:, :S]


def attention(q, k, v, *, causal=True, window=None, prefix_len=0,
              fi: Optional[FaultConfig] = None, salt=0,
              chunk_threshold: int = 2048):
    """Dispatch: chunked for long sequences, full (faultable) otherwise."""
    if fi is None and q.shape[1] >= chunk_threshold \
            and not FORCE_SINGLE_CHUNK:
        qc = min(512, q.shape[1])
        kc = min(512, k.shape[1])
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 prefix_len=prefix_len, q_chunk=qc,
                                 kv_chunk=kc)
    return full_attention(q, k, v, causal=causal, window=window,
                          prefix_len=prefix_len, fi=fi, salt=salt)


def decode_attention(q1, k_cache, v_cache, cache_len, *,
                     fi: Optional[FaultConfig] = None, salt=0):
    """Single-token decode vs a (B, S_max, KV, hd) cache.

    The cache is a *ring buffer*: token t occupies slot ``t % S_max``, so for
    windowed attention (``S_max == window``) every slot is valid once
    ``cache_len >= S_max`` — the ring holds exactly the attention window.
    Attention is permutation-invariant over KV entries, so slot order does
    not matter; RoPE is applied at absolute positions before caching.

    ``cache_len`` may be a scalar (whole batch at one depth — the classic
    static-batch decode) or a ``(B,)`` vector of per-row depths (the
    continuous-batching slot path: every slot attends over its own ragged
    prefix).  A vector whose entries are all equal masks exactly like the
    scalar — the two paths are bit-identical.
    """
    B, _, H, hd = q1.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    qg = (q1 * (hd ** -0.5)).reshape(B, 1, KV, G, hd).transpose(0, 2, 3, 1, 4)
    kt = k_cache.transpose(0, 2, 3, 1)                 # B KV hd S
    s = op_batched_matmul(qg, kt[:, :, None], "qkt", fi, salt)  # B KV G 1 S
    pos = jnp.arange(S)
    if jnp.ndim(cache_len) == 0:
        valid = (pos < jnp.minimum(cache_len, S))[None, None, None, None]
    else:                                              # per-row (ragged) depths
        valid = (pos[None, :] < jnp.minimum(cache_len, S)[:, None]
                 )[:, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q1.dtype)
    vt = v_cache.transpose(0, 2, 1, 3)                 # B KV S hd
    out = op_batched_matmul(p, vt[:, :, None], "sv", fi, salt)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd)
