"""Assigned input-shape cells (seq_len x global_batch) and applicability."""
from __future__ import annotations

import dataclasses

from . import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the brief's applicability rules."""
    if cell.name == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: 500k decode state is "
                       "unbounded (quadratic attention / O(S) KV cache); "
                       "run only for SSM/hybrid archs per the brief")
    return True, ""
