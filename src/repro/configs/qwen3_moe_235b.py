"""Qwen3-MoE 235B-A22B-class: 128 experts top-8, GQA kv=4, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from . import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8),
    mlp="gated", norm="rms", pos="rope", qk_norm=True, rope_theta=1e6,
)
