"""Whisper large-v3 backbone: enc-dec transformer; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings). [arXiv:2212.04356]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    n_encoder_layers=32, encoder_seq=1500,
    mlp="plain", norm="ln", pos="learned",
)
