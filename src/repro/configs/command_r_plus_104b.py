"""Command R+ 104B: GQA kv=8, no-bias LayerNorm, huge vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000,
    mlp="gated", norm="ln", pos="rope", tie_embeddings=True,
)
