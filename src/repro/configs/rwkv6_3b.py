"""RWKV6 (Finch) 3B: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536,
    block_pattern=("rwkv",), mlp="plain", norm="ln", pos="none",
    rwkv_head_dim=64, long_context_ok=True,
    notes="Matrix-valued state per head; O(1) decode state (500k cell runs).",
)
