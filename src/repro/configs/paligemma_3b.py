"""PaliGemma-3B backbone: gemma-2b decoder + SigLIP STUB frontend
(input_specs provides 256 precomputed patch embeddings). [arXiv:2407.07726]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    prefix_tokens=256, tie_embeddings=True, scale_embeds=True,
    mlp="gated", norm="rms", pos="rope",
    notes="Prefix (image) tokens attend bidirectionally; text causal.",
)
