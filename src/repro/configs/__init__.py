"""Architecture configs: 10 assigned archs + the paper's LLaMA-3-8B case study.

Each ``<arch>.py`` exports ``CONFIG`` (exact dims from the public source) —
select with ``--arch <id>`` in the launchers.  ``reduced()`` yields a small
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    mlp: str = "gated"               # gated (SwiGLU) | plain (GELU)
    norm: str = "rms"                # rms | ln
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    block_pattern: Tuple[str, ...] = ("attn",)   # hybrid: ("rec","rec","attn")
    window: Optional[int] = None     # sliding-window attention size
    n_encoder_layers: int = 0        # enc-dec (whisper)
    encoder_seq: int = 1500          # stub frame-embedding length
    prefix_tokens: int = 0           # vlm: stub patch-embedding prefix
    rwkv_head_dim: int = 64
    long_context_ok: bool = False    # constant-size decode state (500k cell)
    scale_embeds: bool = False       # gemma-style sqrt(d) embedding scale
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS roofline)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        mlp_dense = (3 if self.mlp == "gated" else 2) * d * f
        per_layer = 0.0
        n_attn = sum(1 for b in self._pattern_for_all_layers() if b == "attn")
        n_rec = sum(1 for b in self._pattern_for_all_layers() if b == "rec")
        n_rwkv = sum(1 for b in self._pattern_for_all_layers() if b == "rwkv")
        total = 0
        if self.moe:
            moe_mlp = self.moe.n_experts * mlp_dense + d * self.moe.n_experts
            if self.moe.dense_residual:
                moe_mlp += mlp_dense
            total += n_attn * (attn + moe_mlp)
        else:
            total += n_attn * (attn + mlp_dense)
        rec = 3 * d * d + 4 * d + mlp_dense          # rg-lru block + mlp
        total += n_rec * rec
        rwkv = 5 * d * d + 2 * d * 64 + 2 * d * f    # time-mix + channel-mix
        total += n_rwkv * rwkv
        total += self.n_encoder_layers * (attn + mlp_dense)
        if self.n_encoder_layers:                    # decoder cross-attn
            total += self.n_layers * (attn)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return int(total + emb)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D)."""
        if not self.moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_dense = (3 if self.mlp == "gated" else 2) * d * f
        dense_total = self.param_count() - self.n_layers * (
            self.moe.n_experts * mlp_dense)
        return int(dense_total + self.n_layers * self.moe.top_k * mlp_dense)

    def _pattern_for_all_layers(self):
        pat = self.block_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        if self.moe:
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(2, self.moe.top_k),
                                  capacity_factor=self.moe.capacity_factor,
                                  dense_residual=self.moe.dense_residual)
        pat = len(self.block_pattern)
        kw.update(
            n_layers=max(2, pat), d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            else 0,
            d_ff=128, vocab=256, head_dim=16,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq=16, prefix_tokens=8 if self.prefix_tokens else 0,
            window=min(self.window, 16) if self.window else None,
            rwkv_head_dim=8,
        )
        return ModelConfig(**kw)


ARCH_IDS = (
    "arctic_480b", "qwen3_moe_235b", "recurrentgemma_2b", "whisper_large_v3",
    "deepseek_7b", "command_r_plus_104b", "starcoder2_7b", "granite_20b",
    "rwkv6_3b", "paligemma_3b", "llama3_8b",
)


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG
