"""LLaMA-3-8B — the paper's case-study model (Sec. V). [arXiv:2407.21783]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    mlp="gated", norm="rms", pos="rope", rope_theta=5e5,
)
