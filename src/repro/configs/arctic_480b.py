"""Snowflake Arctic 480B: dense-MoE hybrid, 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from . import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True),
    mlp="gated", norm="rms", pos="rope",
    notes="MoE in parallel with a dense residual MLP on every layer.",
)
