"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427; hf]"""
from . import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), window=2048,
    mlp="gated", norm="rms", pos="rope", tie_embeddings=True, scale_embeds=True,
    long_context_ok=True,
    notes="RG-LRU recurrence; local attention window 2048 -> O(1) decode state.",
)
