"""Name-based sharding rules: param pytree path -> PartitionSpec.

Mesh layout (``repro.launch.mesh``): ``("data", "model")`` single-pod,
``("pod", "data", "model")`` multi-pod.  Data parallelism shards the batch
over ``("pod", "data")``; tensor/expert parallelism shards weights over
``"model"``.

Rules are *right-aligned*: a base spec like ``(None, "model", None)`` for
``wq (d, H, hd)`` is padded with leading ``None`` so the same rule covers the
group-stacked form ``(n_groups, d, H, hd)`` produced by the layer scan.

Divisibility-aware fallbacks (recorded in DESIGN.md Sec. 5):

* attention heads ``H % tp != 0`` (arctic 56H, starcoder 36H, whisper 20H,
  paligemma 8H, recurrentgemma 10H): shard the *d_model contraction* side
  instead of the head axis (Megatron-style head sharding needs H % tp == 0);
* GQA ``KV < tp``: KV projections/cache are not KV-sharded — the decode KV
  cache is *sequence*-sharded over ``"model"`` (partial-softmax decode
  attention, the pjit-expressible analogue of ring decode);
* vocab ``V % tp != 0`` (whisper 51866): vocab-parallel head falls back to a
  contraction-sharded head.

Every ``d_ff`` and MoE expert count in the assigned pool divides tp = 16, so
FFN/expert sharding never falls back.

Two layouts share the rule machinery (``layout=`` on :func:`param_pspec`):

* ``"train"`` (default) — the Megatron-style rules above: row-parallel
  ``wo``/``w_down`` contract a sharded dim and rely on a psum, which
  reorders the float reduction.  Maximum-bandwidth, NOT bit-reproducible
  against a single device.
* ``"serve"`` — the exact-TP layout the mesh serving engine uses
  (DESIGN.md §Sharded-Serving): weights shard ONLY on output
  (non-contraction) dims — head axis for ``wq/wk/wv``, ``d_model`` for
  ``wo``/``w_down``, vocab for the (possibly tied) head, the expert axis
  for MoE — and every fallback *replicates* instead of contraction- or
  sequence-sharding.  Activations are pinned replicated over ``"model"``
  at op boundaries (:func:`constrain_replicated` under
  :func:`serve_mesh_scope`), so each shard computes full-contraction
  column slices and every collective is an all-gather: pure data
  movement, no float-reduction reorder.  Sharded generation is therefore
  bit-exact vs the single-device scanned path (locked down by
  ``tests/test_serve_sharded.py``; int8 x int8 -> int32 faulted
  accumulation is associative and stays exact under any split).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig

MODEL_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the batch shards over (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def _tp(mesh: Mesh) -> int:
    return mesh.shape[MODEL_AXIS] if MODEL_AXIS in mesh.axis_names else 1


def _dp(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


# --------------------------------------------------------------------------- #
# parameter rules
# --------------------------------------------------------------------------- #
def _base_spec(name: str, base_ndim: int, cfg: ModelConfig, tp: int):
    """Right-aligned base PartitionSpec entries for one named parameter."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    head_ok = H > 0 and H % tp == 0
    kv_ok = KV > 0 and KV % tp == 0
    vocab_ok = cfg.vocab % tp == 0

    if name == "wq":
        return (None, MODEL_AXIS, None) if head_ok else (MODEL_AXIS, None, None)
    if name in ("wk", "wv"):
        if kv_ok:
            return (None, MODEL_AXIS, None)
        # KV < tp: keep KV whole; shard the d_model contraction side.
        return (MODEL_AXIS, None, None)
    if name == "wo":
        return (MODEL_AXIS, None, None) if head_ok else (None, None, MODEL_AXIS)
    if name in ("w_gate", "w_up"):
        if base_ndim == 3:                       # MoE expert-stacked (E, d, f)
            return (MODEL_AXIS, None, None)
        return (None, MODEL_AXIS)                # dense (d, f)
    if name == "w_down":
        if base_ndim == 3:                       # (E, f, d)
            return (MODEL_AXIS, None, None)
        return (MODEL_AXIS, None)                # (f, d)
    if name == "w_in":                            # rwkv channel-mix (d, f)
        return (None, MODEL_AXIS)
    if name == "w_out":
        # rwkv cm (f, d) & rglru out (d, d): both contract a sharded dim
        return (MODEL_AXIS, None)
    if name in ("w_x", "w_a", "w_i", "w_r", "w_k", "w_v", "w_g"):
        return (None, MODEL_AXIS)                # (d, d) column-parallel
    if name == "w_o":                             # rwkv out proj (d, d)
        return (MODEL_AXIS, None)
    if name == "w_router":
        return (None, None)
    if name == "embed":
        return (None, MODEL_AXIS)                # d always divides tp here
    if name == "lm_head":
        return (None, MODEL_AXIS) if vocab_ok else (MODEL_AXIS, None)
    if name in ("prefix_proj", "dec_pos"):
        return (None, MODEL_AXIS)
    return None                                   # replicate (norms, vectors…)


def _serve_base_spec(name: str, base_ndim: int, cfg: ModelConfig, tp: int):
    """Exact-TP serve layout: shard output dims only, replicate fallbacks.

    Returning ``None`` replicates the leaf.  Divisibility of the chosen
    dim is re-checked generically in :func:`param_pspec` (mismatch ->
    replicate), so e.g. ``wo (H, hd, d)`` only d-shards when d % tp == 0.
    """
    H, KV = cfg.n_heads, cfg.n_kv_heads
    head_ok = H > 0 and H % tp == 0
    kv_ok = KV > 0 and KV % tp == 0
    vocab_ok = cfg.vocab % tp == 0

    if name == "wq":
        return (None, MODEL_AXIS, None) if head_ok else None
    if name in ("wk", "wv"):
        return (None, MODEL_AXIS, None) if kv_ok else None
    if name == "wo":                              # (H, hd, d): output d
        return (None, None, MODEL_AXIS)
    if name in ("w_gate", "w_up"):
        if base_ndim == 3:                        # MoE (E, d, f): experts
            return (MODEL_AXIS, None, None)       # are independent -> exact
        return (None, MODEL_AXIS)
    if name == "w_down":
        if base_ndim == 3:                        # (E, f, d)
            return (MODEL_AXIS, None, None)
        return (None, MODEL_AXIS)                 # (f, d): output d
    if name in ("w_in", "w_x", "w_a", "w_i", "w_r", "w_k", "w_v", "w_g",
                "w_out", "w_o"):
        return (None, MODEL_AXIS)                 # all column-parallel
    if name == "embed":
        # vocab-sharded: the row gather adds zeros from non-owner shards
        # (exact) and the tied unembed becomes column-parallel (exact).
        if vocab_ok:
            return (MODEL_AXIS, None)
        # non-divisible vocab: d-shard the lookup only; a tied head would
        # contract the sharded d -> replicate instead
        return None if cfg.tie_embeddings else (None, MODEL_AXIS)
    if name == "lm_head":
        return (None, MODEL_AXIS) if vocab_ok else None
    if name in ("prefix_proj", "dec_pos"):
        return (None, MODEL_AXIS)
    return None                                   # replicate (norms, router…)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            out.append(e.name)
        elif isinstance(e, jax.tree_util.SequenceKey):
            out.append(f"[{e.idx}]")
        else:
            out.append(str(e))
    return tuple(out)


def param_pspec(path, leaf, cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = False, layout: str = "train") -> P:
    """PartitionSpec for one parameter leaf, by path name + rank.

    ``fsdp=True`` additionally shards every >=2-D weight over the data
    axes (ZeRO-3 style): the first replicated dim that all data axes divide
    gets the data axes.  GSPMD then all-gathers each layer group's weights
    inside the layer scan — parameter+optimizer memory drops by the DP
    degree at the cost of a per-layer weight all-gather (the trade the
    collective roofline term makes visible; required for arctic/qwen3 train
    cells to fit HBM — DESIGN.md Sec. 5).

    ``layout="serve"`` selects the exact-TP rules (:func:`_serve_base_spec`
    — output-dim sharding only, replicated fallbacks), the layout whose
    sharded generation is bit-exact vs a single device.
    """
    tp = _tp(mesh)
    if tp == 1 and not fsdp:
        return P()
    names = _path_names(path)
    name = names[-1]
    if name in ("int8_q", "int8_s") and len(names) >= 2:
        name = names[-2]        # quantised leaf: inherit the weight's rule
    ndim = len(leaf.shape)
    # leading stack axes: "groups" (layer scan) and/or enc/dec_layers (vmap)
    n_stack = sum(1 for n in names if n in ("groups", "enc_layers",
                                            "dec_layers"))
    base_ndim = ndim - n_stack
    rule = _serve_base_spec if layout == "serve" else _base_spec
    base = rule(name, base_ndim, cfg, tp) if tp > 1 else None
    if base is None or len(base) != base_ndim:
        base = (None,) * base_ndim
    # verify divisibility of the sharded dim; replicate on mismatch
    spec = [None] * n_stack + list(base)
    for dim, ax in zip(leaf.shape, spec):
        if ax is not None and dim % tp != 0:
            spec = [None] * ndim
            break
    if fsdp and base_ndim >= 2:
        daxes = data_axes(mesh)
        dp = int(np.prod([mesh.shape[a] for a in daxes]))
        if dp > 1:
            for i in range(n_stack, ndim):
                if spec[i] is None and leaf.shape[i] % dp == 0:
                    spec[i] = daxes if len(daxes) > 1 else daxes[0]
                    break
    if all(ax is None for ax in spec):
        return P()
    return P(*spec)


def param_specs(abstract_params, cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = False, layout: str = "train"):
    """Pytree of PartitionSpec matching an (abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, cfg, mesh, fsdp=fsdp,
                                       layout=layout),
        abstract_params)


def state_specs(abstract_state, cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = False):
    """TrainState specs: params + mirrored opt moments + replicated scalars."""
    def one(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        return param_pspec(path, leaf, cfg, mesh, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(one, abstract_state)


# --------------------------------------------------------------------------- #
# activation / input rules
# --------------------------------------------------------------------------- #
def batch_spec(global_batch: int, mesh: Mesh):
    """Largest prefix of the data axes that divides the global batch."""
    axes = []
    prod = 1
    for a in data_axes(mesh):
        if global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
        else:
            break
    return tuple(axes) if axes else None


def input_shardings(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                    kind: str) -> Dict[str, NamedSharding]:
    """NamedShardings for every model input of a step kind."""
    b = batch_spec(global_batch, mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    out = {"tokens": ns(b, None)}
    if kind == "train":
        out["labels"] = ns(b, None)
    if cfg.prefix_tokens:
        out["prefix_embeds"] = ns(b, None, None)
    if cfg.n_encoder_layers:
        out["frames"] = ns(b, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int):
    """PartitionSpec pytree for the decode cache (matches init_cache).

    Attention KV caches: batch over data axes; KV heads over "model" when
    divisible, otherwise the *sequence* axis is sharded over "model"
    (partial-softmax decode attention).  Recurrent states shard their
    feature axis over "model" when divisible.
    """
    tp = _tp(mesh)
    b = batch_spec(batch, mesh)
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0 and tp > 1

    def attn_spec(stacked: bool):
        lead = (None,) if stacked else ()
        if tp == 1:
            sp = (b, None, None, None)
        elif kv_ok:
            sp = (b, None, MODEL_AXIS, None)
        else:
            sp = (b, MODEL_AXIS, None, None)     # sequence-sharded cache
        return {"k": P(*lead, *sp), "v": P(*lead, *sp)}

    def rec_spec(stacked: bool):
        lead = (None,) if stacked else ()
        d_ok = cfg.d_model % tp == 0 and tp > 1
        ax = MODEL_AXIS if d_ok else None
        return {"conv": P(*lead, b, None, ax), "h": P(*lead, b, ax)}

    def rwkv_spec(stacked: bool):
        lead = (None,) if stacked else ()
        H = cfg.d_model // cfg.rwkv_head_dim
        h_ok = H % tp == 0 and tp > 1
        ax = MODEL_AXIS if h_ok else None
        d_ok = cfg.d_model % tp == 0 and tp > 1
        dax = MODEL_AXIS if d_ok else None
        return {"tm": {"shift": P(*lead, b, dax),
                       "wkv": P(*lead, b, ax, None, None)},
                "cm_shift": P(*lead, b, dax)}

    def one(kind: str, stacked: bool):
        if kind == "attn":
            return attn_spec(stacked)
        if kind == "rec":
            return rec_spec(stacked)
        if kind == "rwkv":
            return rwkv_spec(stacked)
        raise ValueError(kind)

    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    kinds = [pat[i % len(pat)] for i in range(cfg.n_layers)]
    tail_kinds = kinds[n_groups * len(pat):]
    out: Dict[str, Any] = {}
    if n_groups:
        out["groups"] = {f"b{i}_{kind}": one(kind, True)
                         for i, kind in enumerate(pat)}
    if tail_kinds:
        out["tail"] = [{f"b0_{kind}": one(kind, False)} for kind in tail_kinds]
    return out


def encdec_cache_spec(cfg: ModelConfig, mesh: Mesh, batch: int):
    """Whisper decoder self-attn cache (L, B, S, KV, hd)."""
    tp = _tp(mesh)
    b = batch_spec(batch, mesh)
    if tp == 1:
        sp = P(None, b, None, None, None)
    elif cfg.n_kv_heads % tp == 0:
        sp = P(None, b, None, MODEL_AXIS, None)
    else:
        sp = P(None, b, MODEL_AXIS, None, None)
    return {"k": sp, "v": sp}


# --------------------------------------------------------------------------- #
def shard_tree(tree, specs, mesh: Mesh):
    """device_put a pytree according to a spec pytree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


# --------------------------------------------------------------------------- #
# activation sharding constraints (EXPERIMENTS.md §Perf HC2)
# --------------------------------------------------------------------------- #
# GSPMD propagates shardings poorly across scan (while-loop) boundaries: the
# loop-carried activation can silently lose its batch sharding, after which
# every collective in the body operates on the REPLICATED full-batch f32
# tensor (measured: 6.4 GiB single all-reduces in the deepseek train cell).
# Pinning the carry with with_sharding_constraint at each group boundary
# keeps the batch axis sharded through the whole scan — the standard MaxText
# -style fix.  Disabled (None) by default so baselines measure the naive
# behaviour; the dry-run hillclimb variants enable it.
_ACTIVATION_SHARDING: Optional[NamedSharding] = None


def set_activation_sharding(sharding: Optional[NamedSharding]):
    global _ACTIVATION_SHARDING
    _ACTIVATION_SHARDING = sharding


def constrain_activation(x):
    """Apply the configured (batch, None, None) constraint to (B, S, d)."""
    if _ACTIVATION_SHARDING is not None and getattr(x, "ndim", 0) == 3:
        return jax.lax.with_sharding_constraint(x, _ACTIVATION_SHARDING)
    return x


# --------------------------------------------------------------------------- #
# serve-mesh context: exact-TP activation pinning (DESIGN.md §Sharded-Serving)
# --------------------------------------------------------------------------- #
# While a serve mesh is in scope (the MeshServeEngine enters it around the
# trace of its generate function), every op-boundary output in the model
# (op_linear / op_einsum / op_batched_matmul, the embedding gather, the
# unembed, the MoE expert buffers) is pinned REPLICATED over "model" via
# with_sharding_constraint.  Combined with the output-dim-only serve param
# layout this guarantees no float contraction ever spans shards: each
# device computes exact column slices of every matmul and GSPMD's only
# collectives are all-gathers (exact data movement) — the property the
# sharded-vs-single-device bit-exactness tests rely on.  Outside the scope
# (the default) the hook is a no-op, so train/dry-run graphs are untouched.
_SERVE_MESH: Optional[Mesh] = None


def serve_mesh_active() -> Optional[Mesh]:
    """The mesh of the enclosing :func:`serve_mesh_scope`, if any."""
    return _SERVE_MESH


@contextlib.contextmanager
def serve_mesh_scope(mesh: Optional[Mesh]):
    """Trace-time scope enabling the exact-TP activation constraints."""
    global _SERVE_MESH
    prev = _SERVE_MESH
    _SERVE_MESH = mesh
    try:
        yield
    finally:
        _SERVE_MESH = prev


def constrain_replicated(x):
    """Pin ``x`` replicated over the serve mesh (no-op outside the scope)."""
    if _SERVE_MESH is not None and getattr(x, "ndim", 0) >= 1:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_SERVE_MESH, P()))
    return x


def serve_shard_map_info(n_out: int) -> Optional[Tuple[Mesh, str, int]]:
    """Serve-layout axis metadata for the shard_map fused-kernel route.

    Returns ``(mesh, MODEL_AXIS, tp)`` when the enclosing serve mesh can
    shard_map an aged matmul over its ``n_out`` output columns — i.e. a
    serve mesh is in scope, it actually has tensor parallelism, and the
    output dim splits evenly over the axis (each shard's column block is
    then exactly the block :func:`repro.kernels.ops.shard_slices` assigns,
    so the kernel and kernel-free streams line up).  ``None`` means the
    caller must stay on the kernel-free GSPMD route — same streams, so the
    downgrade never changes sampled tokens (see ``aged_linear``).
    """
    mesh = _SERVE_MESH
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return None
    tp = _tp(mesh)
    if tp <= 1 or n_out % tp != 0:
        return None
    return mesh, MODEL_AXIS, tp
