"""Elastic scaling: re-mesh a running job to a different device count.

At 1000+ nodes, device loss is routine: a pod drops out, the scheduler hands
back a different slice.  Elasticity here means the *data* axis is resizable
at a checkpoint boundary without touching the math:

* parameters / optimizer state are data-replicated -> they re-shard to the
  new mesh by ``device_put`` with freshly derived NamedShardings;
* the global batch is preserved by rescaling grad-accumulation microbatches
  (``data * microbatches == const``), so training curves are unchanged;
* the deterministic index-based data pipeline (``repro.data``) is stateless
  per step, so a resumed run on a different DP size reads exactly the same
  global batch for step k.

``plan_remesh`` computes the new layout; ``reshard_state`` applies it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs import ModelConfig
from .sharding import state_specs


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatches: int            # grad-accum steps preserving global batch


def plan_remesh_shape(axis_names: Tuple[str, ...], axis_sizes,
                      new_n_devices: int, *, global_batch: int,
                      old_microbatches: int = 1) -> RemeshPlan:
    """Mesh-free :func:`plan_remesh`: plan from a named shape alone.

    Takes the old layout as ``(axis_names, {name: size})`` instead of a
    live :class:`jax.sharding.Mesh`, so planners that never materialise
    the old mesh — the fleet-retirement co-simulation
    (:mod:`repro.sched.disruption`) runs on one CPU device — can still
    derive the degraded layout.  Semantics are identical: the model (TP)
    axis is pinned by weight shapes, data parallelism absorbs the delta,
    and ``dp * microbatches`` is preserved so the global batch (and the
    training curves) are unchanged.
    """
    names = tuple(axis_names)
    sizes = dict(axis_sizes)
    model = sizes.get("model", 1)
    if new_n_devices % model != 0:
        raise ValueError(f"{new_n_devices} devices not divisible by "
                         f"model={model}")
    new_dp = new_n_devices // model
    old_dp = int(np.prod([sizes[a] for a in names if a != "model"]))
    if global_batch % new_dp != 0:
        # shrink dp to the largest divisor of global_batch
        while new_dp > 1 and global_batch % new_dp != 0:
            new_dp -= 1
    new_micro = max(1, (old_dp * old_microbatches) // new_dp)
    # Preserve EVERY old axis name: steps and batch specs compiled against
    # a ("pod", "data", "model") mesh reference the "pod" axis by name, so
    # dropping it from the plan would make the resharded state unusable
    # without a from-scratch retrace.  The pod axis keeps whole pods when
    # the new DP degree still fills them, else collapses to size 1.
    if "pod" in names:
        per_pod_dp = sizes["data"]
        if new_dp % per_pod_dp == 0:
            new_sizes = {"pod": new_dp // per_pod_dp, "data": per_pod_dp,
                         "model": model}
        else:
            new_sizes = {"pod": 1, "data": new_dp, "model": model}
        new_shape = tuple(new_sizes[a] for a in names)
        new_names = names
    else:
        new_shape = tuple(new_dp if a == "data" else model
                          for a in names if a in ("data", "model"))
        new_names = tuple(a for a in names if a in ("data", "model"))
    return RemeshPlan(tuple(sizes[a] for a in names), new_shape,
                      new_names, new_micro)


def plan_remesh(old_mesh: Mesh, new_n_devices: int, *, global_batch: int,
                old_microbatches: int = 1) -> RemeshPlan:
    """Resize the data axis to fit ``new_n_devices`` (model axis fixed).

    The model (TP) axis is pinned by weight shapes; data parallelism absorbs
    the delta.  Keeps ``dp * microbatch_size`` constant.
    """
    return plan_remesh_shape(
        old_mesh.axis_names, {a: old_mesh.shape[a]
                              for a in old_mesh.axis_names},
        new_n_devices, global_batch=global_batch,
        old_microbatches=old_microbatches)


def make_mesh_from_plan(plan: RemeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(plan.new_shape))
    arr = np.asarray(devices[:n]).reshape(plan.new_shape)
    return Mesh(arr, plan.axis_names)


def reshard_state(state, cfg: ModelConfig, new_mesh: Mesh):
    """Re-place a train/serve state pytree onto a new mesh."""
    specs = state_specs(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        cfg, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        state, specs)
