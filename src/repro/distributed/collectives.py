"""Distributed-optimization collectives: compressed gradient all-reduce.

``tree_psum_compressed`` performs an int8-quantised mean all-reduce of a
gradient pytree across the data axes with an *error-feedback* residual: each
step the un-transmitted quantisation error is carried and added to the next
step's gradient, so the compression bias vanishes over steps (Karimireddy et
al., "Error Feedback Fixes SignSGD").

Implementation: per-leaf symmetric absmax int8 quantisation; the all-reduce
moves 1 byte/element instead of 4 (plus one f32 scale per leaf) — a ~4x
reduction of the DP gradient collective term in the roofline.  The functions
here are called INSIDE a ``shard_map`` body (see
``repro.train.steps.make_dp_train_step``), so the quantised representation
is what actually crosses the mesh.

Compression targets the *data* axes: the parameter sharding already keeps
TP-gradients local to their "model" shard; the inter-pod / inter-replica DP
reduction is the large, latency-tolerant lifetime collective that benefits
from 4x fewer bytes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8_global(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Whole-tensor symmetric absmax int8 quantisation -> (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def psum_compressed_leaf(g: jax.Array, residual: jax.Array,
                         axis_names, n_shards: int):
    """Error-feedback int8 mean-psum of one leaf (inside shard_map).

    Returns ``(mean_grad, new_residual)``.  The residual carries the local
    quantisation error to the next step.
    """
    gf = g.astype(jnp.float32) + residual
    q, scale = quantize_int8_global(gf)
    # int8 payload summed in int32 (shards * 127 << 2^31); per-shard scales
    # averaged.  The residual is taken against the *transmitted*
    # representation q * smean — not the local q * scale — so the
    # shared-scale mismatch enters the feedback loop too; against the local
    # scale it would be a systematic bias the residual never corrects
    # (tests/test_distributed_direct.py locks the convergence down).
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
    smean = jax.lax.psum(scale, axis_names) / n_shards
    new_residual = gf - q.astype(jnp.float32) * smean
    out = qsum.astype(jnp.float32) * smean / n_shards
    return out.astype(g.dtype), new_residual


def tree_psum_compressed(grads, residuals, axis_names, n_shards: int):
    """Tree version of :func:`psum_compressed_leaf` (inside shard_map)."""
    pairs = jax.tree.map(
        lambda g, r: psum_compressed_leaf(g, r, axis_names, n_shards),
        grads, residuals)
    mean = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda p: isinstance(p, tuple))
    return mean, res


def tree_psum(grads, axis_names, n_shards: int):
    """Uncompressed mean all-reduce (the baseline the roofline compares)."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_names) / n_shards,
                        grads)


def zeros_residuals(params):
    """Initial error-feedback state for a param tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
