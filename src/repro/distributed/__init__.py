"""Distribution layer: sharding rules, compressed collectives, elasticity."""
from .sharding import (batch_spec, cache_specs, constrain_replicated,
                       data_axes, input_shardings, param_specs,
                       serve_mesh_scope, shard_tree, state_specs)

__all__ = [
    "batch_spec", "cache_specs", "constrain_replicated", "data_axes",
    "input_shardings", "param_specs", "serve_mesh_scope", "shard_tree",
    "state_specs",
]
