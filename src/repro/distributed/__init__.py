"""Distribution layer: sharding rules, compressed collectives, elasticity."""
from .sharding import (batch_spec, cache_specs, data_axes, input_shardings,
                       param_specs, shard_tree, state_specs)

__all__ = [
    "batch_spec", "cache_specs", "data_axes", "input_shardings",
    "param_specs", "shard_tree", "state_specs",
]
