"""Pallas TPU kernels for the paper's compute hot-spots.

* :mod:`systolic_matmul`   — int8 x int8 -> int32 MXU-tiled matmul (the
  paper's 256x256 systolic array, TPU-native).
* :mod:`bitflip`           — BER-parameterised accumulator bit-error
  injection (standalone three-pass form).
* :mod:`fused_aged_matmul` — matmul + in-kernel PRNG upset injection +
  dequant in ONE pass (the serve hot path).
* :mod:`ops`               — jit'd public wrappers (padding, interpret
  switch).
* :mod:`ref`               — pure-jnp oracles.
"""
from .ops import (aged_linear, fused_aged_matmul, inject_bitflips,  # noqa: F401
                  quantized_matmul, quantize_int8, make_flip_randoms,
                  seed_from_key)
from .systolic_matmul import systolic_matmul  # noqa: F401
from .bitflip import bitflip_words  # noqa: F401
