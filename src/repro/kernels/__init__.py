"""Pallas TPU kernels for the paper's compute hot-spots.

* :mod:`systolic_matmul` — int8 x int8 -> int32 MXU-tiled matmul (the
  paper's 256x256 systolic array, TPU-native).
* :mod:`bitflip`         — BER-parameterised accumulator bit-error injection.
* :mod:`ops`             — jit'd public wrappers (padding, interpret switch).
* :mod:`ref`             — pure-jnp oracles.
"""
from .ops import (aged_linear, inject_bitflips, quantized_matmul,  # noqa: F401
                  quantize_int8, make_flip_randoms)
from .systolic_matmul import systolic_matmul  # noqa: F401
from .bitflip import bitflip_words  # noqa: F401
