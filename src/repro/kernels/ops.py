"""Public jit'd wrappers around the Pallas kernels.

* Shapes are padded to block multiples here, so callers can use arbitrary
  sizes.
* ``interpret`` defaults to True off-TPU (this container is CPU-only; the
  kernels TARGET TPU and are validated in interpret mode against ``ref.py``).
* :func:`aged_linear` is the model-facing op: a float matmul executed the
  way the paper's accelerator executes it — int8 quantisation, int32
  systolic accumulation, BER-parameterised accumulator bit upsets, dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitflip import bitflip_words
from .systolic_matmul import systolic_matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quantized_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256,
                     bn: int = 256, bk: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (M,N), arbitrary shapes (padded)."""
    if interpret is None:
        interpret = _default_interpret()
    M, N = a.shape[0], b.shape[1]
    bm_, bn_, bk_ = (min(bm, _ceil_mult(M)), min(bn, _ceil_mult(N)),
                     min(bk, _ceil_mult(a.shape[1])))
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    out = systolic_matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def _ceil_mult(dim: int, base: int = 128) -> int:
    """Smallest hardware-aligned block >= min(dim, base)."""
    if dim >= base:
        return base
    # small test shapes: round up to the sublane multiple
    return max(8, int(2 ** np.ceil(np.log2(max(dim, 1)))))


def make_flip_randoms(key: jax.Array, shape: tuple[int, ...]):
    """Uniforms + bit positions for the injection kernel (shared w/ oracle)."""
    ku, kp = jax.random.split(key)
    u = jax.random.uniform(ku, shape, jnp.float32)
    pos = jax.random.randint(kp, shape, 0, 32, jnp.int32)
    return u, pos


@functools.partial(jax.jit, static_argnames=("interpret",))
def inject_bitflips(x: jax.Array, ber, key: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Flip bits of an int32 tensor at per-bit error rate ``ber``.

    Any shape; internally flattened to (R, 128) tiles for the TPU kernel.
    """
    if interpret is None:
        interpret = _default_interpret()
    orig_shape = x.shape
    n = int(np.prod(orig_shape))
    block_rows = 256
    rows = -(-n // 128)
    rows_pad = -(-rows // block_rows) * block_rows
    xf = jnp.resize(x.reshape(-1), (rows_pad * 128,)).reshape(rows_pad, 128)
    u, pos = make_flip_randoms(key, (rows_pad, 128))
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    out = bitflip_words(xf, u, pos, q[None], block_rows=block_rows,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-row absmax int8 quantisation; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def aged_linear(x: jax.Array, w: jax.Array, *, ber=0.0,
                key: jax.Array | None = None,
                interpret: bool | None = None,
                use_kernel: bool = True) -> jax.Array:
    """``x (.., K) @ w (K, N)`` executed as the paper's systolic array does.

    Quantise activations per-row and weights per-column to int8, multiply
    with int32 accumulation, inject accumulator bit errors at ``ber``, then
    dequantise.  ``ber=0`` with ``use_kernel=False`` is the clean fast path
    used during training.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, xs = quantize_int8(x2, axis=-1)
    wq, ws = quantize_int8(w, axis=0)
    if use_kernel:
        acc = quantized_matmul(xq, wq, interpret=interpret)
    else:
        acc = ref.systolic_matmul_ref(xq, wq)
    if key is not None:
        acc = inject_bitflips(acc, ber, key, interpret=interpret)
    out = acc.astype(jnp.float32) * xs * ws
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
