"""Public jit'd wrappers around the Pallas kernels.

* Shapes are padded to block multiples here, so callers can use arbitrary
  sizes.
* ``interpret`` defaults to True off-TPU (this container is CPU-only; the
  kernels TARGET TPU and are validated in interpret mode against ``ref.py``).
* :func:`aged_linear` is the model-facing op: a float matmul executed the
  way the paper's accelerator executes it — int8 quantisation, int32
  systolic accumulation, BER-parameterised accumulator bit upsets, dequant.
  Its default fast path is ONE fused kernel (:func:`fused_aged_matmul`):
  upsets drawn by the in-kernel PRNG at the accumulator flush, dequant
  fused, nothing but ``a``, ``b``, scales and the float output touching
  HBM.  The seed-free three-pass route survives as the oracle fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitflip import bitflip_words
from .fused_aged_matmul import fused_aged_matmul as _fused_aged_matmul_kernel
from .systolic_matmul import systolic_matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quantized_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256,
                     bn: int = 256, bk: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (M,N), arbitrary shapes (padded)."""
    if interpret is None:
        interpret = _default_interpret()
    (bm_, bn_, bk_), ap, bp = _resolve_blocks(a, b, bm, bn, bk)
    out = systolic_matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:a.shape[0], :b.shape[1]]


def _ceil_mult(dim: int, base: int = 128) -> int:
    """Requested block ``base``, shrunk to a pow2 >= 8 for small dims."""
    if dim >= base:
        return base
    # small test shapes: round up to the sublane multiple
    return max(8, int(2 ** np.ceil(np.log2(max(dim, 1)))))


def _resolve_blocks(a: jax.Array, b: jax.Array, bm: int, bn: int, bk: int):
    """Shared preamble of the matmul wrappers: honor the requested block
    shape (shrunk for small dims) and zero-pad operands to multiples."""
    bm_, bn_, bk_ = (_ceil_mult(a.shape[0], bm), _ceil_mult(b.shape[1], bn),
                     _ceil_mult(a.shape[1], bk))
    return (bm_, bn_, bk_), _pad_to(a, bm_, bk_), _pad_to(b, bk_, bn_)


def make_flip_randoms(key: jax.Array, shape: tuple[int, ...]):
    """Uniforms + bit positions for the injection kernel (shared w/ oracle)."""
    ku, kp = jax.random.split(key)
    u = jax.random.uniform(ku, shape, jnp.float32)
    pos = jax.random.randint(kp, shape, 0, 32, jnp.int32)
    return u, pos


def _flip_inputs(x: jax.Array, key: jax.Array, block_rows: int = 256):
    """Shared injection preamble: (R, 128)-tiled words + their randoms.

    The layout (and therefore the random stream) is identical for the
    Pallas kernel and the jnp oracle, so the two routes are bit-exact.
    """
    n = int(np.prod(x.shape))
    rows = -(-n // 128)
    rows_pad = -(-rows // block_rows) * block_rows
    # zero-pad (NOT jnp.resize, which tiles real accumulator words into the
    # pad region — wasted RNG spent flipping copies of live data)
    xf = jnp.pad(x.reshape(-1), (0, rows_pad * 128 - n)).reshape(rows_pad,
                                                                 128)
    u, pos = make_flip_randoms(key, (rows_pad, 128))
    return xf, u, pos, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def inject_bitflips(x: jax.Array, ber, key: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Flip bits of an int32 tensor at per-bit error rate ``ber``.

    Any shape; internally flattened to (R, 128) tiles for the TPU kernel.
    """
    if interpret is None:
        interpret = _default_interpret()
    block_rows = 256
    xf, u, pos, n = _flip_inputs(x, key, block_rows)
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    out = bitflip_words(xf, u, pos, q[None], block_rows=block_rows,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


@jax.jit
def inject_bitflips_ref(x: jax.Array, ber, key: jax.Array) -> jax.Array:
    """Pure-jnp injection, bit-exact vs :func:`inject_bitflips`.

    Same word layout, same random draws, same flip rule — only the
    executor differs (``ref.bitflip_words_ref`` instead of the Pallas
    kernel).  This is what the kernel-free ``aged_linear`` route uses:
    unlike a ``pallas_call`` in interpret mode, plain jnp vectorises
    cleanly under ``vmap`` (the resilience-characterisation sweep maps
    whole fault grids over lanes; see ``benchmarks/resilience_bench.py``).
    """
    xf, u, pos, n = _flip_inputs(x, key)
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    out = ref.bitflip_words_ref(xf, u, pos, q[None])
    return out.reshape(-1)[:n].reshape(x.shape)


def shard_slices(n: int, n_shards: int) -> list:
    """Split points assigning ``n`` columns/heads to shards: shard ``s``
    owns ``[s*n//S, (s+1)*n//S)`` — for divisible ``n`` this is exactly the
    contiguous equal-block assignment ``NamedSharding`` uses, and for
    ``n < S`` trailing shards own empty blocks (they hold no heads)."""
    return [s * n // n_shards for s in range(1, n_shards)]


def inject_bitflips_sharded(x: jax.Array, bers, key: jax.Array, *,
                            axis: int = -1) -> jax.Array:
    """Per-shard accumulator upsets: block ``s`` of ``axis`` flips at
    ``bers[s]`` with a shard-distinct stream.

    ``bers`` is an ``(S,)`` vector — one BER per mesh shard of the serve
    layout (each shard of the weight's output dim is a physically distinct
    array region with its own ΔVth history).  The base seed is hashed from
    ``key`` once and each shard's stream is an fmix32 fold
    (``fold_seed(base, s)`` — the same stream derivation the fused kernel
    applies per tile), expanded over that block's own (R, 128) word layout
    by the jnp oracle.  Everything is plain jnp, so the op partitions
    under GSPMD and a hand-built reference (slice -> fold ->
    :func:`inject_bitflips_ref` -> concat) reproduces it exactly
    (``tests/test_serve_sharded.py``).
    """
    bers = jnp.asarray(bers, jnp.float32)
    S = int(bers.shape[0])
    if S == 1:
        return inject_bitflips_ref(x, bers[0], key)
    base = seed_from_key(key)
    blocks = jnp.split(x, shard_slices(x.shape[axis], S), axis=axis)
    out = [inject_bitflips_ref(blk, bers[s],
                               jax.random.PRNGKey(fold_seed(base, s)))
           for s, blk in enumerate(blocks)]
    return jnp.concatenate(out, axis=axis)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fused_aged_matmul(a: jax.Array, b: jax.Array,
                      xs: jax.Array | None = None,
                      ws: jax.Array | None = None, *, ber=0.0, seed=0,
                      bm: int = 256, bn: int = 256, bk: int = 256,
                      interpret: bool | None = None) -> jax.Array:
    """Fused int8 matmul + in-accumulator bit upsets, arbitrary shapes.

    One kernel pass replaces ``quantized_matmul`` -> ``make_flip_randoms``
    -> ``inject_bitflips``: the upset is applied to the accumulator tile in
    VMEM during the K-final flush, keyed on ``(seed, tile)``, so no
    output-sized random arrays and no extra int32 HBM round-trip exist.
    With scales ``xs (M, 1)`` / ``ws (1, N)`` the dequant epilogue is fused
    as well and the result is float32.
    """
    assert (xs is None) == (ws is None), "pass both scales or neither"
    if interpret is None:
        interpret = _default_interpret()
    M, N = a.shape[0], b.shape[1]
    (bm_, bn_, bk_), ap, bp = _resolve_blocks(a, b, bm, bn, bk)
    if xs is not None:
        xs = _pad_to(xs, bm_, 1)
        ws = _pad_to(ws, 1, bn_)
    out = _fused_aged_matmul_kernel(ap, bp, xs, ws, ber, seed, bm=bm_,
                                    bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def seed_from_key(key: jax.Array) -> jax.Array:
    """Derive the fused kernel's int32 seed from a ``jax.random`` key."""
    return jax.random.bits(key, (), jnp.uint32).astype(jnp.int32)


def fold_seed(seed: jax.Array, *indices) -> jax.Array:
    """Mix indices into an int32 seed — the in-trace stream derivation.

    Uses the fused kernel's own fmix32 stream mix (``stream_constant``), so
    nearby (seed, index) pairs never alias, and each fold is ~5 integer ops
    on a scalar: cheap enough to sit inside a ``lax.scan`` decode body once
    per operator per step.  This is how per-(call, operator, layer, step)
    upset streams are derived during scanned generation without threading
    threefry keys through the scan carry.
    """
    from .fused_aged_matmul import stream_constant
    s = jnp.asarray(seed).astype(jnp.uint32)
    for idx in indices:
        s = stream_constant(s, jnp.asarray(idx).astype(jnp.uint32))
    return s.astype(jnp.int32)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-row absmax int8 quantisation; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def aged_linear(x: jax.Array, w: jax.Array, *, ber=0.0,
                key: jax.Array | None = None,
                seed: jax.Array | None = None,
                interpret: bool | None = None,
                use_kernel: bool = True,
                fused: bool = True) -> jax.Array:
    """``x (.., K) @ w (K, N)`` executed as the paper's systolic array does.

    Quantise activations per-row and weights per-column to int8, multiply
    with int32 accumulation, inject accumulator bit errors at ``ber``, then
    dequantise.  ``ber=0`` with ``use_kernel=False`` is the clean fast path
    used during training.

    Injection is requested by passing ``seed`` (int32 scalar) or ``key``
    (a ``jax.random`` key; hashed down to a seed for the fused path).  With
    ``fused=True`` (default) and ``use_kernel=True`` the faulted matmul is
    ONE kernel — upset + dequant fused into the flush step, no materialised
    randoms, no int32 HBM round-trip.  ``fused=False`` keeps the original
    three-pass route (matmul -> ``make_flip_randoms`` -> ``bitflip_words``),
    retained as the oracle / fallback path.

    ``ber`` may be an ``(S,)`` per-shard vector (mesh serving): the matmul
    then stays on the pure-jnp route (a ``pallas_call`` is a single-device
    program and does not partition under GSPMD) and the accumulator's
    output-column blocks are flipped per shard via
    :func:`inject_bitflips_sharded`.
    """
    sharded = jnp.ndim(ber) == 1
    if sharded:
        use_kernel = fused = False
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, xs = quantize_int8(x2, axis=-1)
    wq, ws = quantize_int8(w, axis=0)
    inject = key is not None or seed is not None
    if use_kernel and fused and inject:
        if seed is None:
            seed = seed_from_key(key)
        out = fused_aged_matmul(xq, wq, xs, ws, ber=ber, seed=seed,
                                interpret=interpret)
        return out.reshape(*lead, w.shape[1]).astype(x.dtype)
    if use_kernel:
        acc = quantized_matmul(xq, wq, interpret=interpret)
    else:
        acc = ref.systolic_matmul_ref(xq, wq)
    if inject:
        if key is None:
            key = jax.random.PRNGKey(seed)
        if sharded:
            acc = inject_bitflips_sharded(acc, ber, key)
        else:
            # kernel-free route stays kernel-free: the jnp oracle injection
            # is bit-exact vs the Pallas kernel and vmap-friendly
            acc = (inject_bitflips(acc, ber, key, interpret=interpret)
                   if use_kernel else inject_bitflips_ref(acc, ber, key))
    out = acc.astype(jnp.float32) * xs * ws
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
