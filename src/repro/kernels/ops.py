"""Public jit'd wrappers around the Pallas kernels.

* Shapes are padded to block multiples here, so callers can use arbitrary
  sizes.
* ``interpret`` defaults to True off-TPU (this container is CPU-only; the
  kernels TARGET TPU and are validated in interpret mode against ``ref.py``).
* :func:`aged_linear` is the model-facing op: a float matmul executed the
  way the paper's accelerator executes it — int8 quantisation, int32
  systolic accumulation, BER-parameterised accumulator bit upsets, dequant.
  Its default fast path is ONE fused kernel (:func:`fused_aged_matmul`):
  upsets drawn by the in-kernel PRNG at the accumulator flush, dequant
  fused, nothing but ``a``, ``b``, scales and the float output touching
  HBM.  The seed-free three-pass route survives as the oracle fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitflip import bitflip_words
from .fused_aged_matmul import fused_aged_matmul as _fused_aged_matmul_kernel
from .systolic_matmul import systolic_matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quantized_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256,
                     bn: int = 256, bk: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (M,N), arbitrary shapes (padded)."""
    if interpret is None:
        interpret = _default_interpret()
    (bm_, bn_, bk_), ap, bp = _resolve_blocks(a, b, bm, bn, bk)
    out = systolic_matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:a.shape[0], :b.shape[1]]


def _ceil_mult(dim: int, base: int = 128) -> int:
    """Requested block ``base``, shrunk to a pow2 >= 8 for small dims."""
    if dim >= base:
        return base
    # small test shapes: round up to the sublane multiple
    return max(8, int(2 ** np.ceil(np.log2(max(dim, 1)))))


def _resolve_blocks(a: jax.Array, b: jax.Array, bm: int, bn: int, bk: int):
    """Shared preamble of the matmul wrappers: honor the requested block
    shape (shrunk for small dims) and zero-pad operands to multiples."""
    bm_, bn_, bk_ = (_ceil_mult(a.shape[0], bm), _ceil_mult(b.shape[1], bn),
                     _ceil_mult(a.shape[1], bk))
    return (bm_, bn_, bk_), _pad_to(a, bm_, bk_), _pad_to(b, bk_, bn_)


def make_flip_randoms(key: jax.Array, shape: tuple[int, ...]):
    """Uniforms + bit positions for the injection kernel (shared w/ oracle)."""
    ku, kp = jax.random.split(key)
    u = jax.random.uniform(ku, shape, jnp.float32)
    pos = jax.random.randint(kp, shape, 0, 32, jnp.int32)
    return u, pos


def _flip_inputs(x: jax.Array, key: jax.Array, block_rows: int = 256):
    """Shared injection preamble: (R, 128)-tiled words + their randoms.

    The layout (and therefore the random stream) is identical for the
    Pallas kernel and the jnp oracle, so the two routes are bit-exact.
    """
    n = int(np.prod(x.shape))
    rows = -(-n // 128)
    rows_pad = -(-rows // block_rows) * block_rows
    # zero-pad (NOT jnp.resize, which tiles real accumulator words into the
    # pad region — wasted RNG spent flipping copies of live data)
    xf = jnp.pad(x.reshape(-1), (0, rows_pad * 128 - n)).reshape(rows_pad,
                                                                 128)
    u, pos = make_flip_randoms(key, (rows_pad, 128))
    return xf, u, pos, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def inject_bitflips(x: jax.Array, ber, key: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Flip bits of an int32 tensor at per-bit error rate ``ber``.

    Any shape; internally flattened to (R, 128) tiles for the TPU kernel.
    """
    if interpret is None:
        interpret = _default_interpret()
    block_rows = 256
    xf, u, pos, n = _flip_inputs(x, key, block_rows)
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    out = bitflip_words(xf, u, pos, q[None], block_rows=block_rows,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


@jax.jit
def inject_bitflips_ref(x: jax.Array, ber, key: jax.Array) -> jax.Array:
    """Pure-jnp injection, bit-exact vs :func:`inject_bitflips`.

    Same word layout, same random draws, same flip rule — only the
    executor differs (``ref.bitflip_words_ref`` instead of the Pallas
    kernel).  This is what the kernel-free ``aged_linear`` route uses:
    unlike a ``pallas_call`` in interpret mode, plain jnp vectorises
    cleanly under ``vmap`` (the resilience-characterisation sweep maps
    whole fault grids over lanes; see ``benchmarks/resilience_bench.py``).
    """
    xf, u, pos, n = _flip_inputs(x, key)
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    out = ref.bitflip_words_ref(xf, u, pos, q[None])
    return out.reshape(-1)[:n].reshape(x.shape)


def shard_slices(n: int, n_shards: int) -> list:
    """Split points assigning ``n`` columns/heads to shards: shard ``s``
    owns ``[s*n//S, (s+1)*n//S)`` — for divisible ``n`` this is exactly the
    contiguous equal-block assignment ``NamedSharding`` uses, and for
    ``n < S`` trailing shards own empty blocks (they hold no heads)."""
    return [s * n // n_shards for s in range(1, n_shards)]


def upset_counter_block(acc: jax.Array, ber, seed) -> jax.Array:
    """Upset one 2-D accumulator block with the fused kernel's counter
    stream over the SAME (bm, bn) tile grid the shard-local kernel wrapper
    resolves for this block shape — bit-exact vs :func:`fused_aged_matmul`
    run on the block with the same seed (``tests/test_shard_map_fused.py``).
    """
    from .fused_aged_matmul import tile_counter_bits, upset_words
    M, N = acc.shape
    bits = tile_counter_bits(M, N, seed, bm=_ceil_mult(M, 256),
                             bn=_ceil_mult(N, 256))
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    return upset_words(acc, bits, q)


def inject_bitflips_sharded(x: jax.Array, bers, key: jax.Array | None = None,
                            *, seed=None, axis: int = -1) -> jax.Array:
    """Per-shard accumulator upsets: block ``s`` of ``axis`` flips at
    ``bers[s]`` with a shard-distinct stream.

    ``bers`` is an ``(S,)`` vector — one BER per mesh shard of the serve
    layout (each shard of the weight's output dim is a physically distinct
    array region with its own ΔVth history).  Each shard's stream is an
    fmix32 fold of the base seed (``fold_seed(seed, s)``; ``seed`` hashed
    from ``key`` when only a key is given) expanded by the fused kernel's
    *counter PRNG* over the block's own resolved tile grid
    (:func:`upset_counter_block`): the draws are exactly what
    :func:`fused_aged_matmul` would generate running shard-locally on that
    column block, so the shard_map-wrapped kernel route and this pure-jnp
    route are bit-exact BY CONSTRUCTION — this is the kernel route's
    oracle.  Everything here is plain jnp, so the op partitions under
    GSPMD, vectorises under ``vmap``, and a hand-built reference (slice ->
    fold -> counter draws -> xor) reproduces it exactly
    (``tests/test_serve_sharded.py``).  Rank > 2 inputs (the qkt/sv
    flattened-head blocks) collapse their leading dims, keeping the last
    dim as the tile-layout columns.

    Implementation note: the per-shard blocks are NOT materialised with
    ``jnp.split``/``jnp.concatenate``.  On a serve mesh with a non-trivial
    data axis, XLA's SPMD partitioner miscompiles that concat-of-slices
    pattern on replicated operands — every data replica's contribution is
    summed, returning ``data_parallelism x`` the true accumulator (seen on
    jax 0.4.37 CPU; ``tests/test_shard_map_fused.py`` pins the parity that
    caught it).  Instead, each element's shard id, block-local row/column,
    and resolved tile parameters are precomputed as static constants and
    the whole array is upset in one elementwise pass — identical draws,
    nothing for the partitioner to reassemble.
    """
    from .fused_aged_matmul import counter_bits, upset_words
    bers = jnp.asarray(bers, jnp.float32)
    S = int(bers.shape[0])
    if seed is None:
        seed = seed_from_key(key)
    ax = axis % x.ndim
    n_ax = x.shape[ax]
    D = x.shape[-1]
    R = int(np.prod(x.shape[:-1]))
    bounds = np.asarray([0] + shard_slices(n_ax, S) + [n_ax])
    widths = np.diff(bounds)
    q = 1.0 - (1.0 - bers) ** 32                                  # (S,)
    seeds = fold_seed(seed, np.arange(S, dtype=np.uint32)) \
        .astype(jnp.uint32)                                       # (S,)

    x2 = x.reshape(R, D)
    row = jnp.arange(R, dtype=jnp.uint32)[:, None]
    col = jnp.arange(D, dtype=jnp.uint32)[None, :]
    U = lambda a: jnp.asarray(np.asarray(a, np.uint32))
    if ax == x.ndim - 1:
        # column split: block s is (R, W_s); per-column constants
        sid = np.searchsorted(bounds[1:-1], np.arange(D), side="right")
        bn_s = np.asarray([_ceil_mult(max(int(w), 1), 256)
                           for w in widths])
        grid_s = np.maximum(-(-widths // bn_s), 1)
        bm = np.uint32(_ceil_mult(R, 256))
        lcol = U(np.arange(D) - bounds[sid])
        bn, grid = U(bn_s[sid])[None, :], U(grid_s[sid])[None, :]
        tile_id = (row // bm) * grid + lcol[None, :] // bn
        offset = (row % bm) * bn + lcol[None, :] % bn
        bits = counter_bits(offset, seeds[sid][None, :], tile_id)
        return upset_words(x2, bits, q[sid][None, :]).reshape(x.shape)
    # leading-axis split (flattened-head blocks): block s is
    # (lead, W_s, mid, D) reshaped to (lead * W_s * mid, D); per-row
    # constants recover each row's block-local index and block size
    mid = int(np.prod(x.shape[ax + 1:-1], dtype=np.int64))
    g = np.arange(R)
    h = (g // mid) % n_ax
    a_ = g // (mid * n_ax)
    b_ = g % mid
    sid_ax = np.searchsorted(bounds[1:-1], np.arange(n_ax), side="right")
    s_row = sid_ax[h]
    r_loc = U((a_ * widths[s_row] + (h - bounds[s_row])) * mid + b_)
    rows_s = (R // n_ax) * widths
    bm_row = U(np.asarray([_ceil_mult(max(int(r), 1), 256)
                           for r in rows_s])[s_row])[:, None]
    bn = np.uint32(_ceil_mult(D, 256))
    grid_n = np.uint32(-(-D // int(bn)))
    tile_id = (r_loc[:, None] // bm_row) * grid_n + col // bn
    offset = (r_loc[:, None] % bm_row) * bn + col % bn
    bits = counter_bits(offset, seeds[s_row][:, None], tile_id)
    return upset_words(x2, bits, q[s_row][:, None]).reshape(x.shape)


def _fused_aged_matmul_sharded(xq, wq, bers, seed, mesh,
                               shard_axis: str, interpret):
    """shard_map the fused kernel over ``mesh``'s ``shard_axis``.

    Each shard runs :func:`fused_aged_matmul` — int8 matmul + in-flush
    accumulator upsets, ONE Pallas kernel — locally on the output-column
    block it owns under the serve layout, at ``bers[s]`` with the
    shard-distinct stream ``fold_seed(seed, s)`` passed as shard-local
    scalars.  Inputs/outputs follow the serve layout's invariants:
    activations replicated, weight columns sharded, output column-sharded
    (the caller's ``constrain_replicated`` pin turns the gather into pure
    data movement).  BERs and the seed are traced — shard age/BER updates
    between calls re-jit nothing.

    Returns the faulted **int32 accumulator**, not the dequantised float:
    the caller applies the same ``acc.astype(f32) * xs * ws`` epilogue as
    the kernel-free route.  Fusing the dequant into the kernel would hand
    XLA a differently-shaped program on the oracle side, and its simplifier
    is then free to reassociate the two broadcast multiplies differently —
    last-ulp float drift that breaks cross-route token equality.  Keeping
    the epilogue textually identical in both routes keeps them bit-exact by
    construction; the byte win that matters (no materialised randoms, no
    separate flip-pass round-trip) is unaffected.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(xq, wq_blk, bers, seed):
        s = jax.lax.axis_index(shard_axis)
        return fused_aged_matmul(xq, wq_blk, ber=bers[s],
                                 seed=fold_seed(seed, s),
                                 interpret=interpret)

    col = P(None, shard_axis)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(), col, P(), P()),
                     out_specs=col, check_rep=False)(
        xq, wq, jnp.asarray(bers, jnp.float32),
        jnp.asarray(seed, jnp.int32))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fused_aged_matmul(a: jax.Array, b: jax.Array,
                      xs: jax.Array | None = None,
                      ws: jax.Array | None = None, *, ber=0.0, seed=0,
                      bm: int = 256, bn: int = 256, bk: int = 256,
                      interpret: bool | None = None) -> jax.Array:
    """Fused int8 matmul + in-accumulator bit upsets, arbitrary shapes.

    One kernel pass replaces ``quantized_matmul`` -> ``make_flip_randoms``
    -> ``inject_bitflips``: the upset is applied to the accumulator tile in
    VMEM during the K-final flush, keyed on ``(seed, tile)``, so no
    output-sized random arrays and no extra int32 HBM round-trip exist.
    With scales ``xs (M, 1)`` / ``ws (1, N)`` the dequant epilogue is fused
    as well and the result is float32.
    """
    assert (xs is None) == (ws is None), "pass both scales or neither"
    if interpret is None:
        interpret = _default_interpret()
    M, N = a.shape[0], b.shape[1]
    (bm_, bn_, bk_), ap, bp = _resolve_blocks(a, b, bm, bn, bk)
    if xs is not None:
        xs = _pad_to(xs, bm_, 1)
        ws = _pad_to(ws, 1, bn_)
    out = _fused_aged_matmul_kernel(ap, bp, xs, ws, ber, seed, bm=bm_,
                                    bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def seed_from_key(key: jax.Array) -> jax.Array:
    """Derive the fused kernel's int32 seed from a ``jax.random`` key."""
    return jax.random.bits(key, (), jnp.uint32).astype(jnp.int32)


def fold_seed(seed: jax.Array, *indices) -> jax.Array:
    """Mix indices into an int32 seed — the in-trace stream derivation.

    Uses the fused kernel's own fmix32 stream mix (``stream_constant``), so
    nearby (seed, index) pairs never alias, and each fold is ~5 integer ops
    on a scalar: cheap enough to sit inside a ``lax.scan`` decode body once
    per operator per step.  This is how per-(call, operator, layer, step)
    upset streams are derived during scanned generation without threading
    threefry keys through the scan carry.
    """
    from .fused_aged_matmul import stream_constant
    s = jnp.asarray(seed).astype(jnp.uint32)
    for idx in indices:
        s = stream_constant(s, jnp.asarray(idx).astype(jnp.uint32))
    return s.astype(jnp.int32)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-row absmax int8 quantisation; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def aged_linear(x: jax.Array, w: jax.Array, *, ber=0.0,
                key: jax.Array | None = None,
                seed: jax.Array | None = None,
                interpret: bool | None = None,
                use_kernel: bool = True,
                fused: bool = True,
                shard_axis: str | None = None,
                mesh=None) -> jax.Array:
    """``x (.., K) @ w (K, N)`` executed as the paper's systolic array does.

    Quantise activations per-row and weights per-column to int8, multiply
    with int32 accumulation, inject accumulator bit errors at ``ber``, then
    dequantise.  ``ber=0`` with ``use_kernel=False`` is the clean fast path
    used during training.

    Injection is requested by passing ``seed`` (int32 scalar) or ``key``
    (a ``jax.random`` key; hashed down to a seed for the fused path).  With
    ``fused=True`` (default) and ``use_kernel=True`` the faulted matmul is
    ONE kernel — upset + dequant fused into the flush step, no materialised
    randoms, no int32 HBM round-trip.  ``fused=False`` keeps the original
    three-pass route (matmul -> ``make_flip_randoms`` -> ``bitflip_words``),
    retained as the oracle / fallback path.

    ``ber`` may be an ``(S,)`` per-shard vector (mesh serving): shard ``s``
    of the output columns then flips at ``bers[s]`` with the shard-distinct
    counter stream ``fold_seed(seed, s)``.  Two bit-identical realisations:

    * With ``mesh`` / ``shard_axis`` given (the serve engine passes the
      active serve mesh) and ``N`` divisible by the axis size ``S``, the
      matmul is wrapped in ``shard_map`` and every shard runs the ONE fused
      kernel locally on its own output-column block — the fused path's HBM
      byte economy survives tensor parallelism.  Requires ``use_kernel``
      and ``fused``.
    * Otherwise ``use_kernel=fused=True`` is **silently downgraded** to the
      pure-jnp kernel-free route — a ``pallas_call`` is a single-device
      program and does not partition under GSPMD, so without a mesh to
      shard_map over there is no way to run the kernel per shard.  The
      downgrade draws the SAME counter streams via
      :func:`inject_bitflips_sharded`, so routing affects performance only,
      never sampled tokens, and the kernel-free route doubles as the
      shard_map route's oracle (``tests/test_shard_map_fused.py``).
    """
    sharded = jnp.ndim(ber) == 1
    inject = key is not None or seed is not None
    shard_mapped = False
    if sharded:
        S = int(ber.shape[0])
        shard_mapped = (use_kernel and fused and inject and mesh is not None
                        and shard_axis is not None
                        and shard_axis in mesh.axis_names
                        and int(mesh.shape[shard_axis]) == S
                        and w.shape[1] % S == 0)
        if not shard_mapped:
            # documented downgrade: same streams, kernel-free executor
            use_kernel = fused = False
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, xs = quantize_int8(x2, axis=-1)
    wq, ws = quantize_int8(w, axis=0)
    if sharded and inject:
        if seed is None:
            seed = seed_from_key(key)
        if shard_mapped:
            acc = _fused_aged_matmul_sharded(xq, wq, ber, seed,
                                             mesh, shard_axis, interpret)
        else:
            acc = ref.systolic_matmul_ref(xq, wq)
            acc = inject_bitflips_sharded(acc, ber, seed=seed)
        # one dequant epilogue for BOTH routes — identical jnp expression
        # => identical XLA rewrites => cross-route bit-exactness survives
        # the simplifier's broadcast-multiply reassociation freedom
        out = acc.astype(jnp.float32) * xs * ws
        return out.reshape(*lead, w.shape[1]).astype(x.dtype)
    if use_kernel and fused and inject:
        if seed is None:
            seed = seed_from_key(key)
        out = fused_aged_matmul(xq, wq, xs, ws, ber=ber, seed=seed,
                                interpret=interpret)
        return out.reshape(*lead, w.shape[1]).astype(x.dtype)
    if use_kernel:
        acc = quantized_matmul(xq, wq, interpret=interpret)
    else:
        acc = ref.systolic_matmul_ref(xq, wq)
    if inject:
        if key is None:
            key = jax.random.PRNGKey(seed)
        # kernel-free route stays kernel-free: the jnp oracle injection
        # is bit-exact vs the Pallas kernel and vmap-friendly
        acc = (inject_bitflips(acc, ber, key, interpret=interpret)
               if use_kernel else inject_bitflips_ref(acc, ber, key))
    out = acc.astype(jnp.float32) * xs * ws
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
