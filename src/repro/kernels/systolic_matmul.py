"""Pallas TPU kernel: int8 x int8 -> int32 systolic-array matmul.

The paper's accelerator is a 256x256 systolic array with 8-bit multipliers
and 32-bit accumulators (Sec. V-A).  On TPU that abstraction maps directly
onto the MXU: this kernel is the TPU-native realisation — an MXU-aligned
tiled matmul that keeps an int32 accumulator tile resident in VMEM across
the K-reduction, exactly as the systolic array keeps partial sums in the PE
grid.

Tiling: grid = (M/bm, N/bn, K/bk); A blocks (bm, bk), B blocks (bk, bn),
accumulator scratch (bm, bn) int32 in VMEM.  Defaults bm = bn = 256, bk = 256
echo the paper's array and are MXU-aligned (int8 min tile (32, 128)); the
kernel-bench sweeps block shapes (see EXPERIMENTS.md §Perf).

VMEM working set at defaults: 256*256 (A) + 256*256 (B) int8 + 256*256 int32
= 64 KiB + 64 KiB + 256 KiB ≈ 0.38 MiB — comfortably inside the ~16 MiB/core
VMEM budget, leaving room for double-buffered pipelining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x -> 0.5.x;
# support both so the kernel runs on the baked-in toolchain and newer ones.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def systolic_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256,
                    bn: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """``a (M, K) int8 @ b (K, N) int8 -> (M, N) int32``.

    M, N, K must be multiples of the block shape (``ops.py`` pads).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
