"""Pallas TPU kernel: BER-parameterised bit-error injection.

Models timing-error upsets at the systolic array's int32 accumulator
registers (paper Sec. IV-A): a violating path latches a wrong bit.  For a
per-bit error rate ``p`` the probability a 32-bit word suffers at least one
upset is ``q = 1 - (1-p)**32``; for the BER regime of interest
(p <= 1e-3) multi-bit upsets per word are negligible, so the kernel flips
one uniformly chosen bit with probability ``q`` per word — the standard
first-order fault-injection approximation.

The random inputs (uniforms + bit positions) are produced by ``jax.random``
*outside* the kernel so that the pure-jnp oracle (``ref.py``) consumes
byte-identical randomness — the kernel is then a deterministic elementwise
map, tiled (block_rows, 128) over a 2-D layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitflip_kernel(x_ref, u_ref, pos_ref, q_ref, out_ref):
    x = x_ref[...]
    u = u_ref[...]
    pos = pos_ref[...]
    q = q_ref[0]
    mask = (jnp.int32(1) << pos.astype(jnp.int32))
    flip = u < q
    out_ref[...] = jnp.where(flip, jnp.bitwise_xor(x, mask), x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitflip_words(x: jax.Array, u: jax.Array, pos: jax.Array,
                  q: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False) -> jax.Array:
    """Flip one random bit per word where ``u < q``.

    ``x`` int32 of shape (R, 128); ``u`` float32 uniforms, ``pos`` int32 bit
    positions in [0, 32), same shape.  ``q`` scalar word-upset probability,
    shape (1,).  R must be a multiple of ``block_rows`` (ops.py pads).
    """
    R, C = x.shape
    assert C == 128 and R % block_rows == 0, (x.shape, block_rows)
    grid = (R // block_rows,)
    bspec = pl.BlockSpec((block_rows, C), lambda i: (i, 0))
    return pl.pallas_call(
        _bitflip_kernel,
        grid=grid,
        in_specs=[bspec, bspec, bspec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.int32),
        interpret=interpret,
    )(x, u, pos, q)
