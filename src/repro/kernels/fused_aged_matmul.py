"""Pallas TPU kernel: fused int8 matmul + in-kernel accumulator bit upsets.

The paper's serving hot path (Sec. IV-A/V-A) is a systolic array whose
int32 accumulator registers latch timing-error upsets at the BER the AVS
policy admits.  The three-pass realisation (``systolic_matmul`` -> host-side
``jax.random`` materialising two output-sized arrays -> ``bitflip_words``
read-modify-write over HBM) models that faithfully but moves the int32
accumulator through HBM three times plus 8 bytes/word of randomness.  This
kernel injects the upset *at the accumulator*, in the K-final flush step of
the tiled matmul, the way hardware fault-injection frameworks do — the
accumulator tile never leaves VMEM un-faulted and no randomness is ever
materialised in HBM.

Per 32-bit word the upset model is unchanged (see ``bitflip.py``): flip one
uniformly chosen bit with probability ``q = 1 - (1-p)**32``.

Two in-kernel PRNG implementations, chosen statically:

* ``hw_prng=True`` (compiled TPU path): seed the on-core PRNG via
  ``pltpu.prng_seed`` with the fmix32-mixed (caller seed, ``tile_id =
  i * grid_n + j``) stream constant — the same mixing the counter path
  uses, so nearby seeds / adjacent tiles never alias — then draw
  ``pltpu.prng_random_bits`` in registers.  Every (bm, bn) output tile is
  an independent stream and the result is deterministic per (seed, grid).
* ``hw_prng=False`` (interpret mode / CPU CI): a counter-based murmur3-
  finalizer hash of (seed, tile_id, word-offset-in-tile).  Pure integer
  arithmetic, so it runs anywhere Pallas interprets — and ``ref.py``'s
  ``fused_aged_matmul_ref`` reproduces it *bit-exactly* in plain jnp, which
  is what the parity tests assert.

Both split one 32-bit draw per word: low 5 bits select the bit position,
the high 27 bits form the uniform for the flip decision.  ``q <= 3.2e-2``
for the policy-relevant BER <= 1e-3, so 27-bit resolution is ample.

The dequant epilogue (``acc * xs * ws``) is fused too when ``dequant=True``:
the faulted int32 accumulator is scaled to float32 in VMEM and the int32
tensor never round-trips through HBM at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .systolic_matmul import _CompilerParams

_U = jnp.uint32


def fmix32(x: jax.Array) -> jax.Array:
    """murmur3 finalizer on uint32 — the counter-mode PRNG's mixing step.

    Shared verbatim by the kernel and the pure-jnp oracle so interpret-mode
    parity is bit-exact.
    """
    x = x ^ (x >> _U(16))
    x = x * _U(0x85EBCA6B)
    x = x ^ (x >> _U(13))
    x = x * _U(0xC2B2AE35)
    x = x ^ (x >> _U(16))
    return x


def stream_constant(seed: jax.Array, tile_id: jax.Array) -> jax.Array:
    """Per-(seed, tile) stream id — shared by BOTH PRNG paths.

    Mixed, not added: ``seed + tile_id`` would alias tile t of seed s with
    tile t-1 of seed s+1 (correlated upsets across nearby seeds).
    """
    return fmix32(seed * _U(0x9E3779B1) ^ tile_id * _U(0x7FEB352D))


def counter_bits(offset: jax.Array, seed: jax.Array,
                 tile_id: jax.Array) -> jax.Array:
    """One uint32 draw per word: hash(word offset, hash(seed, tile)).

    ``offset`` uint32 array (word offset within the tile), ``seed`` /
    ``tile_id`` uint32 scalars.  Two fmix32 rounds decorrelate the three
    inputs; sequential-counter + murmur3-finalizer is the standard
    hash-based counter RNG construction.
    """
    return fmix32(offset * _U(0x9E3779B9) ^ stream_constant(seed, tile_id))


def tile_counter_bits(M: int, N: int, seed: jax.Array, *, bm: int,
                      bn: int) -> jax.Array:
    """Counter draws for a whole (M, N) block in the kernel's tile layout.

    One uint32 per word, computed exactly as the flush step of every
    (bm, bn) grid tile computes it — ``tile_id = i * grid_n + j`` over the
    *padded* grid, ``offset = row-in-tile * bn + col-in-tile`` — so a plain
    jnp consumer (``ref.fused_aged_matmul_ref``, the sharded kernel-free
    injection in ``ops.py``) reproduces the kernel's upsets bit-exactly
    without materialising the pad region.  ``M`` / ``N`` are the *live*
    (unpadded) extents; draws for pad words are simply never computed
    (the kernel computes and discards them).
    """
    grid_n = -(-N // bn)
    row = jnp.arange(M, dtype=_U)[:, None]
    col = jnp.arange(N, dtype=_U)[None, :]
    tile_id = (row // _U(bm)) * _U(grid_n) + col // _U(bn)
    offset = (row % _U(bm)) * _U(bn) + col % _U(bn)
    return counter_bits(offset, jnp.asarray(seed, jnp.int32).astype(_U),
                        tile_id)


def upset_words(acc: jax.Array, bits: jax.Array, q: jax.Array) -> jax.Array:
    """Apply the one-bit-per-word upset given raw uint32 draws.

    Low 5 bits -> position, high 27 bits -> uniform in [0, 1); flip where
    the uniform lands below the word-upset probability ``q``.
    """
    pos = (bits & _U(31)).astype(jnp.int32)
    u = (bits >> _U(5)).astype(jnp.float32) * jnp.float32(2.0 ** -27)
    mask = jnp.left_shift(jnp.int32(1), pos)
    return jnp.where(u < q, jnp.bitwise_xor(acc, mask), acc)


def _inject(acc: jax.Array, seed, q, tile_id, *, hw_prng: bool) -> jax.Array:
    if hw_prng:
        pltpu.prng_seed(stream_constant(seed.astype(jnp.uint32),
                                        tile_id.astype(jnp.uint32)))
        bits = pltpu.bitcast(pltpu.prng_random_bits(acc.shape), jnp.uint32)
    else:
        r = jax.lax.broadcasted_iota(jnp.uint32, acc.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.uint32, acc.shape, 1)
        offset = r * _U(acc.shape[1]) + c
        bits = counter_bits(offset, seed.astype(jnp.uint32),
                            tile_id.astype(jnp.uint32))
    return upset_words(acc, bits, q)


def _fused_kernel(seed_ref, q_ref, a_ref, b_ref, *refs, k_steps: int,
                  grid_n: int, hw_prng: bool, dequant: bool):
    if dequant:
        xs_ref, ws_ref, out_ref, acc_ref = refs
    else:
        out_ref, acc_ref = refs

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    # computed outside pl.when: interpret mode cannot lower program_id
    # inside the cond branch
    tile_id = pl.program_id(0) * grid_n + pl.program_id(1)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        acc = _inject(acc_ref[...], seed_ref[0], q_ref[0], tile_id,
                      hw_prng=hw_prng)
        if dequant:
            out_ref[...] = acc.astype(jnp.float32) * xs_ref[...] \
                * ws_ref[...]
        else:
            out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fused_aged_matmul(a: jax.Array, b: jax.Array, xs: jax.Array | None,
                      ws: jax.Array | None, ber, seed, *, bm: int = 256,
                      bn: int = 256, bk: int = 256,
                      interpret: bool = False) -> jax.Array:
    """``a (M, K) int8 @ b (K, N) int8`` with accumulator upsets at ``ber``.

    ``seed`` int32 scalar; each (bm, bn) tile draws an independent stream
    keyed on (seed, tile), so the output is deterministic per (seed, grid).
    With per-row / per-column scales ``xs (M, 1)`` / ``ws (1, N)`` the
    dequant epilogue is fused and the result is float32; with ``xs = ws =
    None`` the faulted int32 accumulator is returned.  M, N, K must be
    multiples of the block shape (``ops.py`` pads).  In interpret mode the
    counter-based PRNG is used (bit-exact vs ``ref.fused_aged_matmul_ref``);
    compiled TPU uses the on-core hardware PRNG.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    dequant = xs is not None
    assert dequant == (ws is not None)
    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)

    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    seed = jnp.asarray(seed, jnp.int32).reshape(1)
    # scalars live in SMEM: Mosaic cannot load from ANY-space refs
    scalar_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [scalar_spec, scalar_spec,
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))]
    operands = [seed, q[None], a, b]
    if dequant:
        assert xs.shape == (M, 1) and ws.shape == (1, N), (xs.shape, ws.shape)
        in_specs += [pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
                     pl.BlockSpec((1, bn), lambda i, j, k: (0, j))]
        operands += [xs.astype(jnp.float32), ws.astype(jnp.float32)]
    out_dtype = jnp.float32 if dequant else jnp.int32

    return pl.pallas_call(
        functools.partial(_fused_kernel, k_steps=k_steps, grid_n=grid[1],
                          hw_prng=not interpret, dequant=dequant),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
