"""Pure-jnp oracles for the Pallas kernels (used by tests and CPU paths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def systolic_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul oracle."""
    return jax.lax.dot_general(
        a.astype(jnp.int32), b.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def bitflip_words_ref(x: jax.Array, u: jax.Array, pos: jax.Array,
                      q: jax.Array) -> jax.Array:
    """Oracle for the bit-flip kernel on identical random inputs."""
    mask = jnp.int32(1) << pos.astype(jnp.int32)
    return jnp.where(u < q[0], jnp.bitwise_xor(x, mask), x)
