"""Pure-jnp oracles for the Pallas kernels (used by tests and CPU paths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def systolic_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul oracle."""
    return jax.lax.dot_general(
        a.astype(jnp.int32), b.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def bitflip_words_ref(x: jax.Array, u: jax.Array, pos: jax.Array,
                      q: jax.Array) -> jax.Array:
    """Oracle for the bit-flip kernel on identical random inputs."""
    mask = jnp.int32(1) << pos.astype(jnp.int32)
    return jnp.where(u < q[0], jnp.bitwise_xor(x, mask), x)


def fused_aged_matmul_ref(a: jax.Array, b: jax.Array,
                          xs: jax.Array | None, ws: jax.Array | None,
                          ber, seed, *, bm: int = 256,
                          bn: int = 256) -> jax.Array:
    """Counter-based oracle for the fused kernel's interpret-mode path.

    Reproduces the in-kernel counter PRNG *bit-exactly* in plain jnp: each
    word's draw is ``counter_bits(word offset in its (bm, bn) tile,
    hash(seed, tile_id))``, with ``tile_id = i * grid_n + j`` exactly as the
    flush step computes it.  Same padded-shape contract as the kernel.
    """
    from .fused_aged_matmul import tile_counter_bits, upset_words

    acc = systolic_matmul_ref(a, b)
    M, N = acc.shape
    assert M % bm == 0 and N % bn == 0, (acc.shape, bm, bn)
    bits = tile_counter_bits(M, N, seed, bm=bm, bn=bn)
    q = 1.0 - (1.0 - jnp.asarray(ber, jnp.float32)) ** 32
    acc = upset_words(acc, bits, q)
    if xs is None:
        return acc
    return acc.astype(jnp.float32) * xs.astype(jnp.float32) \
        * ws.astype(jnp.float32)
