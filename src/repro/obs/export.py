"""Telemetry export pipeline: JSONL event log + Prometheus exposition.

Two sinks, one source — :meth:`repro.obs.metrics.MetricsRegistry.collect`
:class:`~repro.obs.metrics.Sample` rows:

* **JSONL** (:func:`write_jsonl` / :func:`read_jsonl`) — an append-only
  event log.  The first row is a *run manifest* (schema version, run
  name, environment fingerprint); every following row is a typed event:
  ``metric`` rows carry one sample each, ``health`` rows carry a whole
  :meth:`repro.obs.health.FleetHealth.to_dict` snapshot, ``event`` rows
  carry freeform markers (disruptions, retirements, phase changes).
* **Prometheus text exposition** (:func:`prometheus_text` /
  :func:`parse_prometheus`) — the standard ``# HELP`` / ``# TYPE`` /
  ``name{label="v"} value`` format, one scrape of the current registry.

Both directions round-trip: ``parse_prometheus(prometheus_text(s))`` and
``read_jsonl(write_jsonl(...))`` reproduce the samples exactly (asserted
by ``tests/test_obs_metrics.py``) — the parsers double as tooling for
downstream dashboards and as the export layer's own regression guard.
"""
from __future__ import annotations

import json
import math
import platform
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .metrics import REGISTRY, MetricsRegistry, Sample

__all__ = [
    "run_manifest", "write_jsonl", "read_jsonl",
    "prometheus_text", "parse_prometheus",
]

SCHEMA_VERSION = 1


def run_manifest(run: str = "run", **extra) -> Dict:
    """Export-header metadata: schema version + environment fingerprint."""
    man = {
        "schema": SCHEMA_VERSION,
        "run": str(run),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    try:
        import jax
        man["jax"] = jax.__version__
        man["backend"] = jax.default_backend()
    except Exception:                       # export works without jax too
        pass
    man.update(extra)
    return man


def _sample_row(s: Sample) -> Dict:
    return {"type": "metric", "name": s.name, "labels": dict(s.labels),
            "value": None if math.isnan(s.value) else s.value,
            "kind": s.kind}


def write_jsonl(path, samples: Optional[Sequence[Sample]] = None, *,
                manifest: Optional[Dict] = None,
                health: Optional[Dict] = None,
                events: Iterable[Dict] = (),
                registry: MetricsRegistry = REGISTRY) -> int:
    """Write one telemetry event log; returns the number of rows written.

    ``samples`` defaults to a fresh ``registry.collect()`` scrape;
    ``health`` is a :meth:`repro.obs.health.FleetHealth.to_dict` dict;
    ``events`` are freeform dicts logged as ``{"type": "event", ...}``.
    """
    rows: List[Dict] = [{"type": "manifest",
                         **(manifest or run_manifest())}]
    if health is not None:
        rows.append({"type": "health", **health})
    for ev in events:
        rows.append({"type": "event", **ev})
    for s in (registry.collect() if samples is None else samples):
        rows.append(_sample_row(s))
    with open(path, "w") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")
    return len(rows)


def read_jsonl(path) -> Tuple[Dict, List[Sample], List[Dict]]:
    """Parse an event log back: ``(manifest, samples, other_rows)``.

    ``samples`` reconstructs each ``metric`` row as a
    :class:`~repro.obs.metrics.Sample` (labels sorted, NaN restored);
    ``other_rows`` keeps ``health`` / ``event`` rows verbatim.
    """
    manifest: Dict = {}
    samples: List[Sample] = []
    other: List[Dict] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            kind = row.pop("type", "event")
            if kind == "manifest":
                manifest = row
            elif kind == "metric":
                value = row["value"]
                samples.append(Sample(
                    name=row["name"],
                    labels=tuple(sorted(row.get("labels", {}).items())),
                    value=math.nan if value is None else float(value),
                    kind=row.get("kind", "gauge")))
            else:
                other.append({"type": kind, **row})
    return manifest, samples, other


# --------------------------------------------------------------------------- #
# Prometheus text exposition
# --------------------------------------------------------------------------- #
def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _unescape(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        if v[i] == "\\" and i + 1 < len(v):
            out.append({"n": "\n", '"': '"', "\\": "\\"}.get(v[i + 1],
                                                            v[i + 1]))
            i += 2
        else:
            out.append(v[i])
            i += 1
    return "".join(out)


def prometheus_text(samples: Optional[Sequence[Sample]] = None, *,
                    registry: MetricsRegistry = REGISTRY) -> str:
    """Render samples in the Prometheus text exposition format."""
    if samples is None:
        samples = registry.collect()
    lines: List[str] = []
    seen_meta = set()
    for s in samples:
        if s.name not in seen_meta:
            seen_meta.add(s.name)
            if s.help:
                lines.append(f"# HELP {s.name} {s.help}")
            lines.append(f"# TYPE {s.name} {s.kind}")
        if s.labels:
            lab = ",".join(f'{k}="{_escape(str(v))}"' for k, v in s.labels)
            lines.append(f"{s.name}{{{lab}}} {s.value:.17g}")
        else:
            lines.append(f"{s.name} {s.value:.17g}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> List[Sample]:
    """Parse a text exposition back into :class:`Sample` rows.

    Covers what :func:`prometheus_text` emits (single-line samples,
    escaped label values); ``# TYPE`` lines restore each sample's kind.
    """
    kinds: Dict[str, str] = {}
    samples: List[Sample] = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 4 and parts[1] == "TYPE":
                kinds[parts[2]] = parts[3]
            continue
        if "{" in line:
            name, rest = line.split("{", 1)
            lab_str, value = rest.rsplit("}", 1)
            labels = []
            for item in _split_labels(lab_str):
                k, v = item.split("=", 1)
                labels.append((k, _unescape(v.strip('"'))))
            labels = tuple(sorted(labels))
        else:
            name, value = line.rsplit(None, 1)
            labels = ()
        samples.append(Sample(name=name, labels=labels,
                              value=float(value),
                              kind=kinds.get(name, "gauge")))
    return samples


def _split_labels(lab_str: str) -> List[str]:
    """Split ``k1="v1",k2="v2"`` at commas outside quoted values."""
    items, buf, in_q, esc = [], [], False, False
    for ch in lab_str:
        if esc:
            buf.append(ch)
            esc = False
        elif ch == "\\":
            buf.append(ch)
            esc = True
        elif ch == '"':
            buf.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            items.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        items.append("".join(buf))
    return items
