"""Observability layer: metrics registry, in-scan taps, health, export.

Import DAG discipline: :mod:`repro.obs.metrics` is a plain-Python leaf
(no jax, no repro imports) so any layer may depend on it;
:mod:`repro.obs.taps` adds jax-side tap helpers; :mod:`repro.obs.health`
and :mod:`repro.obs.export` sit on top and only ever import *down* (or
lazily), so serve/sched/calibrate can import obs without cycles.

Keep this module light — submodules hold the real surface.  The eager
re-exports below are the host-side spine everything else hangs off.
"""
from .metrics import (REGISTRY, Counter, Gauge, MetricsRegistry, Sample,
                      StreamingHistogram, TraceCounter, cache_stats,
                      clear_caches, observe_span, trace_counts)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "MetricsRegistry", "Sample",
    "StreamingHistogram", "TraceCounter", "cache_stats", "clear_caches",
    "observe_span", "trace_counts",
]
