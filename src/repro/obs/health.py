"""Fleet "aging odometer" health snapshot.

The serving-side answer to the paper's on-chip monitors: given a
:class:`repro.core.fleet.FleetRuntime` (and optionally the results of a
co-sim or online-serve run), produce one structured, renderable snapshot
per aging unit —

* **ΔVth** (worst operator domain) — the aging-monitor readout;
* **guardband headroom** — ``t_clk − delay`` of the worst domain, the
  timing-margin sensor the AVS loop guards;
* **ETA-to-threshold** — remaining margin converted to *time*: the first
  trajectory epoch at which a domain's delay exceeds its ``delay_max``
  with the supply already pinned at ``v_max`` (no boost left to spend),
  read off the fleet's existing lifetime extrapolation — minus the unit's
  current age;
* **admitted BER** and the AVS-chosen supply;
* plus process-level context: compile-cache hit rates and
  compile-vs-warm span timings from :data:`repro.obs.metrics.REGISTRY`.

Everything here is host-side numpy over arrays the fleet has already
computed (trajectories are cached; the snapshot is cached between age
changes) — taking a health reading never traces, compiles or perturbs
anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np

from . import metrics as obs_metrics

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0

__all__ = ["FleetHealth", "fleet_health", "eta_to_threshold_s"]


def eta_to_threshold_s(fleet, eps: float = 1e-6) -> np.ndarray:
    """Per-unit seconds of service left before AVS runs out of guardband.

    A unit is *exhausted* at the first trajectory grid time where some
    operator domain's delay exceeds its policy ``delay_max`` while the
    supply sits at ``v_max`` (within ``eps``) — the boost ladder has no
    rung left.  Returns ``(N*S,)`` seconds from each unit's current age
    to that point; ``inf`` for units whose horizon never reaches it, 0.0
    for units already past it.
    """
    traj = fleet.trajectories                          # (U, O, T) series
    scn = fleet.unit_scenario
    U = np.asarray(traj.V).shape[0]
    dmax = np.asarray(fleet.policy.thresholds(scn, fleet.operators),
                      np.float64)
    dmax = np.broadcast_to(dmax, np.asarray(traj.delay).shape[:2])
    v_max = np.broadcast_to(
        np.asarray(scn.v_max, np.float64).reshape(-1, 1),
        np.asarray(traj.V).shape[:2])
    exhausted = (np.asarray(traj.delay) > dmax[..., None]) \
        & (np.asarray(traj.V) >= v_max[..., None] - eps)
    hit = exhausted.any(axis=1)                        # (U, T) any domain
    t = np.broadcast_to(np.asarray(traj.t, np.float64),
                        exhausted.shape)[:, 0, :]      # (U, T) grid times
    first = np.where(hit.any(axis=-1),
                     t[np.arange(U), hit.argmax(axis=-1)], np.inf)
    ages = np.asarray(fleet.ages_years, np.float64).reshape(-1) \
        * SECONDS_PER_YEAR
    return np.maximum(first - ages, 0.0)


@dataclasses.dataclass
class FleetHealth:
    """One health reading of a fleet: per-unit arrays plus process context.

    Per-unit fields are ``(N*S,)`` in the fleet's device-major unit order
    (units == devices when unsharded).  ``cache_stats`` / ``spans`` come
    from the metrics registry at snapshot time; ``extra`` carries
    run-specific scalars (e.g. online-serving latency percentiles).
    """

    operators: tuple
    n_shards: int
    age_years: np.ndarray            # (U,)
    dvth_p_mv: np.ndarray            # (U,) worst-domain ΔVth_p
    headroom_s: np.ndarray           # (U,) worst-domain t_clk - delay
    v_dd: np.ndarray                 # (U,) max-domain supply
    ber: np.ndarray                  # (U,) worst-domain admitted BER
    eta_s: np.ndarray                # (U,) seconds to threshold (inf ok)
    cache_stats: Dict[str, Dict[str, int]]
    spans: Dict[str, Dict[str, float]]
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def n_units(self) -> int:
        return int(self.age_years.shape[0])

    def to_dict(self) -> Dict:
        """JSON-able form (inf ETAs become None)."""
        eta = [None if math.isinf(v) else float(v) for v in self.eta_s]
        return {
            "operators": list(self.operators),
            "n_shards": self.n_shards,
            "units": [{
                "unit": i,
                "age_years": float(self.age_years[i]),
                "dvth_p_mv": float(self.dvth_p_mv[i]),
                "headroom_ps": float(self.headroom_s[i] * 1e12),
                "v_dd": float(self.v_dd[i]),
                "ber": float(self.ber[i]),
                "eta_years": (None if eta[i] is None
                              else eta[i] / SECONDS_PER_YEAR),
            } for i in range(self.n_units)],
            "cache_stats": self.cache_stats,
            "spans": self.spans,
            "extra": dict(self.extra),
        }

    def render(self) -> str:
        """Plain-text per-unit health table (+ cache / span footers)."""
        hdr = (f"{'unit':>5} {'age[yr]':>8} {'dVth[mV]':>9} "
               f"{'margin[ps]':>11} {'Vdd[V]':>7} {'BER':>9} "
               f"{'ETA[yr]':>8}")
        lines = ["fleet health — aging odometer", hdr, "-" * len(hdr)]
        for i in range(self.n_units):
            eta = self.eta_s[i] / SECONDS_PER_YEAR
            eta_s = "   inf" if math.isinf(eta) else f"{eta:6.2f}"
            label = (f"{i // self.n_shards}.{i % self.n_shards}"
                     if self.n_shards > 1 else f"{i}")
            lines.append(
                f"{label:>5} {self.age_years[i]:8.2f} "
                f"{self.dvth_p_mv[i]:9.2f} "
                f"{self.headroom_s[i] * 1e12:11.1f} "
                f"{self.v_dd[i]:7.3f} {self.ber[i]:9.2e} {eta_s:>8}")
        if self.extra:
            lines.append("")
            lines.append("run metrics:")
            for k in sorted(self.extra):
                lines.append(f"  {k:<24} {self.extra[k]:.6g}")
        if self.cache_stats:
            lines.append("")
            lines.append("compile caches (hit/miss/evict):")
            for name, s in sorted(self.cache_stats.items()):
                lines.append(f"  {name:<20} {s['hits']:>6} {s['misses']:>6} "
                             f"{s['evictions']:>6}  ({s['currsize']}"
                             f"/{s['maxsize']} entries)")
        if self.spans:
            lines.append("")
            lines.append("span timings [s] (count / p50 / p99):")
            for name, s in sorted(self.spans.items()):
                lines.append(f"  {name:<26} {s['count']:>5.0f} "
                             f"{s['p50']:.4g} {s['p99']:.4g}")
        return "\n".join(lines)


def _span_summaries(registry) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for name in registry.names():
        m = registry.get(name)
        if isinstance(m, obs_metrics.StreamingHistogram) and \
                (name.endswith("_s") and m.count):
            out[name] = {"count": float(m.count), "p50": m.p50,
                         "p99": m.p99, "mean": m.mean}
    return out


def fleet_health(fleet, *, online_result=None,
                 registry=None) -> FleetHealth:
    """Take one health reading of ``fleet``.

    ``online_result`` (an :class:`repro.serve.online.OnlineServeResult`)
    folds a serve run's queue metrics — p50/p99 latency, drop rate,
    tok/s — into the snapshot's ``extra`` block.  ``registry`` defaults
    to the process-global :data:`repro.obs.metrics.REGISTRY` (cache
    stats and span timings are read from it, never mutated).
    """
    registry = registry or obs_metrics.REGISTRY
    snap = fleet.snapshot()
    t_clk = np.broadcast_to(
        np.asarray(fleet.unit_scenario.t_clk, np.float64).reshape(-1, 1),
        snap.delay.shape)
    extra: Dict[str, float] = {}
    if online_result is not None:
        extra.update({"p50_latency_steps": online_result.p50,
                      "p99_latency_steps": online_result.p99,
                      "drop_rate": online_result.drop_rate,
                      "tok_per_s": online_result.tok_per_s,
                      "n_completed": float(online_result.n_completed)})
    return FleetHealth(
        operators=fleet.operators,
        n_shards=fleet.n_shards,
        age_years=np.asarray(fleet.ages_years, np.float64).reshape(-1),
        dvth_p_mv=np.asarray(snap.dvth_p_mv, np.float64).max(axis=-1),
        headroom_s=(t_clk - np.asarray(snap.delay, np.float64)).min(axis=-1),
        v_dd=np.asarray(snap.v_dd, np.float64).max(axis=-1),
        ber=np.asarray(snap.ber, np.float64).max(axis=-1),
        eta_s=eta_to_threshold_s(fleet),
        cache_stats=obs_metrics.cache_stats(),
        spans=_span_summaries(registry),
        extra=extra,
    )
