"""Host-layer metrics registry: counters, gauges, streaming histograms.

Everything here is plain Python on the host — the jitted graphs never see
these objects.  The registry is the single spine the rest of the repo's
telemetry hangs off:

* :class:`Counter` / :class:`Gauge` — monotone totals and last-value
  instruments;
* :class:`StreamingHistogram` — a log-bucketed streaming histogram with
  bounded relative error: ``observe`` is O(1), quantiles (p50/p99) read
  off the cumulative bucket walk, and :meth:`StreamingHistogram.merge`
  is *exactly associative* (per-bucket counts add), so shard- or
  process-local histograms fold into fleet-wide ones without bias;
* :class:`TraceCounter` — a ``collections.Counter`` subclass that keeps
  the repo's historical ``TRACE_COUNTS`` protocol (``dict(...)`` before /
  after comparisons, ``+= 1`` ticks inside traced bodies) while living
  in the registry: :func:`trace_counts` is the *unified* retrace guard
  across ``serve/steps``, ``sched/lifetime`` and
  ``calibrate/resilience_sweep``;
* the compile-cache registry — :class:`repro.serve.engine.CompiledFnCache`
  instances register themselves here so :func:`cache_stats` /
  :func:`clear_caches` see every serve-side compiled-fn cache without the
  obs layer importing the serve layer (no import cycle: serve imports
  obs, never the reverse).

:func:`MetricsRegistry.collect` flattens everything (plus any registered
collectors) into :class:`Sample` rows — what
:func:`repro.obs.export.prometheus_text` renders.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "StreamingHistogram", "TraceCounter", "Sample",
    "MetricsRegistry", "REGISTRY", "register_cache", "cache_stats",
    "clear_caches", "trace_counts", "observe_span",
]


@dataclasses.dataclass(frozen=True)
class Sample:
    """One exported metric row: ``name{labels} value``."""

    name: str
    labels: Tuple[Tuple[str, str], ...]
    value: float
    kind: str = "gauge"            # counter | gauge | histogram
    help: str = ""


class Counter:
    """Monotone total."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, "counters only go up"
        self.value += float(amount)

    def samples(self) -> Iterable[Sample]:
        yield Sample(self.name + "_total", (), self.value, "counter",
                     self.help)


class Gauge:
    """Last-set value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)

    def samples(self) -> Iterable[Sample]:
        if not math.isnan(self.value):
            yield Sample(self.name, (), self.value, "gauge", self.help)


class StreamingHistogram:
    """Log-bucketed streaming histogram with relative-error-bounded
    quantiles and exactly-associative merge.

    Positive observations land in bucket ``floor(log(v) / log(growth))``
    — every bucket spans a fixed ``growth`` ratio, so a quantile read off
    a bucket's geometric midpoint is within a factor ``growth`` of some
    order statistic at the target rank (the property
    ``tests/test_obs_metrics.py`` asserts against ``np.quantile``).
    Non-positive observations (latency/telemetry metrics are naturally
    ``>= 0``; zeros happen) collapse into one underflow bucket whose
    quantile estimate is the exact running ``min``.  ``count/sum/min/max``
    are exact.

    ``merge`` adds per-bucket counts — associative and commutative by
    construction, so partial histograms from different shards/processes
    fold in any order to the identical state.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", growth: float = 1.05):
        assert growth > 1.0
        self.name = name
        self.help = help
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.buckets: Dict[int, int] = {}
        self.n_nonpos = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # ------------------------------------------------------------------ #
    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self.n_nonpos += 1
            return
        b = math.floor(math.log(v) / self._log_g)
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(v)

    # ------------------------------------------------------------------ #
    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], within a ``growth`` factor
        of the exact order statistic (NaN on an empty histogram)."""
        if self.count == 0:
            return math.nan
        q = min(max(float(q), 0.0), 1.0)
        target = min(max(int(math.ceil(q * self.count)), 1), self.count)
        if target <= self.n_nonpos:
            return self.min
        cum = self.n_nonpos
        for b in sorted(self.buckets):
            cum += self.buckets[b]
            if cum >= target:
                mid = math.exp((b + 0.5) * self._log_g)
                return min(max(mid, self.min), self.max)
        return self.max                          # numerically unreachable

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    # ------------------------------------------------------------------ #
    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Return a new histogram holding both streams (exact fold)."""
        assert math.isclose(self.growth, other.growth), \
            "cannot merge histograms with different bucket growth"
        out = StreamingHistogram(self.name, self.help, self.growth)
        out.buckets = dict(self.buckets)
        for b, c in other.buckets.items():
            out.buckets[b] = out.buckets.get(b, 0) + c
        out.n_nonpos = self.n_nonpos + other.n_nonpos
        out.count = self.count + other.count
        out.sum = self.sum + other.sum
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        return out

    def state(self) -> Dict:
        """Comparable/serialisable snapshot (merge-associativity tests)."""
        return {"buckets": dict(self.buckets), "n_nonpos": self.n_nonpos,
                "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max}

    def samples(self) -> Iterable[Sample]:
        yield Sample(self.name + "_count", (), float(self.count),
                     "histogram", self.help)
        yield Sample(self.name + "_sum", (), self.sum, "histogram",
                     self.help)
        if self.count:
            for q in (0.5, 0.99):
                yield Sample(self.name, (("quantile", f"{q:g}"),),
                             self.quantile(q), "histogram", self.help)


class TraceCounter(collections.Counter):
    """A ``TRACE_COUNTS`` counter that lives in the metrics registry.

    Subclasses ``collections.Counter`` so every historical idiom keeps
    working unchanged — ``TRACE_COUNTS["generate"] += 1`` inside a traced
    body, ``dict(TRACE_COUNTS)`` before/after snapshots in the
    zero-retrace tests, ``.clear()`` in fixtures — while the registry
    exports each site as a labelled ``repro_trace_total`` sample and
    :func:`trace_counts` folds every registered instance into the one
    unified retrace guard.
    """

    def __init__(self, name: str):
        super().__init__()
        self.name = name

    def samples(self) -> Iterable[Sample]:
        for site, n in sorted(self.items()):
            yield Sample("repro_trace_total",
                         (("registry", self.name), ("site", str(site))),
                         float(n), "counter",
                         "times jax traced an instrumented function body")


# --------------------------------------------------------------------------- #
# the registry
# --------------------------------------------------------------------------- #
class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    ``collect()`` flattens every instrument (and every registered
    collector's extra samples) into :class:`Sample` rows for export.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = collections.OrderedDict()
        self._collectors: List[Callable[[], Iterable[Sample]]] = []

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  growth: float = 1.05) -> StreamingHistogram:
        return self._get(name, StreamingHistogram, help=help, growth=growth)

    def trace_counter(self, name: str) -> TraceCounter:
        m = self._metrics.get(name)
        if m is None:
            m = TraceCounter(name)
            self._metrics[name] = m
        assert isinstance(m, TraceCounter)
        return m

    def add_collector(self, fn: Callable[[], Iterable[Sample]]) -> None:
        self._collectors.append(fn)

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return list(self._metrics)

    def collect(self) -> List[Sample]:
        out: List[Sample] = []
        for m in self._metrics.values():
            out.extend(m.samples())
        for fn in self._collectors:
            out.extend(fn())
        return out

    def reset(self) -> None:
        """Zero every instrument (tests); trace counters clear too."""
        for m in list(self._metrics.values()):
            if isinstance(m, TraceCounter):
                m.clear()
            elif isinstance(m, Counter):
                m.value = 0.0
            elif isinstance(m, Gauge):
                m.value = math.nan
            elif isinstance(m, StreamingHistogram):
                fresh = StreamingHistogram(m.name, m.help, m.growth)
                self._metrics[m.name] = fresh


REGISTRY = MetricsRegistry()


def observe_span(name: str, seconds: float,
                 registry: MetricsRegistry = REGISTRY) -> None:
    """Record one wall-clock span into a streaming histogram."""
    registry.histogram(name, help="wall-clock span [s]").observe(seconds)


def trace_counts(registry: MetricsRegistry = REGISTRY) -> Dict[str, int]:
    """The unified retrace guard: every registered ``TraceCounter`` site,
    flattened to ``{"<registry>.<site>": ticks}``.

    A steady-state serve/co-sim loop must leave this dict unchanged —
    enabling/disabling or re-reading telemetry taps included (asserted by
    ``tests/test_obs_taps.py``).
    """
    out: Dict[str, int] = {}
    for m in registry._metrics.values():
        if isinstance(m, TraceCounter):
            for site, n in m.items():
                out[f"{m.name}.{site}"] = int(n)
    return out


# --------------------------------------------------------------------------- #
# compile-cache registry (populated by repro.serve.engine.CompiledFnCache)
# --------------------------------------------------------------------------- #
_CACHES: list = []


def register_cache(cache) -> None:
    """Called by ``CompiledFnCache.__init__`` — obs never imports serve."""
    _CACHES.append(cache)


def cache_stats() -> Dict[str, Dict[str, int]]:
    """Per-cache ``{currsize, maxsize, hits, misses, evictions}``."""
    return {c.name: c.stats() for c in _CACHES}


def clear_caches() -> None:
    """Drop every cached compiled function (and its XLA executables)."""
    for c in _CACHES:
        c.clear()


def _cache_samples() -> Iterable[Sample]:
    for c in _CACHES:
        s = c.stats()
        for field in ("hits", "misses", "evictions"):
            yield Sample(f"repro_compile_cache_{field}_total",
                         (("cache", c.name),), float(s[field]), "counter",
                         "compiled-fn cache " + field)
        yield Sample("repro_compile_cache_size", (("cache", c.name),),
                     float(s["currsize"]), "gauge",
                     "compiled-fn cache entries")


REGISTRY.add_collector(_cache_samples)
