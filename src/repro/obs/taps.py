"""Zero-retrace in-scan telemetry taps.

The taps are *aux outputs of the one jitted dispatch*: cheap per-step /
per-epoch scalars (top-logit health, ΔVth, guardband headroom, boost
counts, ...) computed **unconditionally inside the already-traced graph**
and returned alongside the primary result as a :class:`Telemetry`
pytree.  The on/off toggle (:func:`enable_taps` / :func:`taps_enabled`)
is **host-side only**: it controls whether engines transfer the aux
leaves to host and record them into :data:`repro.obs.metrics.REGISTRY`
— never what gets traced.  Two properties follow by construction:

* **zero-retrace** — toggling or re-reading taps dispatches the same
  compiled executable (the unified :func:`repro.obs.metrics.trace_counts`
  guard asserts this across serve, online, sharded and co-sim paths);
* **bit-exact** — the primary outputs are the same jaxpr either way, so
  tokens/trajectories with taps enabled are *identical* to disabled.

The aux scalars themselves cost O(batch) FLOPs per step against the
O(batch·d_model²) matmuls of the step body — the ≤1.10× overhead guard
in ``benchmarks/obs_bench.py`` measures the *host* read/record cost.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["Telemetry", "taps_enabled", "enable_taps", "logit_taps",
           "cosim_taps", "telemetry_to_host"]


@jax.tree_util.register_pytree_node_class
class Telemetry:
    """A named bundle of traced telemetry arrays.

    A thin pytree wrapper over ``{signal name: array}`` so tap bundles
    flow through ``jit`` / ``scan`` / ``vmap`` / GSPMD like any other
    output: under :func:`repro.serve.engine.FleetServeEngine`'s vmapped
    dispatch every leaf simply gains the lane axis.  Keys are sorted into
    the treedef (static), values are the leaves (traced).
    """

    def __init__(self, series: Optional[Dict[str, Any]] = None):
        self.series: Dict[str, Any] = dict(series or {})

    def __getitem__(self, key: str):
        return self.series[key]

    def __contains__(self, key: str) -> bool:
        return key in self.series

    def keys(self):
        return self.series.keys()

    def items(self):
        return self.series.items()

    def __repr__(self):
        return f"Telemetry({sorted(self.series)})"

    def tree_flatten(self):
        names = tuple(sorted(self.series))
        return tuple(self.series[k] for k in names), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        return cls(dict(zip(names, leaves)))


# --------------------------------------------------------------------------- #
# host-side toggle — deliberately NOT visible to any traced function
# --------------------------------------------------------------------------- #
_ENABLED = [False]


def taps_enabled() -> bool:
    """Whether engines read telemetry back to host and record it."""
    return _ENABLED[0]


@contextlib.contextmanager
def enable_taps(on: bool = True):
    """Context manager flipping the host-side taps toggle.

    Purely host state: the jitted graphs always compute their aux
    outputs, so entering/leaving this context can never trigger a
    retrace or perturb the primary results.
    """
    prev = _ENABLED[0]
    _ENABLED[0] = bool(on)
    try:
        yield
    finally:
        _ENABLED[0] = prev


# --------------------------------------------------------------------------- #
# traced tap builders
# --------------------------------------------------------------------------- #
def logit_taps(logits: jnp.ndarray,
               active: Optional[jnp.ndarray] = None) -> Dict[str, Any]:
    """Per-step serving-health scalars from a ``(batch, vocab)`` logit slab.

    Two signals that degrade monotonically as admitted BER corrupts the
    forward pass: the batch-mean max logit (bit-flips in late layers
    crater it) and the batch-mean top1−top2 margin (sampling confidence).
    ``active`` (online serving) masks out idle slots whose logits are
    garbage; with no live slot the masked means are 0 by convention.
    """
    top2 = jax.lax.top_k(logits, 2)[0]              # (batch, 2)
    peak = top2[:, 0]
    margin = top2[:, 0] - top2[:, 1]
    if active is not None:
        w = active.astype(logits.dtype)
        denom = jnp.maximum(jnp.sum(w), 1.0)
        return {"logit_max": jnp.sum(peak * w) / denom,
                "logit_margin": jnp.sum(margin * w) / denom}
    return {"logit_max": jnp.mean(peak),
            "logit_margin": jnp.mean(margin)}


def cosim_taps(cos, scenario) -> "Telemetry":
    """Derive the per-epoch aging odometer from a co-sim trajectory.

    Input is a :class:`repro.sched.lifetime.CoSimTrajectory` (epoch axis
    leading, fields ``(E, N, O)``); output leaves are device-leading
    ``(N, E)`` per-device series:

    * ``dvth_eff_mv`` — effective PMOS ΔVth, worst operator domain: the
      paper's aging-monitor readout (recovery-aware when the short-term
      pool ran);
    * ``dvth_mono_mv`` — the monotone total from the per-population
      state, whose gap to ``dvth_eff_mv`` is recovered headroom;
    * ``headroom_s`` — guardband headroom ``t_clk − delay`` (worst
      operator), the timing-margin sensor;
    * ``vdd_v`` — the AVS-chosen supply (max over domains);
    * ``util`` — routed utilization;
    * ``t_node_k`` — closed-loop thermal-node temperature (when run);
    * ``boosts`` — per-epoch AVS boost-event counts (when recorded).

    Pure post-processing of arrays the scan already produced — reading
    the odometer never adds a trace.
    """
    from repro.core import aging
    dvp = jnp.asarray(cos.dvp)                          # (E, N, O) effective
    dv = jnp.asarray(cos.dv)                            # (E, N, O, P) monotone
    pm = jnp.asarray(aging.IS_PMOS, dv.dtype)
    mono_p = jnp.sum(dv * pm, axis=-1)                  # (E, N, O)
    t_clk = jnp.asarray(scenario.t_clk, dvp.dtype).reshape(-1)  # (N,) or (1,)
    dev = lambda x: jnp.moveaxis(x, 0, 1)               # (E, N) -> (N, E)
    series = {
        "dvth_eff_mv": dev(jnp.max(dvp, axis=-1)),
        "dvth_mono_mv": dev(jnp.max(mono_p, axis=-1)),
        "headroom_s": dev(t_clk - jnp.max(jnp.asarray(cos.delay), axis=-1)),
        "vdd_v": dev(jnp.max(jnp.asarray(cos.V), axis=-1)),
        "util": dev(jnp.asarray(cos.util)),
    }
    if getattr(cos, "t_node", None) is not None:
        series["t_node_k"] = dev(jnp.asarray(cos.t_node))
    if getattr(cos, "boosts", None) is not None:
        series["boosts"] = dev(jnp.asarray(cos.boosts))
    return Telemetry(series)


def telemetry_to_host(telem: Optional["Telemetry"]) -> Optional[Dict[str, Any]]:
    """One blocking device->host transfer of every tap leaf (numpy)."""
    if telem is None:
        return None
    import numpy as np
    return {k: np.asarray(v) for k, v in telem.items()}
