"""Lifetime design study: sweep the user's accuracy budget and the clock
guardband to map the reliability/efficiency trade space — the what-if tool
the paper's framework enables (Sec. V: "readily extends to other
applications by parameterizing the acceptable timing-violation level").

Run:  PYTHONPATH=src python examples/lifetime_study.py
"""
import dataclasses

import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.policy import FaultTolerantPolicy, evaluate_policy


def main():
    cal = load_calibration()

    print("== accuracy budget sweep (fault-tolerant AVS) ==")
    print(f"{'loss budget':>12} | {'avg saving':>10} | {'V_final(o)':>10} | "
          f"{'ΔVth,p(q)':>10}")
    for budget in (0.1, 0.5, 1.0, 2.0):
        pol = FaultTolerantPolicy(ber_model=cal.ber, max_loss_pct=budget)
        res = evaluate_policy(pol, cal.aging, cal.delay_poly, cal.power,
                              cal.lifetime_cfg)
        print(f"{budget:11.1f}% | {res['avg_power_saving_pct']:9.1f}% | "
              f"{res['o']['v_final']:9.2f}V | "
              f"{res['q']['dvp_final']:8.1f}mV")

    print("\n== clock guardband sweep (baseline AVS boost count) ==")
    print(f"{'t_clk [ns]':>10} | {'V_final':>8} | {'boosts':>6} | "
          f"{'ΔVth,p':>8}")
    from repro.core.avs import run_lifetime
    for tclk in (1.55e-9, 1.60e-9, 1.65e-9, 1.70e-9):
        cfg = dataclasses.replace(cal.lifetime_cfg, t_clk=tclk)
        traj = run_lifetime(cal.aging, cal.delay_poly, cfg, delay_max=tclk)
        V = np.asarray(traj["V"])
        boosts = int(np.count_nonzero(np.diff(V) > 1e-6))
        print(f"{tclk * 1e9:10.2f} | {float(V[-1]):7.2f}V | {boosts:6d} | "
              f"{float(np.asarray(traj['dvp'])[-1]):6.1f}mV")

    print("\nTighter clocks force more boosts (the aging/voltage positive "
          "feedback); a larger accuracy budget defers them — quantifying "
          "the paper's central trade.")


if __name__ == "__main__":
    main()
