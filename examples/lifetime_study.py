"""Lifetime design study: sweep the user's accuracy budget, the mission
duty factor and the clock guardband to map the reliability/efficiency trade
space — the what-if tool the paper's framework enables (Sec. V: "readily
extends to other applications by parameterizing the acceptable
timing-violation level").

With the pytree Scenario API the whole budget x duty grid — every operator
domain of every cell — runs as ONE vmapped ``simulate`` call: a single
trace/compile instead of a Python loop that re-traces per point.

Run:  PYTHONPATH=src python examples/lifetime_study.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.avs import simulate
from repro.core.policy import BaselinePolicy, FaultTolerantPolicy, sweep_policy
from repro.core.power import batched_lifetime_stats
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario, scenario_grid


def main():
    cal = load_calibration()
    base = Scenario.from_lifetime_config(cal.lifetime_cfg)
    policy = FaultTolerantPolicy(ber_model=cal.ber)

    budgets = [0.1, 0.5, 1.0, 2.0]
    duties = [0.3, 0.5, 0.7]
    grid = scenario_grid(base, max_loss_pct=budgets, duty=duties)
    n = grid.n_scenarios * len(OPERATORS)
    t0 = time.time()
    traj = sweep_policy(policy, cal.aging, cal.delay_poly, grid)
    # baseline ignores the budget axis -> simulate the duty axis only
    base_traj = sweep_policy(BaselinePolicy(t_clk=cal.lifetime_cfg.t_clk),
                             cal.aging, cal.delay_poly,
                             scenario_grid(base, duty=duties))
    print(f"== {len(budgets)}x{len(duties)} scenario grid x "
          f"{len(OPERATORS)} domains = {n} lifetimes in one vmapped call "
          f"({time.time() - t0:.1f}s incl. compile) ==\n")

    stats = batched_lifetime_stats(cal.power, traj)
    bstats = batched_lifetime_stats(cal.power, base_traj)
    saving = 100.0 * (1.0 - stats["p_avg"] / bstats["p_avg"][None])
    i_o = OPERATORS.index("o")
    i_q = OPERATORS.index("q")

    print(f"{'loss budget':>12} | {'duty':>5} | {'avg saving':>10} | "
          f"{'V_final(o)':>10} | {'ΔVth,p(q)':>10}")
    for bi, budget in enumerate(budgets):
        for di, duty in enumerate(duties):
            print(f"{budget:11.1f}% | {duty:5.1f} | "
                  f"{saving[bi, di].mean():9.1f}% | "
                  f"{stats['v_final'][bi, di, i_o]:9.2f}V | "
                  f"{stats['dvp_final'][bi, di, i_q]:8.1f}mV")

    print("\n== clock guardband sweep (baseline AVS boost count) — one "
          "batched call ==")
    tclks = jnp.asarray([1.55e-9, 1.60e-9, 1.65e-9, 1.70e-9])
    gtraj = simulate(cal.aging, cal.delay_poly, base.replace(t_clk=tclks),
                     delay_max=tclks)
    V = np.asarray(gtraj.V)
    print(f"{'t_clk [ns]':>10} | {'V_final':>8} | {'boosts':>6} | "
          f"{'ΔVth,p':>8}")
    for i, tclk in enumerate(np.asarray(tclks)):
        boosts = int(np.count_nonzero(np.diff(V[i]) > 1e-6))
        print(f"{tclk * 1e9:10.2f} | {float(V[i, -1]):7.2f}V | {boosts:6d} | "
              f"{float(np.asarray(gtraj.dvp)[i, -1]):6.1f}mV")

    print("\nTighter clocks force more boosts (the aging/voltage positive "
          "feedback); a larger accuracy budget defers them; higher duty "
          "accelerates BTI — the whole trade space from one traced scan.")


if __name__ == "__main__":
    main()
