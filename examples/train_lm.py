"""End-to-end training driver: a ~100M-parameter LLaMA-class model for a
few hundred steps on the deterministic synthetic pipeline, with the full
fault-tolerant loop (async checkpoints, auto-resume, straggler watchdog).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

On this CPU container the default config is ~100M params (d=512, 8 layers);
the same script scales to any zoo config with --arch/--full + the
production mesh via repro.launch.train.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.steps import init_train_state, make_train_step


def lm_100m():
    """~100M-param llama-family config (CPU-trainable)."""
    base = get_config("llama3_8b")
    return dataclasses.replace(
        base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=1408, vocab=32768, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_100m()
    n_params = cfg.param_count()
    print(f"[train_lm] {cfg.name}-reduced: {n_params / 1e6:.1f}M params")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)
    opt = AdamWConfig(lr=1e-3, total_steps=args.steps,
                      warmup_steps=args.steps // 10)
    step = jax.jit(make_train_step(cfg, opt, microbatches=2, remat=True),
                   donate_argnums=(0,))

    def make_batch(s):
        tb = data.batch_at(s)
        import jax.numpy as jnp
        return {"tokens": jnp.asarray(tb.tokens),
                "labels": jnp.asarray(tb.labels)}

    loop = TrainLoop(step, data, ckpt_dir=args.ckpt_dir,
                     cfg=LoopConfig(total_steps=args.steps, log_every=20,
                                    ckpt_every=100),
                     make_batch=make_batch)
    loop.run(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))

    losses = [h["loss"] for h in loop.history]
    print(f"[train_lm] loss: first5={np.mean(losses[:5]):.3f} "
          f"last5={np.mean(losses[-5:]):.3f} "
          f"(uniform={data.uniform_nll():.3f}, "
          f"oracle={data.oracle_nll():.3f})")
    assert np.mean(losses[-5:]) < data.uniform_nll() - 1.0, \
        "model failed to learn"
    print("[train_lm] OK — model learned the synthetic distribution")


if __name__ == "__main__":
    main()
