"""Quickstart: the paper's pipeline in ~60 lines.

1. Load the calibrated aging framework (BTI/HCI compact models, fitted
   delay polynomial, BER curve, power model).
2. Simulate a 10-year AVS lifetime for the classical policy and for the
   paper's fault-tolerant policy.
3. Serve a (reduced) LLaMA-class model on a simulated 9-year-old device:
   every matmul runs at the BER its voltage domain admits.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.policy import FaultTolerantPolicy, evaluate_policy
from repro.core.fleet import FleetRuntime
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state


def main():
    # --- 1. calibrated physics core -----------------------------------
    cal = load_calibration()
    print(f"nominal critical path: "
          f"{float(cal.delay_poly(0, 0, 0.9)) * 1e9:.3f} ns @ 0.90 V "
          f"(paper: 1.542 ns)")

    # --- 2. lifetime policies ------------------------------------------
    res = evaluate_policy(FaultTolerantPolicy(ber_model=cal.ber),
                          cal.aging, cal.delay_poly, cal.power,
                          cal.lifetime_cfg)
    b = res["baseline"]
    print(f"classical AVS : V 0.90->{b['v_final']:.2f} V, "
          f"ΔVth,p {b['dvp_final']:.1f} mV, P_avg {b['p_avg']:.2f} W")
    q = res["q"]
    print(f"fault-tolerant (Q domain): V stays {q['v_final']:.2f} V, "
          f"ΔVth,p {q['dvp_final']:.1f} mV, saves "
          f"{q['power_saving_pct']:.1f}% power")
    print(f"average lifetime power saving: "
          f"{res['avg_power_saving_pct']:.1f}% (paper: 14.0%)")

    # --- 3. aging-aware serving ----------------------------------------
    cfg = get_config("llama3_8b").reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    runtime = FleetRuntime(n_devices=1, policy="fault_tolerant")
    runtime.set_age(years=9.0)
    engine = ServeEngine(cfg, params, runtime=runtime, max_len=64)

    prompts = SyntheticLM(vocab=cfg.vocab, seq_len=16,
                          global_batch=2).batch_at(0).tokens
    out = engine.generate(prompts, 8)
    print(f"\nserved at age {out.age_years:.1f}y; per-op admitted BER:")
    for op, ber in sorted(out.bers.items()):
        print(f"  {op:5s} {ber:.2e}")
    print(f"generated tokens:\n{out.tokens}")


if __name__ == "__main__":
    main()
