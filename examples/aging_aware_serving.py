"""Aging-aware serving scenario: one accelerator, ten years, two policies.

Serves the same (reduced, briefly trained) model at ages 0/3/6/9.5 years
under (a) classical resilience-agnostic AVS and (b) the paper's
fault-tolerant policy, reporting supply voltage, admitted per-operator BER,
array power, and measured model NLL with real bit-error injection.

Run:  PYTHONPATH=src python examples/aging_aware_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.runtime import AgingAwareRuntime
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state, make_train_step


def quick_train(cfg, data, steps=60):
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=5)))
    for i in range(steps):
        tb = data.batch_at(i)
        state, m = step(state, {"tokens": jnp.asarray(tb.tokens),
                                "labels": jnp.asarray(tb.labels)})
    return state.params, float(m["loss"])


def main():
    cfg = get_config("llama3_8b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=16)
    params, loss = quick_train(cfg, data)
    print(f"[serve] trained reduced model to loss {loss:.3f} "
          f"(uniform {data.uniform_nll():.3f})\n")

    eval_toks = data.batch_at(999).tokens
    hdr = (f"{'age':>5} | {'policy':^15} | {'V(q)':>5} {'V(o)':>5} | "
           f"{'BER(q)':>8} {'BER(o)':>8} | {'P [W]':>6} | {'NLL':>6}")
    print(hdr + "\n" + "-" * len(hdr))
    for years in (0.0, 3.0, 6.0, 9.5):
        for ft in (False, True):
            rt = AgingAwareRuntime(fault_tolerant=ft)
            rt.set_age(years=max(years, 1e-3))
            eng = ServeEngine(cfg, params, runtime=rt, max_len=128)
            nll = eng.score(eval_toks)
            q, o = rt.domain_state("q"), rt.domain_state("o")
            print(f"{years:5.1f} | {'fault-tolerant' if ft else 'baseline':^15}"
                  f" | {q.v_dd:5.2f} {o.v_dd:5.2f} | {q.ber:8.1e} "
                  f"{o.ber:8.1e} | {rt.total_power():6.2f} | {nll:6.3f}")
    print("\nThe fault-tolerant policy holds tolerant domains (q) at "
          "0.90 V, admitting bounded BER instead of boosting — lower "
          "power at bounded quality impact (paper Sec. V-C/V-D).  The "
          "tiny demo model is less BER-resilient than the LLaMA-3-8B the "
          "default thresholds are calibrated for; recalibrate with "
          "repro.core.resilience.fit_curve for a new deployment.")


if __name__ == "__main__":
    main()
