"""Aging-aware serving scenario: a fleet of accelerators, ten years, two
policies.

Builds one :class:`FleetRuntime` per policy holding FOUR devices aged
0/3/6/9.5 years (a staggered deployment), so all ages come from the same
cached vmapped lifetime scan.  Evaluates the same (reduced, briefly
trained) model under (a) classical resilience-agnostic AVS and (b) the
paper's fault-tolerant policy, reporting supply voltage, admitted
per-operator BER, array power, and measured model NLL with real bit-error
injection.

Then serves the whole fault-tolerant fleet the production way: ONE
:class:`FleetServeEngine` dispatch — prefill + scanned decode + sampling
vmapped over all four lanes, each lane running at its own device's
policy-admitted BER vector.  Advancing the fleet's age between calls
reuses the compiled function (the BERs are traced leaves).

Then closes the measured-resilience loop: a batched fault-injection
sweep measures THIS model's per-operator BER -> loss knees and compares
them against the published defaults the policy ships with
(``recalibrate_for_deployment`` — the in-Python form of
``python -m repro.launch.calibrate_resilience``).

Closing act — wear-leveling: the staggered fleet's future is not fate.
Routing the next years of traffic with the ``wear_level`` router
(``FleetRuntime.apply_load``) instead of spreading it uniformly steers
requests away from the old/hot devices, cutting fleet-max ΔVth and the
BER the worst device must serve at — the scheduler as an aging actuator
(``python -m repro.launch.schedule`` for the full router comparison).

Run:  PYTHONPATH=src python examples/aging_aware_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.serve.engine import FleetServeEngine, ServeEngine
from repro.train.steps import init_train_state, make_train_step

AGES = (0.0, 3.0, 6.0, 9.5)


def recalibrate_for_deployment(cfg, params, tokens, *,
                               ber_grid=(1e-6, 1e-5, 1e-4, 1e-3, 1e-2),
                               n_seeds=1):
    """Measure THIS deployment's resilience curves and compare knees.

    The default thresholds are calibrated for the published (REALM-style)
    curves; a new network — here the tiny demo model — can be
    recalibrated in-repo: one batched fault-injection sweep (the whole
    BER x operator grid as vmapped lanes of one dispatch), a logistic fit
    per operator, and the fitted curves drive the same policy via
    ``--policy measured``.  The zoo-wide CLI equivalent:

        PYTHONPATH=src python -m repro.launch.calibrate_resilience \\
            --archs llama3_8b
        PYTHONPATH=src python -m repro.launch.calibrate_resilience --report
        PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \\
            --policy measured
    """
    from repro.calibrate import empirical_resilience
    from repro.core.resilience import DEFAULT_BER50

    curves, res = empirical_resilience(cfg, params, tokens,
                                       ber_grid=ber_grid, n_seeds=n_seeds)
    print("\nmeasured resilience of this deployment (vs published "
          "defaults):")
    for op in ("q", "k", "o", "down"):
        print(f"  {op:>4}: measured BER50 {curves[op].ber50:.1e} "
              f"(published {DEFAULT_BER50[op]:.1e})")
    print("The measured knees differ from the published curves in BOTH "
          "directions: tolerant domains (q, gate, up) measure 1-2 decades "
          "less resilient than the LLaMA-class defaults, while the "
          "published o/down extra-sensitivity does not reproduce at this "
          "tiny scale — either way a policy tuned on published curves is "
          "mis-tuned for this deployment.  Persist the fit with "
          "repro.launch.calibrate_resilience and serve with --policy "
          "measured to close the loop.")
    return curves


def quick_train(cfg, data, steps=60):
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=5)))
    for i in range(steps):
        tb = data.batch_at(i)
        state, m = step(state, {"tokens": jnp.asarray(tb.tokens),
                                "labels": jnp.asarray(tb.labels)})
    return state.params, float(m["loss"])


def main():
    cfg = get_config("llama3_8b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=16)
    params, loss = quick_train(cfg, data)
    print(f"[serve] trained reduced model to loss {loss:.3f} "
          f"(uniform {data.uniform_nll():.3f})\n")

    fleets = {}
    for name, pol in (("baseline", "baseline"),
                      ("fault-tolerant", "fault_tolerant")):
        fleet = FleetRuntime(n_devices=len(AGES), policy=pol)
        for i, years in enumerate(AGES):
            fleet.set_age(years=max(years, 1e-3), device=i)
        fleets[name] = fleet

    eval_toks = data.batch_at(999).tokens
    hdr = (f"{'age':>5} | {'policy':^15} | {'V(q)':>5} {'V(o)':>5} | "
           f"{'BER(q)':>8} {'BER(o)':>8} | {'P [W]':>6} | {'NLL':>6}")
    print(hdr + "\n" + "-" * len(hdr))
    for i, years in enumerate(AGES):
        for name, fleet in fleets.items():
            dev = fleet.device(i)
            eng = ServeEngine(cfg, params, runtime=dev, max_len=128)
            nll = eng.score(eval_toks)
            q, o = dev.domain_state("q"), dev.domain_state("o")
            print(f"{years:5.1f} | {name:^15}"
                  f" | {q.v_dd:5.2f} {o.v_dd:5.2f} | {q.ber:8.1e} "
                  f"{o.ber:8.1e} | {dev.total_power():6.2f} | {nll:6.3f}")

    ft = fleets["fault-tolerant"]
    bl = fleets["baseline"]
    print(f"\nfleet array power (all {len(AGES)} devices): "
          f"fault-tolerant {ft.fleet_power().sum():.2f} W vs baseline "
          f"{bl.fleet_power().sum():.2f} W "
          f"({100 * (1 - ft.fleet_power().sum() / bl.fleet_power().sum()):.1f}%"
          f" saved)")

    # ---------------------------------------------------------------- #
    # fleet-batched generation: the whole staggered fleet, ONE dispatch
    # ---------------------------------------------------------------- #
    n_steps, B = 12, 4
    prompts = data.batch_at(0).tokens[:B, :24]
    engine = FleetServeEngine(cfg, params, ft, max_len=64)
    res = engine.generate(np.stack([prompts] * len(AGES)), n_steps,
                          temperature=0.0)            # compile once
    t0 = time.perf_counter()
    res = engine.generate(np.stack([prompts] * len(AGES)), n_steps,
                          temperature=0.0)
    dt = time.perf_counter() - t0
    total = len(AGES) * B * n_steps
    print(f"\nfleet-batched generation: {res.tokens.shape} tokens "
          f"(lanes x batch x steps) in one dispatch — "
          f"{total / dt:.0f} tok/s warm")
    q = res.operators.index("q")
    for i, years in enumerate(AGES):
        print(f"  dev{i} ({res.ages_years[i]:4.1f}y, "
              f"BER(q)={res.bers[i, q]:.1e}): "
              f"{res.tokens[i, 0][:10].tolist()}")
    print("Lanes share prompts but diverge with age: older devices admit "
          "higher BER, so their upsets perturb the sampled continuations. "
          "The fault-tolerant policy holds tolerant domains (q) at 0.90 V, "
          "admitting bounded BER instead of boosting — lower power at "
          "bounded quality impact (paper Sec. V-C/V-D).")

    # ---------------------------------------------------------------- #
    # close the loop: measure THIS model's curves (not just cite them)
    # ---------------------------------------------------------------- #
    recalibrate_for_deployment(cfg, params, data.batch_at(999).tokens,
                               ber_grid=(1e-5, 1e-4, 1e-3), n_seeds=1)

    # ---------------------------------------------------------------- #
    # closing act: route the NEXT years of traffic to slow aging down
    # ---------------------------------------------------------------- #
    print("\nwear-leveling the staggered fleet's next 3 years of diurnal "
          "traffic (one jitted co-sim scan per router):")
    finals = {}
    for router in ("round_robin", "wear_level"):
        fl = FleetRuntime(n_devices=len(AGES), policy="fault_tolerant")
        for i, years in enumerate(AGES):
            fl.set_age(years=max(years, 1e-3), device=i)
        cos = fl.apply_load(workload="diurnal", router=router,
                            n_epochs=144, utilization=0.55,
                            horizon_s=3 * 365.25 * 24 * 3600.0)
        wear = cos.device_wear()[-1]
        worst = int(wear.argmax())
        finals[router] = (wear, fl.op_ber_array().max())
        print(f"  {router:>12}: fleet-max ΔVth {wear.max():6.2f} mV "
              f"(spread {wear.max() - wear.min():5.2f} mV), worst-device "
              f"BER {fl.op_ber_array()[worst].max():.1e}")
    saved = 100 * (1 - finals["wear_level"][0].max()
                   / finals["round_robin"][0].max())
    print(f"Routing alone removed {saved:.1f}% of the fleet's worst-case "
          "degradation: the wear_level router starves the 9.5-year device "
          "while the young devices absorb the diurnal peaks — the same "
          "serving stack then reads traffic-dependent BERs from "
          "fleet.op_ber_array() with nothing recompiled.")


if __name__ == "__main__":
    main()
