"""Benchmark: short-term recovery + thermal feedback in the co-sim scan.

The disruption subsystem rides entirely inside the existing jitted
co-simulation (`repro.sched.lifetime.cosimulate`): the recoverable trap
pool adds one exact exponential step per epoch and the thermal RC node
adds one power evaluation, both as extra carry slots of the SAME
``lax.scan``.  This bench measures what those physics cost and guards
the structural claims that keep them free to *operate*:

* **epochs/s** — warm throughput of the monotone baseline vs recovery
  enabled vs recovery + closed thermal loop (the overheads the scenario
  tests and the ``--scenario`` CLI pay);
* **structural guards** (wall-clock independent): each feature
  combination traces the scan body exactly ONCE, and sweeping every
  recovery-rate / thermal-RC parameter leaf afterwards re-jits NOTHING
  — scenario parameters are traced pytree leaves, not static args.

``--quick`` is the CI variant.  Results are recorded to
``BENCH_disruption.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aging import RecoveryParams
from repro.core.artifacts import load_calibration
from repro.core.constants import T_AMB
from repro.core.policy import FaultTolerantPolicy
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario
from repro.sched import ThermalParams, cosimulate, get_workload
from repro.sched import lifetime as sched_lifetime

from .common import check, table

YEAR_S = 365.25 * 24 * 3600.0


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False) -> str:
    n, E = (8, 96) if quick else (8, 480)
    reps = 2 if quick else 3
    cal = load_calibration()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
        lifetime_s=1 * YEAR_S,
        t_amb=jnp.asarray(T_AMB + np.linspace(0.0, 20.0, n), jnp.float32))
    policy = FaultTolerantPolicy(ber_model=cal.ber)
    dmax = policy.thresholds(scn, OPERATORS)
    loads = get_workload("flash_crowd", n_devices=n, utilization=0.55,
                         n_epochs=E).loads(0)
    kw = dict(router="wear_level", n_devices=n)

    variants = {
        "monotone (baseline)": {},
        "+ recovery pool": {"recovery_dynamics": True},
        "+ recovery + thermal RC": {"recovery_dynamics": True,
                                    "thermal": True},
    }
    t_warm, trace_counts = {}, {}
    for name, extra in variants.items():
        at_entry = sched_lifetime.TRACE_COUNTS["cosim"]
        out = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads,
                         **kw, **extra)
        jax.block_until_ready(out.V)

        def warm(extra=extra):
            o = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads,
                           **kw, **extra)
            jax.block_until_ready(o.V)

        t_warm[name] = _timed(warm, reps)
        trace_counts[name] = (sched_lifetime.TRACE_COUNTS["cosim"]
                              - at_entry)
    single_trace = all(c == 1 for c in trace_counts.values())

    # structural guard: sweeping EVERY recovery/thermal leaf re-jits
    # nothing (new rates, new rho, new RC constants — all traced)
    rp = RecoveryParams.default()
    before = dict(sched_lifetime.TRACE_COUNTS)
    out = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads,
                     recovery_dynamics=RecoveryParams(
                         rho=rp.rho * 0.7, k_relax=rp.k_relax * 3.0,
                         k_retrap=rp.k_retrap * 0.5),
                     thermal=ThermalParams.from_power_model(
                         cal.power, r_th=4.0, tau_s=3600.0), **kw)
    jax.block_until_ready(out.V)
    zero_retrace = dict(sched_lifetime.TRACE_COUNTS) == before

    base = t_warm["monotone (baseline)"]
    rows = [[name, f"{E}", f"{t * 1e3:.0f}ms", f"{E / t:.0f}/s",
             f"{100.0 * (t / base - 1.0):+.1f}%"]
            for name, t in t_warm.items()]
    txt = table(f"Disruption physics: {E} epochs x {n} devices x "
                f"{len(OPERATORS)} domains (flash_crowd traffic)",
                ["variant", "epochs", "wall", "epochs/s", "vs baseline"],
                rows)
    overhead = t_warm["+ recovery + thermal RC"] / base
    txt += "\n" + check("recovery + thermal stay in the same scan "
                        "(single trace per feature set)", single_trace,
                        f"traces: {trace_counts}")
    txt += "\n" + check("sweeping recovery/thermal parameter leaves "
                        "re-jits nothing", zero_retrace)
    txt += "\n" + check("full disruption physics cost < 3x the monotone "
                        "scan", overhead < 3.0, f"{overhead:.2f}x")

    record = {"mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "n_devices": n, "n_epochs": E,
              "epochs_per_s": {k: E / v for k, v in t_warm.items()},
              "thermal_recovery_overhead_x": overhead,
              "structural": {
                  "single_trace_per_feature_set": bool(single_trace),
                  "zero_retrace_on_leaf_sweep": bool(zero_retrace)}}
    path = Path(__file__).resolve().parent.parent / \
        "BENCH_disruption.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    return txt + f"\n[recorded] {path.name}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI variant: fewer epochs/reps")
    print(run(quick=ap.parse_args().quick))
