"""Benchmark: mesh-sharded serving — one sharded dispatch, per-shard aging.

Must own its process: it fakes 8 host devices via ``XLA_FLAGS`` *before*
jax initialises (run ``PYTHONPATH=src python -m benchmarks.mesh_bench``;
``benchmarks.run --only mesh`` shells out here for the same reason).

Measures, on a reduced decoder-only config over a ``("data", "model")``
mesh with tp=8:

* **sharded vs single-device generation**: compile time, warm whole-call
  wall, decode tokens/sec for the SAME cast params — plus the bit-exactness
  check the serve layout guarantees (clean graphs; the full parity matrix
  lives in ``tests/test_serve_sharded.py``).
* **per-shard aging inside one dispatch**: a shard-granular
  :class:`~repro.core.fleet.FleetRuntime` (``n_shards=8``) with staggered
  shard ages served by :class:`~repro.serve.sharded.MeshServeEngine`;
  structural guards assert the served per-shard BERs differ across shards
  and that advancing shard ages re-jits nothing
  (``serve.steps.TRACE_COUNTS``).

On the CPU container the 8 "devices" share one physical core, so sharded
wall-clock carries partitioning overhead rather than speedup — the numbers
to read are compile cost, the zero-retrace property and the parity flag.
Results are recorded to ``BENCH_mesh.json`` at the repo root.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.serve import steps as serve_steps
from repro.serve.engine import ServeEngine
from repro.serve.sharded import MeshServeEngine

from .common import check, table

ARCH = "deepseek_7b"


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _setup(batch: int, prompt_len: int):
    from repro.train.steps import init_train_state
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    data = SyntheticLM(vocab=cfg.vocab, seq_len=prompt_len,
                      global_batch=batch)
    return cfg, params, data.batch_at(0).tokens


def bench_sharded_dispatch(quick: bool):
    B, S = 2, 8
    n_steps = 4 if quick else 12
    reps = 2
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_steps + 1

    eng = MeshServeEngine(cfg, params, max_len=max_len, seed=0)
    tp = eng.tp
    t0 = time.perf_counter()
    a = eng.generate(prompts, n_steps)
    compile_sharded = time.perf_counter() - t0
    t_sharded = _timed(lambda: eng.generate(prompts, n_steps), reps)

    host_params = jax.device_get(eng.params)
    single = ServeEngine(cfg, host_params, max_len=max_len, seed=0)
    t0 = time.perf_counter()
    b = single.generate(prompts, n_steps)
    compile_single = time.perf_counter() - t0
    t_single = _timed(lambda: single.generate(prompts, n_steps), reps)

    exact = bool(np.array_equal(a.tokens, b.tokens))
    total = B * n_steps
    rows = [["single-device scanned", f"{compile_single:.1f}s",
             f"{t_single * 1e3:.0f}ms", f"{total / t_single:.0f}"],
            [f"mesh-sharded tp={tp}", f"{compile_sharded:.1f}s",
             f"{t_sharded * 1e3:.0f}ms", f"{total / t_sharded:.0f}"]]
    txt = table(f"Mesh-sharded serving (clean graph, B={B}, {n_steps} "
                "steps, 8 faked host devices)",
                ["path", "compile", "wall", "tok/s"], rows)
    txt += "\n" + check("sharded generation bit-exact vs single device",
                        exact)
    return txt, {"tp": tp, "compile_sharded_s": compile_sharded,
                 "compile_single_s": compile_single,
                 "sharded_tok_s": total / t_sharded,
                 "single_tok_s": total / t_single, "bit_exact": exact}


def _decode_weight_matmul_shapes(cfg, B: int) -> list:
    """(M, K, N) of every weight matmul one faulted decode token executes
    (the ``op_linear`` domains — q/k/v/o, the gated MLP, the unembed)."""
    d, hd = cfg.d_model, cfg.head_dim
    per_layer = [(B, d, cfg.n_heads * hd),            # q
                 (B, d, cfg.n_kv_heads * hd),         # k
                 (B, d, cfg.n_kv_heads * hd),         # v
                 (B, cfg.n_heads * hd, d),            # o
                 (B, d, cfg.d_ff), (B, d, cfg.d_ff),  # gate, up
                 (B, cfg.d_ff, d)]                    # down
    return per_layer * cfg.n_layers + [(B, d, cfg.vocab)]    # + unembed


def _route_bytes_per_token(cfg, B: int, tp: int) -> dict:
    """Analytic HBM bytes/decode-token of the two vector-BER routes.

    Reuses ``kernel_bench._hbm_bytes`` (the model the fused-vs-three-pass
    kernel bench validated).  The fused shard_map route runs the kernel on
    each shard's (M, N/tp) column block and, unlike the single-device fused
    kernel, returns the int32 accumulator for the shared external dequant
    epilogue (cross-route bit-exactness — see ``_fused_aged_matmul_sharded``),
    so it pays one extra int32 round-trip per output word on top of the
    fully-fused count.  Non-divisible output dims stay on the kernel-free
    route in both columns (same downgrade the real graph takes).  Shapes are
    padded to their resolved blocks exactly as the wrappers pad."""
    from repro.kernels.ops import _ceil_mult
    from .kernel_bench import _hbm_bytes

    def one(M, K, N, fused):
        bm, bn = _ceil_mult(M, 256), _ceil_mult(N, 256)
        bk = _ceil_mult(K, 256)
        Mp, Np = -(-M // bm) * bm, -(-N // bn) * bn
        b = _hbm_bytes(Mp, -(-K // bk) * bk, Np, bm, bn, fused=fused)
        if fused:
            b += 8 * Mp * Np        # int32 acc write + dequant re-read
        return b

    three_pass = fused = 0
    for M, K, N in _decode_weight_matmul_shapes(cfg, B):
        three_pass += one(M, K, N, False)
        if N % tp == 0:
            fused += tp * one(M, K, N // tp, True)
        else:                        # divisibility fallback: both routes
            fused += one(M, K, N, False)   # stay three-pass kernel-free
    return {"bytes_per_token_three_pass": three_pass,
            "bytes_per_token_fused": fused,
            "bytes_saved_ratio": three_pass / max(fused, 1)}


def bench_per_shard_aging(quick: bool):
    B, S = 2, 8
    n_steps = 3 if quick else 8
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_steps + 1
    tp = len(jax.devices())

    fleet = FleetRuntime(n_devices=1, n_shards=tp)
    for s in range(tp):
        fleet.set_age(years=9.0 * (s + 1) / tp, shard=s)
    engines = {route: MeshServeEngine(cfg, params, fleet=fleet,
                                      max_len=max_len, seed=0,
                                      use_fused_kernel=(route == "fused"))
               for route in ("fused", "kernel_free")}

    res, r1, r2 = {}, {}, {}
    rows = []
    for route, eng in engines.items():
        t0 = time.perf_counter()
        r1[route] = eng.generate(prompts, n_steps)
        compile_s = time.perf_counter() - t0
        before = dict(serve_steps.TRACE_COUNTS)
        fleet.advance(3.15e7, shard=1)           # one shard ages a year
        r2[route] = eng.generate(prompts, n_steps)
        fleet.advance(-3.15e7, shard=1)          # rewind: same ages for both
        zero_retrace = dict(serve_steps.TRACE_COUNTS) == before
        t_warm = _timed(lambda: eng.generate(prompts, n_steps), 2)
        res[route] = {"compile_s": compile_s,
                      "warm_tok_s": B * n_steps / t_warm,
                      "zero_retrace": zero_retrace}
        rows.append([f"{route} tp={tp}", f"{compile_s:.1f}s",
                     f"{t_warm * 1e3:.0f}ms", f"{B * n_steps / t_warm:.0f}"])

    parity = bool(np.array_equal(r1["fused"].tokens,
                                 r1["kernel_free"].tokens)
                  and np.array_equal(r2["fused"].tokens,
                                     r2["kernel_free"].tokens))
    shard_bers_differ = bool(len(np.unique(r1["fused"].bers[:, 0])) > 1)
    zero_retrace = all(r["zero_retrace"] for r in res.values())
    bytes_ = _route_bytes_per_token(cfg, B, tp)

    txt = table("Per-shard aging inside ONE sharded dispatch "
                "(fused shard_map kernel vs kernel-free GSPMD)",
                ["route", "compile", "wall", "tok/s"], rows)
    txt += "\n" + check("fused and kernel-free routes sample identical "
                        "tokens (before AND after aging)", parity)
    txt += "\n" + check("served per-shard BERs differ across mesh shards",
                        shard_bers_differ,
                        f"BER(q) spread {r1['fused'].bers[:, 0].min():.1e} "
                        f"-> {r1['fused'].bers[:, 0].max():.1e}")
    txt += "\n" + check("shard age advance + BER update re-jits nothing "
                        "(both routes)", zero_retrace)
    txt += "\n" + check(
        "fused route saves analytic HBM bytes per decode token",
        bytes_["bytes_saved_ratio"] > 1.0,
        f"{bytes_['bytes_per_token_three_pass'] / 2**20:.2f} MiB -> "
        f"{bytes_['bytes_per_token_fused'] / 2**20:.2f} MiB "
        f"({bytes_['bytes_saved_ratio']:.2f}x)")
    return txt, {"compile_s": res["fused"]["compile_s"],
                 "warm_tok_s": res["fused"]["warm_tok_s"],
                 "kernel_free_compile_s": res["kernel_free"]["compile_s"],
                 "kernel_free_warm_tok_s": res["kernel_free"]["warm_tok_s"],
                 "routes_bit_exact": parity,
                 **bytes_,
                 "shard_bers_differ": shard_bers_differ,
                 "zero_retrace": zero_retrace,
                 "ber_q_per_shard": r1["fused"].bers[:, 0].tolist(),
                 "tokens_changed_after_aging":
                     bool(not np.array_equal(r1["fused"].tokens,
                                             r2["fused"].tokens))}


def run(quick: bool = False) -> str:
    assert len(jax.devices()) >= 2, \
        "mesh_bench needs faked host devices; run it as its own process"
    txt1, disp = bench_sharded_dispatch(quick)
    txt2, aging = bench_per_shard_aging(quick)
    out = "\n".join([txt1, txt2])

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "n_devices": len(jax.devices()),
              "dispatch": disp, "per_shard_aging": aging}
    path = Path(__file__).resolve().parent.parent / "BENCH_mesh.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    out += f"\n[recorded] {path.name}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
