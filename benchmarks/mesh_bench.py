"""Benchmark: mesh-sharded serving — one sharded dispatch, per-shard aging.

Must own its process: it fakes 8 host devices via ``XLA_FLAGS`` *before*
jax initialises (run ``PYTHONPATH=src python -m benchmarks.mesh_bench``;
``benchmarks.run --only mesh`` shells out here for the same reason).

Measures, on a reduced decoder-only config over a ``("data", "model")``
mesh with tp=8:

* **sharded vs single-device generation**: compile time, warm whole-call
  wall, decode tokens/sec for the SAME cast params — plus the bit-exactness
  check the serve layout guarantees (clean graphs; the full parity matrix
  lives in ``tests/test_serve_sharded.py``).
* **per-shard aging inside one dispatch**: a shard-granular
  :class:`~repro.core.fleet.FleetRuntime` (``n_shards=8``) with staggered
  shard ages served by :class:`~repro.serve.sharded.MeshServeEngine`;
  structural guards assert the served per-shard BERs differ across shards
  and that advancing shard ages re-jits nothing
  (``serve.steps.TRACE_COUNTS``).

On the CPU container the 8 "devices" share one physical core, so sharded
wall-clock carries partitioning overhead rather than speedup — the numbers
to read are compile cost, the zero-retrace property and the parity flag.
Results are recorded to ``BENCH_mesh.json`` at the repo root.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.serve import steps as serve_steps
from repro.serve.engine import ServeEngine
from repro.serve.sharded import MeshServeEngine

from .common import check, table

ARCH = "deepseek_7b"


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _setup(batch: int, prompt_len: int):
    from repro.train.steps import init_train_state
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    data = SyntheticLM(vocab=cfg.vocab, seq_len=prompt_len,
                      global_batch=batch)
    return cfg, params, data.batch_at(0).tokens


def bench_sharded_dispatch(quick: bool):
    B, S = 2, 8
    n_steps = 4 if quick else 12
    reps = 2
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_steps + 1

    eng = MeshServeEngine(cfg, params, max_len=max_len, seed=0)
    tp = eng.tp
    t0 = time.perf_counter()
    a = eng.generate(prompts, n_steps)
    compile_sharded = time.perf_counter() - t0
    t_sharded = _timed(lambda: eng.generate(prompts, n_steps), reps)

    host_params = jax.device_get(eng.params)
    single = ServeEngine(cfg, host_params, max_len=max_len, seed=0)
    t0 = time.perf_counter()
    b = single.generate(prompts, n_steps)
    compile_single = time.perf_counter() - t0
    t_single = _timed(lambda: single.generate(prompts, n_steps), reps)

    exact = bool(np.array_equal(a.tokens, b.tokens))
    total = B * n_steps
    rows = [["single-device scanned", f"{compile_single:.1f}s",
             f"{t_single * 1e3:.0f}ms", f"{total / t_single:.0f}"],
            [f"mesh-sharded tp={tp}", f"{compile_sharded:.1f}s",
             f"{t_sharded * 1e3:.0f}ms", f"{total / t_sharded:.0f}"]]
    txt = table(f"Mesh-sharded serving (clean graph, B={B}, {n_steps} "
                "steps, 8 faked host devices)",
                ["path", "compile", "wall", "tok/s"], rows)
    txt += "\n" + check("sharded generation bit-exact vs single device",
                        exact)
    return txt, {"tp": tp, "compile_sharded_s": compile_sharded,
                 "compile_single_s": compile_single,
                 "sharded_tok_s": total / t_sharded,
                 "single_tok_s": total / t_single, "bit_exact": exact}


def bench_per_shard_aging(quick: bool):
    B, S = 2, 8
    n_steps = 3 if quick else 8
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_steps + 1
    tp = len(jax.devices())

    fleet = FleetRuntime(n_devices=1, n_shards=tp)
    for s in range(tp):
        fleet.set_age(years=9.0 * (s + 1) / tp, shard=s)
    eng = MeshServeEngine(cfg, params, fleet=fleet, max_len=max_len, seed=0)

    t0 = time.perf_counter()
    r1 = eng.generate(prompts, n_steps)
    compile_s = time.perf_counter() - t0
    before = dict(serve_steps.TRACE_COUNTS)
    fleet.advance(3.15e7, shard=1)               # one shard ages a year
    r2 = eng.generate(prompts, n_steps)
    zero_retrace = dict(serve_steps.TRACE_COUNTS) == before
    t_warm = _timed(lambda: eng.generate(prompts, n_steps), 2)

    shard_bers_differ = bool(len(np.unique(r1.bers[:, 0])) > 1)
    rows = [[f"per-shard faulted tp={tp}", f"{compile_s:.1f}s",
             f"{t_warm * 1e3:.0f}ms", f"{B * n_steps / t_warm:.0f}"]]
    txt = table("Per-shard aging inside ONE sharded dispatch",
                ["path", "compile", "wall", "tok/s"], rows)
    txt += "\n" + check("served per-shard BERs differ across mesh shards",
                        shard_bers_differ,
                        f"BER(q) spread {r1.bers[:, 0].min():.1e} -> "
                        f"{r1.bers[:, 0].max():.1e}")
    txt += "\n" + check("shard age advance + BER update re-jits nothing",
                        zero_retrace)
    return txt, {"compile_s": compile_s,
                 "warm_tok_s": B * n_steps / t_warm,
                 "shard_bers_differ": shard_bers_differ,
                 "zero_retrace": zero_retrace,
                 "ber_q_per_shard": r1.bers[:, 0].tolist(),
                 "tokens_changed_after_aging":
                     bool(not np.array_equal(r1.tokens, r2.tokens))}


def run(quick: bool = False) -> str:
    assert len(jax.devices()) >= 2, \
        "mesh_bench needs faked host devices; run it as its own process"
    txt1, disp = bench_sharded_dispatch(quick)
    txt2, aging = bench_per_shard_aging(quick)
    out = "\n".join([txt1, txt2])

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "n_devices": len(jax.devices()),
              "dispatch": disp, "per_shard_aging": aging}
    path = Path(__file__).resolve().parent.parent / "BENCH_mesh.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    out += f"\n[recorded] {path.name}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
