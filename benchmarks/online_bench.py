"""Benchmark: continuous-batching online serving under live traffic.

Measures the online engine (:mod:`repro.serve.online`) end to end on a
reduced decoder-only config:

* **workload sweep** — steady-state tok/s, p50/p99 request latency
  (decode-step clock) and admission-drop rate under ``poisson``,
  ``diurnal`` and flash-crowd (``bursty``) arrival traces, served on
  fixed slots with bounded-queue admission control;
* **fleet + aging replay** — the router-dispatched
  :class:`~repro.serve.online.OnlineFleetEngine` serves a diurnal trace
  across aged lanes, then the *measured* per-lane slot occupancy is
  replayed into :meth:`repro.core.fleet.FleetRuntime.apply_load`: the
  recorded wear comes from the duty cycle the serve run actually
  sustained, not a synthetic envelope;
* **structural guards** — a second serve run with a different request
  schedule re-traces NOTHING (slot refills are traced-leaf updates), and
  the chunked online path is bit-exact with the one-shot scanned
  ``generate`` when no mid-decode arrivals occur.

``--quick`` is the CI variant.  Results are recorded to
``BENCH_online.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.serve import steps as serve_steps
from repro.serve.engine import ServeEngine
from repro.serve.online import (OnlineFleetEngine, OnlineServeEngine,
                                Request, requests_from_workload)
from repro.sched.workload import get_workload
from repro.train.steps import init_train_state

from .common import check, table

ARCH = "deepseek_7b"
YEAR_S = 365.25 * 24 * 3600.0


def _setup():
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


def _sizes(quick: bool):
    if quick:
        return dict(n_slots=2, chunk_steps=4, prompt_len=8, max_new=8,
                    n_epochs=4, steps_per_epoch=24, max_queue=8)
    return dict(n_slots=4, chunk_steps=8, prompt_len=16, max_new=16,
                n_epochs=10, steps_per_epoch=64, max_queue=16)


def _workload(name: str, n_devices: int, sz: dict):
    kw = {"n_devices": n_devices, "n_epochs": sz["n_epochs"],
          "utilization": 0.6}
    if name == "bursty":        # flash crowds the admission bound feels
        kw.update(burst_prob=0.3, burst_gain=4.0)
    return get_workload(name, **kw)


def bench_workloads(quick: bool):
    """tok/s + latency percentiles + drop rate per arrival shape."""
    cfg, params = _setup()
    sz = _sizes(quick)
    max_len = sz["prompt_len"] + sz["max_new"] + 1
    horizon = sz["n_epochs"] * sz["steps_per_epoch"]

    rows, res = [], {}
    for name in ("poisson", "diurnal", "bursty"):
        wl = _workload(name, 1, sz)
        reqs = requests_from_workload(
            wl, n_slots=sz["n_slots"],
            steps_per_epoch=sz["steps_per_epoch"], max_new=sz["max_new"],
            prompt_len=sz["prompt_len"], vocab=cfg.vocab, seed=3)
        eng = OnlineServeEngine(
            cfg, params, n_slots=sz["n_slots"], max_len=max_len,
            max_new_cap=sz["max_new"], chunk_steps=sz["chunk_steps"],
            max_queue=sz["max_queue"], seed=0)
        r = eng.serve(reqs, greedy=False, temperature=0.8,
                      max_steps=4 * horizon)
        s = r.summary()
        res[name] = s
        # latency/drop stats come off the result's own properties — the
        # one shared implementation the obs health snapshot reads too
        rows.append([name, r.n_arrived, r.n_completed,
                     f"{r.drop_rate:.3f}", f"{r.tok_per_s:.1f}",
                     f"{r.p50:.0f}", f"{r.p99:.0f}",
                     f"{s['mean_occupancy']:.2f}"])
    txt = table(
        f"Online serving (slots={sz['n_slots']}, chunk="
        f"{sz['chunk_steps']}, queue<={sz['max_queue']}, "
        f"{sz['n_epochs']}x{sz['steps_per_epoch']}-step epochs)",
        ["workload", "arrived", "done", "drop", "tok/s", "p50", "p99",
         "occ"], rows)
    txt += "\n" + check(
        "every workload drains within the step budget",
        all(res[n]["n_completed"] + res[n]["n_dropped"]
            == res[n]["n_arrived"] for n in res))
    return txt, res


def bench_fleet_replay(quick: bool):
    """Fleet lanes + measured occupancy replayed into the aging scan."""
    cfg, params = _setup()
    sz = _sizes(quick)
    N = 2 if quick else 4
    max_len = sz["prompt_len"] + sz["max_new"] + 1
    horizon = sz["n_epochs"] * sz["steps_per_epoch"]

    fleet = FleetRuntime(n_devices=N)
    for i in range(N):
        fleet.set_age(years=6.0 * (i + 1) / N, device=i)
    wl = _workload("diurnal", N, sz)
    reqs = requests_from_workload(
        wl, n_slots=sz["n_slots"], steps_per_epoch=sz["steps_per_epoch"],
        max_new=sz["max_new"], prompt_len=sz["prompt_len"],
        vocab=cfg.vocab, n_devices=N, seed=3)
    eng = OnlineFleetEngine(
        cfg, params, fleet, n_slots=sz["n_slots"], max_len=max_len,
        max_new_cap=sz["max_new"], chunk_steps=sz["chunk_steps"],
        max_queue=4 * sz["max_queue"], router="wear_level", seed=0)
    r = eng.serve(reqs, greedy=False, temperature=0.8,
                  max_steps=4 * horizon)
    s = r.summary()

    util = r.lane_utilization(max(sz["n_epochs"], 2))      # (E, N) measured
    cos = fleet.apply_load(util_trace=util, horizon_s=YEAR_S)
    wear = cos.device_wear()[-1]
    s.update(n_devices=N, mean_util=float(util.mean()),
             replay_max_dvp_mv=float(wear.max()),
             replay_spread_mv=float(wear.max() - wear.min()))

    rows = [[f"fleet x{N} (wear_level)", r.n_arrived, r.n_completed,
             f"{r.drop_rate:.3f}", f"{r.tok_per_s:.1f}",
             f"{r.p50:.0f}", f"{r.p99:.0f}",
             f"{util.mean():.2f}"]]
    txt = table("Fleet online serving (diurnal) + occupancy -> aging "
                "replay", ["mode", "arrived", "done", "drop", "tok/s",
                           "p50", "p99", "duty"], rows)
    txt += "\n" + check(
        "measured occupancy replays into the aging recursion "
        "(finite, loaded-lane wear)",
        np.isfinite(wear).all() and wear.max() > 0.0,
        f"1y at duty {util.mean():.2f} -> max ΔVth {wear.max():.1f} mV")
    return txt, s


def structural_checks(quick: bool):
    cfg, params = _setup()
    sz = _sizes(quick)
    max_len = sz["prompt_len"] + sz["max_new"] + 1
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (3, sz["prompt_len"])).astype(np.int32)

    # chunked online vs one-shot scanned: bit-exact with no arrivals
    n_steps = sz["max_new"] // 2 + 1
    ref = ServeEngine(cfg, params, max_len=max_len, seed=11).generate(
        prompts, n_steps, temperature=0.7).tokens
    eng = OnlineServeEngine(cfg, params, n_slots=3, max_len=max_len,
                            max_new_cap=sz["max_new"],
                            chunk_steps=sz["chunk_steps"], seed=11)
    r = eng.serve([Request(id=i, prompt=prompts[i], max_new=n_steps)
                   for i in range(3)],
                  greedy=False, temperature=0.7, eos_id=-1)
    got = np.stack([q.tokens for q in
                    sorted(r.completed, key=lambda q: q.id)])
    bit_exact = bool(np.array_equal(ref, got))

    # slot churn re-traces nothing: different schedule, zero new traces
    eng.serve([Request(id=i, prompt=prompts[i % 3], max_new=4, arrival=2 * i)
               for i in range(5)], greedy=True)
    before = dict(serve_steps.TRACE_COUNTS)
    eng.serve([Request(id=i, prompt=prompts[(i + 1) % 3], max_new=3,
                       arrival=3 * i) for i in range(6)], greedy=True)
    zero_retrace = dict(serve_steps.TRACE_COUNTS) == before

    txt = check("chunked online decode bit-exact with one-shot scanned "
                "generate (no mid-decode arrivals)", bit_exact)
    txt += "\n" + check("slot refills across a different request schedule "
                        "re-trace nothing", zero_retrace)
    return txt, {"no_arrival_bit_exact": bit_exact,
                 "zero_retrace_refills": zero_retrace}


def run(quick: bool = False) -> str:
    txt1, workloads = bench_workloads(quick)
    txt2, fleet = bench_fleet_replay(quick)
    txt3, struct = structural_checks(quick)
    out = "\n".join([txt1, txt2, txt3])

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "workloads": workloads, "fleet_replay": fleet,
              "structural": struct}
    path = Path(__file__).resolve().parent.parent / "BENCH_online.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    out += f"\n[recorded] {path.name}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
