"""Benchmark: paper Table II — per-operator fault-tolerant AVS over 10 years
(V_final, ΔVth, V_eff, P_avg, lifetime power saving).  All 9 operator rows
plus the baseline evaluate as one scenario-batched vmapped scan."""
from __future__ import annotations

import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.policy import FaultTolerantPolicy, evaluate_policy
from repro.core.scenario import Scenario
from .common import check, table

PAPER = {  # op -> (V_final, dvp, dvn, V_eff, P_avg, saving%)
    "q":    (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "k":    (0.94, 79.0, 52.1, 0.92, 0.88, 14.3),
    "v":    (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "qkt":  (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "sv":   (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "o":    (1.01, 99.7, 77.8, 0.97, 1.00, 3.1),
    "gate": (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "up":   (0.90, 73.1, 46.1, 0.90, 0.85, 17.0),
    "down": (0.99, 90.8, 66.7, 0.95, 0.95, 7.8),
}


def run() -> str:
    cal = load_calibration()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg)
    res = evaluate_policy(FaultTolerantPolicy(ber_model=cal.ber),
                          cal.aging, cal.delay_poly, cal.power, scn)
    base = res["baseline"]
    rows = [["baseline (none)", f"{base['v_final']:.2f} (1.02)",
             f"{base['dvp_final']:.1f} (105.3)",
             f"{base['dvn_final']:.1f} (85.1)",
             f"{base['v_eff']:.2f} (0.99)", f"{base['p_avg']:.2f} (1.03)",
             "/"]]
    for op, ref in PAPER.items():
        r = res[op]
        rows.append([
            op, f"{r['v_final']:.2f} ({ref[0]})",
            f"{r['dvp_final']:.1f} ({ref[1]})",
            f"{r['dvn_final']:.1f} ({ref[2]})",
            f"{r['v_eff']:.2f} ({ref[3]})", f"{r['p_avg']:.2f} ({ref[4]})",
            f"{r['power_saving_pct']:.1f}% ({ref[5]}%)"])
    txt = table("Table II — per-operator fault-tolerant AVS, ours (paper)",
                ["component", "V_final", "dVth,p mV", "dVth,n mV",
                 "V_eff", "P_avg W", "saving"], rows)

    avg = res["avg_power_saving_pct"]
    best_p = min(res[op]["dvp_final"] for op in PAPER)
    best_n = min(res[op]["dvn_final"] for op in PAPER)
    red_p = 100 * (1 - best_p / base["dvp_final"])
    red_n = 100 * (1 - best_n / base["dvn_final"])
    checks = [
        check("avg lifetime power saving ~14.0%", abs(avg - 14.0) < 2.0,
              f"{avg:.1f}%"),
        check("max PMOS ΔVth reduction ~30.6%", abs(red_p - 30.6) < 5.0,
              f"{red_p:.1f}%"),
        check("max NMOS ΔVth reduction ~45.8%", abs(red_n - 45.8) < 6.0,
              f"{red_n:.1f}%"),
        check("O is most sensitive (highest V_final among ops)",
              res["o"]["v_final"] == max(res[op]["v_final"]
                                         for op in PAPER)),
    ]
    return txt + "\n" + "\n".join(checks)


if __name__ == "__main__":
    print(run())
