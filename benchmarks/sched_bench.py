"""Benchmark: traffic-to-aging co-simulation — one scan vs per-epoch loop.

The scheduler closes routing -> stress -> ΔVth -> policy voltage inside
ONE jitted ``lax.scan`` per fleet (`repro.sched.lifetime.cosimulate`).
The naive alternative — what a scheduler written as a Python control
loop would do — dispatches one epoch at a time and round-trips the
fleet state through the host to make the next routing decision.  This
bench measures that choice and guards the structural claims:

* **epochs/s** — warm throughput of the single-scan co-simulation (the
  quantity the router-comparison CLI and the acceptance tests scale
  with), against the same epochs dispatched one by one (the 1-epoch
  scan is compiled once and reused, so the loop pays dispatch + host
  sync only — the fair floor for a Python scheduler);
* **structural guards** (wall-clock independent): the whole horizon
  ticks exactly ONE trace of the co-sim body per (router, shape), and
  re-routing fresh traffic / resuming from new fleet state ticks ZERO —
  loads, scenario leaves, thresholds and initial state are all traced
  arguments, so operating the scheduler never recompiles.

``--quick`` is the CI variant.  Results are recorded to
``BENCH_sched.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.constants import T_AMB
from repro.core.policy import FaultTolerantPolicy
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario
from repro.sched import cosimulate, get_workload
from repro.sched import lifetime as sched_lifetime

from .common import check, table

YEAR_S = 365.25 * 24 * 3600.0


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False) -> str:
    n, E = (8, 96) if quick else (8, 480)
    reps = 2 if quick else 3
    cal = load_calibration()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
        lifetime_s=5 * YEAR_S,
        t_amb=jnp.asarray(T_AMB + np.linspace(0.0, 30.0, n), jnp.float32))
    policy = FaultTolerantPolicy(ber_model=cal.ber)
    dmax = policy.thresholds(scn, OPERATORS)
    loads = get_workload("diurnal", n_devices=n, utilization=0.55,
                         n_epochs=E).loads(0)
    kw = dict(router="wear_level", n_devices=n)

    # ------------------------------------------------------------------ #
    # batched: the whole horizon as ONE scan
    # ------------------------------------------------------------------ #
    traces_at_entry = sched_lifetime.TRACE_COUNTS["cosim"]
    t0 = time.perf_counter()
    cos = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads, **kw)
    jax.block_until_ready(cos.V)
    compile_s = time.perf_counter() - t0

    def batched():
        out = cosimulate(cal.aging, cal.delay_poly, scn, dmax, loads, **kw)
        jax.block_until_ready(out.V)

    t_batched = _timed(batched, reps)

    # structural guards: one trace per (router, shape); re-routing fresh
    # traffic from a different starting state re-jits nothing
    before = dict(sched_lifetime.TRACE_COUNTS)
    re_loads = get_workload("bursty", n_devices=n, utilization=0.45,
                            n_epochs=E).loads(7)
    out2 = cosimulate(cal.aging, cal.delay_poly, scn, dmax, re_loads,
                      dv0=cos.dv[-1], v0=cos.V[-1], **kw)
    jax.block_until_ready(out2.V)
    zero_retrace = dict(sched_lifetime.TRACE_COUNTS) == before
    # cold + warm reps + re-route all share one trace of the scan body
    n_horizon_traces = (sched_lifetime.TRACE_COUNTS["cosim"]
                        - traces_at_entry)
    single_trace = n_horizon_traces == 1

    # ------------------------------------------------------------------ #
    # looped: one dispatch per epoch, fleet state through the host
    # ------------------------------------------------------------------ #
    loads_np = np.asarray(loads)
    epoch_s = 5 * YEAR_S / E
    n_loop = min(E, 16 if quick else 48)

    def looped(n_epochs: int):
        dv0 = jnp.zeros((n, len(OPERATORS), cos.dv.shape[-1]), jnp.float32)
        v0 = jnp.broadcast_to(jnp.float32(scn.v_init),
                              (n, len(OPERATORS)))
        util0 = jnp.zeros((n,), jnp.float32)
        for e in range(n_epochs):
            step = cosimulate(cal.aging, cal.delay_poly, scn, dmax,
                              loads_np[e:e + 1], epoch_s=epoch_s,
                              dv0=dv0, v0=v0, util0=util0, **kw)
            dv0 = step.dv[0]
            v0 = step.V[0]
            util0 = np.asarray(step.util)[0]       # host round-trip

    looped(1)                                       # compile 1-epoch shape
    t_loop = _timed(lambda: looped(n_loop), reps)
    loop_est = t_loop * (E / n_loop)
    speedup = loop_est / max(t_batched, 1e-9)

    rows = [
        ["one scan (cold, incl. compile)", f"{E}", f"{compile_s:.2f}s",
         f"{E / compile_s:.0f}/s"],
        ["one scan (warm)", f"{E}", f"{t_batched * 1e3:.0f}ms",
         f"{E / t_batched:.0f}/s"],
        [f"per-epoch loop est. ({n_loop} epochs measured)", f"{E}",
         f"{loop_est * 1e3:.0f}ms", f"{E / loop_est:.0f}/s"],
    ]
    txt = table(f"Traffic co-sim: {E} epochs x {n} devices x "
                f"{len(OPERATORS)} domains (wear_level router)",
                ["path", "epochs", "wall", "epochs/s"], rows)
    txt += "\n" + check("one jitted scan beats the per-epoch dispatch loop",
                        t_batched < loop_est,
                        f"{speedup:.1f}x")
    txt += "\n" + check("whole horizon co-simulates in a SINGLE trace per "
                        "(router, shape)", single_trace,
                        f"horizon traces: {n_horizon_traces}")
    txt += "\n" + check("re-routing fresh traffic re-jits nothing",
                        zero_retrace)

    record = {"mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "n_devices": n, "n_epochs": E,
              "compile_s": compile_s,
              "batched_epochs_per_s": E / t_batched,
              "looped_epochs_per_s": E / loop_est,
              "batched_vs_looped_speedup": speedup,
              "structural": {"single_trace_cosim": bool(single_trace),
                             "zero_retrace_on_reroute": bool(zero_retrace)}}
    path = Path(__file__).resolve().parent.parent / "BENCH_sched.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    return txt + f"\n[recorded] {path.name}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced horizon for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
