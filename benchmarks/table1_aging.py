"""Benchmark: paper Table I — aging evaluation across AVS scenarios.

Re-simulates all four rows live (not from the cached calibration check) and
compares to the paper's numbers.  Rows 1-3 are calibration targets; row 4
is a genuine prediction of the history-aware framework.  Rows sharing
static flags (1 and 3: no recovery, AVS off) run as one scenario-batched
``simulate`` call vmapped over ``v_init``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.avs import simulate
from repro.core.constants import V_MAX
from repro.core.scenario import Scenario
from .common import check, table

PAPER = {
    "V_nom, no recovery": (19.8, 62.2, 82.0, 50.5),
    "V_nom, recovery": (18.2, 54.9, 73.1, 46.1),
    "V_max, no recovery": (27.3, 103.4, 130.7, 105.2),
    "AVS (history-aware)": (23.7, 81.6, 105.3, 85.1),
}


def _row(dv_final):
    dv = np.asarray(dv_final)
    pmos_hci = dv[2] + dv[3]
    pmos_bti = dv[0] + dv[1]
    nmos = dv[4] + dv[5]
    return pmos_hci, pmos_bti, pmos_hci + pmos_bti, nmos


def run() -> str:
    cal = load_calibration()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg)
    rows = {}
    # rows 1 + 3 share static flags (no recovery, AVS off): ONE vmapped call
    # batched over the initial supply
    norec = simulate(cal.aging, cal.delay_poly,
                     scn.replace(v_init=jnp.asarray([scn.v_init, V_MAX])),
                     recovery=False, avs_enabled=False)
    rows["V_nom, no recovery"] = _row(norec.final()["dv"][0])
    rows["V_max, no recovery"] = _row(norec.final()["dv"][1])
    rec = simulate(cal.aging, cal.delay_poly, scn, recovery=True,
                   avs_enabled=False)
    rows["V_nom, recovery"] = _row(rec.final()["dv"])
    avs = simulate(cal.aging, cal.delay_poly, scn, recovery=True,
                   avs_enabled=True)
    rows["AVS (history-aware)"] = _row(avs.final()["dv"])

    out_rows = []
    for name, got in rows.items():
        ref = PAPER[name]
        out_rows.append([
            name,
            f"{got[0]:.1f} ({ref[0]})", f"{got[1]:.1f} ({ref[1]})",
            f"{got[2]:.1f} ({ref[2]})", f"{got[3]:.1f} ({ref[3]})",
        ])
    txt = table("Table I — ΔVth [mV], ours (paper)",
                ["scenario", "PMOS HCI", "PMOS BTI", "PMOS total", "NMOS"],
                out_rows)

    got = rows["AVS (history-aware)"]
    vmax = rows["V_max, no recovery"]
    red_p = 100 * (1 - got[2] / vmax[2])
    red_n = 100 * (1 - got[3] / vmax[3])
    v_final = float(avs.final()["v_final"])
    checks = [
        check("AVS V trajectory 0.90 -> 1.02 V",
              abs(v_final - V_MAX) < 0.005, f"V_final={v_final:.3f}"),
        check("pessimism reduction PMOS ~19.4%",
              abs(red_p - 19.4) < 4.0, f"{red_p:.1f}%"),
        check("pessimism reduction NMOS ~19.1%",
              abs(red_n - 19.1) < 4.0, f"{red_n:.1f}%"),
        check("row-4 PMOS within 5% of paper",
              abs(got[2] - 105.3) / 105.3 < 0.05, f"{got[2]:.1f} mV"),
        check("row-4 NMOS within 5% of paper",
              abs(got[3] - 85.1) / 85.1 < 0.05, f"{got[3]:.1f} mV"),
    ]
    return txt + "\n" + "\n".join(checks)


if __name__ == "__main__":
    print(run())
