"""Benchmark: scenario-batch sweep throughput of the lifetime simulator.

Tracks the core win of the pytree Scenario/Policy API: a budgets x duty
profiles x operator domains sweep runs as ONE traced, vmapped ``lax.scan``
instead of a per-scenario Python loop that re-dispatches each cell.  Both
sides are measured compile-free (the loop path is warmed first); the cold
vmapped number is reported separately so compile amortisation is visible.
"""
from __future__ import annotations

import time

from .common import check, table


def run() -> str:
    from repro.core.artifacts import load_calibration
    from repro.core.avs import simulate
    from repro.core.policy import FaultTolerantPolicy, sweep_policy
    from repro.core.resilience import OPERATORS
    from repro.core.scenario import Scenario, scenario_grid

    cal = load_calibration()
    base = Scenario.from_lifetime_config(cal.lifetime_cfg)
    grid = scenario_grid(base, max_loss_pct=[0.1, 0.5, 2.0],
                         duty=[0.3, 0.5, 0.7])
    policy = FaultTolerantPolicy(ber_model=cal.ber)
    n_life = grid.n_scenarios * len(OPERATORS)

    t0 = time.time()
    sweep_policy(policy, cal.aging, cal.delay_poly, grid).V.block_until_ready()
    cold = time.time() - t0
    t0 = time.time()
    sweep_policy(policy, cal.aging, cal.delay_poly, grid).V.block_until_ready()
    warm = time.time() - t0

    # the old way: one traced call per scenario cell (threshold vector only).
    # Warm the per-cell executable first so per_cell is steady-state and the
    # comparison against the *warm* vmapped number is compile-free on both
    # sides.
    warm_cell = grid[0, 0]
    simulate(cal.aging, cal.delay_poly, warm_cell,
             delay_max=policy.thresholds(warm_cell,
                                         OPERATORS)).V.block_until_ready()
    n_loop = 3
    t0 = time.time()
    for i in range(n_loop):
        cell = grid[i % 3, i // 3]
        dmax = policy.thresholds(cell, OPERATORS)
        simulate(cal.aging, cal.delay_poly, cell,
                 delay_max=dmax).V.block_until_ready()
    per_cell = (time.time() - t0) / n_loop
    loop_est = per_cell * grid.n_scenarios

    rows = [
        ["vmapped sweep (cold)", f"{n_life}", f"{cold:.2f}s",
         f"{n_life / cold:.0f}/s"],
        ["vmapped sweep (warm)", f"{n_life}", f"{warm:.2f}s",
         f"{n_life / warm:.0f}/s"],
        [f"python loop est. ({n_loop} cells measured)", f"{n_life}",
         f"{loop_est:.2f}s", f"{n_life / loop_est:.0f}/s"],
    ]
    txt = table("Scenario-batch sweep — 9 scenarios x 9 operator domains",
                ["path", "lifetimes", "wall", "throughput"], rows)
    txt += "\n" + check("one vmapped trace beats the per-scenario loop",
                        warm < loop_est,
                        f"{loop_est / max(warm, 1e-9):.1f}x")
    return txt


if __name__ == "__main__":
    print(run())
