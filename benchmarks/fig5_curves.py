"""Benchmark: paper Fig. 5 — lifetime trajectories of V_DD, critical-path
delay and ΔVth, with vs without fault tolerance (components K, O, Down vs
the never-boosting tolerant group)."""
from __future__ import annotations

import numpy as np

from repro.core.artifacts import load_calibration
from repro.core.policy import FaultTolerantPolicy, evaluate_policy
from repro.core.scenario import Scenario
from .common import check, table

YEAR = 365.25 * 24 * 3600.0


def _sample(traj, years):
    t = np.asarray(traj["t"])
    idx = [int(np.clip(np.searchsorted(t, y * YEAR), 0, len(t) - 1))
           for y in years]
    return {k: np.asarray(v)[idx] for k, v in traj.items() if k != "dv"}


def run() -> str:
    cal = load_calibration()
    res = evaluate_policy(FaultTolerantPolicy(ber_model=cal.ber),
                          cal.aging, cal.delay_poly, cal.power,
                          Scenario.from_lifetime_config(cal.lifetime_cfg))
    years = (0.1, 1, 3, 5, 10)
    rows = []
    for name in ("baseline", "k", "o", "down", "q"):
        s = _sample(res[name]["traj"], years)
        rows.append([name if name != "q" else "others (q,v,...)",
                     *(f"{v:.2f}" for v in s["V"])])
    txt = table(f"Fig 5(a) — V_DD [V] at years {years}",
                ["component", *[f"{y}y" for y in years]], rows)

    rows_d = []
    for name in ("baseline", "k", "o", "down", "q"):
        s = _sample(res[name]["traj"], years)
        rows_d.append([name if name != "q" else "others",
                       *(f"{v * 1e9:.3f}" for v in s["delay"])])
    txt += "\n" + table("Fig 5(b) — critical-path delay [ns]",
                        ["component", *[f"{y}y" for y in years]], rows_d)

    rows_p = []
    for name in ("baseline", "k", "o", "down", "q"):
        s = _sample(res[name]["traj"], years)
        rows_p.append([name if name != "q" else "others",
                       *(f"{v:.1f}" for v in s["dvp"])])
    txt += "\n" + table("Fig 5(c) — ΔVth PMOS [mV]",
                        ["component", *[f"{y}y" for y in years]], rows_p)

    base_V = np.asarray(res["baseline"]["traj"]["V"])
    q_V = np.asarray(res["q"]["traj"]["V"])
    o_V = np.asarray(res["o"]["traj"]["V"])
    n_boost = lambda V: int(np.count_nonzero(np.diff(V) > 1e-6))
    checks = [
        check("tolerant group never boosts (paper: threshold never reached)",
              n_boost(q_V) == 0, f"{n_boost(q_V)} boosts"),
        check("fault tolerance reduces boost count (K < baseline)",
              n_boost(np.asarray(res['k']['traj']['V'])) < n_boost(base_V),
              f"K={n_boost(np.asarray(res['k']['traj']['V']))}, "
              f"base={n_boost(base_V)}"),
        check("sensitive O tracks baseline closely",
              abs(float(o_V[-1]) - float(base_V[-1])) <= 0.02),
        check("V increases accelerate aging (baseline ΔVth > tolerant)",
              float(np.asarray(res['baseline']['traj']['dvp'])[-1]) >
              float(np.asarray(res['q']['traj']['dvp'])[-1])),
    ]
    return txt + "\n" + "\n".join(checks)


if __name__ == "__main__":
    print(run())
