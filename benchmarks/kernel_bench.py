"""Benchmark: Pallas kernel block-shape sweep + fused-vs-unfused injection.

No TPU wall-clock exists in this container, so the sweeps report the
*structural* determinants of kernel performance for each BlockSpec choice:
VMEM working set (must fit ~16 MiB with double buffering), MXU alignment,
grid size, arithmetic intensity and — for the fused aged-matmul — the HBM
bytes each realisation moves, plus correctness vs the jnp oracles in
interpret mode.  Interpret wall-clock is reported for relative sanity only
(it is a CPU emulation; see EXPERIMENTS.md §Perf for the methodology and
the recorded numbers).  The chosen default (256x256x256) mirrors the
paper's 256x256 systolic array.

``--quick`` runs a reduced sweep (one shape, two blocks, two BERs) used by
the CI docs job to exercise the fused path on every PR.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import check, table

VMEM_BYTES = 16 * 1024 * 1024


def sweep_blocks(M=512, K=512, N=512):
    rows = []
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (M, K), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (K, N), -128, 128, jnp.int8)
    exact = np.asarray(ref.systolic_matmul_ref(a, b))
    for bm, bn, bk in ((128, 128, 128), (128, 128, 256), (256, 256, 256),
                       (256, 256, 512), (512, 512, 512)):
        if M % bm or N % bn or K % bk:
            continue
        vmem = bm * bk + bk * bn + bm * bn * 4      # A + B int8, acc int32
        grid = (M // bm) * (N // bn) * (K // bk)
        # arithmetic intensity per output tile residency [flops/byte of HBM]
        ai = (2 * bm * bn * bk) / (bm * bk + bk * bn)
        t0 = time.time()
        out = ops.quantized_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
        ok = np.array_equal(np.asarray(out), exact)
        rows.append([f"{bm}x{bn}x{bk}", f"{vmem / 1024:.0f} KiB",
                     f"{100 * 2 * vmem / VMEM_BYTES:.1f}%",
                     str(grid), f"{ai:.0f}",
                     "mult-of-128" if bm % 128 == 0 and bn % 128 == 0
                     else "UNALIGNED",
                     "OK" if ok else "MISMATCH",
                     f"{time.time() - t0:.1f}s"])
    return rows


# --------------------------------------------------------------------------- #
# fused vs three-pass injection
# --------------------------------------------------------------------------- #
def _hbm_bytes(M, K, N, bm, bn, *, fused: bool):
    """Analytic HBM traffic of one faulted+dequantised matmul.

    Counts block revisits (A is streamed once per N-tile column, B once per
    M-tile row) identically for both paths; the difference is everything
    downstream of the accumulator flush.
    """
    gm, gn = M // bm, N // bn
    matmul_reads = M * K * gn + K * N * gm          # int8 operands
    scales = 4 * (M + N)
    out_f32 = 4 * M * N
    if fused:
        # upset + dequant happen in VMEM during the flush; only the float
        # output is ever written.
        return matmul_reads + scales + out_f32
    # three-pass: int32 acc round-trips, plus two output-sized random
    # arrays (u float32 + pos int32) padded to the (rows, 128) layout.
    words = M * N
    rows = -(-words // 128)
    wpad = -(-rows // 256) * 256 * 128              # (rows, 128) padding
    acc_write = 4 * words
    rng_write = 8 * wpad                            # u + pos materialised
    flip_pass = (4 + 8) * wpad + 4 * wpad           # read acc+u+pos, write
    dequant = 4 * words + scales + out_f32          # read acc, write float
    return matmul_reads + acc_write + rng_write + flip_pass + dequant


def _run_three_pass(a, b, xs, ws, ber, key, bm=256, bn=256, bk=256):
    acc = ops.quantized_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    acc = ops.inject_bitflips(acc, ber, key, interpret=True)
    return acc.astype(jnp.float32) * xs * ws


def _traced_array_bytes(fn, *args) -> int:
    """Bytes of every array the traced computation materialises.

    Walks the jaxpr (recursing into pjit/call sub-jaxprs) and sums the
    sizes of all equation outputs.  This measures the path as actually
    staged — a regression that reintroduces output-sized randoms or an
    extra accumulator round-trip shows up here, independently of the
    analytic model above.
    """
    def walk(jaxpr) -> int:
        total = 0
        for eqn in jaxpr.eqns:
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                total += walk(getattr(inner, "jaxpr", inner))
            else:
                total += sum(v.aval.size * v.aval.dtype.itemsize
                             for v in eqn.outvars
                             if hasattr(v.aval, "size"))
        return total
    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def sweep_fused(quick: bool = False):
    shapes = ((256, 256, 256),) if quick else ((256, 256, 256),
                                               (512, 512, 512))
    blocks = ((128, 128, 128), (256, 256, 256))
    bers = (0.0, 1e-3) if quick else (0.0, 1e-4, 1e-3)
    rows, traced, ok_parity, ok_bytes, ok_traced = [], [], True, True, True
    for M, K, N in shapes:
        ka, kb = jax.random.split(jax.random.PRNGKey(1))
        a = jax.random.randint(ka, (M, K), -128, 128, jnp.int8)
        b = jax.random.randint(kb, (K, N), -128, 128, jnp.int8)
        xs = jax.random.uniform(jax.random.PRNGKey(2), (M, 1)) + 0.5
        ws = jax.random.uniform(jax.random.PRNGKey(3), (1, N)) + 0.5
        # structural check on the ACTUAL staged computation (not the
        # analytic model): bytes of every array each path materialises
        tb3 = _traced_array_bytes(
            lambda aa, bb: _run_three_pass(aa, bb, xs, ws, 1e-3,
                                           jax.random.PRNGKey(4)), a, b)
        tbf = _traced_array_bytes(
            lambda aa, bb: ops.fused_aged_matmul(aa, bb, xs, ws, ber=1e-3,
                                                 seed=4, interpret=True),
            a, b)
        ok_traced &= tbf < tb3
        traced.append([f"{M}x{K}x{N}", f"{tb3 / 2**20:.2f} MiB",
                       f"{tbf / 2**20:.2f} MiB", f"{tb3 / tbf:.2f}x"])
        for bm, bn, bk in blocks:
            if M % bm or N % bn or K % bk:
                continue
            for ber in bers:
                # warmup first so trace/compile does not pollute the timing
                key = jax.random.PRNGKey(4)
                jax.block_until_ready(_run_three_pass(a, b, xs, ws, ber,
                                                      key, bm, bn, bk))
                t0 = time.time()
                out3 = _run_three_pass(a, b, xs, ws, ber, key, bm, bn, bk)
                jax.block_until_ready(out3)
                t3 = time.time() - t0
                jax.block_until_ready(
                    ops.fused_aged_matmul(a, b, xs, ws, ber=ber, seed=4,
                                          bm=bm, bn=bn, bk=bk,
                                          interpret=True))
                t0 = time.time()
                outf = ops.fused_aged_matmul(a, b, xs, ws, ber=ber, seed=4,
                                             bm=bm, bn=bn, bk=bk,
                                             interpret=True)
                jax.block_until_ready(outf)
                tf = time.time() - t0
                exp = ref.fused_aged_matmul_ref(a, b, xs, ws, ber, 4,
                                                bm=bm, bn=bn)
                parity = bool((outf == exp).all())
                ok_parity &= parity
                b3 = _hbm_bytes(M, K, N, bm, bn, fused=False)
                bf = _hbm_bytes(M, K, N, bm, bn, fused=True)
                ok_bytes &= bf < b3
                rows.append([f"{M}x{K}x{N}", f"{bm}x{bn}x{bk}",
                             f"{ber:.0e}",
                             f"{b3 / 2**20:.2f} MiB", f"{bf / 2**20:.2f} MiB",
                             f"{b3 / bf:.2f}x",
                             "OK" if parity else "MISMATCH",
                             f"{t3 * 1e3:.0f}ms", f"{tf * 1e3:.0f}ms"])
    txt = table("Fused aged-matmul vs three-pass (HBM bytes analytic, "
                "wall-clock interpret-mode)",
                ["shape MxKxN", "block", "BER", "3-pass HBM", "fused HBM",
                 "saved", "vs oracle", "3-pass t", "fused t"], rows)
    txt += "\n" + table("Arrays materialised by the traced computation "
                        "(jaxpr walk — regression guard)",
                        ["shape MxKxN", "3-pass staged", "fused staged",
                         "ratio"], traced)
    txt += "\n" + check("fused path bit-exact vs counter oracle", ok_parity)
    txt += "\n" + check("fused path moves strictly fewer HBM bytes "
                        "(analytic model)", ok_bytes)
    txt += "\n" + check("fused graph stages strictly fewer array bytes "
                        "(traced jaxpr)", ok_traced)
    return txt


def run(quick: bool = False) -> str:
    if quick:
        txt = sweep_fused(quick=True)
        return txt
    rows = sweep_blocks()
    txt = table("Systolic int8 matmul — BlockSpec sweep (structural)",
                ["block (bm,bn,bk)", "VMEM set", "2x-buf VMEM%", "grid",
                 "AI fl/B", "MXU align", "vs oracle", "interp t"], rows)

    # bitflip kernel: correctness + statistics at the policy-relevant BERs
    x = jax.random.randint(jax.random.PRNGKey(1), (4096, 128),
                           -2**30, 2**30, jnp.int32)
    stats = []
    for ber in (1e-5, 1e-4, 1e-3):
        y = ops.inject_bitflips(x, ber, jax.random.PRNGKey(2),
                                interpret=True)
        q = 1 - (1 - ber) ** 32
        rate = float(jnp.mean(y != x))
        stats.append([f"{ber:.0e}", f"{q:.2e}", f"{rate:.2e}"])
    txt += "\n" + table("Bitflip kernel — word-upset rate vs expectation",
                        ["BER", "expected q", "measured"], stats)

    ok_all = all(r[6] == "OK" for r in rows)
    fits = all(float(r[2].rstrip("%")) < 100 for r in rows)
    txt += "\n" + check("all block shapes match oracle", ok_all)
    txt += "\n" + check("all double-buffered working sets fit VMEM", fits)
    txt += "\n" + sweep_fused(quick=False)
    return txt


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced fused-path sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "MISMATCH" in out or "[FAIL]" in out:
        raise SystemExit(1)
