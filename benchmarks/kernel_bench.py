"""Benchmark: Pallas kernel block-shape sweep (structural, dry-run style).

No TPU wall-clock exists in this container, so the sweep reports the
*structural* determinants of kernel performance for each BlockSpec choice:
VMEM working set (must fit ~16 MiB with double buffering), MXU alignment,
grid size, and arithmetic intensity — plus correctness vs the jnp oracle in
interpret mode.  The chosen default (256x256x256) mirrors the paper's
256x256 systolic array and is the one EXPERIMENTS.md §Perf iterates from.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import check, table

VMEM_BYTES = 16 * 1024 * 1024


def sweep_blocks(M=512, K=512, N=512):
    rows = []
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (M, K), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (K, N), -128, 128, jnp.int8)
    exact = np.asarray(ref.systolic_matmul_ref(a, b))
    for bm, bn, bk in ((128, 128, 128), (128, 128, 256), (256, 256, 256),
                       (256, 256, 512), (512, 512, 512)):
        if M % bm or N % bn or K % bk:
            continue
        vmem = bm * bk + bk * bn + bm * bn * 4      # A + B int8, acc int32
        grid = (M // bm) * (N // bn) * (K // bk)
        # arithmetic intensity per output tile residency [flops/byte of HBM]
        ai = (2 * bm * bn * bk) / (bm * bk + bk * bn)
        t0 = time.time()
        out = ops.quantized_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
        ok = np.array_equal(np.asarray(out), exact)
        rows.append([f"{bm}x{bn}x{bk}", f"{vmem / 1024:.0f} KiB",
                     f"{100 * 2 * vmem / VMEM_BYTES:.1f}%",
                     str(grid), f"{ai:.0f}",
                     "mult-of-128" if bm % 128 == 0 and bn % 128 == 0
                     else "UNALIGNED",
                     "OK" if ok else "MISMATCH",
                     f"{time.time() - t0:.1f}s"])
    return rows


def run() -> str:
    rows = sweep_blocks()
    txt = table("Systolic int8 matmul — BlockSpec sweep (structural)",
                ["block (bm,bn,bk)", "VMEM set", "2x-buf VMEM%", "grid",
                 "AI fl/B", "MXU align", "vs oracle", "interp t"], rows)

    # bitflip kernel: correctness + statistics at the policy-relevant BERs
    x = jax.random.randint(jax.random.PRNGKey(1), (4096, 128),
                           -2**30, 2**30, jnp.int32)
    stats = []
    for ber in (1e-5, 1e-4, 1e-3):
        y = ops.inject_bitflips(x, ber, jax.random.PRNGKey(2),
                                interpret=True)
        q = 1 - (1 - ber) ** 32
        rate = float(jnp.mean(y != x))
        stats.append([f"{ber:.0e}", f"{q:.2e}", f"{rate:.2e}"])
    txt += "\n" + table("Bitflip kernel — word-upset rate vs expectation",
                        ["BER", "expected q", "measured"], stats)

    ok_all = all(r[6] == "OK" for r in rows)
    fits = all(float(r[2].rstrip("%")) < 100 for r in rows)
    txt += "\n" + check("all block shapes match oracle", ok_all)
    txt += "\n" + check("all double-buffered working sets fit VMEM", fits)
    return txt


if __name__ == "__main__":
    print(run())
