"""Benchmark driver: one section per paper table/figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig1b,...]
    PYTHONPATH=src python -m benchmarks.run --summary   # merge BENCH_*.json

``--summary`` folds every per-section ``BENCH_*.json`` record at the repo
root into one ``BENCH_summary.json`` keyed by section, so perf PRs have a
single before/after anchor instead of a dozen scattered files.
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("table1", "table2", "fig5", "scenarios", "sched",
            "disruption", "kernels", "serve", "online", "obs", "mesh",
            "resilience", "fig1b", "roofline")


def write_summary() -> str:
    """Merge all BENCH_*.json records into BENCH_summary.json."""
    import json
    from pathlib import Path
    root = Path(__file__).resolve().parent.parent
    merged = {}
    for path in sorted(root.glob("BENCH_*.json")):
        if path.name == "BENCH_summary.json":
            continue
        section = path.stem[len("BENCH_"):]
        try:
            merged[section] = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            merged[section] = {"error": f"unreadable: {e}"}
    out = root / "BENCH_summary.json"
    out.write_text(json.dumps({"sections": sorted(merged),
                               "records": merged}, indent=2) + "\n")
    return f"[recorded] {out.name} ({len(merged)} sections: " \
           f"{', '.join(sorted(merged))})"


def _run_mesh_subprocess() -> str:
    """mesh_bench fakes 8 host devices via XLA_FLAGS, which jax only reads
    at init — so it must own a fresh process."""
    import os
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.mesh_bench", "--quick"],
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return proc.stdout.rstrip()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {SECTIONS}")
    ap.add_argument("--summary", action="store_true",
                    help="merge all BENCH_*.json into BENCH_summary.json "
                         "(no benchmarks are run)")
    args = ap.parse_args()
    if args.summary:
        print(write_summary())
        return
    want = args.only.split(",") if args.only else list(SECTIONS)

    runners = {}
    if "table1" in want:
        from . import table1_aging
        runners["table1"] = table1_aging.run
    if "table2" in want:
        from . import table2_policy
        runners["table2"] = table2_policy.run
    if "fig5" in want:
        from . import fig5_curves
        runners["fig5"] = fig5_curves.run
    if "scenarios" in want:
        from . import scenario_bench
        runners["scenarios"] = scenario_bench.run
    if "sched" in want:
        from . import sched_bench
        runners["sched"] = sched_bench.run
    if "disruption" in want:
        from . import disruption_bench
        runners["disruption"] = disruption_bench.run
    if "kernels" in want:
        from . import kernel_bench
        runners["kernels"] = kernel_bench.run
    if "serve" in want:
        from . import serve_bench
        runners["serve"] = serve_bench.run
    if "online" in want:
        from . import online_bench
        runners["online"] = online_bench.run
    if "obs" in want:
        from . import obs_bench
        runners["obs"] = obs_bench.run
    if "mesh" in want:
        runners["mesh"] = _run_mesh_subprocess
    if "resilience" in want:
        from . import resilience_bench
        runners["resilience"] = resilience_bench.run
    if "fig1b" in want:
        from . import fig1b_ber
        runners["fig1b"] = fig1b_ber.run
    if "roofline" in want:
        from . import roofline
        runners["roofline"] = roofline.run

    failed = []
    for name in want:
        if name not in runners:
            continue
        t0 = time.time()
        print(f"\n{'#' * 72}\n# benchmark: {name}\n{'#' * 72}")
        try:
            out = runners[name]()
            print(out)
        except Exception as e:                      # pragma: no cover
            failed.append(name)
            print(f"[ERROR] {name}: {type(e).__name__}: {e}")
        print(f"# ({name} took {time.time() - t0:.1f}s)")
    if failed:
        print(f"\nFAILED sections: {failed}")
        sys.exit(1)
    print("\n" + write_summary())
    print("All benchmark sections completed.")


if __name__ == "__main__":
    main()
