"""Benchmark: observability-layer overhead + structural guarantees.

Measures what the telemetry taps cost and locks down what they promise:

* **taps overhead** — median warm-path latency of the scanned serve
  dispatch and of the online chunk loop with taps enabled vs disabled.
  The traced graphs are identical either way (the toggle only controls
  host-side transfer + registry recording), so the guarded ratio is the
  host cost of reading the aux leaves — must stay ≤ 1.10×;
* **structural** — taps on/off bit-exactness of tokens and ZERO retrace
  across the toggle (the unified :func:`repro.obs.metrics.trace_counts`
  guard), plus the registry export round-trip
  (:mod:`repro.obs.export`) on the samples the run just produced.

Records ``BENCH_obs.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs.taps import enable_taps
from repro.serve.engine import ServeEngine
from repro.serve.online import OnlineServeEngine, Request
from repro.train.steps import init_train_state

from .common import check, table

ARCH = "deepseek_7b"
OVERHEAD_LIMIT = 1.10


def _setup():
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    return cfg, params


def _sizes(quick: bool):
    if quick:
        return {"batch": 2, "prompt_len": 8, "gen_len": 8, "reps": 5,
                "n_slots": 2, "chunk_steps": 4, "max_new": 6, "n_reqs": 4}
    return {"batch": 4, "prompt_len": 16, "gen_len": 24, "reps": 15,
            "n_slots": 3, "chunk_steps": 8, "max_new": 12, "n_reqs": 8}


def _median_latency(fn, reps: int) -> float:
    fn()                                        # warm (compile) once
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_overhead(quick: bool):
    """Warm-path latency, taps off vs on: serve dispatch + online loop."""
    cfg, params = _setup()
    sz = _sizes(quick)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (sz["batch"], sz["prompt_len"])).astype(np.int32)
    max_len = sz["prompt_len"] + sz["gen_len"] + 1

    eng = ServeEngine(cfg, params, max_len=max_len, seed=7)
    gen = lambda: eng.generate(prompts, sz["gen_len"], temperature=0.7)
    t_off = _median_latency(gen, sz["reps"])
    with enable_taps():
        t_on = _median_latency(gen, sz["reps"])
    serve_ratio = t_on / t_off

    def online():
        e = OnlineServeEngine(cfg, params, n_slots=sz["n_slots"],
                              max_len=max_len, max_new_cap=sz["max_new"],
                              chunk_steps=sz["chunk_steps"], seed=7)
        e.serve([Request(id=i, prompt=prompts[i % sz["batch"]],
                         max_new=sz["max_new"], arrival=i)
                 for i in range(sz["n_reqs"])], greedy=True)
    o_off = _median_latency(online, max(sz["reps"] // 3, 3))
    with enable_taps():
        o_on = _median_latency(online, max(sz["reps"] // 3, 3))
    online_ratio = o_on / o_off

    rows = [["serve scanned generate", f"{t_off * 1e3:.1f}",
             f"{t_on * 1e3:.1f}", f"{serve_ratio:.3f}"],
            ["online chunk loop", f"{o_off * 1e3:.1f}",
             f"{o_on * 1e3:.1f}", f"{online_ratio:.3f}"]]
    txt = table("Telemetry taps overhead (warm median)",
                ["path", "off [ms]", "on [ms]", "ratio"], rows)
    txt += "\n" + check(
        f"taps overhead <= {OVERHEAD_LIMIT:.2f}x on both paths",
        serve_ratio <= OVERHEAD_LIMIT and online_ratio <= OVERHEAD_LIMIT,
        f"serve {serve_ratio:.3f}x, online {online_ratio:.3f}x")
    return txt, {"serve_off_s": t_off, "serve_on_s": t_on,
                 "serve_ratio": serve_ratio, "online_off_s": o_off,
                 "online_on_s": o_on, "online_ratio": online_ratio,
                 "limit": OVERHEAD_LIMIT}


def structural_checks(quick: bool):
    cfg, params = _setup()
    sz = _sizes(quick)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab,
                           (sz["batch"], sz["prompt_len"])).astype(np.int32)
    max_len = sz["prompt_len"] + sz["gen_len"] + 1

    # bit-exact: same engine seed, taps off vs on
    off = ServeEngine(cfg, params, max_len=max_len, seed=5).generate(
        prompts, sz["gen_len"], temperature=0.7)
    with enable_taps():
        on = ServeEngine(cfg, params, max_len=max_len, seed=5).generate(
            prompts, sz["gen_len"], temperature=0.7)
    bit_exact = bool(np.array_equal(off.tokens, on.tokens))
    has_taps = on.telemetry is not None and off.telemetry is None

    # zero retrace across the toggle, on the unified guard
    eng = ServeEngine(cfg, params, max_len=max_len, seed=5)
    eng.generate(prompts, sz["gen_len"])
    before = obs_metrics.trace_counts()
    with enable_taps():
        eng.generate(prompts, sz["gen_len"])
    eng.generate(prompts, sz["gen_len"])
    zero_retrace = obs_metrics.trace_counts() == before

    # export round-trip on the samples this very run produced
    samples = obs_metrics.REGISTRY.collect()
    back = obs_export.parse_prometheus(obs_export.prometheus_text(samples))
    round_trip = [(s.name, tuple(sorted(s.labels)), s.value)
                  for s in samples] \
        == [(s.name, s.labels, s.value) for s in back]

    txt = check("tokens bit-exact with taps enabled (aux outputs of the "
                "same executable)", bit_exact and has_taps)
    txt += "\n" + check("toggling taps re-traces nothing "
                        "(unified trace_counts guard)", zero_retrace)
    txt += "\n" + check(
        f"Prometheus export round-trips {len(samples)} live samples",
        round_trip and len(samples) > 0)
    return txt, {"bit_exact": bit_exact, "zero_retrace": zero_retrace,
                 "export_round_trip": round_trip,
                 "n_samples": len(samples)}


def run(quick: bool = False) -> str:
    txt1, overhead = bench_overhead(quick)
    txt2, struct = structural_checks(quick)
    out = "\n".join([txt1, txt2])

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "overhead": overhead, "structural": struct}
    path = Path(__file__).resolve().parent.parent / "BENCH_obs.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    out += f"\n[recorded] {path.name}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
