"""Benchmark: paper Fig. 1(b) — model quality vs BER knee, measured by REAL
bit-error injection on a model trained in-repo (not a lookup table).

The paper measures OPT-1.3B perplexity on WikiText-2; offline we train a
reduced-config LM on the deterministic synthetic pipeline until it clearly
beats the uniform baseline, then sweep BER through the knee with the
bitflip kernel on every operator domain.  The qualitative claim under test:
flat below ~1e-5, collapse above ~1e-3 (Fig 1b's shape), which is what the
fault-tolerant policy exploits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import transformer as tf
from repro.models.layers import FaultConfig
from repro.optim import AdamWConfig
from repro.train.steps import init_train_state, make_train_step, softmax_xent
from .common import check, table

OPS = ("q", "k", "v", "qkt", "sv", "o", "gate", "up", "down")


def train_small(steps: int = 80):
    cfg = get_config("llama3_8b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=16)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=5)))
    loss = float("nan")
    for i in range(steps):
        tb = data.batch_at(i)
        state, m = step(state, {"tokens": jnp.asarray(tb.tokens),
                                "labels": jnp.asarray(tb.labels)})
        loss = float(m["loss"])
    return cfg, state.params, data, loss


def run() -> str:
    cfg, params, data, train_loss = train_small()
    toks = data.batch_at(500).tokens

    def nll_at(ber: float, seed: int = 0) -> float:
        fi = None if ber == 0 else FaultConfig(
            bers={op: jnp.float32(ber) for op in OPS},
            key=jax.random.PRNGKey(seed), use_systolic_kernel=False)
        logits, _, _ = tf.forward_logits(params, cfg,
                                         jnp.asarray(toks[:, :-1]), fi=fi)
        return float(softmax_xent(logits, jnp.asarray(toks[:, 1:])))

    bers = (0.0, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2)
    nlls = []
    for b in bers:
        vals = [nll_at(b, s) for s in range(2 if b > 0 else 1)]
        nlls.append(float(np.mean(vals)))
    ppls = [float(np.exp(min(n, 30))) for n in nlls]

    rows = [[f"{b:.0e}" if b else "0", f"{n:.4f}", f"{p:.1f}"]
            for b, n, p in zip(bers, nlls, ppls)]
    txt = table("Fig 1(b) — quality vs BER (trained reduced LM, all "
                "operator domains injected)", ["BER", "NLL", "ppl"], rows)

    clean = nlls[0]
    mono = all(nlls[i + 1] >= nlls[i] - 0.05 for i in range(2, len(nlls) - 1))
    checks = [
        check("model actually trained",
              train_loss < data.uniform_nll() - 0.3,
              f"loss {train_loss:.3f} vs uniform {data.uniform_nll():.3f}"),
        check("quasi-error-free below 1e-6 (Fig 1b: flat at low BER)",
              abs(nlls[2] - clean) < 0.1,
              f"ΔNLL={nlls[2] - clean:+.4f}"),
        check("collapse above 1e-3 (Fig 1b: failure past the knee)",
              nlls[-2] > clean + 0.5, f"ΔNLL={nlls[-2] - clean:+.3f}"),
        check("knee shape (flat -> monotone rise)", mono),
    ]
    note = ("note: the knee sits ~1 decade below the paper's OPT-1.3B "
            "(1e-4): a d=64 reduced model with ALL nine domains injected "
            "simultaneously has far less redundancy — the curve SHAPE, "
            "which the policy exploits, is what transfers.")
    return txt + "\n" + "\n".join(checks) + "\n" + note


if __name__ == "__main__":
    print(run())
