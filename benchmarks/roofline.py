"""Benchmark: roofline table over all dry-run cells (reads
results/dryrun/*.json produced by ``python -m repro.launch.dryrun --all``)."""
from __future__ import annotations

import glob
import json
import os

from .common import table

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> str:
    cells = load_cells()
    if not cells:
        return ("== Roofline == (no dry-run artifacts found; run "
                "`PYTHONPATH=src python -m repro.launch.dryrun --all`)")
    rows, skips, fails = [], [], []
    for c in cells:
        if "shape" not in c:        # auxiliary artifact (elastic dry-run)
            continue
        mesh = "2x16x16" if c.get("multi_pod") else "16x16"
        tag = f"{c['arch']}/{c['shape']}"
        if "skipped" in c:
            skips.append(f"{tag} [{mesh}]: {c['skipped'][:70]}")
            continue
        if "error" in c:
            fails.append(f"{tag} [{mesh}]: {c['error'][:90]}")
            continue
        rt = c["roofline"]
        rows.append([
            tag, mesh,
            f"{rt['t_compute']:.2e}", f"{rt['t_memory']:.2e}",
            f"{rt['t_collective']:.2e}", rt["dominant"],
            f"{(rt['useful_flops_frac'] or 0):.2f}",
            f"{(rt['roofline_frac'] or 0) * 100:.2f}%",
            f"{c.get('state_bytes_per_dev', 0) / 2**30:.1f}",
        ])
    txt = table("Roofline — per (arch x shape x mesh); terms in seconds",
                ["cell", "mesh", "t_comp", "t_mem", "t_coll", "dominant",
                 "MODEL/HLO", "roofline%", "state GiB/dev"], rows)
    if skips:
        txt += "\n-- documented skips --\n" + "\n".join(skips)
    if fails:
        txt += "\n-- FAILURES --\n" + "\n".join(fails)
    n_ok = len(rows)
    txt += (f"\n[INFO] {n_ok} compiled cells, {len(skips)} documented "
            f"skips, {len(fails)} failures")
    return txt


if __name__ == "__main__":
    print(run())
