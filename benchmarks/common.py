"""Shared benchmark output helpers."""
from __future__ import annotations

from typing import Iterable, List, Sequence


def table(title: str, header: Sequence[str], rows: Iterable[Sequence]) -> str:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    def fmt(cells):
        return " | ".join(c.ljust(w) for c, w in zip(cells, widths))
    lines = [f"== {title} ==", fmt(header),
             "-+-".join("-" * w for w in widths)]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines)


def check(name: str, ok: bool, detail: str = "") -> str:
    mark = "PASS" if ok else "FAIL"
    return f"[{mark}] {name}" + (f" — {detail}" if detail else "")
