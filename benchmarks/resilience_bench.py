"""Benchmark: resilience-characterisation sweep — batched vs looped grid.

The measured-resilience subsystem evaluates a model's whole
BER x operator-domain fault grid as vmapped lanes of ONE dispatch
(`repro.calibrate.resilience_sweep`, cf. the fleet engine's lane vmap).
This bench measures that choice and guards its structural claims:

* **grid points/sec** — warm throughput of the single-dispatch grid
  evaluation (the quantity the zoo-wide calibration CLI scales with),
  for the default chunking AND the wide-vmap variant (the TPU shape; on
  CPU its lane-scaled injection randoms are cache-bound — the measured
  6x pathology `default_chunk()` avoids, which the 1.5x bound below
  regression-guards);
* **batched-vs-looped speedup** — the same grid dispatched lane by lane
  (what a naive per-(BER, operator) characterisation loop would do).  On
  CPU this is a wall-clock wash (the per-lane executable is already
  cache-local); the batched win that transfers is structural — ONE
  dispatch, no per-lane host round-trips, one executable to ship to a
  device (cf. the fleet-vmap framing in EXPERIMENTS.md §Serving);
* **structural guards** (wall-clock independent): the whole grid ticks
  exactly ONE trace of the evaluation body, and re-sweeping with new BER
  values / fresh seeds ticks ZERO — BERs and keys are traced
  `FaultConfig` leaves, so refining the measurement never recompiles.

``--quick`` is the CI variant.  Results are recorded to
``BENCH_resilience.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.calibrate import resilience_sweep as rs
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.train.steps import init_train_state

from .common import check, table

ARCH = "llama3_8b"


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False) -> str:
    B, S = (2, 16) if quick else (4, 32)
    n_bers = 3 if quick else 5
    reps = 2 if quick else 3
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    tokens = SyntheticLM(vocab=cfg.vocab, seq_len=S,
                         global_batch=B).batch_at(0).tokens
    ber_grid = tuple(float(b) for b in np.logspace(-6, -2, n_bers))

    # ------------------------------------------------------------------ #
    # batched: the whole grid as one dispatch
    # ------------------------------------------------------------------ #
    t0 = time.perf_counter()
    res = rs.run_sweep(cfg, params, tokens, ber_grid=ber_grid, n_seeds=1)
    compile_s = time.perf_counter() - t0
    lanes = len(ber_grid) * len(res.operators)
    before = dict(rs.TRACE_COUNTS)
    rs.run_sweep(cfg, params, tokens, ber_grid=ber_grid, n_seeds=1, seed=7)
    zero_retrace = dict(rs.TRACE_COUNTS) == before
    n_grid_traces = rs.TRACE_COUNTS["grid_eval"]
    single_trace = n_grid_traces == 1

    gfn = rs._grid_eval_fn(cfg, rs.default_chunk())
    pred = rs._predict_fn(cfg)(
        params, tokens,
        rs._reference_fault_config(res.operators, jax.random.PRNGKey(0),
                                   use_kernel=False, fused=False))
    fi = rs.grid_fault_config(res.operators, ber_grid, jax.random.PRNGKey(0))
    t_batched = _timed(
        lambda: gfn(params, tokens, pred, fi).block_until_ready(), reps)

    # wide-vmap variant: the whole lane axis as one vmap (the TPU shape).
    # On CPU its per-matmul injection randoms scale with the lane axis and
    # blow the cache — the measured pathology default_chunk() avoids.
    gfn_wide = rs._grid_eval_fn(cfg, None)
    gfn_wide(params, tokens, pred, fi).block_until_ready()
    t_wide = _timed(
        lambda: gfn_wide(params, tokens, pred, fi).block_until_ready(),
        reps)

    # ------------------------------------------------------------------ #
    # looped: the same lanes dispatched one by one
    # ------------------------------------------------------------------ #
    lane_fis = [jax.tree.map(lambda x, i=i: x[i:i + 1], fi)
                for i in range(lanes)]

    def looped():
        for lf in lane_fis:
            gfn(params, tokens, pred, lf)[0].block_until_ready()
    looped()                                        # compile the 1-lane shape
    t_looped = _timed(looped, reps)

    speedup = t_looped / max(t_batched, 1e-9)
    rows = [
        [f"looped ({lanes} dispatches)", f"{t_looped * 1e3:.0f}ms",
         f"{lanes / t_looped:.1f}"],
        ["batched, ONE dispatch (default chunk)",
         f"{t_batched * 1e3:.0f}ms", f"{lanes / t_batched:.1f}"],
        ["batched, ONE dispatch (wide vmap)",
         f"{t_wide * 1e3:.0f}ms", f"{lanes / t_wide:.1f}"],
    ]
    txt = table(f"Resilience sweep: {lanes} fault lanes "
                f"({n_bers} BERs x {len(res.operators)} operators, "
                f"B={B}, S={S}; CPU wall-clock — the batched win that "
                "transfers is structural, see EXPERIMENTS.md",
                ["path", "wall", "grid points/s"], rows)
    txt += "\n" + check(
        "single-dispatch grid within 1.5x of the per-lane loop's "
        "wall-clock (default chunking avoids the wide-vmap cache "
        "pathology)", speedup > 1.0 / 1.5, f"{speedup:.2f}x looped")
    txt += "\n" + check("whole grid evaluates in a SINGLE trace",
                        single_trace, f"grid_eval traces: {n_grid_traces}")
    txt += "\n" + check("re-sweep with new BER values/seeds re-jits "
                        "nothing", zero_retrace)

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "lanes": lanes, "compile_s": compile_s,
              "batched_points_per_s": lanes / t_batched,
              "wide_vmap_points_per_s": lanes / t_wide,
              "looped_points_per_s": lanes / t_looped,
              "batched_vs_looped_speedup": speedup,
              "structural": {"single_trace_grid": single_trace,
                             "zero_retrace_on_resweep": zero_retrace}}
    path = Path(__file__).resolve().parent.parent / "BENCH_resilience.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    return txt + f"\n[recorded] {path.name}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
