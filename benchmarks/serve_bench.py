"""Benchmark: serving dispatch granularity — eager loop vs scanned vs fleet.

Measures the serving stack on a reduced decoder-only config:

* **TTFT** (time-to-first-token): one prefill + first sample, warm.
* **steady-state decode tokens/sec**: two warm whole-generation calls at
  different ``n_steps`` isolate the marginal decode rate
  ``B * (n_hi - n_lo) / (t_hi - t_lo)`` — prefill and fixed dispatch
  overheads cancel.  The eager path pays one device dispatch plus a host
  sync (``np.asarray(tok)``) per token; the scanned path is ONE dispatch
  per generation (prefill + ``lax.scan`` decode + in-graph sampling).
* **fleet-vmapped**: a heterogeneous-age ``FleetRuntime`` served by
  :class:`~repro.serve.engine.FleetServeEngine` in one dispatch vs the
  same lanes dispatched sequentially per device (faulted graphs, fused
  kernel in interpret mode — relative comparison only, see
  EXPERIMENTS.md §Serving for the methodology caveat).

Structural guards (independent of wall-clock):

* the scanned generation's jaxpr contains the decode ``lax.scan`` and NO
  host callbacks — there is nothing to sync per token;
* a repeated ``generate()`` after advancing the device age performs zero
  new traces (``serve.steps.TRACE_COUNTS``) — the compile-cache claim.

``--quick`` is the CI variant.  Results are recorded to
``BENCH_serve.json`` at the repo root (the checked-in copy is from a full
run in the CPU container).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.serve import steps as serve_steps
from repro.serve.engine import FleetServeEngine, ServeEngine
from repro.train.steps import init_train_state

from .common import check, table

ARCH = "deepseek_7b"


def _timed(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _setup(batch: int, prompt_len: int):
    cfg = get_config(ARCH).reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    data = SyntheticLM(vocab=cfg.vocab, seq_len=prompt_len,
                      global_batch=batch)
    return cfg, params, data.batch_at(0).tokens


def bench_dispatch(quick: bool):
    """Eager vs scanned on the clean graph (kernel interpret overhead would
    otherwise swamp the dispatch-granularity signal being measured)."""
    B, S = (2, 8) if quick else (4, 16)
    n_lo, n_hi = (4, 12) if quick else (8, 40)
    reps = 2 if quick else 3
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_hi + 1
    eng = ServeEngine(cfg, params, max_len=max_len, seed=0)

    rows, res = [], {}
    for name, kw in (("eager", {"scan": False}), ("scanned", {})):
        t0 = time.perf_counter()
        eng.generate(prompts, n_hi, **kw)              # compile
        compile_s = time.perf_counter() - t0
        eng.generate(prompts, 1, **kw)
        ttft = _timed(lambda: eng.generate(prompts, 1, **kw), reps)
        eng.generate(prompts, n_lo, **kw)              # warm the lo bucket
        t_lo = _timed(lambda: eng.generate(prompts, n_lo, **kw), reps)
        t_hi = _timed(lambda: eng.generate(prompts, n_hi, **kw), reps)
        tok_s = B * (n_hi - n_lo) / max(t_hi - t_lo, 1e-9)
        res[name] = {"compile_s": compile_s, "ttft_s": ttft,
                     "decode_tok_s": tok_s}
        rows.append([name, f"{compile_s:.2f}s", f"{ttft * 1e3:.1f}ms",
                     f"{t_lo * 1e3:.0f}ms", f"{t_hi * 1e3:.0f}ms",
                     f"{tok_s:.0f}"])
    res["speedup"] = res["scanned"]["decode_tok_s"] \
        / max(res["eager"]["decode_tok_s"], 1e-9)
    txt = table(f"Serving dispatch granularity (clean graph, B={B}, "
                f"decode {n_lo}->{n_hi} steps)",
                ["path", "compile", "TTFT", f"t({n_lo})", f"t({n_hi})",
                 "decode tok/s"], rows)
    txt += "\n" + check(
        "scanned strictly faster than eager in steady-state decode",
        res["speedup"] > 1.0, f"{res['speedup']:.2f}x")
    return txt, res


def bench_fleet(quick: bool):
    """One vmapped dispatch for N aged lanes vs N sequential dispatches."""
    N = 2 if quick else 4
    B, S = 2, 8
    n_steps = 3 if quick else 8
    reps = 2
    cfg, params, prompts = _setup(B, S)
    max_len = S + n_steps + 1
    fleet = FleetRuntime(n_devices=N)
    for i in range(N):
        fleet.set_age(years=9.0 * (i + 1) / N, device=i)
    lane_prompts = np.stack([prompts] * N)

    fe = FleetServeEngine(cfg, params, fleet, max_len=max_len, seed=0,
                          use_systolic_kernel=True)
    fe.generate(lane_prompts, n_steps)                  # compile
    t_fleet = _timed(lambda: fe.generate(lane_prompts, n_steps), reps)

    lanes = [ServeEngine(cfg, params, runtime=fleet, device=i,
                         max_len=max_len, seed=0, use_systolic_kernel=True)
             for i in range(N)]

    def sequential():
        for eng in lanes:
            eng.generate(prompts, n_steps)
    sequential()                                        # compile
    t_seq = _timed(sequential, reps)

    total = N * B * n_steps
    rows = [["per-lane sequential", f"{t_seq * 1e3:.0f}ms",
             f"{total / t_seq:.0f}"],
            ["fleet-vmapped (1 dispatch)", f"{t_fleet * 1e3:.0f}ms",
             f"{total / t_fleet:.0f}"]]
    txt = table(f"Fleet serving: {N} aged lanes x B={B} x {n_steps} steps "
                "(faulted fused graph, interpret mode)",
                ["path", "wall", "total tok/s"], rows)
    return txt, {"n_devices": N, "fleet_tok_s": total / t_fleet,
                 "sequential_tok_s": total / t_seq}


def structural_checks(quick: bool):
    cfg, params, prompts = _setup(2, 8)
    gen = serve_steps.make_generate_fn(cfg, 16, 4)
    jaxpr = jax.make_jaxpr(gen)(
        params, jnp.asarray(prompts[:, :8], jnp.int32), None,
        jax.random.PRNGKey(0), jnp.float32(0.0))
    prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    has_scan = "scan" in prims
    no_callbacks = not any("callback" in p for p in prims)

    rt = FleetRuntime(n_devices=1)
    rt.set_age(years=5.0)
    eng = ServeEngine(cfg, params, runtime=rt, max_len=16, seed=0,
                      use_systolic_kernel=True)
    eng.generate(prompts[:, :8], 4)
    before = dict(serve_steps.TRACE_COUNTS)
    rt.set_age(years=9.5)
    eng.generate(prompts[:, :8], 4)
    zero_retrace = dict(serve_steps.TRACE_COUNTS) == before

    txt = check("scanned generation lowers to ONE dispatch with a decode "
                "lax.scan (no per-token host sync primitives)",
                has_scan and no_callbacks)
    txt += "\n" + check("repeated generate() on an advanced-age runtime "
                        "triggers zero recompilation", zero_retrace)
    return txt, {"decode_is_scan": has_scan and no_callbacks,
                 "zero_retrace_on_aging": zero_retrace}


def run(quick: bool = False) -> str:
    txt1, disp = bench_dispatch(quick)
    txt2, fleet = bench_fleet(quick)
    txt3, struct = structural_checks(quick)
    out = "\n".join([txt1, txt2, txt3])

    record = {"arch": ARCH, "mode": "quick" if quick else "full",
              "backend": jax.default_backend(),
              "dispatch": disp, "fleet": fleet, "structural": struct}
    path = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    out += f"\n[recorded] {path.name}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(out)
    if "[FAIL]" in out:
        raise SystemExit(1)
