#!/usr/bin/env python
"""Docs reference checker: fail CI on dangling intra-repo references.

Guards against the EXPERIMENTS.md class of bug — a docstring or document
citing a repo file that does not exist.  Three passes:

1. **Markdown links** — every relative link target in every ``*.md`` file
   (anchors stripped) must exist on disk, resolved against the file's
   directory — strictly file-relative, because that is how the link
   renders.
2. **.md mentions** — every ``<name>.md`` token mentioned in Python
   sources or in our own markdown must exist: bare names at the repo
   root, ``dir/<name>.md`` paths against the repo root or the mentioning
   file's directory.  ``SNIPPETS.md`` / ``PAPERS.md`` are exempt from
   this pass: they quote *external* repos' files as provenance.
3. **Sphinx roles** — every ``:func:`` / ``:class:`` / ``:meth:`` /
   ``:mod:`` / ``:data:`` reference in docstrings and markdown must
   resolve against a statically-built symbol table of the repo's own
   python sources (ast only — no imports, so the pass runs before any
   install).  Guards against the ``:func:`empirical_resilience``` class
   of bug: a docstring promising an entry point that does not exist.
   Fully-qualified dotted paths resolve module -> symbol [-> method];
   bare names resolve against any top-level symbol, class or method
   defined anywhere in the repo (lenient by design — the target of this
   pass is promised-but-absent symbols, not ambiguous shorthand).

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".claude", ".pytest_cache", "__pycache__",
             ".hypothesis", "results", "node_modules"}
# SNIPPETS/PAPERS quote external repos' files as provenance; ISSUE.md is
# the incoming task spec (may cite files the task is about to create)
MENTION_EXEMPT = {"SNIPPETS.md", "PAPERS.md", "ISSUE.md"}

# pass 3 must cover every first-party layer: a package that silently
# drops out of the symbol table (moved, or caught by SKIP_DIRS) would
# let its docstring references rot unchecked.  One representative module
# per layer; extend when adding a layer.
REQUIRED_MODULES = (
    "repro.core.scenario", "repro.core.fleet", "repro.core.policy",
    "repro.sched.workload", "repro.sched.router", "repro.sched.lifetime",
    "repro.sched.disruption",
    "repro.calibrate.resilience_sweep", "repro.serve.steps",
    "repro.serve.online", "repro.serve.sharded", "repro.kernels.ops",
    "repro.launch.schedule", "repro.distributed.sharding",
    "repro.distributed.collectives", "repro.distributed.elastic",
    "repro.obs.metrics", "repro.obs.taps", "repro.obs.health",
    "repro.obs.export",
)

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MD_MENTION = re.compile(r"[A-Za-z0-9_./-]*[A-Za-z0-9_-]\.md\b")
EXTERNAL = re.compile(r"^(https?|mailto|ftp):")
SPHINX_ROLE = re.compile(r":(func|class|meth|mod|data):`([^`]+)`")
# a resolvable target: dotted identifier path, optional ~ prefix / () suffix
ROLE_TARGET = re.compile(r"^~?[A-Za-z_][A-Za-z0-9_]*(\.[A-Za-z_]"
                         r"[A-Za-z0-9_]*)*(\(\))?$")


def _files(suffix: str):
    for p in sorted(ROOT.rglob(f"*{suffix}")):
        if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts):
            yield p


def _exists(target: str, base: Path) -> bool:
    return (base / target).exists() or (ROOT / target).exists()


def check_links() -> list[str]:
    errors = []
    for md in _files(".md"):
        rel = md.relative_to(ROOT)
        for m in MD_LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1).split("#", 1)[0]
            if not target or EXTERNAL.match(m.group(1)) \
                    or m.group(1).startswith("#"):
                continue
            # strictly file-relative: that is how the link renders
            if not (md.parent / target).exists():
                errors.append(f"{rel}: dangling link -> {m.group(1)}")
    return errors


def check_mentions() -> list[str]:
    errors = []
    for path in list(_files(".py")) + [
            p for p in _files(".md") if p.name not in MENTION_EXEMPT]:
        rel = path.relative_to(ROOT)
        # external URLs ending in .md are not intra-repo references
        text = re.sub(r"(?:https?|ftp)://\S+", "",
                      path.read_text(encoding="utf-8"))
        for m in MD_MENTION.finditer(text):
            token = m.group(0).removeprefix("./")
            if not _exists(token, path.parent):
                errors.append(f"{rel}: mentions missing file {token}")
    return errors


# --------------------------------------------------------------------------- #
# pass 3: Sphinx-style :func:/:class:/:meth:/:mod:/:data: references
# --------------------------------------------------------------------------- #
def _module_name(path: Path) -> str:
    rel = path.relative_to(ROOT).with_suffix("")
    parts = list(rel.parts)
    if parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _symbol_table():
    """Static (ast) symbol table of every repo python file.

    Returns ``(modules, methods, global_names)`` where ``modules`` maps a
    module path to its top-level names, ``methods`` maps
    ``module -> class -> method/attr names``, and ``global_names`` is the
    union of all top-level names, class names and method names (the
    fallback for bare references).
    """
    modules: dict[str, set[str]] = {}
    methods: dict[str, dict[str, set[str]]] = {}
    global_names: set[str] = set()
    for p in _files(".py"):
        try:
            tree = ast.parse(p.read_text(encoding="utf-8"))
        except SyntaxError:
            continue            # pass 0 of some other tool's problem
        mod = _module_name(p)
        top: set[str] = set()
        cls_methods: dict[str, set[str]] = {}
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                top.add(node.name)
                if isinstance(node, ast.ClassDef):
                    names = set()
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            names.add(sub.name)
                        elif isinstance(sub, ast.AnnAssign) and \
                                isinstance(sub.target, ast.Name):
                            names.add(sub.target.id)
                        elif isinstance(sub, ast.Assign):
                            names.update(t.id for t in sub.targets
                                         if isinstance(t, ast.Name))
                    cls_methods[node.name] = names
                    global_names.update(names)
            elif isinstance(node, ast.Assign):
                top.update(t.id for t in node.targets
                           if isinstance(t, ast.Name))
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                top.add(node.target.id)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                top.update((a.asname or a.name).split(".")[0]
                           for a in node.names if a.name != "*")
        modules[mod] = top
        methods[mod] = cls_methods
        global_names.update(top)
    # bare :mod:`bitflip` references resolve by module basename
    global_names.update(m.rsplit(".", 1)[-1] for m in modules if m)
    return modules, methods, global_names


def _resolves(target: str, role: str, modules, methods, global_names) -> bool:
    name = target.lstrip("~").removesuffix("()")
    if "." not in name:
        return name in global_names or name in modules
    parts = name.split(".")
    # fully-qualified: longest known module prefix, then symbol [+ method]
    for cut in range(len(parts), 0, -1):
        mod = ".".join(parts[:cut])
        if mod not in modules:
            continue
        rest = parts[cut:]
        if not rest:
            return True                      # a module (any role; :mod:)
        if len(rest) == 1:
            return rest[0] in modules[mod]
        if len(rest) == 2:
            return rest[1] in methods[mod].get(rest[0], set())
        return False
    if parts[0] in (p.split(".")[0] for p in modules):
        return False         # rooted in a repo package but didn't resolve
    # foreign dotted path (jax.numpy, pltpu.prng_seed, ...): out of scope
    return True


def check_sphinx_refs() -> list[str]:
    modules, methods, global_names = _symbol_table()
    errors = [f"symbol table lost required module {mod} "
              "(moved? add the new path to REQUIRED_MODULES)"
              for mod in REQUIRED_MODULES if mod not in modules]
    for path in list(_files(".py")) + [
            p for p in _files(".md") if p.name not in MENTION_EXEMPT]:
        rel = path.relative_to(ROOT)
        for m in SPHINX_ROLE.finditer(path.read_text(encoding="utf-8")):
            role, target = m.group(1), m.group(2)
            if not ROLE_TARGET.match(target):
                continue      # prose mentioning the role syntax itself
            if not _resolves(target, role, modules, methods, global_names):
                errors.append(f"{rel}: unresolved :{role}:`{target}`")
    return errors


def main() -> int:
    errors = check_links() + check_mentions() + check_sphinx_refs()
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    n_md = len(list(_files(".md")))
    n_py = len(list(_files(".py")))
    print(f"check_docs: OK ({n_md} markdown files, {n_py} python files, "
          "no dangling references)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
