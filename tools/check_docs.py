#!/usr/bin/env python
"""Docs reference checker: fail CI on dangling intra-repo references.

Guards against the EXPERIMENTS.md class of bug — a docstring or document
citing a repo file that does not exist.  Two passes:

1. **Markdown links** — every relative link target in every ``*.md`` file
   (anchors stripped) must exist on disk, resolved against the file's
   directory — strictly file-relative, because that is how the link
   renders.
2. **.md mentions** — every ``<name>.md`` token mentioned in Python
   sources or in our own markdown must exist: bare names at the repo
   root, ``dir/<name>.md`` paths against the repo root or the mentioning
   file's directory.  ``SNIPPETS.md`` / ``PAPERS.md`` are exempt from
   this pass: they quote *external* repos' files as provenance.

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".claude", ".pytest_cache", "__pycache__",
             ".hypothesis", "results", "node_modules"}
MENTION_EXEMPT = {"SNIPPETS.md", "PAPERS.md"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MD_MENTION = re.compile(r"[A-Za-z0-9_./-]*[A-Za-z0-9_-]\.md\b")
EXTERNAL = re.compile(r"^(https?|mailto|ftp):")


def _files(suffix: str):
    for p in sorted(ROOT.rglob(f"*{suffix}")):
        if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts):
            yield p


def _exists(target: str, base: Path) -> bool:
    return (base / target).exists() or (ROOT / target).exists()


def check_links() -> list[str]:
    errors = []
    for md in _files(".md"):
        rel = md.relative_to(ROOT)
        for m in MD_LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1).split("#", 1)[0]
            if not target or EXTERNAL.match(m.group(1)) \
                    or m.group(1).startswith("#"):
                continue
            # strictly file-relative: that is how the link renders
            if not (md.parent / target).exists():
                errors.append(f"{rel}: dangling link -> {m.group(1)}")
    return errors


def check_mentions() -> list[str]:
    errors = []
    for path in list(_files(".py")) + [
            p for p in _files(".md") if p.name not in MENTION_EXEMPT]:
        rel = path.relative_to(ROOT)
        # external URLs ending in .md are not intra-repo references
        text = re.sub(r"(?:https?|ftp)://\S+", "",
                      path.read_text(encoding="utf-8"))
        for m in MD_MENTION.finditer(text):
            token = m.group(0).removeprefix("./")
            if not _exists(token, path.parent):
                errors.append(f"{rel}: mentions missing file {token}")
    return errors


def main() -> int:
    errors = check_links() + check_mentions()
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    n_md = len(list(_files(".md")))
    n_py = len(list(_files(".py")))
    print(f"check_docs: OK ({n_md} markdown files, {n_py} python files, "
          "no dangling references)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
