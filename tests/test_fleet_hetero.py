"""Heterogeneous-fleet regression: per-device ``set_age``/``advance``,
mixed-age snapshots and ``op_ber_array`` consistency — the fleet state
machinery the traffic scheduler routes on."""
import numpy as np
import pytest

from repro.core.fleet import SECONDS_PER_YEAR, FleetRuntime
from repro.core.resilience import OPERATORS

MIXED_YEARS = (0.5, 9.5, 2.0, 6.0, 4.0)


@pytest.fixture()
def fleet():
    return FleetRuntime(n_devices=len(MIXED_YEARS),
                        policy="fault_tolerant")


def test_mixed_set_age_reflected_in_ages_years(fleet):
    for i, years in enumerate(MIXED_YEARS):
        fleet.set_age(years=years, device=i)
    np.testing.assert_allclose(fleet.ages_years, MIXED_YEARS, rtol=1e-12)
    # fleet-wide set_age overwrites every device
    fleet.set_age(years=3.0)
    np.testing.assert_allclose(fleet.ages_years, 3.0)
    # seconds= and years= agree
    fleet.set_age(seconds=2.5 * SECONDS_PER_YEAR, device=1)
    assert fleet.ages_years[1] == pytest.approx(2.5)
    assert fleet.ages_years[0] == pytest.approx(3.0)


def test_mixed_advance_per_device_and_fleet_wide(fleet):
    for i, years in enumerate(MIXED_YEARS):
        fleet.set_age(years=years, device=i)
    fleet.advance(SECONDS_PER_YEAR, device=2)
    want = np.asarray(MIXED_YEARS, np.float64)
    want[2] += 1.0
    np.testing.assert_allclose(fleet.ages_years, want, rtol=1e-12)
    fleet.advance(0.5 * SECONDS_PER_YEAR)          # whole fleet
    np.testing.assert_allclose(fleet.ages_years, want + 0.5, rtol=1e-12)
    # vector advance: one value per device
    fleet.advance(np.arange(len(MIXED_YEARS)) * SECONDS_PER_YEAR)
    np.testing.assert_allclose(
        fleet.ages_years, want + 0.5 + np.arange(len(MIXED_YEARS)),
        rtol=1e-12)


def test_mixed_age_snapshot_matches_per_device_reference(fleet):
    """A mixed-age snapshot must equal, device by device, the snapshot of
    a uniform fleet pinned at that device's age (round-trip through the
    shared vmapped trajectories)."""
    for i, years in enumerate(MIXED_YEARS):
        fleet.set_age(years=years, device=i)
    snap = fleet.snapshot()
    ref = FleetRuntime(n_devices=1, policy="fault_tolerant")
    for i, years in enumerate(MIXED_YEARS):
        ref.set_age(years=years)
        rsnap = ref.snapshot()
        for f in ("v_dd", "delay", "dvth_p_mv", "dvth_n_mv", "ber",
                  "power_w"):
            np.testing.assert_allclose(
                getattr(snap, f)[i], getattr(rsnap, f)[0],
                rtol=1e-6, err_msg=f"{f} device {i} @ {years}y")


def test_snapshot_cache_invalidation_round_trip(fleet):
    fleet.set_age(years=5.0)
    a = fleet.snapshot()
    assert fleet.snapshot() is a                   # cached between changes
    fleet.advance(SECONDS_PER_YEAR, device=0)
    b = fleet.snapshot()
    assert b is not a
    assert (b.dvth_p_mv[0] > a.dvth_p_mv[0]).all()
    np.testing.assert_allclose(b.dvth_p_mv[1:], a.dvth_p_mv[1:])
    # setting the same ages again reproduces the identical state
    fleet.set_age(years=5.0)
    fleet.advance(SECONDS_PER_YEAR, device=0)
    c = fleet.snapshot()
    for f in ("v_dd", "delay", "dvth_p_mv", "dvth_n_mv", "ber", "power_w"):
        np.testing.assert_array_equal(getattr(c, f), getattr(b, f))


def test_op_ber_array_consistent_with_scalar_accessors(fleet):
    for i, years in enumerate(MIXED_YEARS):
        fleet.set_age(years=years, device=i)
    arr = fleet.op_ber_array()
    assert arr.shape == (len(MIXED_YEARS), len(OPERATORS))
    for i in range(fleet.n_devices):
        bers = fleet.op_bers(device=i)
        for j, op in enumerate(fleet.operators):
            assert arr[i, j] == pytest.approx(bers[op], rel=1e-12)
            assert arr[i, j] == pytest.approx(fleet.op_ber(op, device=i),
                                              rel=1e-12)
        view = fleet.device(i)
        assert view.op_bers() == bers
    # older devices never admit a lower worst-domain BER
    order = np.argsort(MIXED_YEARS)
    worst = arr.max(axis=1)
    assert (np.diff(worst[order]) >= -1e-30).all()


# --------------------------------------------------------------------------- #
# state_dict round-trip, including the recoverable-state leaves
# --------------------------------------------------------------------------- #
def test_state_dict_roundtrip_with_recoverable_pool(fleet):
    """A recovery-enabled fleet serialises to JSON and resumes
    bit-exactly: the restored fleet replays the next traffic segment to
    the SAME trajectory as the original."""
    import json

    U = np.linspace(0.2, 1.0, 24 * fleet.n_devices).reshape(
        24, fleet.n_devices).astype(np.float32)
    fleet.apply_load(util_trace=U, horizon_s=SECONDS_PER_YEAR,
                     recovery=True)
    d = json.loads(json.dumps(fleet.state_dict()))
    assert d["version"] == 1
    assert np.asarray(d["rec_mv"]).any()          # the pool is non-trivial

    other = FleetRuntime(n_devices=fleet.n_devices,
                         policy="fault_tolerant")
    other.load_state_dict(d)
    st_a, st_b = fleet.trap_state(), other.trap_state()
    for k in ("ages_s", "dv", "rec", "v"):
        np.testing.assert_allclose(st_b[k], st_a[k], rtol=0, atol=1e-6,
                                   err_msg=k)
    U2 = np.flip(U, axis=0).copy()
    cos_a = fleet.apply_load(util_trace=U2, horizon_s=SECONDS_PER_YEAR,
                             recovery=True)
    cos_b = other.apply_load(util_trace=U2, horizon_s=SECONDS_PER_YEAR,
                             recovery=True)
    np.testing.assert_allclose(np.asarray(cos_b.dvp),
                               np.asarray(cos_a.dvp), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cos_b.rec),
                               np.asarray(cos_a.rec), atol=1e-5)


def test_old_artifact_without_rec_loads_zero_filled(fleet):
    """Snapshots written before short-term recovery existed carry no
    ``rec_mv`` leaf: they must load with an empty recoverable pool."""
    fleet.apply_load(util_trace=np.ones((12, fleet.n_devices),
                                        np.float32),
                     horizon_s=SECONDS_PER_YEAR)
    d = fleet.state_dict()
    d.pop("rec_mv")                                # simulate the old format
    other = FleetRuntime(n_devices=fleet.n_devices,
                         policy="fault_tolerant")
    other.load_state_dict(d)
    st = other.trap_state()
    np.testing.assert_array_equal(st["rec"], 0.0)
    np.testing.assert_allclose(st["dv"], fleet.trap_state()["dv"],
                               atol=1e-6)
