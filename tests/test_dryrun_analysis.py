"""Roofline extraction utilities + the scan-trip-blindness evidence that
motivates the probe methodology (launch/dryrun.py docstring)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import analysis


# --------------------------------------------------------------------------- #
# HLO collective parser
# --------------------------------------------------------------------------- #
HLO_SAMPLE = """
HloModule test
  %p = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[4096,512]{1,0} all-gather(%p), replica_groups={}
  %ar = f32[128]{0} all-reduce(%x), to_apply=%sum
  %t = (f32[64,32]{1,0}, f32[64,32]{1,0}) all-reduce(%a, %b), to_apply=%sum
  %rs = bf16[256,512]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = s8[32,128]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[16,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = bf16[2048,128]{1,0} all-gather-start(%q)
  %agd = bf16[2048,128]{1,0} all-gather-done(%ags)
  %not = bf16[9,9]{1,0} add(%p, %p)
"""


def test_collective_bytes_parser():
    got = analysis.collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 4096 * 512 * 2 + 2048 * 128 * 2  # start once
    assert got["all-reduce"] == 128 * 4 + 2 * 64 * 32 * 4        # tuple sum
    assert got["reduce-scatter"] == 256 * 512 * 2
    assert got["all-to-all"] == 32 * 128
    assert got["collective-permute"] == 16 * 16 * 2
    assert got["total"] == sum(got[k] for k in analysis.COLLECTIVE_OPS)


def test_collective_parser_on_real_lowering():
    """Parse a real partitioned module: fully-sharded matmul -> the known
    all-reduce of the (M, N) f32 output."""
    import subprocess, sys, textwrap, json
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import analysis
        mesh = jax.make_mesh((4,), ("k",))
        f = jax.jit(lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P(None, "k")),
                                  NamedSharding(mesh, P("k", None))),
                    out_shardings=NamedSharding(mesh, P()))
        sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = f.lower(sds, sds).compile()
        print("RESULT " + json.dumps(analysis.collective_bytes(c.as_text())))
    """)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=300,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    got = json.loads(line[len("RESULT "):])
    assert got["all-reduce"] == 64 * 64 * 4
    assert got["total"] == got["all-reduce"]


def test_scan_trip_blindness_documented():
    """XLA cost_analysis counts a scan body ONCE — the undercount the probe
    extrapolation in launch/dryrun.py corrects.  If this test ever fails,
    XLA fixed trip-count accounting and the probes can be retired."""
    def f(ws, x):
        return jax.lax.scan(lambda c, w: (c @ w, ()), x, ws)[0]

    w8 = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    w2 = jax.ShapeDtypeStruct((2, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def flops(wsds):
        c = jax.jit(f).lower(wsds, x).compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        return float(ca["flops"])

    assert flops(w8) == pytest.approx(flops(w2), rel=0.01)


# --------------------------------------------------------------------------- #
# roofline terms
# --------------------------------------------------------------------------- #
def test_roofline_terms_math():
    t = analysis.RooflineTerms(flops=197e12 * 256, hbm_bytes=819e9 * 256,
                               coll_bytes_per_dev=50e9, n_devices=256,
                               model_flops=197e12 * 128)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0)
    assert t.t_collective == pytest.approx(1.0)
    assert t.useful_flops_frac == pytest.approx(0.5)
    assert t.roofline_frac == pytest.approx(0.5)
    assert t.dominant in ("compute", "memory", "collective")


def test_model_flops_active_params_for_moe():
    cfg = get_config("qwen3_moe_235b")
    cell = SHAPES["train_4k"]
    mf = analysis.model_flops_for(cfg, cell, 10_000)
    dense_equiv = 6 * cfg.param_count() * 10_000
    active = 6 * cfg.active_param_count() * 10_000
    assert mf == active < dense_equiv / 5      # top-8 of 128 experts


def test_analytic_hbm_model_sane():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
        size = 256
    cfg = get_config("deepseek_7b")
    hbm = analysis.analytic_hbm_bytes(cfg, SHAPES["train_4k"], FakeMesh(),
                                      microbatches=16, fsdp=True)
    assert hbm["total"] == sum(v for k, v in hbm.items() if k != "total")
    # training reads weights once per microbatch
    assert hbm["weights"] == pytest.approx(
        2 * cfg.param_count() / 16 * 16)
    dec = analysis.analytic_hbm_bytes(cfg, SHAPES["decode_32k"], FakeMesh())
    # decode is dominated by weights + cache, no optimizer traffic
    assert dec["opt"] == 0.0 and dec["cache"] > 0
    assert dec["total"] < hbm["total"]


# --------------------------------------------------------------------------- #
# shape-cell applicability (the documented skips)
# --------------------------------------------------------------------------- #
def test_long_context_applicability():
    runs, skips = [], []
    for arch in ("rwkv6_3b", "recurrentgemma_2b", "deepseek_7b",
                 "command_r_plus_104b", "whisper_large_v3"):
        ok, why = applicable(get_config(arch), SHAPES["long_500k"])
        (runs if ok else skips).append(arch)
    assert runs == ["rwkv6_3b", "recurrentgemma_2b"]
    assert len(skips) == 3


def test_input_specs_cover_all_inputs():
    from repro.launch import dryrun
    for arch in ("paligemma_3b", "whisper_large_v3", "deepseek_7b"):
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            spec = dryrun.input_specs(cfg, SHAPES[shape])
            assert "tokens" in spec
            if cfg.prefix_tokens:
                assert "prefix_embeds" in spec
            if cfg.n_encoder_layers:
                assert "frames" in spec
            for s in jax.tree.leaves(spec):
                assert isinstance(s, jax.ShapeDtypeStruct)
