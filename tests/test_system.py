"""End-to-end system tests: the full stack wired together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.steps import init_train_state, make_train_step


@pytest.mark.slow
def test_train_loop_learns_and_resumes(tmp_path):
    """Train -> interrupt -> auto-resume -> loss continues to fall, and the
    resumed run hits the same step count as an uninterrupted one."""
    cfg = get_config("granite_20b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))

    def init():
        return init_train_state(cfg, jax.random.PRNGKey(0))

    def make_batch(s):
        tb = data.batch_at(s)
        return {"tokens": jnp.asarray(tb.tokens),
                "labels": jnp.asarray(tb.labels)}

    ckpt = str(tmp_path / "ckpt")
    logs = []
    # phase 1: run 25 of 60 steps, checkpoint every 10, then "preempt"
    loop1 = TrainLoop(step, data, ckpt_dir=ckpt,
                      cfg=LoopConfig(total_steps=25, ckpt_every=10,
                                     log_every=1000),
                      make_batch=make_batch, log_fn=logs.append)
    loop1.run(init)

    # phase 2: fresh loop object resumes from the last committed step
    loop2 = TrainLoop(step, data, ckpt_dir=ckpt,
                      cfg=LoopConfig(total_steps=60, ckpt_every=10,
                                     log_every=1000),
                      make_batch=make_batch, log_fn=logs.append)
    loop2.run(init)
    assert any("resumed" in l for l in logs)
    resumed_steps = [h["step"] for h in loop2.history]
    assert resumed_steps[0] >= 20 and resumed_steps[-1] == 59

    first_losses = [h["loss"] for h in loop1.history[:5]]
    last_losses = [h["loss"] for h in loop2.history[-5:]]
    assert np.mean(last_losses) < np.mean(first_losses) - 0.3


def test_microbatched_step_matches_single_shot():
    """Gradient accumulation must not change the math (same global batch)."""
    cfg = get_config("starcoder2_7b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8)
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=0)
    tb = data.batch_at(0)
    batch = {"tokens": jnp.asarray(tb.tokens),
             "labels": jnp.asarray(tb.labels)}

    s1 = init_train_state(cfg, jax.random.PRNGKey(0))
    s2 = init_train_state(cfg, jax.random.PRNGKey(0))
    st1, m1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))(s1,
                                                                     batch)
    st4, m4 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))(s2,
                                                                     batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        st1.params, st4.params)
    assert max(jax.tree.leaves(deltas)) < 2e-5


def test_remat_preserves_gradients():
    cfg = get_config("deepseek_7b").reduced()
    from repro.train.steps import make_loss_fn
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tb = data.batch_at(0)
    batch = {"tokens": jnp.asarray(tb.tokens),
             "labels": jnp.asarray(tb.labels)}
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    g1 = jax.grad(lambda p: make_loss_fn(cfg, remat=False)(p, batch)[0])(
        params)
    g2 = jax.grad(lambda p: make_loss_fn(cfg, remat=True)(p, batch)[0])(
        params)
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
    assert max(jax.tree.leaves(deltas)) < 1e-5


def test_cli_train_entrypoint():
    """The launcher runs end-to-end (tiny budget)."""
    from repro.launch.train import main
    loop = main(["--arch", "rwkv6_3b", "--steps", "6", "--seq", "32",
                 "--batch", "4"])
    assert len(loop.history) == 6
    assert np.isfinite(loop.history[-1]["loss"])


def test_cli_serve_entrypoint():
    from repro.launch.serve import main
    res = main(["--arch", "granite_20b", "--age-years", "8.0",
                "--batch", "2", "--prompt-len", "16", "--gen-len", "4"])
    assert res.tokens.shape == (2, 4)
    assert res.bers["q"] > res.bers["o"]
