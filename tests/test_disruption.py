"""Disruption scenarios: flash crowd + thermal feedback, retirement /
hot-swap with trap-state-preserving resize, and rest-to-recover routing.

The scenario regression layer for :mod:`repro.sched.disruption`: the
closed thermal loop reaches a *bounded* fixed point and is monotone in
routed power, mid-horizon retirement resumes the survivors bit-exactly
(replay-verified against the undisturbed run), the ``rest_to_recover``
router beats round-robin on effective fleet-max ΔVth (mirroring the
wear-leveling acceptance test), and the un-orphaned elastic dry-run
compiles the degraded mesh end to end in a subprocess.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifacts import load_calibration
from repro.core.fleet import FleetRuntime
from repro.core.policy import FaultTolerantPolicy
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario
from repro.sched import cosimulate, get_workload
from repro.sched.disruption import (recovered_totals, run_flash_crowd,
                                    run_rest_to_recover, run_retirement)
from repro.sched.workload import WORKLOADS

YEAR_S = 365.25 * 24 * 3600.0


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


@pytest.fixture(scope="module")
def policy(cal):
    return FaultTolerantPolicy(ber_model=cal.ber)


# --------------------------------------------------------------------------- #
# flash_crowd workload
# --------------------------------------------------------------------------- #
def test_flash_crowd_workload_window():
    wl = get_workload("flash_crowd", n_devices=8, utilization=0.5,
                      n_epochs=240, surge_gain=4.0)
    loads = np.asarray(wl.loads(0))
    s0, sl = int(wl.surge_start), int(wl.surge_len)
    assert 0 < s0 and s0 + sl <= 240 and sl >= 1
    inside = loads[s0:s0 + sl].mean()
    outside = np.concatenate([loads[:s0], loads[s0 + sl:]]).mean()
    assert inside > 2.5 * outside            # the x4 surge is visible
    np.testing.assert_array_equal(loads, np.asarray(wl.loads(0)))


def test_flash_crowd_zero_length_surge_is_identity():
    base = get_workload("poisson", n_devices=4, utilization=0.5,
                        n_epochs=96)
    fc = get_workload("flash_crowd", n_devices=4, utilization=0.5,
                      n_epochs=96, surge_len=0)
    # no window -> the envelope degenerates to the base arrival model's
    np.testing.assert_array_equal(np.asarray(fc.envelope()),
                                  np.asarray(base.envelope()))
    # every legacy workload still defaults to a unit surge envelope
    for name in WORKLOADS:
        if name == "flash_crowd":
            continue
        wl = get_workload(name, n_devices=4, utilization=0.5, n_epochs=96)
        assert float(wl.surge_len) == 0.0
    del base


# --------------------------------------------------------------------------- #
# closed thermal loop
# --------------------------------------------------------------------------- #
def _thermal_replay(cal, policy, util, epochs=48, n=4):
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
        lifetime_s=1.0 * YEAR_S)
    dmax = policy.thresholds(scn, OPERATORS)
    U = np.full((epochs, n), util, np.float32)
    return cosimulate(cal.aging, cal.delay_poly, scn, dmax, None,
                      util_trace=jnp.asarray(U), thermal=True)


def test_thermal_node_bounded_fixed_point(cal, policy):
    cos = _thermal_replay(cal, policy, 1.0)
    tn = np.asarray(cos.t_node)
    assert np.isfinite(tn).all()
    t_amb = float(np.asarray(Scenario.from_lifetime_config(
        cal.lifetime_cfg).t_amb))
    assert (tn >= t_amb - 1e-3).all()        # dissipation only heats
    assert tn.max() < t_amb + 60.0           # bounded: util<=1, V<=v_max
    # a constant-power run settles: the last epochs stop moving
    assert abs(tn[-1].max() - tn[-2].max()) < 0.1


def test_thermal_node_monotone_in_routed_power(cal, policy):
    lo = np.asarray(_thermal_replay(cal, policy, 0.2).t_node)
    hi = np.asarray(_thermal_replay(cal, policy, 0.9).t_node)
    assert (hi >= lo - 1e-4).all()
    assert hi[-1].max() > lo[-1].max() + 1.0  # strictly hotter in steady


def test_flash_crowd_driver_heats_and_relaxes(cal):
    out = run_flash_crowd(cal, n_devices=4, epochs=96, surge_gain=4.0)
    s, tn = out["stats"], np.asarray(out["cos"].t_node)
    assert np.isfinite(tn).all()
    assert s["t_peak_k"] >= s["t_steady_k"] - 1e-3
    assert s["t_surge_rise_k"] > 1.0         # the fleet-mean spike shows
    assert 0.0 < s["surge_served_frac"] < 1.0   # x4 overload saturates
    # the node relaxes after the window (RC decay, not a ratchet)
    fm = tn.mean(axis=1)
    assert fm[-1] < fm[int(s["surge_start"]):int(s["surge_end"])].max()
    assert s["fleet_max_dvp_mv"] > 0.0
    assert s["recovered_mv_final"] >= 0.0


# --------------------------------------------------------------------------- #
# retirement / hot-swap: trap-state-preserving resize
# --------------------------------------------------------------------------- #
def _mk_fleet(cal, n):
    return FleetRuntime(cal, n_devices=n)


def test_retirement_survivors_bit_exact_vs_undisturbed(cal):
    """Replay the SAME measured duty with and without a mid-horizon
    resize: survivors' monotone state, recoverable pool and supplies
    must be bit-identical to the undisturbed run."""
    E, e, n, keep = 64, 32, 4, [0, 2, 3]
    rnd = np.random.default_rng(7)
    U = rnd.uniform(0.0, 1.0, (E, n)).astype(np.float32)
    H = 2.0 * YEAR_S

    full = _mk_fleet(cal, n).apply_load(util_trace=U, horizon_s=H,
                                        recovery=True)

    fleet = _mk_fleet(cal, n)
    fleet.apply_load(util_trace=U[:e], horizon_s=H * e / E, recovery=True)
    fleet2 = fleet.resize(keep)
    cos2 = fleet2.apply_load(util_trace=U[e:][:, keep],
                             horizon_s=H * (E - e) / E, recovery=True)

    ref = lambda x: np.asarray(x)[e:][:, keep]
    np.testing.assert_array_equal(np.asarray(cos2.dv), ref(full.dv))
    np.testing.assert_array_equal(np.asarray(cos2.rec), ref(full.rec))
    np.testing.assert_array_equal(np.asarray(cos2.V), ref(full.V))


def test_hot_swap_fresh_devices_start_clean(cal):
    n, keep = 4, [1, 2, 3]
    fleet = _mk_fleet(cal, n)
    fleet.apply_load(util_trace=np.ones((16, n), np.float32),
                     horizon_s=1.0 * YEAR_S, recovery=True)
    worn = fleet.trap_state()
    fleet2 = fleet.resize(keep, n_fresh=1)
    st = fleet2.trap_state()
    assert st["dv"].shape[0] == len(keep) + 1
    # survivors carry their exact state, the swap-in starts from zero
    np.testing.assert_array_equal(st["dv"][:3], worn["dv"][keep])
    np.testing.assert_array_equal(st["dv"][3], 0.0)
    np.testing.assert_array_equal(st["rec"][3], 0.0)
    assert st["ages_s"][3] == 0.0 and (st["ages_s"][:3] > 0).all()
    # the fresh device inherits the retired rack slot's thermal seat
    t_amb = np.asarray(fleet.scenario.t_amb)
    if t_amb.ndim:
        assert float(np.asarray(fleet2.scenario.t_amb)[3]) == \
            pytest.approx(float(t_amb[0]))


def test_run_retirement_driver_plans_and_stats(cal):
    out = run_retirement(cal, n_devices=8, retire=(0, 1), hot_swap=1,
                         epochs=48, tp=2, global_batch=64)
    pd, pr, s = out["plan_degraded"], out["plan_restored"], out["stats"]
    assert pd.old_shape == (8, 2) and pd.new_shape[0] < 8
    # global batch preserved: dp * microbatches never shrinks
    assert pd.new_shape[0] * pd.microbatches >= 8
    assert pr is not None and pr.new_shape[0] >= pd.new_shape[0]
    assert s["n_before"] == 8 and s["n_after"] == 7
    assert s["survivor_pre_max_dvp_mv"] <= s["pre_retire_max_dvp_mv"]
    assert s["fleet_max_dvp_mv"] >= s["survivor_pre_max_dvp_mv"]
    assert out["cos_after"].util.shape[1] == 7


# --------------------------------------------------------------------------- #
# rest_to_recover: deliberate idling harvests the recoverable pool
# --------------------------------------------------------------------------- #
def test_rest_to_recover_beats_round_robin(cal):
    """The acceptance criterion (mirrors the wear_level -13% test): on
    the 8-device heterogeneous fleet with recovery enabled, resting the
    most-worn devices reduces fleet-max effective ΔVth vs round-robin."""
    res = run_rest_to_recover(cal, n_devices=8, epochs=120)
    rr = res["round_robin"]["fleet_max_dvp_mv"]
    rest = res["rest_to_recover"]["fleet_max_dvp_mv"]
    assert rest < 0.95 * rr, (rest, rr)
    assert res["headline"]["rest_vs_round_robin_pct"] > 5.0
    assert res["headline"]["recovered_mv_final"] > 0.0
    # resting may not drop traffic at this utilization
    assert res["rest_to_recover"]["served_frac"] == \
        pytest.approx(1.0, abs=1e-3)


def test_recovered_totals_shape_and_positivity(cal):
    out = run_flash_crowd(cal, n_devices=4, epochs=48)
    rec = recovered_totals(out["cos"])
    assert rec.shape == (48, 4)
    assert (rec >= 0.0).all() and np.isfinite(rec).all()


# --------------------------------------------------------------------------- #
# CLI + un-orphaned elastic dry-run
# --------------------------------------------------------------------------- #
def test_schedule_cli_scenarios_inprocess(capsys):
    from repro.launch.schedule import main
    out = main(["--scenario", "flash_crowd", "--n-devices", "4",
                "--epochs", "48"])
    assert "stats" in out
    out = main(["--scenario", "rest_to_recover", "--n-devices", "8",
                "--epochs", "96"])
    assert out["headline"]["rest_vs_round_robin_pct"] > 0.0
    out = main(["--scenario", "retirement", "--n-devices", "4",
                "--epochs", "48", "--hot-swap", "1"])
    assert out["stats"]["n_after"] == 4
    text = capsys.readouterr().out
    assert "[disrupt]" in text


@pytest.mark.slow
def test_elastic_dryrun_quick_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic_dryrun", "--quick"],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "degraded-mesh train step compiles" in proc.stdout
    assert "survivors resumed" in proc.stdout
