"""Sharding-rule unit tests (spec shapes, divisibility fallbacks) plus
multi-device integration via a subprocess (8 faked host devices — kept out
of this process so other tests see the real single CPU device).

Property tests sweep the WHOLE zoo x tp x layout grid (hypothesis when
installed, the deterministic fallback shim otherwise); the explicit tests
below them pin each documented serve-layout fallback to the config that
fires it."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import batch_spec, param_specs


class FakeMesh:
    """Duck-typed mesh: shape mapping + axis_names (no devices needed)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


def _specs(arch, fsdp=False, mesh=None):
    cfg = get_config(arch)
    mesh = mesh or FakeMesh({"data": 16, "model": 16})
    sds = jax.eval_shape(
        lambda k: __import__("repro.models.transformer",
                             fromlist=["init_params"])
        .init_params(cfg, k, jnp.bfloat16), jax.random.PRNGKey(0))
    return cfg, param_specs(sds, cfg, mesh, fsdp=fsdp), sds


def _leaf(specs, *path):
    node = specs
    for p in path:
        node = node[p]
    return node


def test_dense_tp_rules():
    cfg, specs, sds = _specs("deepseek_7b")          # H=32, KV=32 both %16==0
    g = specs["groups"]["b0_attn"]
    assert _leaf(g, "attn", "wq") == P(None, None, "model", None)
    assert _leaf(g, "attn", "wk") == P(None, None, "model", None)
    assert _leaf(g, "attn", "wo") == P(None, "model", None, None)
    assert _leaf(g, "ffn", "w_up") == P(None, None, "model")
    assert _leaf(g, "ffn", "w_down") == P(None, "model", None)
    assert specs["embed"] == P(None, "model")
    assert specs["lm_head"] == P(None, "model")       # vocab % 16 == 0
    assert _leaf(g, "norm1", "scale") == P()


def test_awkward_heads_fall_back_to_contraction_sharding():
    cfg, specs, _ = _specs("starcoder2_7b")           # H=36, KV=4: not %16
    g = specs["groups"]["b0_attn"]
    assert _leaf(g, "attn", "wq") == P(None, "model", None, None)
    assert _leaf(g, "attn", "wo") == P(None, None, None, "model")
    assert _leaf(g, "attn", "wk") == P(None, "model", None, None)


def test_moe_expert_parallel():
    cfg, specs, _ = _specs("qwen3_moe_235b")          # 128 experts % 16
    g = specs["groups"]["b0_attn"]
    assert _leaf(g, "ffn", "w_up") == P(None, "model", None, None)
    assert _leaf(g, "ffn", "w_down") == P(None, "model", None, None)
    assert _leaf(g, "ffn", "w_router") == P()


def test_fsdp_adds_data_axis():
    cfg, specs, sds = _specs("deepseek_7b", fsdp=True)
    g = specs["groups"]["b0_attn"]
    wq = _leaf(g, "attn", "wq")
    assert "model" in wq and any(
        ax == "data" or (isinstance(ax, tuple) and "data" in ax)
        for ax in wq if ax)
    # every >=2D leaf gets data-sharded somewhere when divisible
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    flat_sds = jax.tree.leaves(sds)
    n_fsdp = sum(1 for s, l in zip(flat_specs, flat_sds)
                 if len(l.shape) >= 3 and any(
                     ax == "data" or (isinstance(ax, tuple) and "data" in ax)
                     for ax in s if ax))
    assert n_fsdp > 0


def test_divisibility_never_violated():
    mesh = FakeMesh({"data": 16, "model": 16})
    for arch in ("arctic_480b", "whisper_large_v3", "paligemma_3b",
                 "rwkv6_3b", "recurrentgemma_2b"):
        cfg = get_config(arch)
        from repro.models import encdec, transformer as tf
        init = encdec.init_params if cfg.n_encoder_layers else tf.init_params
        sds = jax.eval_shape(lambda k: init(cfg, k, jnp.bfloat16),
                             jax.random.PRNGKey(0))
        specs = param_specs(sds, cfg, mesh, fsdp=True)
        for leaf, spec in zip(
                jax.tree.leaves(sds),
                jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % n == 0, (arch, leaf.shape, spec)


def test_batch_spec_divisibility():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec(256, mesh) == ("pod", "data")
    assert batch_spec(2, mesh) == ("pod",)
    assert batch_spec(1, mesh) is None
    mesh1 = FakeMesh({"data": 16, "model": 16})
    assert batch_spec(32, mesh1) == ("data",)


# --------------------------------------------------------------------------- #
# property tests: the whole zoo x tp x layout grid
# --------------------------------------------------------------------------- #
_SDS_CACHE = {}


def _abstract_params(arch):
    """Abstract param tree for one zoo config (cached: eval_shape only)."""
    if arch not in _SDS_CACHE:
        cfg = get_config(arch)
        from repro.models import encdec, transformer as tf
        init = encdec.init_params if cfg.n_encoder_layers else tf.init_params
        _SDS_CACHE[arch] = (cfg, jax.eval_shape(
            lambda k: init(cfg, k, jnp.bfloat16), jax.random.PRNGKey(0)))
    return _SDS_CACHE[arch]


_STACKS = ("groups", "enc_layers", "dec_layers")


def _flat_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda s: isinstance(s, P))
    out = []
    for path, leaf in flat:
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        out.append(([n for n in names if n is not None], leaf))
    return out


@settings(max_examples=40, deadline=None)
@given(arch=st.sampled_from(ARCH_IDS),
       tp=st.sampled_from([1, 2, 4, 8, 16]),
       serve=st.booleans())
def test_every_param_gets_a_valid_spec(arch, tp, serve):
    """For every zoo config x tp x layout: every leaf has a spec, specs are
    full-rank (right-aligned: leading stack axes replicated), and a sharded
    dim is always divisible by the axis size."""
    cfg, sds = _abstract_params(arch)
    mesh = FakeMesh({"data": 2, "model": tp})
    layout = "serve" if serve else "train"
    specs = param_specs(sds, cfg, mesh, layout=layout)

    leaves = _flat_with_names(sds)
    spec_leaves = _flat_with_names(specs)
    assert len(leaves) == len(spec_leaves) and len(leaves) > 0
    for (names, leaf), (snames, spec) in zip(leaves, spec_leaves):
        assert names == snames
        assert isinstance(spec, P)
        if tp == 1:
            assert spec == P(), (arch, names)
            continue
        if spec == P():            # fully replicated leaves compress to P()
            continue
        assert len(spec) == len(leaf.shape), (arch, names, spec)
        # leading stacked-layer axes are never sharded
        n_stack = sum(1 for n in names if n in _STACKS)
        assert all(ax is None for ax in tuple(spec)[:n_stack]), \
            (arch, names, spec)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (arch, names, leaf.shape, spec)


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(ARCH_IDS), tp=st.sampled_from([2, 4, 8, 16]))
def test_serve_layout_never_shards_contraction_dims(arch, tp):
    """The exact-TP contract: serve specs shard OUTPUT dims only — for 2-D
    weights (in, out) the contraction (first) dim must stay replicated, so
    no float reduction ever spans shards."""
    cfg, sds = _abstract_params(arch)
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": tp}),
                        layout="serve")
    for names, spec in _flat_with_names(specs):
        if names[-1] in ("embed",) or spec == P():
            continue                       # row gather / fully replicated
        base = tuple(spec)[sum(1 for n in names if n in _STACKS):]
        if len(base) == 2:
            assert base[0] is None, (arch, names, spec)


# --------------------------------------------------------------------------- #
# the documented serve-layout fallbacks, each pinned to a firing config
# --------------------------------------------------------------------------- #
def _serve_wq(arch, tp=16):
    cfg, sds = _abstract_params(arch)
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": tp}),
                        layout="serve")
    return [(n, s) for n, s in _flat_with_names(specs) if n[-1] == "wq"]


@pytest.mark.parametrize("arch,heads", [
    ("arctic_480b", 56), ("starcoder2_7b", 36), ("whisper_large_v3", 20),
    ("paligemma_3b", 8), ("recurrentgemma_2b", 10)])
def test_serve_head_fallback_replicates(arch, heads):
    """Head counts not divisible by tp=16 REPLICATE wq under the serve
    layout (the train layout would contraction-shard instead — exactness
    over memory)."""
    cfg = get_config(arch)
    assert cfg.n_heads == heads and heads % 16 != 0
    wqs = _serve_wq(arch)
    assert wqs, arch
    for names, spec in wqs:
        assert all(ax is None for ax in tuple(spec)), (arch, names, spec)


def test_serve_head_rule_fires_when_divisible():
    for names, spec in _serve_wq("deepseek_7b"):     # H=32 % 16 == 0
        assert "model" in tuple(spec), (names, spec)


def test_serve_gqa_kv_fallback():
    """GQA with fewer KV heads than tp: wk/wv replicate, wq still shards."""
    cfg, sds = _abstract_params("llama3_8b")         # H=32, KV=8
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": 16}),
                        layout="serve")
    for names, spec in _flat_with_names(specs):
        if names[-1] in ("wk", "wv"):
            assert all(ax is None for ax in tuple(spec)), (names, spec)
        if names[-1] == "wq":
            assert "model" in tuple(spec), (names, spec)


def test_serve_vocab_fallback_whisper():
    """vocab=51866 is not divisible by 16: the lm_head replicates."""
    cfg, sds = _abstract_params("whisper_large_v3")
    assert cfg.vocab % 16 != 0
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": 16}),
                        layout="serve")
    assert specs["lm_head"] == P()         # replicated (compressed spec)


def test_serve_tied_vocab_shards_embed():
    """command_r ties embeddings with vocab % tp == 0: the embed row-shards
    over the vocab (gather adds exact zeros; the tied unembed becomes
    column-parallel)."""
    cfg, sds = _abstract_params("command_r_plus_104b")
    assert cfg.tie_embeddings and cfg.vocab % 16 == 0
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": 16}),
                        layout="serve")
    assert specs["embed"] == P("model", None)


def test_serve_vs_train_output_dim_contrast():
    """w_down: train contraction-shards (f, d) -> ("model", None); serve
    output-shards -> (None, "model").  The disagreement IS the layout."""
    cfg, sds = _abstract_params("deepseek_7b")
    mesh = FakeMesh({"data": 1, "model": 16})
    train = param_specs(sds, cfg, mesh, layout="train")
    serve = param_specs(sds, cfg, mesh, layout="serve")
    g_t = train["groups"]["b0_attn"]["ffn"]["w_down"]
    g_s = serve["groups"]["b0_attn"]["ffn"]["w_down"]
    assert g_t == P(None, "model", None)
    assert g_s == P(None, None, "model")


def test_serve_moe_expert_parallel():
    cfg, sds = _abstract_params("qwen3_moe_235b")    # 128 experts % 16
    specs = param_specs(sds, cfg, FakeMesh({"data": 1, "model": 16}),
                        layout="serve")
    g = specs["groups"]["b0_attn"]["ffn"]
    assert g["w_up"] == P(None, "model", None, None)
    assert g["w_down"] == P(None, "model", None, None)


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.distributed.sharding import param_specs, state_specs
    from repro.distributed.elastic import (make_mesh_from_plan, plan_remesh,
                                           reshard_state)
    from repro.optim import AdamWConfig
    from repro.train.steps import (TrainState, dp_residuals_init,
                                   init_train_state, make_dp_train_step,
                                   make_train_step)

    out = {}
    cfg = get_config("deepseek_7b").reduced()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8)

    # --- pjit TP+DP step executes and matches single-device math ----------
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=0)
    step = make_train_step(cfg, opt_cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    sds = jax.eval_shape(lambda: state)
    specs = state_specs(sds, cfg, mesh, fsdp=True)
    ns = lambda s: NamedSharding(mesh, s)
    shardings = jax.tree.map(ns, specs, is_leaf=lambda s: isinstance(s, P))
    state_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), state,
                            shardings)
    tb = data.batch_at(0)
    batch = {"tokens": jnp.asarray(tb.tokens), "labels": jnp.asarray(tb.labels)}
    jstep = jax.jit(step, in_shardings=(shardings, None),
                    out_shardings=(shardings, None))
    st2, m2 = jstep(state_sh, batch)
    st1, m1 = jax.jit(step)(state, batch)
    out["pjit_loss_delta"] = abs(float(m1["loss"]) - float(m2["loss"]))

    # --- compressed-DP shard_map step approximates exact DP ---------------
    mesh_dp = jax.make_mesh((8,), ("data",))
    st = init_train_state(cfg, jax.random.PRNGKey(0))
    res = dp_residuals_init(st.params, mesh_dp)
    st_c = TrainState(st.params, st.opt, res)
    step_c = make_dp_train_step(cfg, opt_cfg, mesh_dp, compress=True)
    step_u = make_dp_train_step(cfg, opt_cfg, mesh_dp, compress=False)
    st2 = init_train_state(cfg, jax.random.PRNGKey(0))  # independent buffers
    st_u = TrainState(st2.params, st2.opt, None)
    lc, lu = [], []
    for i in range(6):
        tb = data.batch_at(i)
        b = {"tokens": jnp.asarray(tb.tokens),
             "labels": jnp.asarray(tb.labels)}
        st_c, mc = step_c(st_c, b)
        st_u, mu = step_u(st_u, b)
        lc.append(float(mc["loss"])); lu.append(float(mu["loss"]))
    out["dp_loss_compressed"] = lc
    out["dp_loss_uncompressed"] = lu

    # --- elastic re-mesh: 8 -> 4 devices preserves state ------------------
    plan = plan_remesh(mesh, 4, global_batch=8)
    new_mesh = make_mesh_from_plan(plan)
    st_new = reshard_state(st_u.params, cfg, new_mesh)
    d = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a, np.float32) - np.asarray(b, np.float32)))),
        st_u.params, st_new)
    out["remesh_max_delta"] = max(jax.tree.leaves(d))
    out["remesh_shape"] = list(plan.new_shape)
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_integration():
    """TP+DP pjit step, compressed-DP shard_map step, elastic re-mesh — on
    8 faked devices in a subprocess."""
    proc = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # distributed step == single-device step
    assert out["pjit_loss_delta"] < 1e-4
    # compressed DP tracks exact DP within quantisation noise
    lc, lu = out["dp_loss_compressed"], out["dp_loss_uncompressed"]
    assert abs(lc[0] - lu[0]) < 1e-5          # first step: same loss
    assert all(abs(a - b) < 0.05 for a, b in zip(lc, lu))
    # elastic re-mesh is value-preserving
    assert out["remesh_max_delta"] == 0.0
    assert out["remesh_shape"] == [2, 2]
