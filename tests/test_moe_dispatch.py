"""Grouped (per-row) vs global-cumsum MoE dispatch equivalence — the §Perf
HC1 optimization must not change the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import MoEConfig
from repro.models import moe as M


@pytest.mark.parametrize("dense_residual", [False, True])
@pytest.mark.parametrize("variant", ["gated", "plain"])
def test_grouped_matches_global_no_drops(variant, dense_residual):
    """At no-drop capacity both dispatches route identically."""
    cfg = MoEConfig(n_experts=8, top_k=2, capacity_factor=8.0,
                    dense_residual=dense_residual)
    p = M.moe_init(jax.random.PRNGKey(0), 32, 64, cfg, variant, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 24, 32))
    g, aux_g = M.moe_apply_global(x, p, cfg, variant)
    r, aux_r = M.moe_apply_grouped(x, p, cfg, variant)
    np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                               rtol=2e-5, atol=2e-5)
    assert float(aux_g) == pytest.approx(float(aux_r), rel=1e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 5), S=st.sampled_from([8, 17, 32]),
       E=st.sampled_from([4, 8]), K=st.integers(1, 3))
def test_grouped_dispatch_properties(B, S, E, K):
    """Any capacity: finite outputs, dropped tokens fall back to residual
    (output zero for the MoE branch -> bounded norm)."""
    cfg = MoEConfig(n_experts=E, top_k=min(K, E), capacity_factor=1.0)
    p = M.moe_init(jax.random.PRNGKey(2), 16, 32, cfg, "gated", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, 16))
    out, aux = M.moe_apply_grouped(x, p, cfg, "gated")
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3


def test_dispatch_flag_switch():
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)
    p = M.moe_init(jax.random.PRNGKey(4), 16, 32, cfg, "gated", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 16))
    try:
        M.MOE_DISPATCH = "grouped"
        r1, _ = M.moe_apply(x, p, cfg, "gated")
    finally:
        M.MOE_DISPATCH = "global"
    r2, _ = M.moe_apply_grouped(x, p, cfg, "gated")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
