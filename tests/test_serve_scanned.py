"""Single-dispatch serving: scanned-vs-eager parity, in-graph sampling,
compile-cache (zero retrace), and the fleet-vmapped engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.data import SyntheticLM
from repro.serve import steps
from repro.serve.engine import FleetServeEngine, ServeEngine, _generate_fn
from repro.train.steps import init_train_state

ARCHS = {
    "deepseek_7b": "plain",          # decoder-only
    "paligemma_3b": "prefix",        # VLM prefix-embedding family
    "whisper_large_v3": "encdec",    # encoder-decoder
}


@pytest.fixture(scope="module")
def setups():
    out = {}
    for arch, kind in ARCHS.items():
        cfg = get_config(arch).reduced()
        params = init_train_state(cfg, jax.random.PRNGKey(0)).params
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=2)
        prompts = data.batch_at(0).tokens
        extras = {}
        rng = np.random.RandomState(0)
        if kind == "prefix":
            extras["prefix_embeds"] = rng.randn(
                2, cfg.prefix_tokens, cfg.d_model).astype(np.float32)
        elif kind == "encdec":
            extras["frames"] = rng.randn(
                2, cfg.encoder_seq, cfg.d_model).astype(np.float32)
        out[arch] = (cfg, params, prompts, extras)
    return out


def _aged_runtime():
    rt = FleetRuntime(n_devices=1)
    rt.set_age(years=9.0)
    return rt


# --------------------------------------------------------------------------- #
# scanned vs eager parity — all three families, clean and faulted
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", list(ARCHS))
def test_scanned_matches_eager_clean(setups, arch):
    cfg, params, prompts, extras = setups[arch]
    a = ServeEngine(cfg, params, max_len=64, seed=3) \
        .generate(prompts, 5, **extras)
    b = ServeEngine(cfg, params, max_len=64, seed=3) \
        .generate(prompts, 5, scan=False, **extras)
    assert a.tokens.shape == (2, 5)
    np.testing.assert_array_equal(a.tokens, b.tokens)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_scanned_matches_eager_faulted_fused(setups, arch):
    """Bit-exact tokens with real BER > 0 through the fused Pallas kernel:
    the scanned loop derives the same per-(call, operator, step) upset
    streams in-trace that the eager oracle derives step by step."""
    cfg, params, prompts, extras = setups[arch]
    rt = _aged_runtime()
    assert max(rt.op_bers().values()) > 0      # end-of-life: errors admitted
    mk = lambda: ServeEngine(cfg, params, runtime=rt, max_len=64, seed=3,
                             use_systolic_kernel=True, use_fused_kernel=True)
    a = mk().generate(prompts, 4, **extras)
    b = mk().generate(prompts, 4, scan=False, **extras)
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_scanned_matches_eager_three_pass_oracle(setups):
    """Parity also holds on the unfused (three-pass injection) route."""
    cfg, params, prompts, extras = setups["deepseek_7b"]
    rt = _aged_runtime()
    mk = lambda: ServeEngine(cfg, params, runtime=rt, max_len=64, seed=3,
                             use_systolic_kernel=False,
                             use_fused_kernel=False)
    a = mk().generate(prompts, 4, **extras)
    b = mk().generate(prompts, 4, scan=False, **extras)
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_encdec_cache_matches_teacher_forced_rollout(setups):
    """The enc-dec prefill stashes the prompt's decoder self-attention K/V
    in the cache (regression: it used to return an all-zero cache, so
    decode steps attended over zeroed prompt slots).  Greedy incremental
    decode must equal a from-scratch teacher-forced rollout."""
    from repro.models import encdec
    cfg, params, prompts, extras = setups["whisper_large_v3"]
    frames = jnp.asarray(extras["frames"])
    gen = ServeEngine(cfg, params, max_len=48, seed=3) \
        .generate(prompts, 5, **extras).tokens

    toks = jnp.asarray(prompts, jnp.int32)
    enc = encdec.encode(params, cfg, frames)
    ref = []
    for _ in range(5):
        logits, _ = encdec.decode(params, cfg, toks, enc_out=enc)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(gen, np.stack(ref, axis=1))


# --------------------------------------------------------------------------- #
# in-graph sampling
# --------------------------------------------------------------------------- #
def test_temperature_zero_is_greedy(setups):
    cfg, params, prompts, _ = setups["deepseek_7b"]
    g = ServeEngine(cfg, params, max_len=64, seed=7) \
        .generate(prompts, 6, greedy=True)
    t0 = ServeEngine(cfg, params, max_len=64, seed=7) \
        .generate(prompts, 6, temperature=0.0)
    k1 = ServeEngine(cfg, params, max_len=64, seed=7) \
        .generate(prompts, 6, temperature=0.9, top_k=1)
    np.testing.assert_array_equal(g.tokens, t0.tokens)   # T=0 is exact argmax
    np.testing.assert_array_equal(g.tokens, k1.tokens)   # top_k=1 too


def test_sampling_deterministic_and_scan_parity(setups):
    cfg, params, prompts, _ = setups["deepseek_7b"]
    mk = lambda: ServeEngine(cfg, params, max_len=64, seed=7)
    s1 = mk().generate(prompts, 6, temperature=0.8, top_k=8)
    s2 = mk().generate(prompts, 6, temperature=0.8, top_k=8)
    s3 = mk().generate(prompts, 6, temperature=0.8, top_k=8, scan=False)
    np.testing.assert_array_equal(s1.tokens, s2.tokens)  # seed-deterministic
    np.testing.assert_array_equal(s1.tokens, s3.tokens)  # same RNG chain
    assert (s1.tokens >= 0).all() and (s1.tokens < cfg.vocab).all()


def test_sample_token_top_k_support():
    """top_k masking really restricts the support."""
    logits = jnp.asarray(np.random.RandomState(1).randn(64, 32), jnp.float32)
    top2 = set(np.asarray(
        jax.lax.top_k(logits, 2)[1]).reshape(-1, 2).flatten().tolist())
    for s in range(3):
        tok = steps.sample_token(logits, jax.random.PRNGKey(s),
                                 jnp.float32(5.0), top_k=2)
        picked = np.asarray(tok)
        kidx = np.asarray(jax.lax.top_k(logits, 2)[1])
        for row, t in enumerate(picked):
            assert t in kidx[row]


# --------------------------------------------------------------------------- #
# compile-cache: repeated generate performs zero new traces
# --------------------------------------------------------------------------- #
def test_repeated_generate_zero_retrace(setups):
    """Advancing device age between calls re-jits NOTHING — the docstring
    claim, now enforced: BERs/keys enter as traced pytree leaves of a
    cached compiled function (scanned AND eager oracle paths)."""
    cfg, params, prompts, _ = setups["deepseek_7b"]
    rt = FleetRuntime(n_devices=1)
    rt.set_age(years=2.0)
    eng = ServeEngine(cfg, params, runtime=rt, max_len=64, seed=1,
                      use_systolic_kernel=True)
    eng.generate(prompts, 4)                      # compile scanned flavour
    eng.generate(prompts, 4, scan=False)          # compile eager flavour
    before = dict(steps.TRACE_COUNTS)
    rt.set_age(years=9.5)                         # new BER values, same avals
    eng.generate(prompts, 4)
    eng.generate(prompts, 4, scan=False)
    eng.generate(prompts, 4, temperature=0.8)     # sampling knob is traced
    assert dict(steps.TRACE_COUNTS) == before


def test_engines_share_compile_cache(setups):
    """A second engine instance with the same config reuses the module-level
    compiled functions — no per-engine jit wrappers."""
    cfg, params, prompts, _ = setups["deepseek_7b"]
    ServeEngine(cfg, params, max_len=64, seed=1).generate(prompts, 4)
    before = dict(steps.TRACE_COUNTS)
    ServeEngine(cfg, params, max_len=64, seed=99).generate(prompts, 4)
    assert dict(steps.TRACE_COUNTS) == before


# --------------------------------------------------------------------------- #
# fleet-batched serving
# --------------------------------------------------------------------------- #
def test_fleet_engine_matches_per_lane_dispatch(setups):
    """The vmapped fleet generation is exactly N independent per-lane calls
    of the same generation function: slicing the batched FaultConfig /
    keys per lane and dispatching the single-device function reproduces
    every lane's tokens bit-for-bit."""
    cfg, params, prompts, _ = setups["deepseek_7b"]
    N = 3
    fleet = FleetRuntime(n_devices=N)
    for i in range(N):
        fleet.set_age(years=3.0 * (i + 1), device=i)
    fe = FleetServeEngine(cfg, params, fleet, max_len=64, seed=5,
                          use_systolic_kernel=True)
    lane_prompts = np.stack([prompts, prompts + 1, prompts + 2]) % cfg.vocab
    res = fe.generate(lane_prompts, 4)
    assert res.tokens.shape == (N, 2, 4)
    assert res.bers.shape == (N, len(fleet.operators))

    # replay the engine's key schedule and dispatch lanes one by one
    key = jax.random.PRNGKey(5)
    _, call_key = jax.random.split(key)
    fi = fe._fleet_fault_config(call_key)
    keys = jax.random.split(jax.random.fold_in(call_key, 1), N)
    gen = _generate_fn(cfg, 64, 4, None)
    for i in range(N):
        fi_i = jax.tree.map(lambda x: x[i], fi)
        toks, _ = gen(params, jnp.asarray(lane_prompts[i], jnp.int32),
                      fi_i, keys[i], jnp.float32(0.0))
        np.testing.assert_array_equal(res.tokens[i], np.asarray(toks))


def test_fleet_engine_shards_flat_batch(setups):
    cfg, params, prompts, _ = setups["deepseek_7b"]
    fleet = FleetRuntime(n_devices=2)
    fleet.set_age(years=1.0)
    fe = FleetServeEngine(cfg, params, fleet, max_len=64, seed=5)
    flat = np.concatenate([prompts, prompts])      # (4, S) -> 2 lanes x 2
    res = fe.generate(flat, 3)
    assert res.tokens.shape == (2, 2, 3)
    assert res.ages_years.shape == (2,) and res.power_w.shape == (2,)
    # flat (N, S) means one prompt PER LANE (B=1), not a rank-1 lane batch
    res1 = fe.generate(prompts, 3)                 # (2, S) -> 2 lanes x 1
    assert res1.tokens.shape == (2, 1, 3)


def test_fleet_zero_retrace_on_aging(setups):
    cfg, params, prompts, _ = setups["deepseek_7b"]
    fleet = FleetRuntime(n_devices=2)
    fleet.set_age(years=2.0)
    fe = FleetServeEngine(cfg, params, fleet, max_len=64, seed=5,
                          use_systolic_kernel=True)
    lane_prompts = np.stack([prompts, prompts])
    fe.generate(lane_prompts, 3)
    before = dict(steps.TRACE_COUNTS)
    fleet.advance(3600 * 24 * 365, device=1)       # age one lane a year
    res = fe.generate(lane_prompts, 3)
    assert dict(steps.TRACE_COUNTS) == before
    assert res.ages_years[1] > res.ages_years[0]


def test_op_ber_array_matches_device_views():
    fleet = FleetRuntime(n_devices=3)
    for i in range(3):
        fleet.set_age(years=3.0 * (i + 1), device=i)
    mat = fleet.op_ber_array()
    assert mat.shape == (3, len(fleet.operators))
    for i in range(3):
        bers = fleet.op_bers(device=i)
        for j, op in enumerate(fleet.operators):
            assert mat[i, j] == bers[op]
    # heterogeneous ages -> older devices admit >= BER on tolerant domains
    q = fleet.operators.index("q")
    assert mat[2, q] >= mat[0, q]
