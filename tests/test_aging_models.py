"""Unit + property tests for the BTI/HCI compact models (paper Sec. III)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aging
from repro.core.artifacts import load_calibration
from repro.core.constants import T_AMB, V_MAX, V_NOM


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


def _advance(params, V, t, rates, n_seg=1):
    dv = jnp.zeros((aging.N_POP,), jnp.float32)
    for _ in range(n_seg):
        dv = aging.update_state(params, dv, jnp.asarray(V), rates,
                                jnp.asarray(t / n_seg))
    return dv


def test_monotone_in_time(cal):
    rates = aging.stress_rates(cal.aging)
    t_prev = None
    for t in (1e3, 1e5, 1e7, 3e8):
        dv = _advance(cal.aging, V_NOM, t, rates)
        tot = float(dv.sum())
        if t_prev is not None:
            assert tot > t_prev
        t_prev = tot


def test_monotone_in_voltage(cal):
    rates = aging.stress_rates(cal.aging)
    prev = None
    for v in (0.85, 0.90, 0.95, 1.02):
        dv = float(_advance(cal.aging, v, 1e8, rates).sum())
        if prev is not None:
            assert dv > prev
        prev = dv


def test_recovery_reduces_aging(cal):
    r_on = aging.stress_rates(cal.aging, recovery=True)
    r_off = aging.stress_rates(cal.aging, recovery=False)
    dv_on = _advance(cal.aging, V_NOM, 1e8, r_on)
    dv_off = _advance(cal.aging, V_NOM, 1e8, r_off)
    assert float(dv_on.sum()) < float(dv_off.sum())
    assert np.all(np.asarray(r_on) <= np.asarray(r_off) + 1e-12)


@settings(max_examples=25, deadline=None)
@given(t1=st.floats(1e3, 1e7), t2=st.floats(1e3, 1e7),
       v=st.floats(0.85, 1.05))
def test_history_time_additivity(t1, t2, v):
    """At constant V, splitting a stress interval must not change the result
    (the effective-time update is exactly time-additive)."""
    cal = load_calibration()
    rates = aging.stress_rates(cal.aging)
    one = _advance(cal.aging, v, t1 + t2, rates, n_seg=1)
    dv = jnp.zeros((aging.N_POP,), jnp.float32)
    dv = aging.update_state(cal.aging, dv, jnp.asarray(v), rates,
                            jnp.asarray(t1))
    two = aging.update_state(cal.aging, dv, jnp.asarray(v), rates,
                             jnp.asarray(t2))
    np.testing.assert_allclose(np.asarray(one), np.asarray(two),
                               rtol=2e-3, atol=1e-4)


def test_history_voltage_order_matters_less_than_max(cal):
    """V_nom->V_max stress must age less than V_max-const but more than
    V_nom-const (the paper's Table I row-4-between-rows-2-and-3 logic)."""
    rates = aging.stress_rates(cal.aging)
    t = 1.5e8

    dv = jnp.zeros((aging.N_POP,), jnp.float32)
    dv = aging.update_state(cal.aging, dv, jnp.asarray(V_NOM), rates,
                            jnp.asarray(t))
    mixed = aging.update_state(cal.aging, dv, jnp.asarray(V_MAX), rates,
                               jnp.asarray(t))
    lo = _advance(cal.aging, V_NOM, 2 * t, rates)
    hi = _advance(cal.aging, V_MAX, 2 * t, rates)
    assert float(lo.sum()) < float(mixed.sum()) < float(hi.sum())


def test_self_heating_increases_with_v(cal):
    t1 = aging.self_heating_temp(jnp.asarray(0.9), T_AMB, 8.0)
    t2 = aging.self_heating_temp(jnp.asarray(1.02), T_AMB, 8.0)
    assert float(t2) > float(t1) > T_AMB


def test_hci_gamma_bounds(cal):
    for i in range(aging.N_POP):
        if not aging.IS_BTI[i]:
            g = aging.hci_gamma(float(cal.aging.B[i]), V_NOM,
                                float(cal.aging.n[i]))
            assert 0.0 < g <= 1.0


def test_hci_gamma_closed_matches_numeric(cal):
    """The traced simulator uses the closed form; it must agree with the
    numeric linear-ramp integral it replaced, per population."""
    for i in range(aging.N_POP):
        if aging.IS_BTI[i]:
            continue
        B, n = float(cal.aging.B[i]), float(cal.aging.n[i])
        numeric = aging.hci_gamma(B, V_NOM, n, num=4096)
        closed = float(aging.hci_gamma_closed(B, V_NOM, n))
        assert closed == pytest.approx(numeric, rel=1e-4), i
    # small-x limit branch stays finite and -> 1
    assert float(aging.hci_gamma_closed(1e-9, V_NOM, 0.5)) == \
        pytest.approx(1.0, abs=1e-5)


def test_totals_split(cal):
    dv = jnp.arange(1.0, 7.0)
    dvp, dvn = aging.totals(dv)
    # populations 0-3 are PMOS, 4-5 NMOS
    assert float(dvp) == pytest.approx(1 + 2 + 3 + 4)
    assert float(dvn) == pytest.approx(5 + 6)


def test_waveform_extrapolation_matches_explicit_cycles():
    """Iterative equivalent-waveform extrapolation (Fig 4 f-h) vs explicit
    cycle-by-cycle simulation of the same micro-kinetics."""
    from repro.core import waveform
    mp = waveform.MicroTrapParams()
    V, duty, period = 0.9, 0.5, 1e-4
    n = 4096
    explicit = float(waveform.simulate_cycles(mp, V, duty, period, 0.0, n)[-1])
    extrap = float(waveform.extrapolate(mp, V, duty, period, n * period,
                                        n_base=16))
    dc = float(waveform.f_trapping(mp, 0.0, V, n * period))
    assert explicit > 0
    # the equivalent-waveform iteration is an approximation: agree within
    # 25% and stay strictly below the DC (no-recovery) bound
    assert abs(extrap - explicit) / explicit < 0.25, (extrap, explicit)
    assert explicit < dc and extrap < dc


def test_waveform_ac_factor_below_one_and_monotone_in_duty():
    from repro.core import waveform
    mp = waveform.MicroTrapParams()
    prev = 0.0
    for duty in (0.25, 0.5, 0.75):
        r = float(waveform.ac_factor_empirical(mp, 0.9, duty, 1e-4, 2048))
        assert 0.0 < r < 1.0
        assert r > prev
        prev = r
