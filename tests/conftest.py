"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py fakes 512 devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests")
    # Lock the backend to the real single CPU device BEFORE any test module
    # imports repro.launch.dryrun (which sets XLA_FLAGS for ITS OWN process;
    # jax ignores the env var once initialised).
    assert len(jax.devices()) >= 1
