"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py fakes 512 devices."""
import sys

# Install-or-skip guard for the `hypothesis` test dependency (declared in
# pyproject.toml's [test] extra): when it is absent, inject the deterministic
# in-repo fallback so the six property-test modules still collect and run a
# fixed-seed sample instead of erroring at import time.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_fallback
    sys.modules.setdefault("hypothesis", _hypothesis_fallback)

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests")
    # Lock the backend to the real single CPU device BEFORE any test module
    # imports repro.launch.dryrun (which sets XLA_FLAGS for ITS OWN process;
    # jax ignores the env var once initialised).
    assert len(jax.devices()) >= 1
