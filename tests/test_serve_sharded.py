"""Mesh-sharded serving: exact-TP parity, per-shard fault streams, zero
retrace.  Multi-device coverage runs on 8 faked host devices in
subprocesses (kept out of this process so other tests see the real single
CPU device); the per-shard injection semantics are locked down in-process
on one device (the (S,)-vector paths are plain jnp and device-agnostic).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.kernels import ops as kops
from repro.models.layers import FaultConfig, op_batched_matmul, op_linear
from repro.serve.engine import FleetServeEngine


# --------------------------------------------------------------------------- #
# shard_slices / inject_bitflips_sharded unit semantics (single device)
# --------------------------------------------------------------------------- #
def test_shard_slices_boundaries():
    assert kops.shard_slices(256, 8) == [32 * s for s in range(1, 8)]
    assert kops.shard_slices(12, 8) == [1, 3, 4, 6, 7, 9, 10]
    # n < S: duplicate boundaries -> some zero-width blocks, still S blocks
    cuts = kops.shard_slices(4, 8)
    blocks = np.split(np.arange(4), cuts)
    assert len(blocks) == 8
    assert sum(b.size for b in blocks) == 4


def test_inject_sharded_single_shard_counter_stream():
    """S == 1 is just the one-shard case of the counter-stream contract:
    the whole tensor flips under ``fold_seed(seed, 0)`` — the same draws a
    tp=1 shard_map of the fused kernel would generate."""
    acc = jax.random.randint(jax.random.PRNGKey(0), (16, 32), -2000, 2000,
                             jnp.int32)
    key = jax.random.PRNGKey(7)
    a = kops.inject_bitflips_sharded(acc, jnp.float32([0.01]), key)
    seed = kops.seed_from_key(key)
    b = kops.upset_counter_block(acc, jnp.float32(0.01),
                                 kops.fold_seed(seed, 0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(acc)).any()


def test_inject_sharded_per_shard_seed_streams():
    """The per-shard streams are pinned: block s flips exactly as the fused
    kernel's counter PRNG does under ``fold_seed(seed_from_key(key), s)``
    over the block's own resolved tile grid — the contract that makes the
    shard_map-fused route and this kernel-free route bit-exact."""
    from repro.kernels.fused_aged_matmul import (tile_counter_bits,
                                                 upset_words)
    S = 4
    acc = jax.random.randint(jax.random.PRNGKey(1), (8, 64), -2000, 2000,
                             jnp.int32)
    bers = jnp.float32([0.0, 0.02, 0.05, 0.1])
    key = jax.random.PRNGKey(3)
    got = np.asarray(kops.inject_bitflips_sharded(acc, bers, key))
    base = kops.seed_from_key(key)
    expect = []
    for s, blk in enumerate(jnp.split(acc, kops.shard_slices(64, S),
                                      axis=-1)):
        M, N = blk.shape
        bits = tile_counter_bits(M, N, kops.fold_seed(base, s),
                                 bm=kops._ceil_mult(M, 256),
                                 bn=kops._ceil_mult(N, 256))
        q = 1.0 - (1.0 - bers[s]) ** 32
        expect.append(np.asarray(upset_words(blk, bits, q)))
    np.testing.assert_array_equal(got, np.concatenate(expect, axis=-1))
    # shard 0 at BER 0 is untouched; faulted shards actually flipped
    np.testing.assert_array_equal(got[:, :16], np.asarray(acc)[:, :16])
    assert (got[:, 16:] != np.asarray(acc)[:, 16:]).any()


def test_inject_sharded_block_isolation():
    """Changing one shard's BER changes ONLY that shard's column block."""
    acc = jax.random.randint(jax.random.PRNGKey(2), (8, 64), -2000, 2000,
                             jnp.int32)
    key = jax.random.PRNGKey(9)
    a = np.asarray(kops.inject_bitflips_sharded(
        acc, jnp.float32([0.05, 0.05, 0.05, 0.05]), key))
    b = np.asarray(kops.inject_bitflips_sharded(
        acc, jnp.float32([0.05, 0.5, 0.05, 0.05]), key))
    np.testing.assert_array_equal(a[:, :16], b[:, :16])
    np.testing.assert_array_equal(a[:, 32:], b[:, 32:])
    assert (a[:, 16:32] != b[:, 16:32]).any()


def test_inject_sharded_empty_blocks():
    """More shards than columns: zero-width blocks are legal no-ops."""
    acc = jax.random.randint(jax.random.PRNGKey(3), (4, 4), -2000, 2000,
                             jnp.int32)
    out = kops.inject_bitflips_sharded(
        acc, jnp.full((8,), 0.3, jnp.float32), jax.random.PRNGKey(0))
    assert out.shape == acc.shape


def test_aged_linear_vector_zero_ber_matches_scalar_clean():
    """(S,) all-zero BER vector == scalar-zero legacy route: both quantise
    identically and flip nothing, so the sharded dispatch's dequant output
    is bit-identical to the oracle path."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64), jnp.bfloat16)
    key = jax.random.PRNGKey(2)
    a = kops.aged_linear(x, w, ber=jnp.zeros((4,), jnp.float32), key=key)
    b = kops.aged_linear(x, w, ber=jnp.float32(0.0), key=key,
                         use_kernel=False, fused=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _vec_fi(bers, seed=0):
    ops = ("q", "k", "v", "qkt", "sv", "o", "gate", "up", "down")
    return FaultConfig(bers={op: jnp.asarray(bers, jnp.float32)
                             for op in ops},
                       key=jax.random.PRNGKey(seed), step=jnp.int32(0),
                       use_systolic_kernel=False, fused=False)


def test_vector_ber_routes_kernel_free():
    """A (S,) BER vector must never lower to a pallas_call — a Pallas
    program is single-device and would not partition under GSPMD."""
    x = jnp.ones((2, 32), jnp.bfloat16)
    w = jnp.ones((32, 64), jnp.bfloat16)
    fi = dataclasses.replace(_vec_fi([0.0, 0.01]), use_systolic_kernel=True,
                             fused=True)
    jaxpr = jax.make_jaxpr(lambda: op_linear(x, w, "q", fi))()
    prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert "pallas_call" not in prims


def test_op_batched_matmul_vector_ber_head_blocks():
    """qkt/sv vector BER maps shards onto the flattened head axis: head
    blocks of a zero-BER shard match the scalar-zero path exactly."""
    B, H, M, N = 2, 4, 8, 8
    a = jax.random.normal(jax.random.PRNGKey(0), (B, H, M, N), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, H, N, M), jnp.bfloat16)
    fi_vec = _vec_fi([0.0, 0.4], seed=5)
    fi_zero = _vec_fi(0.0, seed=5)          # scalar: legacy oracle stream
    out_v = np.asarray(op_batched_matmul(a, b, "qkt", fi_vec))
    out_0 = np.asarray(op_batched_matmul(a, b, "qkt", fi_zero))
    np.testing.assert_array_equal(out_v[:, :2], out_0[:, :2])  # shard 0
    assert (out_v[:, 2:] != out_0[:, 2:]).any()                # shard 1


# --------------------------------------------------------------------------- #
# shard-granular FleetRuntime
# --------------------------------------------------------------------------- #
def test_fleet_shard_granularity():
    fl = FleetRuntime(n_devices=2, n_shards=4)
    fl.set_age(years=3.0)
    fl.set_age(years=9.0, device=1, shard=2)
    assert fl.ages_years.shape == (2, 4)
    assert fl.ages_years[1, 2] == pytest.approx(9.0)
    so = fl.op_ber_shard_array()
    assert so.shape == (2, 4, len(fl.operators))
    np.testing.assert_allclose(fl.op_ber_array(), so.max(axis=1))
    # worst-shard collapse also governs the scalar accessors
    assert fl.op_ber("q", device=1) == pytest.approx(so[1, :, 0].max())
    assert fl.op_ber("q", device=1, shard=0) == pytest.approx(so[1, 0, 0])


def test_fleet_shard_jax_cache_invalidation():
    fl = FleetRuntime(n_devices=1, n_shards=4)
    fl.set_age(years=5.0)
    j1 = fl.op_ber_shard_jax()
    assert j1 is fl.op_ber_shard_jax()          # cached between age changes
    assert fl.op_ber_jax().shape == (1, len(fl.operators))
    fl.advance(3.15e7, shard=1)
    j2 = fl.op_ber_shard_jax()
    assert j2 is not j1
    assert float(jnp.abs(j2 - j1).max()) > 0.0


def test_fleet_unsharded_unchanged():
    fl = FleetRuntime(n_devices=3)
    fl.set_age(years=5.0, device=2)
    assert fl.ages_years.shape == (3,)
    assert fl.op_ber_array().shape == (3, len(fl.operators))
    assert fl.fleet_power().shape == (3,)


def test_fleet_engine_rejects_shard_granular_fleet():
    cfg = get_config("deepseek_7b").reduced()
    fl = FleetRuntime(n_devices=1, n_shards=2)
    with pytest.raises(AssertionError, match="MeshServeEngine"):
        FleetServeEngine(cfg, {}, fl)


# --------------------------------------------------------------------------- #
# multi-device integration (8 faked devices, subprocess)
# --------------------------------------------------------------------------- #
def _run_script(script: str, timeout: int) -> dict:
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.core.fleet import FleetRuntime
    from repro.serve import steps
    from repro.serve.engine import ServeEngine
    from repro.serve.sharded import MeshServeEngine
    from repro.train.steps import init_train_state

    out = {}
    cfg = get_config("deepseek_7b").reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    prompts = (np.arange(2 * 8).reshape(2, 8) % cfg.vocab).astype(np.int32)

    # clean: sharded dispatch vs single device on the SAME cast params
    eng = MeshServeEngine(cfg, params, max_len=24, seed=3)
    out["tp"] = eng.tp
    a = eng.generate(prompts, 5)
    host = jax.device_get(eng.params)
    b = ServeEngine(cfg, host, max_len=24, seed=3).generate(prompts, 5)
    out["clean_exact"] = bool(np.array_equal(a.tokens, b.tokens))

    # uniform BER: sharded scalar-BER graph vs the single-device oracle
    rt = FleetRuntime(n_devices=1); rt.set_age(years=9.0)
    ef = MeshServeEngine(cfg, params, runtime=rt.device(0), max_len=24,
                         seed=3)
    af = ef.generate(prompts, 4)
    bf = ServeEngine(cfg, host, runtime=rt.device(0), max_len=24, seed=3,
                     use_systolic_kernel=False,
                     use_fused_kernel=False).generate(prompts, 4)
    out["uniform_exact"] = bool(np.array_equal(af.tokens, bf.tokens))
    out["uniform_ber_max"] = float(max(af.bers.max(), 0.0))

    # per-shard aging inside ONE dispatch + zero retrace across age
    # advances and shard-BER updates
    fl = FleetRuntime(n_devices=1, n_shards=8)
    for s in range(8):
        fl.set_age(years=1.0 + s, shard=s)
    es = MeshServeEngine(cfg, params, fleet=fl, max_len=24, seed=3)
    steps.TRACE_COUNTS.clear()
    r1 = es.generate(prompts, 4)
    n1 = dict(steps.TRACE_COUNTS)
    fl.advance(3.15e7, shard=3)                  # age one shard a year
    r2 = es.generate(prompts, 4)
    fl.set_age(years=0.1, shard=0)               # swap in a fresh shard
    r3 = es.generate(prompts, 4)
    out["zero_retrace"] = dict(steps.TRACE_COUNTS) == n1
    out["shard_bers"] = r1.bers[:, 0].tolist()
    out["aging_changed_tokens"] = bool(
        not np.array_equal(r1.tokens, r2.tokens))
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_generate_multidevice():
    """Sharded generation on 8 faked devices: bit-exact vs single device
    (clean AND uniform-BER), per-shard BERs heterogeneous inside the one
    dispatch, zero retrace across shard age changes."""
    out = _run_script(SHARDED_SCRIPT, timeout=1500)
    assert out["tp"] == 8
    assert out["clean_exact"] is True
    assert out["uniform_exact"] is True
    assert out["uniform_ber_max"] > 0          # end-of-life BERs were live
    assert out["zero_retrace"] is True
    assert len(set(out["shard_bers"])) > 1     # shards aged differently
    assert out["aging_changed_tokens"] is True


BIG_MODEL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import dataclasses, gc, json
    import jax, jax.numpy as jnp
    import numpy as np
    mark = lambda m: (print(m, file=sys.stderr), sys.stderr.flush())
    from repro.configs import get_config
    from repro.core.fleet import FleetRuntime
    from repro.models import transformer as tf
    from repro.serve import steps
    from repro.serve.engine import ServeEngine
    from repro.serve.sharded import MeshServeEngine

    out = {}
    # command_r_plus_104b at REAL width (d=12288, H=96, KV=8, f=33792,
    # V=256000, tied embeddings), reduced depth: the big-zoo shape whose
    # serve layout shards heads, KV, FFN and the tied vocab over tp=8.
    cfg = dataclasses.replace(get_config("command_r_plus_104b"), n_layers=2)
    params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    prompts = (np.arange(1 * 4).reshape(1, 4) * 997 % cfg.vocab
               ).astype(np.int32)

    rt = FleetRuntime(n_devices=1); rt.set_age(years=9.0)
    eng = MeshServeEngine(cfg, params, runtime=rt.device(0), max_len=8,
                          seed=3)
    out["tp"] = eng.tp
    mark("[big] params sharded; compiling uniform-BER sharded dispatch")
    a = eng.generate(prompts, 2)
    mark("[big] sharded generate done; compiling single-device oracle")
    host = jax.device_get(eng.params)
    b = ServeEngine(cfg, host, runtime=rt.device(0), max_len=8, seed=3,
                    use_systolic_kernel=False,
                    use_fused_kernel=False).generate(prompts, 2)
    out["uniform_exact"] = bool(np.array_equal(a.tokens, b.tokens))
    out["tokens"] = a.tokens.tolist()
    del host, b, eng; gc.collect()

    fl = FleetRuntime(n_devices=1, n_shards=8)
    for s in range(8):
        fl.set_age(years=1.0 + s, shard=s)
    es = MeshServeEngine(cfg, params, fleet=fl, max_len=8, seed=3)
    mark("[big] oracle parity done; compiling per-shard faulted dispatch")
    steps.TRACE_COUNTS.clear()
    r1 = es.generate(prompts, 2)
    n1 = dict(steps.TRACE_COUNTS)
    fl.advance(3.15e7, shard=5)
    r2 = es.generate(prompts, 2)
    out["zero_retrace"] = dict(steps.TRACE_COUNTS) == n1
    out["shard_bers"] = r1.bers[:, 0].tolist()
    print("RESULT " + json.dumps(out))
""")


def _total_ram_gb() -> float:
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal"):
                    return int(line.split()[1]) / 1024 ** 2
    except OSError:
        pass
    return 0.0


@pytest.mark.slow
@pytest.mark.skipif(_total_ram_gb() < 32.0,
                    reason="command_r at real width needs >= 32 GB RAM")
@pytest.mark.skipif(not os.environ.get("REPRO_BIG_MESH"),
                    reason="opt-in (REPRO_BIG_MESH=1): ~12.6 GB of bf16 "
                           "params and three real-width sharded compiles "
                           "(about an hour on one CPU core)")
def test_big_zoo_model_sharded_acceptance():
    """command_r_plus_104b (reduced depth, REAL width) generates through
    ONE sharded dispatch on 8 host devices: bit-exact with the
    single-device oracle at uniform BER, per-shard BERs demonstrably
    differing inside the dispatch, zero retrace across shard aging.

    Passing run recorded in EXPERIMENTS.md §Mesh-Serving."""
    out = _run_script(BIG_MODEL_SCRIPT, timeout=7200)
    assert out["tp"] == 8
    assert out["uniform_exact"] is True
    assert out["zero_retrace"] is True
    assert len(set(out["shard_bers"])) > 1
