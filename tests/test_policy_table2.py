"""Fault-tolerant voltage scaling (paper Sec. IV / Table II)."""
import numpy as np
import pytest

from repro.core.artifacts import load_calibration
from repro.core.policy import (BaselinePolicy, FaultTolerantPolicy,
                               evaluate_policy)
from repro.core.resilience import OPERATORS


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


@pytest.fixture(scope="module")
def results(cal):
    pol = FaultTolerantPolicy(ber_model=cal.ber)
    return evaluate_policy(pol, cal.aging, cal.delay_poly, cal.power,
                           cal.lifetime_cfg)


# Paper Table II:  op -> (V_final, dvp, dvn, power saving %)
TABLE2 = {
    "q":    (0.90, 73.1, 46.1, 17.0),
    "k":    (0.94, 79.0, 52.1, 14.3),
    "v":    (0.90, 73.1, 46.1, 17.0),
    "qkt":  (0.90, 73.1, 46.1, 17.0),
    "sv":   (0.90, 73.1, 46.1, 17.0),
    "o":    (1.01, 99.7, 77.8, 3.1),
    "gate": (0.90, 73.1, 46.1, 17.0),
    "up":   (0.90, 73.1, 46.1, 17.0),
    "down": (0.99, 90.8, 66.7, 7.8),
}


def test_final_voltages_match_table2(results):
    for op, (vf, *_rest) in TABLE2.items():
        assert results[op]["v_final"] == pytest.approx(vf, abs=0.015), op


def test_vth_shifts_match_table2(results):
    for op, (_vf, dvp, dvn, _s) in TABLE2.items():
        assert results[op]["dvp_final"] == pytest.approx(dvp, rel=0.05), op
        assert results[op]["dvn_final"] == pytest.approx(dvn, rel=0.13), op


def test_power_savings_match_table2(results):
    for op, (*_x, saving) in TABLE2.items():
        assert results[op]["power_saving_pct"] == \
            pytest.approx(saving, abs=2.5), op
    assert results["avg_power_saving_pct"] == pytest.approx(14.0, abs=2.0)


def test_max_aging_reduction_claims(results):
    """Up to 30.6% (PMOS) / 45.8% (NMOS) DVth reduction vs baseline."""
    base = results["baseline"]
    best_p = min(results[op]["dvp_final"] for op in TABLE2)
    best_n = min(results[op]["dvn_final"] for op in TABLE2)
    red_p = 1 - best_p / base["dvp_final"]
    red_n = 1 - best_n / base["dvn_final"]
    assert red_p == pytest.approx(0.306, abs=0.05)
    assert red_n == pytest.approx(0.458, abs=0.06)


def test_sensitive_ops_get_tighter_thresholds(cal):
    """Paper: O and Down are the most error-sensitive -> smallest delay_max;
    the tolerant group never reaches its threshold."""
    pol = FaultTolerantPolicy(ber_model=cal.ber)
    dmax = pol.delay_max()
    assert dmax["o"] == min(dmax.values())
    assert dmax["down"] < dmax["k"] < dmax["q"]
    for op in ("q", "v", "qkt", "sv", "gate", "up"):
        assert dmax[op] == max(dmax.values())


def test_baseline_policy_is_tclk_everywhere(cal):
    dmax = BaselinePolicy().delay_max()
    assert set(dmax) == set(OPERATORS)
    assert all(v == cal.lifetime_cfg.t_clk for v in dmax.values())


def test_accuracy_budget_scales_policy(cal):
    """A larger admissible accuracy loss must never tighten thresholds."""
    d_small = FaultTolerantPolicy(ber_model=cal.ber,
                                  max_loss_pct=0.1).delay_max()
    d_large = FaultTolerantPolicy(ber_model=cal.ber,
                                  max_loss_pct=2.0).delay_max()
    for op in d_small:
        assert d_large[op] >= d_small[op] - 1e-15


def test_deferring_never_increases_power(results):
    base_p = results["baseline"]["p_avg"]
    for op in TABLE2:
        assert results[op]["p_avg"] <= base_p + 1e-9
