"""Pins the aging framework against the paper's Table I (see DESIGN.md:
rows 1-3 are calibration targets; row 4 — the AVS run — is a PREDICTION)."""
import numpy as np
import pytest

from repro.core.artifacts import load_calibration
from repro.core.avs import final_shifts, run_lifetime
from repro.core.constants import T_CLK, V_MAX, V_NOM


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


def test_table1_calibration_rows(cal):
    """Rows 1-3 were fit targets; they must still reproduce to <1%."""
    chk = cal.raw["table1_check"]
    targets = {
        "nom_norec": dict(pmos_total=82.0, nmos=50.5, pmos_hci=19.8,
                          pmos_bti=62.2),
        "nom_rec": dict(pmos_total=73.1, nmos=46.1),
        "vmax_norec": dict(pmos_total=130.7, nmos=105.2, pmos_hci=27.3,
                           pmos_bti=103.4),
    }
    for row, vals in targets.items():
        for k, v in vals.items():
            assert chk[row][k] == pytest.approx(v, rel=0.01), (row, k)


def test_table1_avs_prediction(cal):
    """Row 4 (history-aware AVS) is *predicted*: PMOS 105.3, NMOS 85.1 mV.
    Accept 5% — the paper's own identification of the reduction is ~19%."""
    chk = cal.raw["table1_check"]["avs"]
    assert chk["pmos_total"] == pytest.approx(105.3, rel=0.05)
    assert chk["nmos"] == pytest.approx(85.1, rel=0.05)
    assert chk["v_final"] == pytest.approx(V_MAX, abs=0.005)


def test_avs_pessimism_reduction(cal):
    """The headline claim: history-aware AVS estimate reduces DVth vs
    constant-V_max by ~19.4% (PMOS) / ~19.1% (NMOS)."""
    chk = cal.raw["table1_check"]
    red_p = 1 - chk["avs"]["pmos_total"] / chk["vmax_norec"]["pmos_total"]
    red_n = 1 - chk["avs"]["nmos"] / chk["vmax_norec"]["nmos"]
    assert red_p == pytest.approx(0.194, abs=0.04)
    assert red_n == pytest.approx(0.191, abs=0.04)


def test_avs_trajectory_regenerates(cal):
    """Re-run the lifetime simulator live: staircase 0.90 -> 1.02 V."""
    traj = run_lifetime(cal.aging, cal.delay_poly, cal.lifetime_cfg,
                        delay_max=cal.lifetime_cfg.t_clk)
    fin = final_shifts(traj)
    assert fin["v_final"] == pytest.approx(V_MAX, abs=0.005)
    V = np.asarray(traj["V"])
    assert V[0] == pytest.approx(V_NOM, abs=1e-6)
    assert np.all(np.diff(V) >= -1e-9)            # monotone staircase
    steps = np.count_nonzero(np.diff(V) > 1e-6)
    assert steps == pytest.approx(12, abs=1)      # (1.02-0.90)/0.010


def test_delay_polynomial_fit_quality(cal):
    """Paper: ternary 6th-degree polynomial, RMSE 5.85e-5 ns << 1.5 ns."""
    rmse = cal.raw["delay_poly"].get("rmse", None)
    assert rmse is not None and rmse < 5e-3 * 1.542  # <0.5% of nominal
    # nominal critical path at fresh, V_nom
    d0 = float(cal.delay_poly(0.0, 0.0, V_NOM))
    assert d0 == pytest.approx(1.542e-9, rel=0.01)
    # delay increases with aging, decreases with voltage
    assert float(cal.delay_poly(0.08, 0.05, V_NOM)) > d0
    assert float(cal.delay_poly(0.0, 0.0, 1.0)) < d0


def test_lifetime_vmapped_matches_scalar(cal):
    import jax.numpy as jnp
    dmax = jnp.asarray([T_CLK, T_CLK * 1.02])
    trajs = run_lifetime(cal.aging, cal.delay_poly, cal.lifetime_cfg,
                         delay_max=dmax)
    scalar = run_lifetime(cal.aging, cal.delay_poly, cal.lifetime_cfg,
                          delay_max=T_CLK)
    np.testing.assert_allclose(np.asarray(trajs["V"])[0],
                               np.asarray(scalar["V"]), rtol=1e-6)
    # relaxed threshold -> final V no higher
    assert float(np.asarray(trajs["V"])[1, -1]) <= \
        float(np.asarray(trajs["V"])[0, -1]) + 1e-6
