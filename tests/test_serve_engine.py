"""Aging-aware serving engine + AVS runtime integration (Sec. IV as a
framework feature)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime import AgingAwareRuntime
from repro.data import SyntheticLM
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek_7b").reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    data = SyntheticLM(vocab=cfg.vocab, seq_len=48, global_batch=4)
    return cfg, params, data


def test_runtime_domains_age_monotonically():
    rt = AgingAwareRuntime(fault_tolerant=True)
    prev = {}
    for years in (0.5, 3.0, 9.9):
        rt.set_age(years=years)
        for op in ("q", "o", "down"):
            st = rt.domain_state(op)
            assert st.dvth_p_mv >= prev.get(op, 0.0)
            prev[op] = st.dvth_p_mv
            assert 0.9 - 1e-6 <= st.v_dd <= 1.02 + 1e-6


def test_runtime_fresh_device_error_free():
    rt = AgingAwareRuntime(fault_tolerant=True)
    rt.set_age(years=0.02)
    for op, ber in rt.op_bers().items():
        assert ber < 1e-12, (op, ber)


def test_runtime_policy_difference_late_life():
    """Late in life the fault-tolerant runtime admits errors on tolerant
    ops while the baseline runtime has boosted voltage instead."""
    ft = AgingAwareRuntime(fault_tolerant=True)
    bl = AgingAwareRuntime(fault_tolerant=False)
    ft.set_age(years=9.5)
    bl.set_age(years=9.5)
    assert ft.op_ber("q") > bl.op_ber("q")
    assert ft.domain_state("q").v_dd < bl.domain_state("q").v_dd
    assert ft.total_power() < bl.total_power()


def test_generate_shapes_and_determinism(setup):
    cfg, params, data = setup
    eng = ServeEngine(cfg, params, runtime=None, max_len=96, seed=7)
    prompts = data.batch_at(0).tokens[:, :24]
    r1 = eng.generate(prompts, 6)
    r2 = ServeEngine(cfg, params, runtime=None, max_len=96,
                     seed=7).generate(prompts, 6)
    assert r1.tokens.shape == (4, 6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy + clean
    assert (r1.tokens >= 0).all() and (r1.tokens < cfg.vocab).all()


@pytest.mark.slow
def test_trained_model_ber_knee():
    """Fig. 1(b) structure on a model we actually train: flat NLL in the
    quasi-error-free regime, collapse past the knee.  (On an *untrained*
    model bit noise pushes logits toward uniform and can even lower NLL —
    the knee only exists once there is structure to destroy.)"""
    import jax.numpy as jnp
    from repro.models import transformer as tf
    from repro.models.layers import FaultConfig
    from repro.optim import AdamWConfig
    from repro.train.steps import (init_train_state, make_train_step,
                                   softmax_xent)

    cfg = get_config("deepseek_7b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=16)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)))
    for i in range(60):
        tb = data.batch_at(i)
        state, m = step(state, {"tokens": jnp.asarray(tb.tokens),
                                "labels": jnp.asarray(tb.labels)})
    assert float(m["loss"]) < data.uniform_nll() - 0.3   # actually learned

    toks = data.batch_at(100).tokens

    def nll(ber, seed=0):
        fi = None if ber is None else FaultConfig(
            bers={op: jnp.float32(ber) for op in
                  ("q", "k", "v", "qkt", "sv", "o", "gate", "up", "down")},
            key=jax.random.PRNGKey(seed), use_systolic_kernel=False)
        logits, _, _ = tf.forward_logits(state.params, cfg,
                                         jnp.asarray(toks[:, :-1]), fi=fi)
        return float(softmax_xent(logits, jnp.asarray(toks[:, 1:])))

    clean = nll(0.0)
    policy_level = np.mean([nll(1e-5, s) for s in range(4)])
    broken = np.mean([nll(1e-2, s) for s in range(4)])
    # quasi-error-free regime: the shift at policy-level BER is an order of
    # magnitude below the collapse criterion.  (The exact value is injection
    # RNG / backend dependent on this tiny demo model, hence the seed
    # average and the 0.25 margin.)
    assert abs(policy_level - clean) < 0.25
    assert broken > clean + 0.5                  # past the knee: collapse

    # end-of-life engine integration stays finite
    rt = AgingAwareRuntime(fault_tolerant=True)
    rt.set_age(years=9.5)
    aged = ServeEngine(cfg, state.params, runtime=rt).score(toks)
    assert np.isfinite(aged)


def test_family_operator_sets():
    """§Arch-applicability: attention-free families get their projection
    domains — rwkv's r/g projections are injected, qkt/sv are absent."""
    rt = AgingAwareRuntime.for_model(get_config("rwkv6_3b"))
    rt.set_age(years=9.0)
    bers = rt.op_bers()
    assert "qkt" not in bers and "sv" not in bers
    assert bers["r"] > 0 and bers["g"] > 0          # tolerant: errors admitted
    assert bers["o"] < bers["r"]                    # output proj stays tight

    rt2 = AgingAwareRuntime.for_model(get_config("qwen3_moe_235b"))
    rt2.set_age(years=9.0)
    assert "router" in rt2.op_bers()                # MoE adds the router row

    rt3 = AgingAwareRuntime.for_model(get_config("recurrentgemma_2b"))
    assert set(("r", "g", "qkt")) <= set(rt3.operators)   # hybrid: both


def test_engine_uses_policy_bers(setup):
    cfg, params, data = setup
    rt = AgingAwareRuntime(fault_tolerant=True)
    rt.set_age(years=9.0)
    eng = ServeEngine(cfg, params, runtime=rt, max_len=64)
    res = eng.generate(data.batch_at(0).tokens[:2, :16], 4)
    assert set(res.bers) == set(rt.operators)
    # sensitive ops are throttled to lower admitted BER than tolerant ones
    assert res.bers["o"] <= res.bers["q"]
    assert res.age_years == pytest.approx(9.0)
    assert res.power_w > 0


# --------------------------------------------------------------------------- #
# bounded compile caches
# --------------------------------------------------------------------------- #
def test_compile_cache_registry_and_stats():
    """Every serve-path compiled-fn cache registers into cache_stats()."""
    import repro.serve.online  # noqa: F401  (registers the online caches)
    from repro.serve.engine import cache_stats
    stats = cache_stats()
    for name in ("step_fns", "generate", "fleet_generate",
                 "online_prefill", "online_chunk",
                 "online_fleet_prefill", "online_fleet_chunk"):
        assert name in stats, name
        s = stats[name]
        assert set(s) == {"currsize", "maxsize", "hits", "misses",
                          "evictions"}
        assert 0 <= s["currsize"] <= s["maxsize"]


def test_compile_cache_eviction_and_rehit(setup):
    """Shrinking maxsize bounds the cache: old entries evict LRU-first and
    a re-request after eviction rebuilds (miss) then re-hits."""
    from repro.serve.engine import _generate_fn

    cfg, params, _ = setup
    saved_max = _generate_fn.maxsize
    _generate_fn.clear()
    h0, m0, e0 = (_generate_fn.hits, _generate_fn.misses,
                  _generate_fn.evictions)
    try:
        _generate_fn.maxsize = 2
        keys = [(cfg, 48, n, None) for n in (2, 3, 4)]
        fns = [_generate_fn(*k) for k in keys]       # 3 builds into size 2
        assert _generate_fn.misses - m0 == 3
        assert _generate_fn.evictions - e0 == 1      # (cfg,48,2) evicted
        assert len(_generate_fn._entries) == 2

        assert _generate_fn(*keys[1]) is fns[1]      # survivor: hit
        assert _generate_fn.hits - h0 == 1

        rebuilt = _generate_fn(*keys[0])             # evicted: miss again
        assert _generate_fn.misses - m0 == 4
        assert rebuilt is not fns[0]
        assert _generate_fn(*keys[0]) is rebuilt     # and re-hits
        assert _generate_fn.hits - h0 == 2
        assert len(_generate_fn._entries) == 2       # still bounded
    finally:
        _generate_fn.maxsize = saved_max
        _generate_fn.clear()


def test_clear_caches_drops_entries(setup):
    from repro.serve.engine import _generate_fn, cache_stats, clear_caches

    cfg, _, _ = setup
    _generate_fn(cfg, 48, 2, None)
    assert cache_stats()["generate"]["currsize"] >= 1
    clear_caches()
    assert all(s["currsize"] == 0 for s in cache_stats().values())
