"""BER model (Sec. IV-A) and DNN resilience curves (Sec. IV-B)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.artifacts import load_calibration
from repro.core.ber import DELAY_MAX_CAP
from repro.core.constants import T_CLK
from repro.core.resilience import (OPERATORS, default_curves, fit_curve,
                                   tolerable_bers)


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


def test_ber_monotone_in_delay(cal):
    ds = np.linspace(1.45e-9, 2.0e-9, 64)
    bers = np.asarray([float(cal.ber.ber_from_delay(d)) for d in ds])
    assert np.all(np.diff(bers) >= 0)


def test_ber_negligible_with_slack(cal):
    """Positive slack -> BER vanishes double-exponentially."""
    assert float(cal.ber.ber_from_delay(1.45e-9)) < 1e-20


@settings(max_examples=30, deadline=None)
@given(logb=st.floats(-8.0, -5.0))
def test_ber_inversion_roundtrip(logb):
    cal = load_calibration()
    ber = 10.0 ** logb
    d = cal.ber.delay_max_for_ber(ber)
    if d >= DELAY_MAX_CAP:        # threshold unreachable (tolerant op)
        return
    back = float(cal.ber.ber_from_delay(d))
    assert np.log10(back) == pytest.approx(logb, abs=0.02)


def test_tolerable_ber_heterogeneity():
    """REALM-style heterogeneity [14]: sensitive ops (O, Down) orders of
    magnitude below tolerant ones; full span within the 1e-7..1e-3 range."""
    tols = tolerable_bers(max_loss_pct=0.5)
    assert set(tols) == set(OPERATORS)
    assert tols["o"] == min(tols.values())
    assert tols["o"] < 1e-6
    assert max(tols.values()) > 1e-4
    for v in tols.values():
        assert 1e-8 <= v <= 1e-2


def test_resilience_curves_monotone():
    for op, curve in default_curves().items():
        losses = [curve.accuracy_loss(b) for b in (1e-9, 1e-7, 1e-5, 1e-3)]
        assert all(np.diff(losses) >= -1e-12), op
        assert losses[0] < 0.05                      # quasi-error-free floor


def test_fit_curve_recovers_knee():
    curve0 = default_curves()["down"]
    bers = np.logspace(-9, -2, 40)
    losses = np.asarray([curve0.accuracy_loss(b) for b in bers])
    fit = fit_curve(bers, losses)
    for b in (1e-7, 1e-5, 1e-4):
        assert fit.accuracy_loss(b) == pytest.approx(
            curve0.accuracy_loss(b), abs=3.0)   # grid fit; steep knee
    # the policy-relevant quantity: tolerable BER within a factor of 2
    assert fit.tolerable_ber(0.5) == pytest.approx(
        curve0.tolerable_ber(0.5), rel=1.0)


def test_policy_chain_ber_to_delay_consistency(cal):
    """delay_max(tolerable_ber(op)) must admit no more than that BER."""
    tols = tolerable_bers(max_loss_pct=0.5)
    for op, tol in tols.items():
        d = cal.ber.delay_max_for_ber(tol)
        if d < DELAY_MAX_CAP:
            admitted = float(cal.ber.ber_from_delay(d))
            assert admitted <= tol * 1.1, op
