"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the brief; hypothesis drives the shape space for
the padding logic of the public wrappers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitflip import bitflip_words
from repro.kernels.systolic_matmul import systolic_matmul


# --------------------------------------------------------------------------- #
# systolic int8 matmul
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n", [(256, 256, 256), (256, 512, 256),
                                   (512, 256, 768)])
@pytest.mark.parametrize("bm,bn,bk", [(256, 256, 256), (128, 128, 128)])
def test_systolic_matmul_block_aligned(m, k, n, bm, bn, bk):
    ka, kb = jax.random.split(jax.random.PRNGKey(m + k + n))
    a = jax.random.randint(ka, (m, k), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (k, n), -128, 128, jnp.int8)
    out = systolic_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.systolic_matmul_ref(a, b)))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
def test_quantized_matmul_arbitrary_shapes(m, k, n):
    """Public wrapper pads arbitrary shapes to hardware blocks."""
    ka, kb = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n))
    a = jax.random.randint(ka, (m, k), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (k, n), -128, 128, jnp.int8)
    out = ops.quantized_matmul(a, b, interpret=True)
    assert out.shape == (m, n) and out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.systolic_matmul_ref(a, b)))


def test_systolic_matmul_accumulator_width():
    """Worst-case int8 dot must not overflow int32 (the paper's 32-bit
    accumulator): 127*127*K for K=2048 ~ 3.3e7 << 2^31."""
    K = 2048
    a = jnp.full((128, K), 127, jnp.int8)
    b = jnp.full((K, 128), 127, jnp.int8)
    out = ops.quantized_matmul(a, b, interpret=True)
    assert int(out[0, 0]) == 127 * 127 * K


# --------------------------------------------------------------------------- #
# bitflip injection
# --------------------------------------------------------------------------- #
def test_bitflip_kernel_matches_oracle():
    R = 512
    x = jax.random.randint(jax.random.PRNGKey(0), (R, 128), -2**30, 2**30,
                           jnp.int32)
    u, pos = ops.make_flip_randoms(jax.random.PRNGKey(1), (R, 128))
    q = jnp.asarray([0.3], jnp.float32)
    out = bitflip_words(x, u, pos, q, interpret=True)
    exp = ref.bitflip_words_ref(x, u, pos, q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("ber,shape", [(1e-3, (1000, 64)), (1e-2, (64, 257)),
                                       (0.0, (33,))])
def test_inject_bitflips_statistics(ber, shape):
    x = jax.random.randint(jax.random.PRNGKey(2), shape, -2**20, 2**20,
                           jnp.int32)
    y = ops.inject_bitflips(x, ber, jax.random.PRNGKey(3), interpret=True)
    assert y.shape == x.shape
    rate = float(jnp.mean(y != x))
    q = 1 - (1 - ber) ** 32
    n = int(np.prod(shape))
    tol = 4 * np.sqrt(max(q * (1 - q), 1e-12) / n)
    assert abs(rate - q) <= tol + 1e-9, (rate, q)


def test_inject_bitflips_flips_single_bit():
    x = jnp.zeros((4096,), jnp.int32)
    y = ops.inject_bitflips(x, 0.05, jax.random.PRNGKey(4), interpret=True)
    changed = np.asarray(y)[np.asarray(y != x)]
    # exactly one bit set per corrupted word
    assert all(bin(int(w) & 0xFFFFFFFF).count("1") == 1 for w in changed)


def test_inject_bitflips_pad_region_does_not_leak():
    """Regression: the wrapper used ``jnp.resize``, tiling real accumulator
    words into the pad region.  Padding must be zeros and — whatever the
    pad holds — the unpadded result may only depend on the first n words'
    randomness (the injection is elementwise)."""
    n = 33                                   # pads to a (256, 128) tile
    x = jax.random.randint(jax.random.PRNGKey(20), (n,), -2**20, 2**20,
                           jnp.int32)
    key = jax.random.PRNGKey(21)
    y = ops.inject_bitflips(x, 1e-2, key, interpret=True)

    rows_pad = 256
    u, pos = ops.make_flip_randoms(key, (rows_pad, 128))
    q = jnp.asarray([1 - (1 - 1e-2) ** 32], jnp.float32)
    for pad_value in (0, 0x7FFFFFFF, -1):    # any pad content, same result
        xf = jnp.full((rows_pad * 128,), pad_value, jnp.int32)
        xf = xf.at[:n].set(x).reshape(rows_pad, 128)
        exp = ref.bitflip_words_ref(xf, u, pos, q).reshape(-1)[:n]
        np.testing.assert_array_equal(np.asarray(y), np.asarray(exp))


def test_inject_bitflips_deterministic():
    x = jax.random.randint(jax.random.PRNGKey(5), (256, 64), -100, 100,
                           jnp.int32)
    y1 = ops.inject_bitflips(x, 1e-2, jax.random.PRNGKey(6), interpret=True)
    y2 = ops.inject_bitflips(x, 1e-2, jax.random.PRNGKey(6), interpret=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# --------------------------------------------------------------------------- #
# aged_linear (the model-facing op)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aged_linear_clean_quantization_error(dtype):
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 64, 96), dtype)
    w = jax.random.normal(jax.random.PRNGKey(8), (96, 128), dtype)
    out = ops.aged_linear(x, w, ber=0.0, key=None, use_kernel=True,
                          interpret=True)
    exact = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    rel = float(jnp.linalg.norm(out.astype(jnp.float32) - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.02, rel           # int8 quantisation noise only


def test_aged_linear_ber_increases_error():
    x = jax.random.normal(jax.random.PRNGKey(9), (32, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(10), (128, 64), jnp.float32)
    exact = x @ w
    errs = []
    for ber in (0.0, 1e-4, 1e-2):
        out = ops.aged_linear(x, w, ber=ber, key=jax.random.PRNGKey(11),
                              use_kernel=False)
        errs.append(float(jnp.linalg.norm(out - exact)))
    assert errs[0] <= errs[1] <= errs[2]
    assert errs[2] > 2 * errs[0]


def test_quantize_int8_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(12), (64, 256), jnp.float32)
    q, scale = ops.quantize_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    assert float(jnp.max(err / jnp.maximum(scale, 1e-9))) <= 0.5 + 1e-3
