"""Scenario-batched policy API: pytree round-trips, vmapped `simulate`
equivalence, policy registry, and FleetRuntime fleet/single consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifacts import load_calibration
from repro.core.avs import run_lifetime, simulate
from repro.core.fleet import FleetRuntime
from repro.core.policy import (BaselinePolicy, FaultTolerantPolicy,
                               get_policy, register_policy, sweep_policy)
from repro.core.resilience import OPERATORS
from repro.core.runtime import AgingAwareRuntime
from repro.core.scenario import (LifetimeTrajectory, Scenario, scenario_grid,
                                 stack_scenarios)


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


# --------------------------------------------------------------------------- #
# Scenario pytree mechanics
# --------------------------------------------------------------------------- #
def test_scenario_pytree_roundtrip():
    scn = Scenario.nominal(duty=jnp.asarray([0.3, 0.5]), max_loss_pct=1.0)
    leaves, treedef = jax.tree_util.tree_flatten(scn)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, Scenario)
    assert back.n_steps == scn.n_steps
    assert back.max_boosts_per_step == scn.max_boosts_per_step
    np.testing.assert_array_equal(np.asarray(back.duty), np.asarray(scn.duty))
    assert back.max_loss_pct == scn.max_loss_pct
    assert scn.batch_shape == (2,)


def test_scenario_jit_and_vmap():
    scn = Scenario.nominal(duty=jnp.linspace(0.3, 0.7, 4),
                           t_amb=jnp.linspace(290.0, 330.0, 4))

    @jax.jit
    def hottest(s: Scenario):
        return jnp.max(jnp.asarray(s.t_amb) * jnp.asarray(s.duty))

    assert float(hottest(scn)) == pytest.approx(330.0 * 0.7, rel=1e-6)

    per = jax.vmap(lambda s: jnp.asarray(s.duty) + jnp.asarray(s.t_amb))(
        scn.broadcast_leaves())
    assert per.shape == (4,)


def test_scenario_grid_and_stack():
    g = scenario_grid(max_loss_pct=[0.1, 0.5, 2.0], duty=[0.3, 0.5])
    assert g.batch_shape == (3, 2)
    assert g.n_scenarios == 6
    # swept leaves broadcast, unswept leaves stay scalar
    assert jnp.shape(g.max_loss_pct) == (3, 1)
    assert jnp.shape(g.duty) == (1, 2)
    assert jnp.shape(g.toggle) == ()

    s = stack_scenarios([Scenario.nominal(duty=0.4),
                         Scenario.nominal(duty=0.6)])
    assert s.batch_shape == (2,)
    np.testing.assert_allclose(np.asarray(s.duty), [0.4, 0.6])

    cell = g[2, 1]
    assert cell.batch_shape == ()
    assert float(cell.max_loss_pct) == pytest.approx(2.0)
    assert float(cell.duty) == pytest.approx(0.5)


# --------------------------------------------------------------------------- #
# simulate: batched == scalar, single trace
# --------------------------------------------------------------------------- #
def test_simulate_scalar_matches_run_lifetime(cal):
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg)
    traj = simulate(cal.aging, cal.delay_poly, scn)
    assert isinstance(traj, LifetimeTrajectory)
    legacy = run_lifetime(cal.aging, cal.delay_poly, cal.lifetime_cfg,
                          delay_max=cal.lifetime_cfg.t_clk)
    np.testing.assert_allclose(np.asarray(traj.V), np.asarray(legacy["V"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(traj.dvp),
                               np.asarray(legacy["dvp"]), rtol=1e-6)


def test_simulate_batched_matches_scalar(cal):
    """Acceptance: a 2-D sweep (3 budgets x 3 duty profiles x all operator
    domains) in ONE vmapped call matches the per-scenario scalar path to
    <=1e-5 relative error."""
    base = Scenario.from_lifetime_config(cal.lifetime_cfg)
    grid = scenario_grid(base, max_loss_pct=[0.1, 0.5, 2.0],
                         duty=[0.3, 0.5, 0.7])
    policy = FaultTolerantPolicy(ber_model=cal.ber)
    traj = sweep_policy(policy, cal.aging, cal.delay_poly, grid)
    assert traj.batch_shape == (3, 3, len(OPERATORS))

    for bi, di, oi in ((0, 0, 0), (1, 2, 5), (2, 1, 8)):
        cell = grid[bi, di]
        dmax = policy.thresholds(cell, OPERATORS)[oi]
        scalar = simulate(cal.aging, cal.delay_poly, cell, delay_max=dmax)
        for field in ("V", "delay", "dvp", "dvn"):
            got = np.asarray(getattr(traj, field))[bi, di, oi]
            want = np.asarray(getattr(scalar, field))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-12)


def test_simulate_single_trace_for_any_batch(cal):
    """The whole sweep must trace the delay polynomial ONCE (one vmapped
    scan), not once per scenario: tracing executes Python, so a per-scenario
    retrace inflates the call counter linearly with the batch."""
    calls = {"n": 0}
    poly = cal.delay_poly

    class CountingPoly:
        def __call__(self, dp, dn, V):
            calls["n"] += 1
            return poly(dp, dn, V)

    counting = CountingPoly()
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg)

    calls["n"] = 0
    simulate(cal.aging, poly, scn, delay_max=cal.lifetime_cfg.t_clk,
             recovery=True)  # warm any global caches
    simulate(cal.aging, counting, scn, delay_max=cal.lifetime_cfg.t_clk)
    scalar_traces = calls["n"]
    assert scalar_traces > 0

    grid = scenario_grid(scn, max_loss_pct=[0.1, 0.5, 2.0],
                         duty=[0.3, 0.5, 0.7])
    calls["n"] = 0
    sweep_policy(FaultTolerantPolicy(ber_model=cal.ber), cal.aging, counting,
                 grid)
    batched_traces = calls["n"]
    # 27 lifetimes must not cost 27x the traces of one lifetime
    assert batched_traces <= scalar_traces + 2, \
        (batched_traces, scalar_traces)


def test_simulate_batches_activity_knobs(cal):
    """duty/toggle/t_amb are computed inside the traced fn: batching them
    must change the physics (more duty -> more BTI aging)."""
    scn = Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
        duty=jnp.asarray([0.2, 0.8]))
    traj = simulate(cal.aging, cal.delay_poly, scn,
                    delay_max=cal.lifetime_cfg.t_clk, avs_enabled=False)
    dvp = np.asarray(traj.dvp)[..., -1]
    assert dvp[1] > dvp[0] * 1.2

    hot = simulate(cal.aging, cal.delay_poly,
                   Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
                       t_amb=jnp.asarray([298.15, 348.15])),
                   avs_enabled=False)
    d = np.asarray(hot.dvp)[..., -1]
    assert d[1] > d[0]          # hotter device ages faster


# --------------------------------------------------------------------------- #
# Policy protocol + registry
# --------------------------------------------------------------------------- #
def test_policy_registry(cal):
    bl = get_policy("baseline")
    assert isinstance(bl, BaselinePolicy)
    ft = get_policy("fault_tolerant", ber_model=cal.ber)
    assert isinstance(ft, FaultTolerantPolicy)
    with pytest.raises(KeyError):
        get_policy("nope")

    @register_policy
    @dataclasses.dataclass(frozen=True)
    class FixedPolicy:
        name = "fixed_test_policy"
        dmax: float = 1.7e-9

        def thresholds(self, scenario, operators=OPERATORS):
            return jnp.full(scenario.batch_shape + (len(operators),),
                            self.dmax, jnp.float32)

    assert isinstance(get_policy("fixed_test_policy"), FixedPolicy)


def test_thresholds_match_legacy_delay_max(cal):
    """Traced thresholds must agree with the legacy float64 inversion."""
    for budget in (0.1, 0.5, 2.0):
        pol = FaultTolerantPolicy(ber_model=cal.ber, max_loss_pct=budget)
        legacy = pol.delay_max()
        scn = Scenario.nominal(max_loss_pct=budget)
        traced = np.asarray(pol.thresholds(scn, OPERATORS))
        for i, op in enumerate(OPERATORS):
            assert traced[i] == pytest.approx(legacy[op], rel=1e-5), op


def test_policy_pinned_budget_overrides_scenario(cal):
    """An explicit policy budget wins over the scenario's; the default
    (None) defers to the scenario — both paths stay consistent with the
    legacy delay_max()."""
    pinned = FaultTolerantPolicy(ber_model=cal.ber, max_loss_pct=2.0)
    scn_05 = Scenario.nominal()                       # budget 0.5
    got = np.asarray(pinned.thresholds(scn_05, OPERATORS))
    legacy = pinned.delay_max()
    for i, op in enumerate(OPERATORS):
        assert got[i] == pytest.approx(legacy[op], rel=1e-5), op

    deferring = FaultTolerantPolicy(ber_model=cal.ber)
    got2 = np.asarray(deferring.thresholds(
        Scenario.nominal(max_loss_pct=2.0), OPERATORS))
    np.testing.assert_allclose(got2, got, rtol=1e-6)


def test_thresholds_batch_over_budget(cal):
    pol = FaultTolerantPolicy(ber_model=cal.ber)
    scn = Scenario.nominal(max_loss_pct=jnp.asarray([0.1, 0.5, 2.0]))
    th = np.asarray(pol.thresholds(scn, OPERATORS))
    assert th.shape == (3, len(OPERATORS))
    # larger budget never tightens any threshold
    assert (np.diff(th, axis=0) >= -1e-15).all()


# --------------------------------------------------------------------------- #
# FleetRuntime
# --------------------------------------------------------------------------- #
def test_fleet_n1_matches_aging_aware_runtime():
    rt = AgingAwareRuntime(fault_tolerant=True)
    fleet = FleetRuntime(n_devices=1, policy="fault_tolerant")
    for years in (0.5, 5.0, 9.5):
        rt.set_age(years=years)
        fleet.set_age(years=years)
        legacy, new = rt.summary(), fleet.summary(device=0)
        assert set(legacy) == set(new)
        for op in legacy:
            for k in ("v_dd", "delay", "dvth_p_mv", "dvth_n_mv", "ber",
                      "power_w"):
                assert new[op][k] == pytest.approx(legacy[op][k],
                                                   rel=1e-6, abs=1e-30), \
                    (op, k, years)
        assert fleet.total_power() == pytest.approx(rt.total_power(),
                                                    rel=1e-6)


def test_fleet_multi_device_consistency():
    """Same scenario, same age -> every device identical to the single-
    device path; heterogeneous ages -> monotone aging across the fleet."""
    fleet = FleetRuntime(n_devices=4, policy="fault_tolerant")
    single = FleetRuntime(n_devices=1, policy="fault_tolerant")
    fleet.set_age(years=7.0)
    single.set_age(years=7.0)
    snap = fleet.snapshot()
    ref = single.snapshot()
    for f in ("v_dd", "delay", "dvth_p_mv", "dvth_n_mv", "ber", "power_w"):
        arr = getattr(snap, f)
        assert arr.shape == (4, len(OPERATORS))
        np.testing.assert_allclose(arr, np.broadcast_to(getattr(ref, f),
                                                        arr.shape), rtol=1e-7)

    for i, years in enumerate((1.0, 4.0, 7.0, 9.9)):
        fleet.set_age(years=years, device=i)
    dvp = fleet.snapshot().dvth_p_mv
    assert (np.diff(dvp, axis=0) >= -1e-9).all()    # older -> more aged
    assert fleet.fleet_power().shape == (4,)


def test_fleet_per_device_scenarios():
    """A (N,)-batched scenario gives each device its own mission profile."""
    scn = Scenario.nominal(duty=jnp.asarray([0.2, 0.8]))
    fleet = FleetRuntime(scenario=scn, policy="fault_tolerant")
    assert fleet.n_devices == 2
    fleet.set_age(years=9.5)
    snap = fleet.snapshot()
    # the high-duty device has aged strictly more in every domain
    assert (snap.dvth_p_mv[1] > snap.dvth_p_mv[0]).all()


def test_fleet_device_view_protocol():
    fleet = FleetRuntime(n_devices=2)
    dev = fleet.device(1)
    dev.set_age(years=3.0)
    assert dev.age_years == pytest.approx(3.0)
    assert fleet.ages_years[0] == 0.0               # untouched
    bers = dev.op_bers()
    assert set(bers) == set(OPERATORS)
    st = dev.domain_state("o")
    assert st.power_w > 0 and st.v_dd >= 0.9 - 1e-6
    dev.advance(365.25 * 24 * 3600.0)
    assert dev.age_years == pytest.approx(4.0)
