"""Telemetry taps: bit-exactness (taps on == taps off) across the serve,
online, sharded and co-sim dispatch paths, zero retrace under the unified
``trace_counts`` guard, the fleet health snapshot (co-sim and online
runs), and the obs_report CLI + export pipeline end to end."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import FleetRuntime
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs.taps import (Telemetry, cosim_taps, enable_taps,
                            taps_enabled, telemetry_to_host)
from repro.serve.engine import FleetServeEngine, ServeEngine
from repro.serve.online import OnlineServeEngine, Request
from repro.train.steps import init_train_state

S, MAX_LEN = 8, 48
YEAR_S = 365.25 * 24 * 3600.0


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek_7b").reduced()
    params = init_train_state(cfg, jax.random.PRNGKey(0)).params
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (3, S), 0, cfg.vocab), np.int32)
    return cfg, params, prompts


@pytest.fixture(scope="module")
def aged_fleet():
    fl = FleetRuntime(n_devices=2)
    fl.set_age(years=3.0, device=0)
    fl.set_age(years=8.0, device=1)
    return fl


# --------------------------------------------------------------------------- #
# bit-exact: the toggle is host-side, tokens cannot change
# --------------------------------------------------------------------------- #
def test_serve_taps_bit_exact_clean_and_faulted(setup):
    cfg, params, prompts = setup
    for rt in (None, _aged_device()):
        kw = dict(runtime=rt, max_len=MAX_LEN, seed=5)
        off = ServeEngine(cfg, params, **kw).generate(
            prompts, 6, temperature=0.7)
        assert off.telemetry is None
        with enable_taps():
            on = ServeEngine(cfg, params, **kw).generate(
                prompts, 6, temperature=0.7)
        np.testing.assert_array_equal(off.tokens, on.tokens)
        assert set(on.telemetry) == {"logit_max", "logit_margin"}
        assert on.telemetry["logit_max"].shape == (6,)
        assert np.isfinite(on.telemetry["logit_margin"]).all()
        assert (on.telemetry["logit_margin"] >= 0).all()


def _aged_device():
    rt = FleetRuntime(n_devices=1)
    rt.set_age(years=9.0)
    return rt


def test_fleet_taps_bit_exact(setup, aged_fleet):
    cfg, params, prompts = setup
    tile = np.broadcast_to(prompts, (2,) + prompts.shape).copy()
    off = FleetServeEngine(cfg, params, aged_fleet, max_len=MAX_LEN,
                           seed=5).generate(tile, 5)
    with enable_taps():
        on = FleetServeEngine(cfg, params, aged_fleet, max_len=MAX_LEN,
                              seed=5).generate(tile, 5)
    np.testing.assert_array_equal(off.tokens, on.tokens)
    assert off.telemetry is None
    # vmapped dispatch: every tap leaf gains the lane axis
    assert on.telemetry["logit_max"].shape == (2, 5)


def test_mesh_taps_bit_exact(setup):
    from repro.serve.sharded import MeshServeEngine
    cfg, params, prompts = setup
    off = MeshServeEngine(cfg, params, max_len=MAX_LEN, seed=3).generate(
        prompts, 4)
    with enable_taps():
        on = MeshServeEngine(cfg, params, max_len=MAX_LEN,
                             seed=3).generate(prompts, 4)
    np.testing.assert_array_equal(off.tokens, on.tokens)
    assert on.telemetry["logit_max"].shape == (4,)


def test_online_taps_bit_exact(setup):
    cfg, params, prompts = setup
    def run():
        eng = OnlineServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN,
                                max_new_cap=8, chunk_steps=4, seed=5)
        return eng.serve([Request(id=i, prompt=prompts[i], max_new=6,
                                  arrival=i) for i in range(3)],
                         greedy=False, temperature=0.7, eos_id=-1)
    off = run()
    with enable_taps():
        on = run()
    assert off.telemetry is None and on.telemetry is not None
    for a, b in zip(sorted(off.completed, key=lambda r: r.id),
                    sorted(on.completed, key=lambda r: r.id)):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # one row per served chunk step, active-masked means stay finite
    assert on.telemetry["logit_max"].ndim == 1
    assert on.telemetry["logit_max"].shape[0] >= off.total_steps
    assert np.isfinite(on.telemetry["logit_max"]).all()


def test_cosim_bit_exact_and_boosts_tap(aged_fleet):
    """apply_load's trajectory is identical with taps enabled, and the
    in-scan boost-event counter is recorded either way (aux output of the
    same dispatch)."""
    def run():
        fl = FleetRuntime(n_devices=2)
        fl.set_age(years=3.0, device=0)
        fl.set_age(years=8.0, device=1)
        return fl.apply_load(workload="diurnal", utilization=0.7,
                             horizon_s=2 * YEAR_S), fl
    off, _ = run()
    with enable_taps():
        on, fl = run()
    np.testing.assert_array_equal(np.asarray(off.dvp), np.asarray(on.dvp))
    np.testing.assert_array_equal(np.asarray(off.V), np.asarray(on.V))
    assert on.boosts is not None
    boosts = np.asarray(on.boosts)                     # (E, N)
    assert boosts.shape == np.asarray(on.util).shape
    assert (boosts >= 0).all() and boosts.sum() > 0    # AVS actually boosted
    telem = telemetry_to_host(cosim_taps(on, fl.unit_scenario))
    assert telem["dvth_eff_mv"].shape == telem["boosts"].shape
    n_dev = telem["dvth_eff_mv"].shape[0]
    assert n_dev == 2
    # the monotone total never falls below the recovery-aware effective
    assert (telem["dvth_mono_mv"] >= telem["dvth_eff_mv"] - 1e-5).all()


# --------------------------------------------------------------------------- #
# zero retrace: the toggle and re-reads tick no trace counter
# --------------------------------------------------------------------------- #
def test_taps_toggle_zero_retrace(setup):
    cfg, params, prompts = setup
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, seed=5)
    eng.generate(prompts, 5)                           # warm the cache
    before = obs_metrics.trace_counts()
    with enable_taps():
        eng.generate(prompts, 5)
        eng.generate(prompts, 5, temperature=0.9)
    eng.generate(prompts, 5)
    assert obs_metrics.trace_counts() == before


def test_cosim_taps_zero_retrace(aged_fleet):
    aged_fleet.apply_load(workload="diurnal", utilization=0.6,
                          horizon_s=YEAR_S)            # warm
    before = obs_metrics.trace_counts()
    with enable_taps():
        cos = aged_fleet.apply_load(workload="diurnal", utilization=0.6,
                                    horizon_s=YEAR_S)
        cosim_taps(cos, aged_fleet.unit_scenario)
    assert obs_metrics.trace_counts() == before


# --------------------------------------------------------------------------- #
# health snapshot: co-sim run and online run
# --------------------------------------------------------------------------- #
def test_health_from_cosim_run(aged_fleet):
    with enable_taps():
        aged_fleet.apply_load(workload="diurnal", utilization=0.7,
                              horizon_s=YEAR_S)
    h = aged_fleet.health()
    assert h.n_units == 2
    # the older device has less margin and more wear
    assert h.dvth_p_mv[1] > h.dvth_p_mv[0] > 0
    assert h.headroom_s[1] <= h.headroom_s[0]
    assert (h.eta_s >= 0).all()
    txt = h.render()
    assert "aging odometer" in txt and "ETA[yr]" in txt
    assert len([ln for ln in txt.splitlines()
                if ln.strip().startswith(("0 ", "1 "))]) == 2
    json.dumps(h.to_dict())                            # JSON-able end to end


def test_health_eta_monotone_in_age():
    """A freshly deployed device has at least as much service left as the
    same device aged — ETA read off the same extrapolated trajectory."""
    fl = FleetRuntime(n_devices=2)
    fl.set_age(years=1.0, device=0)
    fl.set_age(years=10.0, device=1)
    h = fl.health()
    assert h.eta_s[0] >= h.eta_s[1]


def test_health_from_online_run(setup):
    cfg, params, prompts = setup
    fl = FleetRuntime(n_devices=1)
    fl.set_age(years=6.0)
    with enable_taps():
        eng = OnlineServeEngine(cfg, params, runtime=fl, n_slots=2,
                                max_len=MAX_LEN, max_new_cap=8,
                                chunk_steps=4, seed=5)
        res = eng.serve([Request(id=i, prompt=prompts[i], max_new=6,
                                 arrival=2 * i) for i in range(3)],
                        greedy=False, temperature=0.7, eos_id=-1)
    h = fl.health(online_result=res)
    assert h.extra["n_completed"] == float(res.n_completed)
    assert h.extra["p50_latency_steps"] == res.p50
    assert "p50_latency_steps" in h.render()
    # the run recorded into the registry: latency histogram + counters
    lat = obs_metrics.REGISTRY.get("online_latency_steps")
    assert lat is not None and lat.count >= res.n_completed


# --------------------------------------------------------------------------- #
# obs_report CLI + export pipeline, in-process
# --------------------------------------------------------------------------- #
def test_obs_report_cli_cosim(tmp_path, capsys):
    from repro.launch import obs_report
    jsonl = tmp_path / "run.jsonl"
    prom = tmp_path / "metrics.prom"
    h = obs_report.main(["--quick", "--jsonl", str(jsonl),
                         "--prom", str(prom)])
    out = capsys.readouterr().out
    assert "aging odometer" in out and "boost events" in out
    assert h.n_units == 2
    manifest, samples, other = obs_export.read_jsonl(jsonl)
    assert manifest["run"] == "obs_report:cosim"
    assert other and other[0]["type"] == "health"
    assert len(other[0]["units"]) == h.n_units
    parsed = obs_export.parse_prometheus(prom.read_text())
    assert {s.name for s in parsed} & {"repro_trace_total",
                                       "repro_compile_cache_misses_total"}


def test_obs_report_cli_online(tmp_path, capsys):
    from repro.launch import obs_report
    jsonl = tmp_path / "run.jsonl"
    h = obs_report.main(["--mode", "online", "--quick", "--n-devices", "1",
                        "--jsonl", str(jsonl)])
    out = capsys.readouterr().out
    assert "aging odometer" in out and "p50_latency_steps" in out
    assert not math.isnan(h.extra["drop_rate"])
    _, _, other = obs_export.read_jsonl(jsonl)
    assert other[0]["extra"]["n_completed"] >= 0


# --------------------------------------------------------------------------- #
# Telemetry pytree mechanics
# --------------------------------------------------------------------------- #
def test_telemetry_pytree_round_trip():
    t = Telemetry({"b": np.ones(3), "a": np.zeros(2)})
    leaves, treedef = jax.tree_util.tree_flatten(t)
    assert [leaf.shape for leaf in leaves] == [(2,), (3,)]  # sorted keys
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert sorted(back.keys()) == ["a", "b"]
    assert telemetry_to_host(None) is None
    host = telemetry_to_host(t)
    assert isinstance(host["a"], np.ndarray)
    assert not taps_enabled()
    with enable_taps():
        assert taps_enabled()
        with enable_taps(False):
            assert not taps_enabled()
        assert taps_enabled()
    assert not taps_enabled()
