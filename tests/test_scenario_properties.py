"""Hypothesis property tests for Scenario batch mechanics.

The traffic scheduler reshapes/broadcasts scenario batches per epoch
(``repro.sched.lifetime`` broadcasts per-device leaves; ``FleetRuntime``
indexes them), so the ``broadcast_leaves`` / ``reshape`` /
``__getitem__`` invariants are load-bearing.  Runs under real
``hypothesis`` when installed (the ``[test]`` extra) and under the
deterministic in-repo fallback otherwise.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scenario import SCENARIO_FIELDS, Scenario, scenario_grid

_dim = st.integers(min_value=1, max_value=4)
_field = st.sampled_from(SCENARIO_FIELDS)


def _grid(b1: int, b2: int, f1: str, f2: str) -> Scenario:
    """A 2-axis scenario grid over two (possibly equal) swept fields."""
    if f1 == f2:
        f2 = SCENARIO_FIELDS[(SCENARIO_FIELDS.index(f1) + 1)
                             % len(SCENARIO_FIELDS)]
    return scenario_grid(**{f1: np.linspace(0.1, 0.9, b1),
                            f2: np.linspace(1.0, 2.0, b2)})


@settings(max_examples=20, deadline=None)
@given(b1=_dim, b2=_dim, f1=_field, f2=_field)
def test_broadcast_leaves_materialises_batch_shape(b1, b2, f1, f2):
    scn = _grid(b1, b2, f1, f2)
    assert scn.batch_shape == (b1, b2)
    mat = scn.broadcast_leaves()
    for f in SCENARIO_FIELDS:
        assert jnp.shape(getattr(mat, f)) == (b1, b2), f
        # broadcasting must not change any cell's value
        np.testing.assert_allclose(
            np.asarray(getattr(mat, f)),
            np.broadcast_to(np.asarray(getattr(scn, f),
                                       np.float32), (b1, b2)),
            rtol=1e-7, err_msg=f)
    # static aux survives
    assert mat.n_steps == scn.n_steps
    assert mat.max_boosts_per_step == scn.max_boosts_per_step
    # idempotent
    again = mat.broadcast_leaves()
    for f in SCENARIO_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(again, f)),
                                      np.asarray(getattr(mat, f)))


@settings(max_examples=20, deadline=None)
@given(b1=_dim, b2=_dim, f1=_field, f2=_field)
def test_reshape_round_trip(b1, b2, f1, f2):
    scn = _grid(b1, b2, f1, f2)
    flat = scn.reshape((b1 * b2,))
    assert flat.batch_shape == (b1 * b2,)
    back = flat.reshape((b1, b2))
    mat = scn.broadcast_leaves()
    for f in SCENARIO_FIELDS:
        np.testing.assert_allclose(np.asarray(getattr(back, f)),
                                   np.asarray(getattr(mat, f)),
                                   rtol=1e-7, err_msg=f)
    # row-major flattening order (what simulate()'s vmap relies on)
    for f in SCENARIO_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(flat, f)),
            np.asarray(getattr(mat, f)).reshape(-1), err_msg=f)


@settings(max_examples=20, deadline=None)
@given(b1=_dim, b2=_dim, i=st.integers(min_value=0, max_value=99),
       j=st.integers(min_value=0, max_value=99), f1=_field, f2=_field)
def test_getitem_matches_broadcast_cell(b1, b2, i, j, f1, f2):
    scn = _grid(b1, b2, f1, f2)
    i, j = i % b1, j % b2
    cell = scn[i, j]
    assert cell.batch_shape == ()
    mat = scn.broadcast_leaves()
    for f in SCENARIO_FIELDS:
        assert float(np.asarray(getattr(cell, f))) == float(
            np.asarray(getattr(mat, f))[i, j]), f
    # a row index keeps the trailing axis
    row = scn[i]
    assert row.batch_shape == (b2,)
    for f in SCENARIO_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(row, f)),
                                      np.asarray(getattr(mat, f))[i],
                                      err_msg=f)


@settings(max_examples=15, deadline=None)
@given(b=_dim, f=_field)
def test_expand_dims_then_index_recovers_vector(b, f):
    scn = Scenario.nominal(**{f: jnp.linspace(0.2, 0.8, b)})
    wide = scn.expand_dims(-1)
    assert wide.batch_shape == (b, 1)
    back = wide[:, 0]
    np.testing.assert_allclose(np.asarray(getattr(back, f)),
                               np.asarray(getattr(scn, f)), rtol=1e-7)
