"""Direct tests for the distributed support layers: compressed collectives
(``repro.distributed.collectives``) and elastic re-meshing
(``repro.distributed.elastic``).

The collective math and the remesh *planning* are exercised in-process (a
1-device shard_map gives psum its axis context without faking devices);
actual cross-device behaviour — 8-shard compressed psum vs the plain mean,
and a value-preserving reshard across a device-count change on a 3-axis
("pod", "data", "model") mesh — runs on 8 faked host devices in a
subprocess.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.collectives import (psum_compressed_leaf,
                                           quantize_int8_global,
                                           tree_psum, tree_psum_compressed,
                                           zeros_residuals)
from repro.distributed.elastic import plan_remesh


# --------------------------------------------------------------------------- #
# collectives: quantisation + error feedback (1-device axis context)
# --------------------------------------------------------------------------- #
def test_quantize_int8_global_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 3.0
    q, scale = quantize_int8_global(x)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= 127
    # symmetric absmax: dequant error is bounded by half a quantisation step
    err = jnp.max(jnp.abs(x - q.astype(jnp.float32) * scale))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_quantize_int8_global_zero_tensor():
    q, scale = quantize_int8_global(jnp.zeros((8, 8)))
    assert int(jnp.abs(q).max()) == 0
    assert float(scale) > 0.0          # guarded against divide-by-zero


def _one_device_psum(fn, *args):
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    specs = tuple(P() for _ in args)
    return shard_map(fn, mesh=mesh, in_specs=specs,
                     out_specs=(P(), P()))(*args)


def test_psum_compressed_error_feedback_conservation():
    """With one shard the compressed psum is exactly conservative:
    out + new_residual == grad + old_residual, every step — the invariant
    that makes the quantisation bias vanish over steps."""
    g1 = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g2 = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    r0 = jnp.zeros_like(g1)
    out1, r1 = _one_device_psum(
        lambda g, r: psum_compressed_leaf(g, r, "data", 1), g1, r0)
    np.testing.assert_allclose(np.asarray(out1 + r1), np.asarray(g1),
                               atol=1e-6)
    out2, r2 = _one_device_psum(
        lambda g, r: psum_compressed_leaf(g, r, "data", 1), g2, r1)
    np.testing.assert_allclose(np.asarray(out2 + r2), np.asarray(g2 + r1),
                               atol=1e-6)
    # and the transmitted value is the quantised gradient, not zero
    assert float(jnp.abs(out1).max()) > 0.0


def test_psum_compressed_close_to_plain():
    g = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    out, _ = _one_device_psum(
        lambda x, r: psum_compressed_leaf(x, r, "data", 1),
        g, jnp.zeros_like(g))
    # one shard: plain mean is g itself; int8 error ~ amax/127
    tol = float(jnp.abs(g).max()) / 127.0
    assert float(jnp.abs(out - g).max()) <= tol + 1e-6


def test_tree_helpers_structure():
    params = {"a": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.ones((3,))}
    res = zeros_residuals(params)
    assert res["a"].dtype == jnp.float32 and res["a"].shape == (4, 4)

    def body(g, r):
        mean, new_r = tree_psum_compressed(g, r, "data", 1)
        plain = tree_psum(g, "data", 1)
        return (mean, new_r, plain)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    spec = jax.tree.map(lambda _: P(), params)
    mean, new_r, plain = shard_map(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec, spec, spec))(params, res)
    assert jax.tree.structure(mean) == jax.tree.structure(params)
    assert jax.tree.structure(new_r) == jax.tree.structure(params)
    assert mean["a"].dtype == jnp.bfloat16      # leaf dtype preserved
    np.testing.assert_allclose(np.asarray(plain["b"]), np.ones(3))


# --------------------------------------------------------------------------- #
# elastic: remesh planning (no devices needed)
# --------------------------------------------------------------------------- #
class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_plan_remesh_resizes_data_axis():
    plan = plan_remesh(FakeMesh({"data": 4, "model": 2}), 4, global_batch=8)
    assert plan.new_shape == (2, 2)
    assert plan.axis_names == ("data", "model")
    assert plan.microbatches == 2              # dp 4 -> 2 doubles accum


def test_plan_remesh_shrinks_dp_to_batch_divisor():
    plan = plan_remesh(FakeMesh({"data": 4, "model": 2}), 6, global_batch=8)
    assert plan.new_shape == (2, 2)            # dp 3 would not divide 8


def test_plan_remesh_rejects_non_tp_multiple():
    with pytest.raises(ValueError):
        plan_remesh(FakeMesh({"data": 4, "model": 2}), 5, global_batch=8)


def test_plan_remesh_preserves_pod_axis():
    """Steps compiled against a ("pod", "data", "model") mesh reference the
    pod axis by name — the plan must keep it even when resized."""
    old = FakeMesh({"pod": 2, "data": 4, "model": 2})
    # grow: 16 -> 32 devices keeps whole pods (dp 16 = 4 pods x 4)
    plan = plan_remesh(old, 32, global_batch=64)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.new_shape == (4, 4, 2)
    # shrink below one pod: collapses the pod axis to size 1, keeps the name
    plan = plan_remesh(old, 4, global_batch=64)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.new_shape == (1, 2, 2)
    assert plan.microbatches == 4              # dp 8 -> 2 preserves batch


def test_plan_remesh_pod_axis_microbatch_invariant():
    old = FakeMesh({"pod": 2, "data": 4, "model": 2})
    for n_dev, micro in ((32, 1), (16, 1), (8, 2), (4, 4)):
        plan = plan_remesh(old, n_dev, global_batch=64,
                           old_microbatches=1)
        dp = int(np.prod([s for s, a in zip(plan.new_shape,
                                            plan.axis_names)
                          if a != "model"]))
        assert dp * plan.microbatches >= 8 * 1  # global tokens preserved
        assert plan.microbatches == micro


# --------------------------------------------------------------------------- #
# 8 faked devices: compressed psum vs plain, reshard round-trip
# --------------------------------------------------------------------------- #
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.distributed.collectives import (psum_compressed_leaf,
                                               tree_psum)
    from repro.distributed.elastic import (make_mesh_from_plan, plan_remesh,
                                           reshard_state)
    from repro.models import transformer as tf

    out = {}
    # --- compressed psum across 8 real shards vs the plain mean ---------
    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

    def body(gs, rs):
        mean, new_r = psum_compressed_leaf(gs[0], rs[0], "data", 8)
        plain = tree_psum({"g": gs[0]}, "data", 8)["g"]
        return mean[None], new_r[None], plain[None]

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data"), P("data")))
    r = jnp.zeros_like(g)
    mean, r1, plain = f(g, r)
    # every shard returns the same reduced mean
    out["psum_err"] = float(jnp.abs(mean[0] - plain[0]).max())
    # analytic single-shot bound: per-shard rounding (scale_i / 2) plus the
    # shared-scale mismatch (|q| <= 127 times |smean - scale_i|), averaged
    scales = jnp.abs(g).max(axis=(1, 2)) / 127.0
    smean = scales.mean()
    out["psum_bound"] = float(jnp.mean(
        scales / 2.0 + 127.0 * jnp.abs(smean - scales)))

    # error feedback: repeated same gradient -> running average converges
    errs = []
    acc = jnp.zeros_like(plain[0])
    for t in range(40):
        mean, r, _ = f(g, r)
        acc = acc + mean[0]
        errs.append(float(jnp.abs(acc / (t + 1) - plain[0]).max()))
    out["ef_err_first"] = errs[0]
    out["ef_err_last"] = errs[-1]
    out["g_amax"] = float(jnp.abs(g).max())

    # --- reshard across a device-count change on a 3-axis mesh ----------
    cfg = get_config("deepseek_7b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    from repro.distributed.sharding import param_specs
    specs = param_specs(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        cfg, mesh3)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh3, s)),
        params, specs)

    plan = plan_remesh(mesh3, 4, global_batch=8)
    out["plan_names"] = list(plan.axis_names)
    out["plan_shape"] = list(plan.new_shape)
    new_mesh = make_mesh_from_plan(plan)
    moved = reshard_state(placed, cfg, new_mesh)
    d = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a) - np.asarray(b)))), params, moved)
    out["reshard_max_delta"] = max(jax.tree.leaves(d))
    one = jax.tree.leaves(moved)[0]
    out["moved_axis_names"] = list(one.sharding.mesh.axis_names)
    out["moved_n_devices"] = len(one.sharding.mesh.devices.flatten())

    # round-trip back up to 8 devices
    plan8 = plan_remesh(new_mesh, 8, global_batch=8)
    back = reshard_state(moved, cfg, make_mesh_from_plan(plan8))
    d2 = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a) - np.asarray(b)))), params, back)
    out["roundtrip_max_delta"] = max(jax.tree.leaves(d2))
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_collectives_and_reshard():
    proc = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # int8 mean-psum tracks the exact mean within its analytic error bound
    assert out["psum_err"] <= out["psum_bound"] + 1e-6
    # error feedback: the running average converges at O(1/T) — the
    # quantisation bias vanishes over steps instead of accumulating
    assert out["ef_err_last"] < 0.5 * out["ef_err_first"]
    assert out["ef_err_last"] < 0.02 * out["g_amax"]
    # reshard across 8 -> 4 devices: values bit-identical, pod axis kept
    assert out["reshard_max_delta"] == 0.0
    assert out["roundtrip_max_delta"] == 0.0
    assert out["plan_names"] == ["pod", "data", "model"]
    assert out["plan_shape"] == [1, 2, 2]
    assert out["moved_axis_names"] == ["pod", "data", "model"]
    assert out["moved_n_devices"] == 4
