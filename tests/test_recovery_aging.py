"""Short-term recoverable trap pool: relaxation physics + co-sim collapse.

The recoverable component (``repro.core.aging.RecoveryParams`` /
``relax_step``) rides on top of the monotone six-population recursion;
these tests pin its load-bearing invariants: the pool is bounded by the
recoverable fraction (the effective shift never drops below the
permanent floor nor exceeds the stress trajectory), the always-stressed
limit collapses bit-exactly onto the existing historical-effect
recursion, the extended trap-state pytree round-trips, and sweeping any
recovery/thermal parameter leaf re-jits NOTHING.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.aging import (N_POP, RecoveryParams, effective_dv,
                              relax_step)
from repro.core.artifacts import load_calibration
from repro.core.policy import FaultTolerantPolicy
from repro.core.resilience import OPERATORS
from repro.core.scenario import Scenario
from repro.sched import ThermalParams, cosimulate
from repro.sched import lifetime as sched_lifetime

YEAR_S = 365.25 * 24 * 3600.0


@pytest.fixture(scope="module")
def cal():
    return load_calibration()


@pytest.fixture(scope="module")
def policy(cal):
    return FaultTolerantPolicy(ber_model=cal.ber)


def _scn(cal, horizon_years=2.0):
    return Scenario.from_lifetime_config(cal.lifetime_cfg).replace(
        lifetime_s=horizon_years * YEAR_S)


def _replay(cal, policy, util_trace, **kw):
    scn = _scn(cal)
    dmax = policy.thresholds(scn, OPERATORS)
    return cosimulate(cal.aging, cal.delay_poly, scn, dmax, None,
                      util_trace=jnp.asarray(util_trace, jnp.float32),
                      **kw)


# --------------------------------------------------------------------------- #
# relax_step physics (hypothesis properties)
# --------------------------------------------------------------------------- #
_dv = st.floats(min_value=0.0, max_value=250.0)
_frac = st.floats(min_value=0.0, max_value=1.0)
_act = st.floats(min_value=0.0, max_value=1.0)
_dt = st.floats(min_value=1.0, max_value=3.0e7)


@settings(max_examples=40, deadline=None)
@given(dv=_dv, frac=_frac, act=_act, dt=_dt)
def test_pool_bounded_by_recoverable_fraction(dv, frac, act, dt):
    """0 <= rec <= rho*dv, so (1-rho)*dv <= dv_eff <= dv — always."""
    rp = RecoveryParams.default()
    dv_mv = jnp.full((N_POP,), dv, jnp.float32)
    rec0 = frac * rp.rho * dv_mv                      # any admissible pool
    rec = np.asarray(relax_step(rp, dv_mv, rec0, act, dt))
    cap = np.asarray(rp.rho) * dv
    assert (rec >= -1e-6).all()
    assert (rec <= cap + 1e-4).all()
    eff = np.asarray(effective_dv(dv_mv, rec))
    assert (eff <= dv + 1e-4).all()                   # never above stress
    assert (eff >= (1.0 - np.asarray(rp.rho)) * dv - 1e-4).all()


@settings(max_examples=40, deadline=None)
@given(dv=_dv, dt=_dt)
def test_always_stressed_pool_stays_exactly_empty(dv, dt):
    """act == 1 kills the detrapping drive: an empty pool stays empty
    bit-exactly, whatever the rates — the collapse onto the monotone
    recursion is not approximate."""
    rp = RecoveryParams.default()
    dv_mv = jnp.full((N_POP,), dv, jnp.float32)
    rec = relax_step(rp, dv_mv, jnp.zeros((N_POP,), jnp.float32), 1.0, dt)
    np.testing.assert_array_equal(np.asarray(rec), 0.0)


@settings(max_examples=25, deadline=None)
@given(dv=st.floats(min_value=1.0, max_value=250.0), frac=_frac)
def test_idle_relaxation_is_monotone_toward_cap(dv, frac):
    """act == 0: the pool approaches rho*dv monotonically in time."""
    rp = RecoveryParams.default()
    dv_mv = jnp.full((N_POP,), dv, jnp.float32)
    rec = frac * rp.rho * dv_mv
    prev = np.asarray(rec)
    for dt in (3.6e3, 3.6e4, 3.6e5, 3.6e6):
        rec = relax_step(rp, dv_mv, rec, 0.0, dt)
        cur = np.asarray(rec)
        assert (cur >= prev - 1e-5).all()
        prev = cur
    # fast NBTI population (index 0) essentially saturates within weeks
    assert prev[0] == pytest.approx(float(rp.rho[0]) * dv, rel=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_random_stress_history_keeps_invariants(seed):
    """Iterate relax_step over a random (act, dt) history riding a
    growing monotone trajectory: bounds hold at every step."""
    rnd = np.random.default_rng(seed)
    rp = RecoveryParams.default()
    rho = np.asarray(rp.rho)
    dv = np.zeros((N_POP,), np.float32)
    rec = jnp.zeros((N_POP,), jnp.float32)
    for _ in range(12):
        dv = dv + rnd.uniform(0.0, 8.0, N_POP).astype(np.float32)
        rec = relax_step(rp, jnp.asarray(dv), rec,
                         float(rnd.uniform()), float(rnd.uniform(60, 1e6)))
        r = np.asarray(rec)
        assert (r >= -1e-6).all() and (r <= rho * dv + 1e-4).all()
        assert np.isfinite(r).all()


# --------------------------------------------------------------------------- #
# co-sim collapse + effective-wear ordering
# --------------------------------------------------------------------------- #
def test_always_stressed_cosim_matches_monotone_recursion(cal, policy):
    """Replaying a fully-stressed fleet with the recovery pool enabled
    must reproduce the legacy recursion within 1e-5 mV (acceptance
    criterion; in practice the collapse is exact)."""
    U = np.ones((48, 4), np.float32)
    off = _replay(cal, policy, U)
    on = _replay(cal, policy, U, recovery_dynamics=True)
    assert float(np.abs(np.asarray(on.dvp)
                        - np.asarray(off.dvp)).max()) <= 1e-5
    assert float(np.abs(np.asarray(on.V) - np.asarray(off.V)).max()) <= 1e-5
    np.testing.assert_array_equal(np.asarray(on.rec), 0.0)
    assert off.rec is None                       # legacy trajectory shape


def test_idle_windows_relax_effective_wear_only(cal, policy):
    """A duty-cycled trace relaxes the *effective* shift strictly below
    the monotone trajectory but never below the permanent floor; the
    monotone state itself is untouched by the pool."""
    E, N = 64, 4
    U = np.zeros((E, N), np.float32)
    U[0::3] = 1.0                                # stress 1 epoch in 3
    off = _replay(cal, policy, U)
    on = _replay(cal, policy, U, recovery_dynamics=True)
    dv_on, dv_off = np.asarray(on.dv), np.asarray(off.dv)
    np.testing.assert_allclose(dv_on, dv_off, atol=1e-5)
    dvp_on, dvp_off = np.asarray(on.dvp), np.asarray(off.dvp)
    assert (dvp_on <= dvp_off + 1e-5).all()
    # epoch -2 is idle (the 1-in-3 stress pattern recaptures the pool on
    # stressed epochs): the relaxed gap must be visible there
    assert dvp_on[-2].max() < 0.9 * dvp_off[-2].max()
    rho_max = float(np.max(np.asarray(RecoveryParams.default().rho)))
    assert (dvp_on >= (1.0 - rho_max) * dvp_off - 1e-4).all()
    # the relaxed pool accounts exactly for the dvp gap
    from repro.core.aging import IS_PMOS
    rec_tot = (np.asarray(on.rec) * IS_PMOS).sum(-1)
    np.testing.assert_allclose(dvp_off - dvp_on, rec_tot, atol=2e-3)


def test_recovery_params_pytree_roundtrip():
    rp = RecoveryParams.default()
    back = RecoveryParams.from_dict(json.loads(json.dumps(rp.to_dict())))
    for f in ("rho", "k_relax", "k_retrap"):
        np.testing.assert_allclose(np.asarray(getattr(back, f)),
                                   np.asarray(getattr(rp, f)), rtol=1e-7)
    # traced-leaf pytree: flatten/unflatten preserves values
    leaves, aux = rp.tree_flatten()
    again = RecoveryParams.tree_unflatten(aux, leaves)
    np.testing.assert_array_equal(np.asarray(again.rho),
                                  np.asarray(rp.rho))


def test_extended_trajectory_pytree_roundtrip(cal, policy):
    cos = _replay(cal, policy, np.ones((12, 2), np.float32),
                  recovery_dynamics=True, thermal=True)
    leaves, aux = cos.tree_flatten()
    again = type(cos).tree_unflatten(aux, leaves)
    for f in cos._FIELDS:
        a, b = getattr(cos, f), getattr(again, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), f)
    assert cos.rec.shape == (12, 2, len(OPERATORS), N_POP)
    assert cos.t_node.shape == (12, 2)


# --------------------------------------------------------------------------- #
# structural guard: zero retrace across recovery/thermal leaves
# --------------------------------------------------------------------------- #
def test_zero_retrace_across_recovery_and_thermal_leaves(cal, policy):
    scn = _scn(cal)
    dmax = policy.thresholds(scn, OPERATORS)
    U = np.ones((24, 4), np.float32) * 0.6
    kw = dict(util_trace=jnp.asarray(U))
    rp = RecoveryParams.default()
    cosimulate(cal.aging, cal.delay_poly, scn, dmax, None,
               recovery_dynamics=rp, thermal=True, **kw)
    before = dict(sched_lifetime.TRACE_COUNTS)
    # sweep EVERY recovery-rate leaf and the thermal RC leaves: all traced
    swept = RecoveryParams(rho=rp.rho * 0.5, k_relax=rp.k_relax * 2.0,
                           k_retrap=rp.k_retrap * 3.0)
    cosimulate(cal.aging, cal.delay_poly, scn, dmax, None,
               recovery_dynamics=swept,
               thermal=ThermalParams.from_power_model(
                   cal.power, r_th=5.0, tau_s=7200.0), **kw)
    assert dict(sched_lifetime.TRACE_COUNTS) == before, \
        "sweeping recovery/thermal parameters must re-jit NOTHING"
